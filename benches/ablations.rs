//! Robustness ablations (paper §4's "robust to the shape of 2-norm
//! distribution" claim + supplementary "more configurations"):
//!
//! (a) norm-distribution sweep — log-normal σ from 0 (uniform norms, where
//!     RANGE degenerates to SIMPLE) to 0.6 (heavy tail): RANGE must never
//!     lose, and the gap must widen with the tail;
//! (b) top-k sweep (k ∈ {1, 10, 50}) at a fixed operating point;
//! (c) full baseline field including SIGN-ALSH (Shrivastava & Li 2015) —
//!     the lineage panel: RANGE > SIMPLE > SIGN-ALSH ≥ L2-ALSH.
//!
//! Run with: `cargo bench --bench ablations`

mod common;

use rangelsh::bench::Table;
use rangelsh::config::IndexAlgo;
use rangelsh::data::synthetic;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;

fn main() -> rangelsh::Result<()> {
    // ---- (a) norm-distribution robustness --------------------------------
    println!("=== (a) 2-norm distribution sweep: log-normal sigma, 20K x 64d, L=32 ===");
    let mut table = Table::new(&[
        "sigma", "tail ratio", "range@50%", "simple@50%", "advantage",
    ]);
    for sigma in [0.0f32, 0.1, 0.2, 0.35, 0.5, 0.6] {
        let items = synthetic::longtail_with_sigma(20_000, 64, sigma, 11);
        let queries = synthetic::correlated_queries(&items, 200, 0.4, 12);
        let gt = ground_truth(&items, &queries, 10);
        let cps = geometric_checkpoints(10, items.len(), 5);
        let range = run_curve(
            &items, &queries, &gt, &cps,
            &CurveSpec::new(IndexAlgo::RangeLsh, 32, 32),
            "r",
        )?;
        let simple = run_curve(
            &items, &queries, &gt, &cps,
            &CurveSpec::new(IndexAlgo::SimpleLsh, 32, 1),
            "s",
        )?;
        let rp = range.curve.probes_to_reach(0.5).unwrap_or(items.len());
        let sp = simple.curve.probes_to_reach(0.5).unwrap_or(items.len());
        table.row(vec![
            format!("{sigma}"),
            format!("{:.2}", items.norm_stats().tail_ratio()),
            rp.to_string(),
            sp.to_string(),
            format!("{:.2}x", sp as f64 / rp as f64),
        ]);
    }
    println!("{}", table.render());
    println!("expected shape: advantage ~1x at sigma=0, growing with the tail\n");

    // ---- (b) top-k sweep ---------------------------------------------------
    println!("=== (b) top-k sweep on yahoo-sim, L=32 m=64 ===");
    let wl = common::yahoo();
    let cps = geometric_checkpoints(10, wl.items.len(), 4);
    let mut table = Table::new(&["k", "range@80%", "simple@80%", "advantage"]);
    for k in [1usize, 10, 50] {
        let gt = ground_truth(&wl.items, &wl.queries, k);
        let mut rspec = CurveSpec::new(IndexAlgo::RangeLsh, 32, 64);
        rspec.top_k = k;
        let mut sspec = CurveSpec::new(IndexAlgo::SimpleLsh, 32, 1);
        sspec.top_k = k;
        let range = run_curve(&wl.items, &wl.queries, &gt, &cps, &rspec, "r")?;
        let simple = run_curve(&wl.items, &wl.queries, &gt, &cps, &sspec, "s")?;
        let rp = range.curve.probes_to_reach(0.8).unwrap_or(wl.items.len());
        let sp = simple.curve.probes_to_reach(0.8).unwrap_or(wl.items.len());
        table.row(vec![
            k.to_string(),
            rp.to_string(),
            sp.to_string(),
            format!("{:.2}x", sp as f64 / rp as f64),
        ]);
    }
    println!("{}", table.render());

    // ---- (c) full baseline field (incl. SIGN-ALSH) -------------------------
    println!("=== (c) all baselines on netflix-sim, L=32 ===");
    let wl = common::netflix();
    let gt = ground_truth(&wl.items, &wl.queries, 10);
    let cps = geometric_checkpoints(10, wl.items.len(), 4);
    let mut results = Vec::new();
    for (algo, m, label) in [
        (IndexAlgo::RangeLsh, 64, "range_lsh      L=32 m=64"),
        (IndexAlgo::SimpleLsh, 1, "simple_lsh     L=32"),
        (IndexAlgo::SignAlsh, 1, "sign_alsh      L=32"),
        (IndexAlgo::L2Alsh, 1, "l2_alsh        K=32"),
        (IndexAlgo::RangedL2Alsh, 64, "ranged_l2_alsh K=32 m=64"),
    ] {
        results.push(run_curve(
            &wl.items,
            &wl.queries,
            &gt,
            &cps,
            &CurveSpec::new(algo, 32, m),
            label,
        )?);
    }
    println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9]));
    Ok(())
}
