//! Shared workload definitions for the paper-figure benches.
//!
//! The three corpora mirror the paper's datasets (DESIGN.md §3):
//! netflix-sim (17,770 x 300, MF, mild norms), yahoo-sim (50K x 300, MF),
//! imagenet-sim (200K x 128, long-tailed). `RANGELSH_BENCH_SCALE=small`
//! shrinks everything ~10x for smoke runs.
#![allow(dead_code)] // each bench target uses a different subset

use rangelsh::data::{synthetic, Dataset};

pub struct Workload {
    pub name: &'static str,
    pub items: Dataset,
    pub queries: Dataset,
}

fn scale() -> f64 {
    match std::env::var("RANGELSH_BENCH_SCALE").as_deref() {
        Ok("small") => 0.1,
        Ok("tiny") => 0.02,
        _ => 1.0,
    }
}

fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(500)
}

pub fn n_queries() -> usize {
    if scale() < 1.0 {
        100
    } else {
        1000
    }
}

/// Netflix stand-in: the paper's exact Netflix cardinality and dim.
pub fn netflix() -> Workload {
    Workload {
        name: "netflix-sim",
        items: synthetic::mf_embeddings(scaled(17_770), 300, 32, 42),
        queries: synthetic::mf_user_queries(n_queries(), 300, 32, 42),
    }
}

/// Yahoo!Music stand-in (full corpus ~136K; scaled to 50K for time).
pub fn yahoo() -> Workload {
    Workload {
        name: "yahoo-sim",
        items: synthetic::mf_embeddings(scaled(50_000), 300, 32, 43),
        queries: synthetic::mf_user_queries(n_queries(), 300, 32, 43),
    }
}

/// ImageNet-SIFT stand-in (full corpus ~2M; scaled to 200K for time).
pub fn imagenet() -> Workload {
    Workload {
        name: "imagenet-sim",
        items: synthetic::longtail_sift(scaled(200_000), 128, 44),
        queries: synthetic::gaussian_queries(n_queries(), 128, 1009),
    }
}

pub fn all_workloads() -> Vec<Workload> {
    vec![netflix(), yahoo(), imagenet()]
}

/// The paper's Fig. 2 grid: (code length, number of ranges).
pub const FIG2_GRID: &[(usize, usize)] = &[(16, 32), (32, 64), (64, 128)];
