//! §5 regenerator: norm-range partitioning applied to L2-ALSH.
//!
//! Theory side: Eq. 13's per-range ρ_j < Eq. 7's ρ for every range with
//! confined norms. Empirical side: ranged L2-ALSH beats vanilla L2-ALSH
//! on the probed-items/recall curve (supplementary experiment).
//!
//! Run with: `cargo bench --bench ext_l2alsh`

mod common;

use rangelsh::bench::Table;
use rangelsh::config::IndexAlgo;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::index::{partition, PartitionScheme};
use rangelsh::theory::rho::ranged_l2alsh_grid_search;
use rangelsh::theory::rho_l2alsh;

fn main() -> rangelsh::Result<()> {
    // ---- Theory: Eq. 13 + per-range parameter freedom vs Eq. 7 ----------
    // §5's two levers: (a) confined norms tighten both collision terms,
    // (b) each range only needs U_j < 1/u_hi, freeing the grid search.
    let (s0, c, m, r) = (0.5f64, 0.7f64, 3u32, 2.5f64);
    let full_rho = rho_l2alsh(s0, c, m, 0.83, r);
    println!(
        "=== §5 theory: per-range Eq.13 grid search vs Eq.7 rho = {full_rho:.4} \
         (S0=0.5, c=0.7, m=3, r=2.5) ==="
    );
    let mut t = Table::new(&["range (u_lo, u_hi]", "best U_j", "rho_j (Eq.13)", "vs Eq.7"]);
    for (lo, hi) in [(0.0, 0.25), (0.25, 0.5), (0.5, 0.75), (0.75, 1.0)] {
        let (u_j, rho_j) = ranged_l2alsh_grid_search(s0, c, m, r, lo * s0, hi * s0);
        t.row(vec![
            format!("({:.2}, {:.2}]", lo * s0, hi * s0),
            format!("{u_j:.2}"),
            format!("{rho_j:.4}"),
            format!("{:+.4}", rho_j - full_rho),
        ]);
    }
    println!("{}", t.render());

    // ---- Empirical: ranged L2-ALSH vs L2-ALSH ---------------------------
    for wl in [common::netflix(), common::imagenet()] {
        println!(
            "=== {} ({} items): ranged L2-ALSH vs L2-ALSH, K=16 ===",
            wl.name,
            wl.items.len()
        );
        let gt = ground_truth(&wl.items, &wl.queries, 10);
        let cps = geometric_checkpoints(10, wl.items.len(), 4);
        let mut results = Vec::new();
        for (algo, parts, label) in [
            (IndexAlgo::RangedL2Alsh, 32, "ranged_l2_alsh K=16 m=32"),
            (IndexAlgo::L2Alsh, 1, "l2_alsh        K=16"),
        ] {
            results.push(run_curve(
                &wl.items,
                &wl.queries,
                &gt,
                &cps,
                &CurveSpec::new(algo, 16, parts),
                label,
            )?);
        }
        println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9]));
    }

    // ---- Per-range scaling factors (the "flexibility" §5 argues for) ----
    let wl = common::imagenet();
    let parts = partition(&wl.items, 8, PartitionScheme::Percentile)?;
    println!("=== per-range norm bounds on {} (m=8) ===", wl.name);
    let mut t = Table::new(&["range", "u_min", "u_max", "u_max/U"]);
    let u = wl.items.max_norm();
    for (j, p) in parts.iter().enumerate() {
        t.row(vec![
            j.to_string(),
            format!("{:.3}", p.u_min),
            format!("{:.3}", p.u_max),
            format!("{:.3}", p.u_max / u),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
