//! Fig. 1 + Theorem 1 regenerator.
//!
//! (a) ρ = G(c, S0) vs S0 — the theory curve behind the whole paper;
//! (b) the 2-norm distribution of imagenet-sim (long tail);
//! (c) max-inner-product distribution after SIMPLE-LSH normalisation;
//! (d) same after RANGE-LSH's per-range normalisation (32 ranges);
//! (e) Theorem 1 condition check + Eq. 11 predicted cost ratio, plus an
//!     empirical probes-at-recall scaling in n.
//!
//! Run with: `cargo bench --bench fig1_theory`

mod common;

use rangelsh::config::IndexAlgo;
use rangelsh::data::synthetic;
use rangelsh::eval::harness::{ground_truth, run_curve, CurveSpec};
use rangelsh::eval::max_inner_products;
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::index::{partition, PartitionScheme};
use rangelsh::theory::{g_rho, theorem1_check};

fn histogram(values: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    for &v in values {
        let t = ((v - lo) / (hi - lo) * bins as f32) as usize;
        h[t.min(bins - 1)] += 1;
    }
    h
}

fn print_hist(h: &[usize], lo: f32, hi: f32) {
    let max = *h.iter().max().unwrap_or(&1);
    for (i, &c) in h.iter().enumerate() {
        let l = lo + (hi - lo) * i as f32 / h.len() as f32;
        let bar = "#".repeat((c * 48 / max.max(1)).max(usize::from(c > 0)));
        println!("  {l:>5.2}  {c:>8}  {bar}");
    }
}

fn main() -> rangelsh::Result<()> {
    // ---- Fig 1(a): rho vs S0 ------------------------------------------
    println!("=== Fig 1(a): rho = G(c, S0) (query time O(n^rho log n)) ===");
    println!("{:>5}  {:>8}  {:>8}  {:>8}", "S0", "c=0.5", "c=0.7", "c=0.9");
    for i in 1..=19 {
        let s0 = 0.05 * i as f64;
        println!(
            "{s0:>5.2}  {:>8.4}  {:>8.4}  {:>8.4}",
            g_rho(0.5, s0),
            g_rho(0.7, s0),
            g_rho(0.9, s0)
        );
    }

    // ---- Fig 1(b): norm distribution -----------------------------------
    let wl = common::imagenet();
    let u = wl.items.max_norm();
    println!(
        "\n=== Fig 1(b): 2-norm distribution of {} (max scaled to 1) ===",
        wl.name
    );
    let norms: Vec<f32> = wl.items.norms().iter().map(|&n| n / u).collect();
    print_hist(&histogram(&norms, 0.0, 1.0, 12), 0.0, 1.0);
    let stats = wl.items.norm_stats();
    println!(
        "  median/max = {:.3} — the long tail the paper identifies",
        stats.median / stats.max
    );

    // ---- Fig 1(c): S0 after SIMPLE-LSH normalisation -------------------
    println!("\n=== Fig 1(c): max inner product after SIMPLE-LSH normalisation ===");
    let mips = max_inner_products(&wl.items, &wl.queries);
    let qn: Vec<f32> = (0..wl.queries.len()).map(|i| wl.queries.norm(i)).collect();
    let simple_s0: Vec<f32> = mips.iter().zip(&qn).map(|(&s, &q)| s / (u * q)).collect();
    print_hist(&histogram(&simple_s0, 0.0, 1.0, 12), 0.0, 1.0);
    let mean_simple = simple_s0.iter().sum::<f32>() / simple_s0.len() as f32;

    // ---- Fig 1(d): S0 after RANGE-LSH normalisation --------------------
    println!("\n=== Fig 1(d): max inner product after RANGE-LSH normalisation (32 ranges) ===");
    let parts = partition(&wl.items, 32, PartitionScheme::Percentile)?;
    let range_s0: Vec<f32> = (0..wl.queries.len())
        .map(|qi| {
            let q = wl.queries.row(qi);
            parts
                .iter()
                .flat_map(|p| {
                    p.ids
                        .iter()
                        .map(|&id| wl.items.dot(id as usize, q) / (p.u_max * qn[qi]))
                })
                .fold(f32::MIN, f32::max)
        })
        .collect();
    print_hist(&histogram(&range_s0, 0.0, 1.0, 12), 0.0, 1.0);
    let mean_range = range_s0.iter().sum::<f32>() / range_s0.len() as f32;
    println!(
        "  mean S0: SIMPLE {mean_simple:.3} -> RANGE {mean_range:.3} \
         (rho at c=0.7: {:.3} -> {:.3})",
        g_rho(0.7, (mean_simple as f64).clamp(1e-6, 1.0)),
        g_rho(0.7, (mean_range as f64).clamp(1e-6, 1.0)),
    );

    // ---- Theorem 1 ------------------------------------------------------
    println!("\n=== Theorem 1 check on {} ===", wl.name);
    let us: Vec<f32> = parts.iter().map(|p| p.u_max).collect();
    let s0 = (mips.iter().zip(&qn).map(|(&s, &q)| (s / q) as f64).sum::<f64>()
        / mips.len() as f64)
        .min(u as f64);
    let rep = theorem1_check(wl.items.len(), &us, u, s0, 0.7);
    println!(
        "  rho = {:.4}, rho* = {:.4}, alpha = {:.4} (< {:.4}?), beta = {:.4} (< {:.4}?)",
        rep.rho, rep.rho_star, rep.alpha, rep.alpha_limit, rep.beta, rep.beta_limit
    );
    println!(
        "  conditions hold: {}, Eq.11 predicted RANGE/SIMPLE cost ratio: {:.4}",
        rep.conditions_hold, rep.predicted_cost_ratio
    );

    // ---- Empirical complexity scaling in n ------------------------------
    // Correlated queries (noisy copies of items — the recommendation
    // regime) so a fixed high recall target is reachable at every n;
    // the Theorem 1 story is the *ratio* of probes as n grows.
    println!("\n=== Empirical probes@90% top-1 recall vs n (RANGE vs SIMPLE, L=32) ===");
    println!(
        "{:>8}  {:>14}  {:>14}  {:>8}",
        "n", "range probes", "simple probes", "ratio"
    );
    for n in [10_000usize, 30_000, 100_000] {
        let items = synthetic::longtail_sift(n, 64, 5);
        let queries = synthetic::correlated_queries(&items, 200, 0.3, 6);
        let gt = ground_truth(&items, &queries, 1); // top-1: the planted near-copy
        let cps = geometric_checkpoints(10, n, 6);
        let range = run_curve(
            &items, &queries, &gt, &cps,
            &CurveSpec::new(IndexAlgo::RangeLsh, 32, 32),
            "range",
        )?;
        let simple = run_curve(
            &items, &queries, &gt, &cps,
            &CurveSpec::new(IndexAlgo::SimpleLsh, 32, 1),
            "simple",
        )?;
        let (rp, sp) = (
            range.curve.probes_to_reach(0.9),
            simple.curve.probes_to_reach(0.9),
        );
        match (rp, sp) {
            (Some(rp), Some(sp)) => println!(
                "{n:>8}  {rp:>14}  {sp:>14}  {:>8.2}x",
                sp as f64 / rp as f64
            ),
            _ => println!("{n:>8}  {rp:?} vs {sp:?}"),
        }
    }
    Ok(())
}
