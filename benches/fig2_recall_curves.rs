//! Fig. 2 regenerator: probed-items vs recall@10 curves for RANGE-LSH,
//! SIMPLE-LSH and L2-ALSH on the three corpora at L in {16, 32, 64}.
//!
//! The paper's qualitative claims to reproduce:
//!   - RANGE-LSH probes far fewer items than SIMPLE-LSH at equal recall
//!     (order of magnitude on the long-tailed corpus);
//!   - SIMPLE-LSH beats or matches L2-ALSH;
//!   - the gap persists across code lengths.
//!
//! Run with: `cargo bench --bench fig2_recall_curves`
//! (set RANGELSH_BENCH_SCALE=small for a quick pass)

mod common;

use rangelsh::config::IndexAlgo;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::util::json::Json;

fn main() -> rangelsh::Result<()> {
    let mut json_panels = Vec::new();
    for wl in common::all_workloads() {
        println!(
            "\n=== {} ({} items x {}d, tail ratio {:.2}) ===",
            wl.name,
            wl.items.len(),
            wl.items.dim(),
            wl.items.norm_stats().tail_ratio()
        );
        let gt = ground_truth(&wl.items, &wl.queries, 10);
        let max_probe = wl.items.len();
        let cps = geometric_checkpoints(10, max_probe, 4);

        for &(bits, m) in common::FIG2_GRID {
            println!("\n--- code length L = {bits} (RANGE uses m = {m} ranges) ---");
            let mut results = Vec::new();
            for (algo, parts, label) in [
                (IndexAlgo::RangeLsh, m, format!("range_lsh  L={bits} m={m}")),
                (IndexAlgo::SimpleLsh, 1, format!("simple_lsh L={bits}")),
                (IndexAlgo::L2Alsh, 1, format!("l2_alsh    K={bits}")),
            ] {
                let spec = CurveSpec::new(algo, bits, parts);
                let res = run_curve(&wl.items, &wl.queries, &gt, &cps, &spec, label)?;
                results.push(res);
            }
            println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9, 0.95]));
            for r in &results {
                json_panels.push(Json::obj(vec![
                    ("dataset", Json::Str(wl.name.to_string())),
                    ("code_bits", Json::Num(bits as f64)),
                    ("label", Json::Str(r.label.clone())),
                    ("checkpoints", Json::arr_usize(r.curve.checkpoints.iter().copied())),
                    ("recalls", Json::arr_f64(r.curve.recalls.iter().copied())),
                ]));
            }
        }
    }
    let out = "bench_results_fig2.json";
    std::fs::write(out, Json::Arr(json_panels).to_string())?;
    println!("\nwrote {out}");
    Ok(())
}
