//! Fig. 3 regenerator (+ ε ablation).
//!
//! (a) percentile vs uniform partitioning at L=32, m=32 on yahoo-sim —
//!     the paper finds them comparable (uniform slightly better);
//! (b) the number of sub-datasets m in {32, 64, 128, 256} at L=32 —
//!     improves then saturates;
//! (c) [ablation beyond the paper] the Eq. 12 ε knob.
//!
//! Run with: `cargo bench --bench fig3_partitioning`

mod common;

use rangelsh::config::IndexAlgo;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::index::PartitionScheme;

fn main() -> rangelsh::Result<()> {
    let wl = common::yahoo();
    println!(
        "=== Fig 3 on {} ({} items x {}d) ===",
        wl.name,
        wl.items.len(),
        wl.items.dim()
    );
    let gt = ground_truth(&wl.items, &wl.queries, 10);
    let cps = geometric_checkpoints(10, wl.items.len(), 4);

    // ---- (a) percentile vs uniform --------------------------------------
    println!("\n--- Fig 3(a): percentile (prc32) vs uniform (uni32), L=32 ---");
    let mut results = Vec::new();
    for (scheme, label) in [
        (PartitionScheme::Percentile, "prc32"),
        (PartitionScheme::UniformRange, "uni32"),
    ] {
        let mut spec = CurveSpec::new(IndexAlgo::RangeLsh, 32, 32);
        spec.scheme = scheme;
        results.push(run_curve(&wl.items, &wl.queries, &gt, &cps, &spec, label)?);
    }
    println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9, 0.95]));

    // ---- (b) number of sub-datasets --------------------------------------
    println!("--- Fig 3(b): m in {{32, 64, 128, 256}}, L=32 ---");
    let mut results = Vec::new();
    for m in [32usize, 64, 128, 256] {
        let spec = CurveSpec::new(IndexAlgo::RangeLsh, 32, m);
        results.push(run_curve(
            &wl.items,
            &wl.queries,
            &gt,
            &cps,
            &spec,
            format!("RH{m}"),
        )?);
    }
    println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9, 0.95]));

    // ---- (c) epsilon ablation (beyond the paper) -------------------------
    println!("--- ablation: Eq. 12 epsilon in {{0, 0.05, 0.1, 0.2, 0.4}}, L=32 m=64 ---");
    let mut results = Vec::new();
    for eps in [0.0f32, 0.05, 0.1, 0.2, 0.4] {
        let mut spec = CurveSpec::new(IndexAlgo::RangeLsh, 32, 64);
        spec.epsilon = eps;
        results.push(run_curve(
            &wl.items,
            &wl.queries,
            &gt,
            &cps,
            &spec,
            format!("eps={eps}"),
        )?);
    }
    println!("{}", format_probe_table(&results, &[0.5, 0.8, 0.9, 0.95]));
    Ok(())
}
