//! §Perf hot-path microbenchmarks (criterion stand-in, offline build).
//!
//! Measures each stage of the serving path in isolation plus end-to-end:
//!   1. bulk item hashing — native SIMD path vs AOT Pallas kernel via PJRT
//!   2. query hashing (single + batched)
//!   3. probe scheduling at each code width (64 / 128 / 256-bit codes) —
//!      the counting sort + Eq. 12 schedule walk, i.e. the surface the
//!      `CodeWord` genericization must not regress at width 64
//!   3b. probe-budget axis (10 / 100 / 1k / 10k) on the m=32 config,
//!      eager (sort every range up front) vs lazy (budget-adaptive) —
//!      the auditable record of the lazy-probing speedup
//!   3c. probe-session axis (cumulative 10 → 100 → 1k → 10k): one
//!      resumable session extended to each target vs the pre-session
//!      client pattern of a fresh one-shot re-probe per target — the
//!      auditable record of the Prober cursor's resume payoff
//!   3d. probe-backend axis (64 / 128 / 256-bit codes at budgets
//!      10 / 100 / 1k / 10k on the m=8 config): multi-index Hamming
//!      chunk tables vs the dense counting-sort scan — the auditable
//!      record of MIH's sub-linear candidate generation and the width
//!      gate on the auto default
//!   4. exact re-rank
//!   4b. rerank axis (k = 1 / 10 / 100 on the long-tail m=32 config):
//!      the fused streaming-pruned path (Cauchy–Schwarz admission +
//!      schedule early-out + range-ordered RerankView reads) vs the
//!      exhaustive probe-then-score oracle, plus a range-ordered vs
//!      original-layout gather pair over one probed candidate set —
//!      the auditable record of the streaming re-rank's payoff
//!   5. engine end-to-end (batched)
//!   6. exact ground-truth scan (the brute-force baseline RANGE beats)
//!   7. degraded-serving axis: end-to-end latency + degraded fraction
//!      under per-query wall-clock deadlines
//!   8. mutation axis: the WAL-backed mutable store — acked ingest
//!      batches, recovery replay over the accumulated WAL, and
//!      tombstone-laden vs compacted query twins
//!
//! Results are printed as a table and written to `BENCH_hotpath.json`
//! (schema: see the repo-root file) so width-64 probe throughput can be
//! diffed against the pre-refactor baseline across commits.
//!
//! Run with: `cargo bench --bench hotpath`. Set `HOTPATH_SMOKE=1` for a
//! fast CI smoke run (smaller dataset, fewer reps, no JSON written).

use std::sync::Arc;

use rangelsh::bench::{bench, Table, Timing};
use rangelsh::config::ServeConfig;
use rangelsh::coordinator::SearchEngine;
use rangelsh::data::synthetic;
use rangelsh::eval::exact_topk;
use rangelsh::hash::{Code128, Code256, CodeWord, ItemHasher, NativeHasher, Projection};
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::CodeProbe;
use rangelsh::runtime::{PjrtHasher, RuntimeHandle, DEFAULT_ARTIFACT_DIR};
use rangelsh::util::json::Json;

/// One probe-throughput measurement at a given code width and budget.
struct ProbeRow {
    code_bits: usize,
    budget: usize,
    timing: Timing,
}

/// One bulk-hashing measurement at a given code width and path.
struct HashRow {
    code_bits: usize,
    mode: &'static str,
    timing: Timing,
}

/// Measure blocked vs per-item bulk hashing at one code width — the
/// wide-code batched backend's native rows (PJRT joins via section 1b
/// when artifacts exist).
fn bench_hash_width<C: CodeWord>(
    items: &rangelsh::data::Dataset,
    slice: &[f32],
    u: f32,
    code_bits: usize,
    rows: &mut Vec<HashRow>,
    table: &mut Table,
) {
    let hasher: NativeHasher<C> = NativeHasher::new(items.dim(), code_bits, 5);
    let n = slice.len() / items.dim();
    let t_blocked = bench(1, 5, || {
        std::hint::black_box(hasher.hash_items_blocked(slice, u).unwrap());
    });
    let t_item = bench(1, 5, || {
        std::hint::black_box(hasher.hash_items_unblocked(slice, u).unwrap());
    });
    let speedup = t_item.median.as_secs_f64() / t_blocked.median.as_secs_f64().max(1e-12);
    table.row(vec![
        format!("item hash L={code_bits} per-item ({n} rows)"),
        format!("{:?}", t_item.median),
        format!("{:.2} Mitems/s", t_item.throughput(n) / 1e6),
    ]);
    table.row(vec![
        format!("item hash L={code_bits} blocked  ({n} rows)"),
        format!("{:?}", t_blocked.median),
        format!("{speedup:.2}x vs per-item"),
    ]);
    rows.push(HashRow { code_bits, mode: "per_item", timing: t_item });
    rows.push(HashRow { code_bits, mode: "blocked", timing: t_blocked });
}

/// Build a RANGE-LSH index at width `C` over `items` and measure
/// `probe_with_code` throughput at each budget.
fn bench_probe_width<C: CodeWord>(
    items: &rangelsh::data::Dataset,
    query: &[f32],
    code_bits: usize,
    budgets: &[usize],
    rows: &mut Vec<ProbeRow>,
    table: &mut Table,
) -> rangelsh::Result<()> {
    let params = RangeLshParams::new(code_bits, 64);
    let width = params.hash_bits().min(C::MAX_BITS);
    let hasher: NativeHasher<C> = NativeHasher::new(items.dim(), width, 1);
    let index: RangeLshIndex<C> = RangeLshIndex::build(items, &hasher, params)?;
    let qcode = index.hash_query(query);
    for &budget in budgets {
        let t = bench(2, 20, || {
            let mut out = Vec::with_capacity(budget);
            index.probe_with_code(qcode, budget, &mut out);
            std::hint::black_box(out);
        });
        table.row(vec![
            format!("probe schedule L={code_bits} (budget {budget})"),
            format!("{:?}", t.median),
            format!("{:.0} probes/s", t.throughput(1)),
        ]);
        rows.push(ProbeRow { code_bits, budget, timing: t });
    }
    Ok(())
}

/// One candidate-generation-backend measurement (MIH vs counting sort)
/// at a given code width and budget.
struct BackendRow {
    code_bits: usize,
    budget: usize,
    mode: &'static str,
    timing: Timing,
}

/// Build one RANGE-LSH index at width `C` on the m=8 config (~n/8 items
/// per range) and measure `probe_with_code` with the counting-sort scan
/// vs the MIH chunk tables at each budget — the same index, toggled
/// between backends, so the pair differs only in candidate generation.
fn bench_probe_backend_width<C: CodeWord>(
    items: &rangelsh::data::Dataset,
    query: &[f32],
    code_bits: usize,
    budgets: &[usize],
    reps: usize,
    rows: &mut Vec<BackendRow>,
    table: &mut Table,
) -> rangelsh::Result<()> {
    let params = RangeLshParams::new(code_bits, 8);
    let width = params.hash_bits().min(C::MAX_BITS);
    let hasher: NativeHasher<C> = NativeHasher::new(items.dim(), width, 3);
    let mut index: RangeLshIndex<C> = RangeLshIndex::build(items, &hasher, params)?;
    let qcode = index.hash_query(query);
    for &budget in budgets {
        index.clear_mih();
        let t_sort = bench(2, reps, || {
            let mut out = Vec::with_capacity(budget);
            index.probe_with_code(qcode, budget, &mut out);
            std::hint::black_box(out);
        });
        index.enable_mih();
        let t_mih = bench(2, reps, || {
            let mut out = Vec::with_capacity(budget);
            index.probe_with_code(qcode, budget, &mut out);
            std::hint::black_box(out);
        });
        let speedup = t_sort.median.as_secs_f64() / t_mih.median.as_secs_f64().max(1e-12);
        table.row(vec![
            format!("probe L={code_bits} m=8 budget {budget} (counting_sort)"),
            format!("{:?}", t_sort.median),
            format!("{:.0} probes/s", t_sort.throughput(1)),
        ]);
        table.row(vec![
            format!("probe L={code_bits} m=8 budget {budget} (mih)"),
            format!("{:?}", t_mih.median),
            format!("{speedup:.1}x vs counting_sort"),
        ]);
        rows.push(BackendRow { code_bits, budget, mode: "counting_sort", timing: t_sort });
        rows.push(BackendRow { code_bits, budget, mode: "mih", timing: t_mih });
    }
    Ok(())
}

fn main() -> rangelsh::Result<()> {
    // Smoke mode (CI): shrink the dataset and rep counts so the whole
    // bench is a build-and-run sanity check, and leave the committed
    // BENCH_hotpath.json (real-hardware numbers) untouched.
    let smoke = std::env::var_os("HOTPATH_SMOKE").is_some();
    let (n, dim) = if smoke { (20_000usize, 32usize) } else { (100_000usize, 128usize) };
    let items = Arc::new(synthetic::longtail_sift(n, dim, 42));
    let queries = synthetic::gaussian_queries(1024, dim, 7);
    let proj = Arc::new(Projection::gaussian(dim + 1, 64, 1));
    let native: Arc<NativeHasher> = Arc::new(NativeHasher::with_projection(proj.clone()));
    let u = items.max_norm();
    let mut table = Table::new(&["stage", "median", "throughput"]);

    // 1. bulk item hashing (native)
    let hash_rows = 16_384usize;
    let slice = &items.flat()[..hash_rows * dim];
    let t = bench(1, 5, || {
        std::hint::black_box(native.hash_items(slice, u).unwrap());
    });
    table.row(vec![
        format!("item hash native ({hash_rows} rows)"),
        format!("{:?}", t.median),
        format!("{:.2} Mitems/s", t.throughput(hash_rows) / 1e6),
    ]);

    // 1b. bulk item hashing (PJRT Pallas kernel), when artifacts exist.
    let pjrt_hasher: Option<Arc<dyn ItemHasher>> =
        if std::path::Path::new(DEFAULT_ARTIFACT_DIR).join("manifest.json").exists() {
            match RuntimeHandle::load(DEFAULT_ARTIFACT_DIR)
                .and_then(|rt| PjrtHasher::<u64>::new(rt, proj.clone()))
            {
                Ok(h) => Some(Arc::new(h)),
                Err(e) => {
                    eprintln!("(PJRT unavailable: {e:#})");
                    None
                }
            }
        } else {
            None
        };
    if let Some(h) = &pjrt_hasher {
        let t = bench(1, 5, || {
            std::hint::black_box(h.hash_items(slice, u).unwrap());
        });
        table.row(vec![
            format!("item hash pjrt   ({hash_rows} rows)"),
            format!("{:?}", t.median),
            format!("{:.2} Mitems/s", t.throughput(hash_rows) / 1e6),
        ]);
    }

    // 1c. bulk hashing across the code-width axis: blocked (the default
    // batch path since the wide-code backend) vs the per-item oracle at
    // L = 64 / 128 / 256.
    let mut hash_width_rows: Vec<HashRow> = Vec::new();
    let axis_rows = if smoke { 2048usize } else { hash_rows };
    {
        let axis_slice = &items.flat()[..axis_rows * dim];
        bench_hash_width::<u64>(&items, axis_slice, u, 64, &mut hash_width_rows, &mut table);
        bench_hash_width::<Code128>(&items, axis_slice, u, 128, &mut hash_width_rows, &mut table);
        bench_hash_width::<Code256>(&items, axis_slice, u, 256, &mut hash_width_rows, &mut table);
    }

    // 2. query hashing
    let qrows = queries.flat();
    let t = bench(1, 10, || {
        std::hint::black_box(native.hash_queries(&qrows[..dim]).unwrap());
    });
    table.row(vec![
        "query hash native (single)".into(),
        format!("{:?}", t.median),
        format!("{:.0} q/s", t.throughput(1)),
    ]);
    let t = bench(1, 5, || {
        std::hint::black_box(native.hash_queries(qrows).unwrap());
    });
    table.row(vec![
        "query hash native (1024 batch)".into(),
        format!("{:?}", t.median),
        format!("{:.0} q/s", t.throughput(1024)),
    ]);
    if let Some(h) = &pjrt_hasher {
        let t = bench(1, 5, || {
            std::hint::black_box(h.hash_queries(qrows).unwrap());
        });
        table.row(vec![
            "query hash pjrt   (1024 batch)".into(),
            format!("{:?}", t.median),
            format!("{:.0} q/s", t.throughput(1024)),
        ]);
    }

    // 3. probe scheduling across the code-width axis. L=32 is the paper's
    // historical operating point (pre-refactor baseline row); 128/256 are
    // the regimes the CodeWord refactor opens. Budgets as before.
    let budgets = [512usize, 4096];
    let mut probe_rows: Vec<ProbeRow> = Vec::new();
    bench_probe_width::<u64>(&items, queries.row(0), 32, &budgets, &mut probe_rows, &mut table)?;
    bench_probe_width::<u64>(&items, queries.row(0), 64, &budgets, &mut probe_rows, &mut table)?;
    bench_probe_width::<Code128>(
        &items,
        queries.row(0),
        128,
        &budgets,
        &mut probe_rows,
        &mut table,
    )?;
    bench_probe_width::<Code256>(
        &items,
        queries.row(0),
        256,
        &budgets,
        &mut probe_rows,
        &mut table,
    )?;

    // 3b. probe-budget axis: eager vs lazy on the m=32 config (the
    // paper's §4 shape: 32-bit budget, 32 ranges). Small budgets are
    // where lazy probing earns its keep — the acceptance bar is >= 5x at
    // budgets <= 100 on the same machine.
    struct BudgetRow {
        budget: usize,
        mode: &'static str,
        timing: Timing,
    }
    let mut budget_rows: Vec<BudgetRow> = Vec::new();
    let mut session_rows: Vec<BudgetRow> = Vec::new();
    {
        let params = RangeLshParams::new(32, 32);
        let index: RangeLshIndex = RangeLshIndex::build(&items, native.as_ref(), params)?;
        let qcode = index.hash_query(queries.row(0));
        let reps = if smoke { 5 } else { 30 };
        for &budget in &[10usize, 100, 1_000, 10_000] {
            let t_eager = bench(2, reps, || {
                let mut out = Vec::with_capacity(budget);
                index.probe_with_code_eager(qcode, budget, &mut out);
                std::hint::black_box(out);
            });
            let t_lazy = bench(2, reps, || {
                let mut out = Vec::with_capacity(budget);
                index.probe_with_code(qcode, budget, &mut out);
                std::hint::black_box(out);
            });
            let speedup = t_eager.median.as_secs_f64() / t_lazy.median.as_secs_f64().max(1e-12);
            table.row(vec![
                format!("probe m=32 budget {budget} (eager)"),
                format!("{:?}", t_eager.median),
                format!("{:.0} probes/s", t_eager.throughput(1)),
            ]);
            table.row(vec![
                format!("probe m=32 budget {budget} (lazy)"),
                format!("{:?}", t_lazy.median),
                format!("{speedup:.1}x vs eager"),
            ]);
            budget_rows.push(BudgetRow { budget, mode: "eager", timing: t_eager });
            budget_rows.push(BudgetRow { budget, mode: "lazy", timing: t_lazy });
        }

        // 3c. probe-session axis: a client that wants more candidates
        // after inspecting the first batch. "session" opens one resumable
        // Prober and extends it through every cumulative target up to
        // `cum`; "reprobe" is the pre-session pattern — a fresh one-shot
        // probe per target, rescanning the shared prefix each time.
        use rangelsh::index::Prober;
        let steps = [10usize, 100, 1_000, 10_000];
        for (i, &cum) in steps.iter().enumerate() {
            let t_session = bench(1, reps, || {
                let mut out = Vec::with_capacity(cum);
                let mut session = index.session(qcode);
                let mut have = 0usize;
                for &b in &steps[..=i] {
                    session.extend(b - have, &mut out);
                    have = b;
                }
                std::hint::black_box(&out);
            });
            let t_reprobe = bench(1, reps, || {
                let mut out = Vec::with_capacity(cum);
                for &b in &steps[..=i] {
                    out.clear();
                    index.probe_with_code(qcode, b, &mut out);
                }
                std::hint::black_box(&out);
            });
            let speedup =
                t_reprobe.median.as_secs_f64() / t_session.median.as_secs_f64().max(1e-12);
            table.row(vec![
                format!("probe m=32 to {cum} via {} steps (reprobe)", i + 1),
                format!("{:?}", t_reprobe.median),
                format!("{:.0} probes/s", t_reprobe.throughput(1)),
            ]);
            table.row(vec![
                format!("probe m=32 to {cum} via {} steps (session)", i + 1),
                format!("{:?}", t_session.median),
                format!("{speedup:.1}x vs reprobe"),
            ]);
            session_rows.push(BudgetRow { budget: cum, mode: "reprobe", timing: t_reprobe });
            session_rows.push(BudgetRow { budget: cum, mode: "session", timing: t_session });
        }
    }

    // 3d. probe-backend axis: MIH chunk tables vs the counting-sort scan,
    // per code width and budget on the m=8 config (~n/8 items per range —
    // the 10k-item-per-range shape at paper scale). Acceptance: MIH must
    // beat counting sort at 256-bit codes on this shape; it may lose at
    // 64-bit, where one XOR+POPCNT per bucket is already near memory
    // speed — exactly why the auto default is width-gated at 128.
    let mut backend_rows: Vec<BackendRow> = Vec::new();
    {
        let reps = if smoke { 5 } else { 30 };
        let budgets = [10usize, 100, 1_000, 10_000];
        bench_probe_backend_width::<u64>(
            &items,
            queries.row(0),
            64,
            &budgets,
            reps,
            &mut backend_rows,
            &mut table,
        )?;
        bench_probe_backend_width::<Code128>(
            &items,
            queries.row(0),
            128,
            &budgets,
            reps,
            &mut backend_rows,
            &mut table,
        )?;
        bench_probe_backend_width::<Code256>(
            &items,
            queries.row(0),
            256,
            &budgets,
            reps,
            &mut backend_rows,
            &mut table,
        )?;
    }

    // 4. exact re-rank of 4096 candidates
    let cands: Vec<u32> = (0..4096u32).collect();
    let q0: Vec<f32> = queries.row(0).to_vec();
    let t = bench(2, 20, || {
        let mut c = cands.clone();
        rangelsh::runtime::PjrtScorer::rerank(&items, &q0, &mut c, 10);
        std::hint::black_box(c);
    });
    table.row(vec![
        "re-rank 4096 candidates".into(),
        format!("{:?}", t.median),
        format!("{:.2} Mdots/s", t.throughput(4096) / 1e6),
    ]);

    // 4b. rerank axis: the fused streaming-pruned path vs the exhaustive
    // probe-then-score oracle, end to end per query on the long-tail m=32
    // config (acceptance: at k=10 the streaming median must beat the
    // oracle twin, target >= 2x — the pruned dots plus the early-out pay
    // for the admission tests). Plus the storage-layout pair: scoring one
    // probed candidate set through the range-ordered RerankView vs
    // gathering from the original-order matrix.
    struct RerankRow {
        k: usize,
        mode: &'static str,
        timing: Timing,
    }
    let mut rerank_rows: Vec<RerankRow> = Vec::new();
    let rerank_budget = if smoke { 4_096usize } else { 16_384 };
    {
        use rangelsh::config::{QueryParams, RerankMode};
        use rangelsh::data::RerankView;
        let params = RangeLshParams::new(32, 32);
        let index: Arc<RangeLshIndex> =
            Arc::new(RangeLshIndex::build(&items, native.as_ref(), params)?);
        let budget = rerank_budget;
        let reps = if smoke { 5 } else { 20 };
        let nq = 8usize;
        // One engine pair serves every k via per-request overrides — a
        // per-k rebuild would copy the whole matrix into a fresh
        // RerankView each round for an identical measured path.
        let cfg = ServeConfig {
            probe_budget: budget,
            top_k: 10,
            rerank: RerankMode::Streaming,
            ..Default::default()
        };
        let streaming = SearchEngine::new(index.clone(), items.clone(), native.clone(), cfg)?;
        let cfg = ServeConfig {
            probe_budget: budget,
            top_k: 10,
            rerank: RerankMode::Exhaustive,
            ..Default::default()
        };
        let oracle = SearchEngine::new(index.clone(), items.clone(), native.clone(), cfg)?;
        for &k in &[1usize, 10, 100] {
            let p = QueryParams::new().with_top_k(k);
            let t_stream = bench(1, reps, || {
                for qi in 0..nq {
                    std::hint::black_box(streaming.search_with(queries.row(qi), &p).unwrap());
                }
            });
            let t_oracle = bench(1, reps, || {
                for qi in 0..nq {
                    std::hint::black_box(oracle.search_with(queries.row(qi), &p).unwrap());
                }
            });
            let speedup =
                t_oracle.median.as_secs_f64() / t_stream.median.as_secs_f64().max(1e-12);
            table.row(vec![
                format!("rerank m=32 k={k} budget {budget} (exhaustive)"),
                format!("{:?}", t_oracle.median),
                format!("{:.0} q/s", t_oracle.throughput(nq)),
            ]);
            table.row(vec![
                format!("rerank m=32 k={k} budget {budget} (streaming)"),
                format!("{:?}", t_stream.median),
                format!("{speedup:.1}x vs exhaustive"),
            ]);
            rerank_rows.push(RerankRow { k, mode: "exhaustive", timing: t_oracle });
            rerank_rows.push(RerankRow { k, mode: "streaming", timing: t_stream });
        }

        // Layout pair: same candidate ids, same dots — only the storage
        // order differs. The probe stream arrives roughly range-by-range,
        // so the view reads contiguous lines where the original layout
        // scatters (k = 0 marks these rows in the JSON).
        let view = RerankView::build(&items);
        let qcode = index.hash_query(queries.row(0));
        let mut probe_cands: Vec<u32> = Vec::with_capacity(budget);
        index.probe_with_code(qcode, budget, &mut probe_cands);
        let slots: Vec<usize> =
            probe_cands.iter().map(|&id| view.slot_of(id)).collect();
        let t_orig = bench(2, reps, || {
            let mut s = 0.0f32;
            for &id in &probe_cands {
                s += items.dot(id as usize, &q0);
            }
            std::hint::black_box(s);
        });
        let t_view = bench(2, reps, || {
            let mut s = 0.0f32;
            for &slot in &slots {
                s += view.dot_at(slot, &q0);
            }
            std::hint::black_box(s);
        });
        let speedup = t_orig.median.as_secs_f64() / t_view.median.as_secs_f64().max(1e-12);
        table.row(vec![
            format!("gather+dot {} cands (original layout)", probe_cands.len()),
            format!("{:?}", t_orig.median),
            format!("{:.2} Mdots/s", t_orig.throughput(probe_cands.len()) / 1e6),
        ]);
        table.row(vec![
            format!("gather+dot {} cands (range-ordered view)", probe_cands.len()),
            format!("{:?}", t_view.median),
            format!("{speedup:.2}x vs original"),
        ]);
        rerank_rows.push(RerankRow { k: 0, mode: "gather_original", timing: t_orig });
        rerank_rows.push(RerankRow { k: 0, mode: "gather_view", timing: t_view });
    }

    // 5. engine end-to-end, batched (the original u64 serving path)
    let index: Arc<RangeLshIndex> = Arc::new(RangeLshIndex::build(
        &items,
        native.as_ref(),
        RangeLshParams::new(32, 64),
    )?);
    let cfg = ServeConfig { probe_budget: 4096, top_k: 10, ..Default::default() };
    let engine = SearchEngine::new(index, items.clone(), native.clone(), cfg)?;
    let batch = &qrows[..256 * dim];
    let t = bench(1, 5, || {
        std::hint::black_box(engine.search_batch(batch).unwrap());
    });
    table.row(vec![
        "engine e2e (256-query batch)".into(),
        format!("{:?}", t.median),
        format!("{:.0} q/s", t.throughput(256)),
    ]);

    // 6. brute-force baseline
    let sample = rangelsh::data::Dataset::from_flat(dim, qrows[..64 * dim].to_vec());
    let t = bench(0, 3, || {
        std::hint::black_box(exact_topk(&items, &sample, 10));
    });
    table.row(vec![
        "exact scan (64 queries)".into(),
        format!("{:?}", t.median),
        format!("{:.0} q/s", t.throughput(64)),
    ]);

    // 7. degraded-serving axis: the same engine under a per-query
    // wall-clock budget (`--deadline-ms` in the CLI). Each row records
    // end-to-end latency plus the fraction of queries answered with a
    // `Degraded { Deadline }` tag — the knob's trade: tighter deadlines
    // cap tail latency and raise the degraded fraction. deadline_us = 0
    // is the budget-less baseline (its degraded fraction must be 0).
    struct DegradedRow {
        deadline_us: u64,
        degraded_pct: f64,
        timing: Timing,
    }
    let mut degraded_rows: Vec<DegradedRow> = Vec::new();
    {
        use rangelsh::config::QueryParams;
        use std::time::Duration;
        let reps = if smoke { 3 } else { 10 };
        let nq = 64usize;
        for &deadline_us in &[0u64, 50, 500, 5_000] {
            let p = if deadline_us == 0 {
                QueryParams::new()
            } else {
                QueryParams::new().with_time_budget(Duration::from_micros(deadline_us))
            };
            let mut degraded = 0usize;
            for qi in 0..nq {
                degraded += usize::from(engine.search_full(queries.row(qi), &p)?.is_degraded());
            }
            let degraded_pct = 100.0 * degraded as f64 / nq as f64;
            let t = bench(1, reps, || {
                for qi in 0..nq {
                    std::hint::black_box(engine.search_full(queries.row(qi), &p).unwrap());
                }
            });
            let label = if deadline_us == 0 {
                format!("engine e2e no deadline ({nq} queries)")
            } else {
                format!("engine e2e deadline {deadline_us}us ({nq} queries)")
            };
            table.row(vec![
                label,
                format!("{:?}", t.median),
                format!("{:.0} q/s, {degraded_pct:.0}% degraded", t.throughput(nq)),
            ]);
            degraded_rows.push(DegradedRow { deadline_us, degraded_pct, timing: t });
        }
    }

    // 8. mutation axis: the WAL-backed mutable-store write path. One
    // store on the 16-bit m=8 config (the fsync and the insert routing
    // dominate these costs, not the hash width), three op families:
    //   - ingest: one acked 64-row batch = WAL append + fsync + per-range
    //     insert routing into a freshly swapped epoch
    //   - recover_replay: `MutableStore::open` over the accumulated WAL
    //     (open never consumes the log, so every rep replays the same
    //     records into the last published checkpoint)
    //   - query_tombstoned vs query_compacted: the same live set served
    //     through a ~20%-tombstoned epoch (just below the 0.25
    //     auto-compaction trigger) vs after `compact()` — the probe
    //     stream's per-candidate tombstone-filter overhead
    struct MutationRow {
        op: &'static str,
        n_mutations: usize,
        timing: Timing,
    }
    let mut mutation_rows: Vec<MutationRow> = Vec::new();
    {
        use rangelsh::coordinator::{MutableConfig, MutableStore};
        use rangelsh::util::tmp::TempPath;
        use rangelsh::ItemId;

        let reps = if smoke { 3 } else { 10 };
        let n0 = if smoke { 2_000usize } else { 10_000usize };
        let scfg = ServeConfig {
            probe_budget: usize::MAX,
            top_k: 10,
            code_bits: 16,
            ..Default::default()
        };
        let dir = TempPath::new("bench-mutation");
        let store: MutableStore<u64> = MutableStore::create(
            dir.path(),
            Arc::new(synthetic::longtail_sift(n0, dim, 43)),
            RangeLshParams::new(16, 8),
            7,
            scfg.clone(),
            MutableConfig::manual(),
        )?;

        let batch = 64usize;
        let n_batches = reps + 1; // one warmup call + `reps` measured calls
        let pool = synthetic::longtail_sift(batch * n_batches, dim, 44);
        let mut cursor = 0usize;
        let t_ingest = bench(1, reps, || {
            let b = cursor % n_batches;
            cursor += 1;
            let rows = &pool.flat()[b * batch * dim..(b + 1) * batch * dim];
            std::hint::black_box(store.ingest(rows).unwrap());
        });
        table.row(vec![
            format!("store ingest ({batch}-row acked batch)"),
            format!("{:?}", t_ingest.median),
            format!("{:.0} rows/s", t_ingest.throughput(batch)),
        ]);
        mutation_rows.push(MutationRow { op: "ingest", n_mutations: batch, timing: t_ingest });

        // Tombstone ~20% of the rows, spread across the norm ranges.
        let victims: Vec<ItemId> = (0..store.n_rows() as u32).step_by(5).collect();
        store.delete(&victims)?;

        let wal_records = cursor * batch + victims.len();
        let t_recover = bench(0, reps, || {
            let reopened: MutableStore<u64> =
                MutableStore::open(dir.path(), scfg.clone(), MutableConfig::manual()).unwrap();
            std::hint::black_box(reopened.live_len());
        });
        table.row(vec![
            format!("store recover ({wal_records}-record WAL replay)"),
            format!("{:?}", t_recover.median),
            format!("{:.0} records/s", t_recover.throughput(wal_records)),
        ]);
        mutation_rows.push(MutationRow {
            op: "recover_replay",
            n_mutations: wal_records,
            timing: t_recover,
        });

        let nq = 64usize;
        let n_tombs = store.tombstoned_len();
        let tombstoned = store.current();
        let t_tomb = bench(1, reps, || {
            for qi in 0..nq {
                std::hint::black_box(tombstoned.search(queries.row(qi)).unwrap());
            }
        });
        store.compact()?;
        let compacted = store.current();
        let t_comp = bench(1, reps, || {
            for qi in 0..nq {
                std::hint::black_box(compacted.search(queries.row(qi)).unwrap());
            }
        });
        let overhead = t_tomb.median.as_secs_f64() / t_comp.median.as_secs_f64().max(1e-12);
        table.row(vec![
            format!("query {n_tombs}-tombstoned ({nq} queries)"),
            format!("{:?}", t_tomb.median),
            format!("{overhead:.2}x vs compacted"),
        ]);
        table.row(vec![
            format!("query compacted ({nq} queries)"),
            format!("{:?}", t_comp.median),
            format!("{:.0} q/s", t_comp.throughput(nq)),
        ]);
        mutation_rows.push(MutationRow {
            op: "query_tombstoned",
            n_mutations: n_tombs,
            timing: t_tomb,
        });
        mutation_rows.push(MutationRow { op: "query_compacted", n_mutations: 0, timing: t_comp });
    }

    println!("{}", table.render());

    if smoke {
        println!("(smoke mode: skipping BENCH_hotpath.json)");
        return Ok(());
    }

    // Machine-readable record for cross-commit regression diffs
    // (acceptance: width-64 probe throughput within noise of baseline;
    // lazy small-budget rows >= 5x faster than their eager twins).
    let json = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        (
            // Required by scripts/validate_bench_schema.py; the committed
            // file's hand-written note carries the full per-axis
            // acceptance criteria, so regeneration keeps a summary of
            // them rather than dropping the field.
            "note",
            Json::Str(
                "Measured by `cargo bench --bench hotpath`. Acceptance per axis: \
                 lazy >= 5x eager at budgets <= 100; session below reprobe at 10k; \
                 blocked hashing never slower than per-item; streaming re-rank >= 2x \
                 exhaustive at k=10 with gather_view at-or-below gather_original; \
                 mih below counting_sort at 256-bit codes at every budget; \
                 query_tombstoned within 1.5x of query_compacted and recover_replay \
                 roughly linear in n_mutations. Full rationale: the note field in \
                 the pre-regeneration git history of BENCH_hotpath.json."
                    .into(),
            ),
        ),
        ("n_items", Json::Num(n as f64)),
        ("dim", Json::Num(dim as f64)),
        (
            "hash_width_axis",
            Json::Arr(
                hash_width_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(r.code_bits as f64)),
                            ("mode", Json::Str(r.mode.into())),
                            ("rows", Json::Num(axis_rows as f64)),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                            (
                                "items_per_sec",
                                Json::Num(r.timing.throughput(axis_rows)),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "probe_schedule",
            Json::Arr(
                probe_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(r.code_bits as f64)),
                            ("budget", Json::Num(r.budget as f64)),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                            ("probes_per_sec", Json::Num(r.timing.throughput(1))),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "probe_budget_axis",
            Json::Arr(
                budget_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(32.0)),
                            ("m", Json::Num(32.0)),
                            ("budget", Json::Num(r.budget as f64)),
                            ("mode", Json::Str(r.mode.into())),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "probe_session_axis",
            Json::Arr(
                session_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(32.0)),
                            ("m", Json::Num(32.0)),
                            ("cumulative_budget", Json::Num(r.budget as f64)),
                            ("mode", Json::Str(r.mode.into())),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            // streaming/exhaustive engine pairs per k (8 queries per
            // rep); k = 0 rows are the storage-layout gather pair over
            // the same probed candidate set.
            "rerank_axis",
            Json::Arr(
                rerank_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(32.0)),
                            ("m", Json::Num(32.0)),
                            ("budget", Json::Num(rerank_budget as f64)),
                            ("k", Json::Num(r.k as f64)),
                            ("mode", Json::Str(r.mode.into())),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            // counting_sort/mih pairs per code width and budget on the
            // m=8 config — the probe-backend axis.
            "probe_backend_axis",
            Json::Arr(
                backend_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(r.code_bits as f64)),
                            ("m", Json::Num(8.0)),
                            ("budget", Json::Num(r.budget as f64)),
                            ("mode", Json::Str(r.mode.into())),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            // per-deadline latency + degraded fraction on the m=64
            // serving engine; deadline_us = 0 is the budget-less
            // baseline. Optional in the schema so older files stay
            // valid — see scripts/validate_bench_schema.py.
            "degraded_axis",
            Json::Arr(
                degraded_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(32.0)),
                            ("m", Json::Num(64.0)),
                            ("deadline_us", Json::Num(r.deadline_us as f64)),
                            ("degraded_pct", Json::Num(r.degraded_pct)),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            // WAL-backed mutable-store write path: acked ingest batches,
            // recovery replay over the accumulated WAL, and the
            // tombstone filter's query overhead vs the compacted twin.
            // Optional in the schema, like degraded_axis.
            "mutation_axis",
            Json::Arr(
                mutation_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("code_bits", Json::Num(16.0)),
                            ("m", Json::Num(8.0)),
                            ("op", Json::Str(r.op.into())),
                            ("n_mutations", Json::Num(r.n_mutations as f64)),
                            ("median_us", Json::Num(r.timing.median.as_secs_f64() * 1e6)),
                            ("min_us", Json::Num(r.timing.min.as_secs_f64() * 1e6)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_hotpath.json", json.to_string())?;
    println!("wrote BENCH_hotpath.json");
    Ok(())
}
