//! Supplementary regenerator: multi-table single-probe comparison.
//!
//! Classical LSH theory uses T independent tables and probes only the
//! query's exact bucket in each. The paper's supplementary compares
//! RANGE-LSH and SIMPLE-LSH under this protocol; the shape to reproduce:
//! RANGE-LSH reaches higher recall with fewer probed items at every T.
//!
//! Run with: `cargo bench --bench multitable`

mod common;

use rangelsh::bench::Table;
use rangelsh::data::Dataset;
use rangelsh::eval::exact_topk;
use rangelsh::index::multitable::{range_multitable, simple_multitable};
use rangelsh::index::range::RangeLshParams;
use rangelsh::ItemId;

fn recall_and_probes(
    probe: impl Fn(&[f32], &mut Vec<ItemId>),
    queries: &Dataset,
    gt: &[Vec<ItemId>],
) -> (f64, f64) {
    let (mut hits, mut total_probed) = (0usize, 0usize);
    for qi in 0..queries.len() {
        let mut out = Vec::new();
        probe(queries.row(qi), &mut out);
        total_probed += out.len();
        hits += gt[qi].iter().filter(|id| out.contains(id)).count();
    }
    (
        hits as f64 / (gt.len() * gt[0].len().max(1)) as f64,
        total_probed as f64 / queries.len() as f64,
    )
}

fn main() -> rangelsh::Result<()> {
    let wl = common::yahoo();
    // Short codes (L = 12): the single-probe protocol only ever visits the
    // exact-match bucket, so code length trades precision for non-empty
    // probes; 12 bits keeps buckets populated at this corpus size.
    println!(
        "=== multi-table single-probe on {} ({} items), L=12 ===",
        wl.name,
        wl.items.len()
    );
    let gt = exact_topk(&wl.items, &wl.queries, 10);

    let mut table = Table::new(&[
        "T", "range recall", "range probed", "simple recall", "simple probed",
    ]);
    for t_tables in [1usize, 2, 4, 8, 16, 32] {
        let range = range_multitable(&wl.items, RangeLshParams::new(12, 16), t_tables)?;
        let simple = simple_multitable(&wl.items, 12, t_tables)?;
        let (rr, rp) =
            recall_and_probes(|q, out| range.probe_union(q, out), &wl.queries, &gt);
        let (sr, sp) =
            recall_and_probes(|q, out| simple.probe_union(q, out), &wl.queries, &gt);
        table.row(vec![
            t_tables.to_string(),
            format!("{rr:.3}"),
            format!("{rp:.0}"),
            format!("{sr:.3}"),
            format!("{sp:.0}"),
        ]);
    }
    println!("{}", table.render());
    println!("shape to reproduce: at every T, RANGE recall >= SIMPLE recall");
    Ok(())
}
