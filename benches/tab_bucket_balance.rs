//! §3.1 / §3.2 bucket-balance statistics regenerator.
//!
//! Paper quotes (ImageNet, 32-bit codes): SIMPLE-LSH maps ~2M items into
//! only ~60K buckets with the largest holding ~200K items (~10% of the
//! corpus); RANGE-LSH maps them to ~2M buckets, mostly singletons. The
//! *shape* to reproduce at our scale: SIMPLE's largest bucket holds a
//! double-digit percentage of the corpus, RANGE's largest is tiny, and
//! RANGE's bucket count is within a small factor of n.
//!
//! Run with: `cargo bench --bench tab_bucket_balance`

mod common;

use rangelsh::bench::Table;
use rangelsh::config::IndexAlgo;
use rangelsh::eval::harness::{build_index, CurveSpec};

fn main() -> rangelsh::Result<()> {
    let mut table = Table::new(&[
        "dataset", "algo", "L", "buckets", "largest", "largest/n", "mean occ",
    ]);
    for wl in common::all_workloads() {
        let n = wl.items.len();
        for &(bits, m) in common::FIG2_GRID {
            for (algo, parts) in [(IndexAlgo::SimpleLsh, 1), (IndexAlgo::RangeLsh, m)] {
                let spec = CurveSpec::new(algo, bits, parts);
                let idx = build_index(&wl.items, &spec)?;
                let s = idx.stats();
                table.row(vec![
                    wl.name.to_string(),
                    format!("{algo:?}"),
                    bits.to_string(),
                    s.n_buckets.to_string(),
                    s.largest_bucket.to_string(),
                    format!("{:.2}%", 100.0 * s.largest_bucket as f64 / n as f64),
                    format!("{:.2}", s.mean_occupancy()),
                ]);
            }
        }
    }
    println!("{}", table.render());
    println!(
        "paper (ImageNet 2M, L=32): SIMPLE ~60K buckets, largest ~200K (10%); \
         RANGE ~2M buckets, mostly singletons"
    );
    Ok(())
}
