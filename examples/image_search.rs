//! Image-descriptor search (the paper's ImageNet scenario): long-tailed
//! SIFT-like descriptors where SIMPLE-LSH's global normalisation collapses
//! bucket balance (§3.1) and RANGE-LSH restores it (§3.2).
//!
//! Demonstrates the *mechanism*, not just the end metric: prints the norm
//! distribution, the per-scheme max-inner-product distributions
//! (Fig. 1(b–d)), bucket-balance stats, and the recall comparison.
//!
//! Run with: `cargo run --release --example image_search`

use rangelsh::config::IndexAlgo;
use rangelsh::data::synthetic;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::max_inner_products;
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::index::{partition, PartitionScheme};

fn histogram(values: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    for &v in values {
        let t = ((v - lo) / (hi - lo) * bins as f32) as usize;
        h[t.min(bins - 1)] += 1;
    }
    h
}

fn print_hist(title: &str, h: &[usize], lo: f32, hi: f32) {
    println!("{title}");
    let max = *h.iter().max().unwrap_or(&1);
    for (i, &c) in h.iter().enumerate() {
        let l = lo + (hi - lo) * i as f32 / h.len() as f32;
        let r = lo + (hi - lo) * (i + 1) as f32 / h.len() as f32;
        let bar = "#".repeat((c * 50 / max.max(1)).max(usize::from(c > 0)));
        println!("  [{l:.2},{r:.2})  {c:>7} {bar}");
    }
}

fn main() -> rangelsh::Result<()> {
    // ImageNet-SIFT stand-in, scaled (full corpus 2M; see DESIGN.md §3).
    let items = synthetic::longtail_sift(100_000, 128, 42);
    let queries = synthetic::gaussian_queries(200, 128, 7);
    let u = items.max_norm();

    // Fig 1(b): the long-tailed norm distribution (scaled to max = 1).
    let norms: Vec<f32> = items.norms().iter().map(|&n| n / u).collect();
    print_hist(
        "\nFig 1(b) — 2-norm distribution (max scaled to 1):",
        &histogram(&norms, 0.0, 1.0, 10),
        0.0,
        1.0,
    );

    // Fig 1(c): max inner product after SIMPLE-LSH normalisation (by U).
    let mips = max_inner_products(&items, &queries);
    let qnorms: Vec<f32> = (0..queries.len())
        .map(|i| queries.norm(i))
        .collect();
    let simple_s0: Vec<f32> = mips
        .iter()
        .zip(&qnorms)
        .map(|(&s, &qn)| s / (u * qn))
        .collect();
    print_hist(
        "\nFig 1(c) — max inner product after SIMPLE-LSH normalisation:",
        &histogram(&simple_s0, 0.0, 1.0, 10),
        0.0,
        1.0,
    );

    // Fig 1(d): with RANGE-LSH (32 ranges), each query's best item is
    // normalised by its range's U_j instead of the global U.
    let parts = partition(&items, 32, PartitionScheme::Percentile)?;
    let range_s0: Vec<f32> = (0..queries.len())
        .map(|qi| {
            let q = queries.row(qi);
            let qn = qnorms[qi];
            parts
                .iter()
                .flat_map(|p| {
                    p.ids
                        .iter()
                        .map(|&id| items.dot(id as usize, q) / (p.u_max * qn))
                })
                .fold(f32::MIN, f32::max)
        })
        .collect();
    print_hist(
        "\nFig 1(d) — max inner product after RANGE-LSH normalisation (32 ranges):",
        &histogram(&range_s0, 0.0, 1.0, 10),
        0.0,
        1.0,
    );

    // §3.1 / §3.2 bucket balance + Fig 2-style recall rows at L = 32.
    let gt = ground_truth(&items, &queries, 10);
    let cps = geometric_checkpoints(10, items.len(), 4);
    let mut results = Vec::new();
    for (algo, m, label) in [
        (IndexAlgo::RangeLsh, 64, "range_lsh  L=32 m=64"),
        (IndexAlgo::SimpleLsh, 1, "simple_lsh L=32"),
    ] {
        results.push(run_curve(
            &items,
            &queries,
            &gt,
            &cps,
            &CurveSpec::new(algo, 32, m),
            label,
        )?);
    }
    println!("\n{}", format_probe_table(&results, &[0.5, 0.8, 0.9]));
    println!(
        "bucket balance: RANGE {} buckets (largest {}), SIMPLE {} buckets (largest {})",
        results[0].stats.n_buckets,
        results[0].stats.largest_bucket,
        results[1].stats.n_buckets,
        results[1].stats.largest_bucket,
    );
    Ok(())
}
