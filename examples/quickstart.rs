//! Quickstart: build a NORM-RANGING LSH index over a small long-tailed
//! corpus, run a few top-10 MIPS queries, and compare against exact
//! search and SIMPLE-LSH.
//!
//! Run with: `cargo run --release --example quickstart`

use rangelsh::data::synthetic;
use rangelsh::eval::exact_topk;
use rangelsh::hash::NativeHasher;
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::simple::{SimpleLshIndex, SimpleLshParams};
use rangelsh::index::{MipsIndex, Prober};

fn main() -> rangelsh::Result<()> {
    // 1. A long-tailed corpus (the regime the paper targets) + queries.
    let items = synthetic::longtail_sift(20_000, 64, 42);
    let queries = synthetic::gaussian_queries(5, 64, 7);
    let stats = items.norm_stats();
    println!(
        "corpus: {} items, dim {}, norm median {:.3} / max {:.3} (tail ratio {:.1}x)",
        items.len(),
        items.dim(),
        stats.median,
        stats.max,
        stats.tail_ratio()
    );

    // 2. Build RANGE-LSH (paper Alg. 1): 16-bit code budget, 32 norm
    //    ranges (5 id bits + 11 hash bits).
    let hasher: NativeHasher = NativeHasher::new(items.dim(), 64, 1);
    let range: RangeLshIndex = RangeLshIndex::build(&items, &hasher, RangeLshParams::new(16, 32))?;
    let simple: SimpleLshIndex = SimpleLshIndex::build(&items, &hasher, SimpleLshParams::new(16))?;
    println!(
        "RANGE-LSH : {} buckets, largest {}",
        range.stats().n_buckets,
        range.stats().largest_bucket
    );
    println!(
        "SIMPLE-LSH: {} buckets, largest {}",
        simple.stats().n_buckets,
        simple.stats().largest_bucket
    );

    // 3. Query through a resumable session: probe 500 of 20,000 items
    //    (2.5%) first; if the answer looks weak, ask the *same* session
    //    for 1,500 more — the schedule walk continues where it stopped
    //    instead of rescanning (Alg. 2 is incremental by design).
    let budget = 500;
    let gt = exact_topk(&items, &queries, 10);
    for qi in 0..queries.len() {
        let q = queries.row(qi);
        let mut session = range.prober(q);
        let mut cands = Vec::new();
        session.extend(budget, &mut cands);
        // Re-rank the probed candidates by exact inner product.
        let rerank = |cands: &[u32]| {
            let mut scored: Vec<(f32, u32)> =
                cands.iter().map(|&id| (items.dot(id as usize, q), id)).collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            scored.truncate(10);
            scored
        };
        let mut scored = rerank(&cands);
        let mut probed = budget;
        let mut found = scored.iter().filter(|(_, id)| gt[qi].contains(id)).count();
        if found < 10 {
            // Not satisfied: resume the session for the next 1,500.
            session.extend(1500, &mut cands);
            probed += 1500;
            scored = rerank(&cands);
            found = scored.iter().filter(|(_, id)| gt[qi].contains(id)).count();
        }
        println!(
            "query {qi}: probed {probed}/{} items, recall@10 = {found}/10, top hit ip={:.3} (exact {:.3})",
            items.len(),
            scored[0].0,
            items.dot(gt[qi][0] as usize, q),
        );
    }
    Ok(())
}
