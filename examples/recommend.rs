//! Recommendation scenario (the paper's §1 motivation): MIPS over matrix-
//! factorisation embeddings. Items are ALS-style item vectors, queries are
//! user vectors; top-10 inner products = top-10 recommendations.
//!
//! Compares RANGE-LSH against SIMPLE-LSH and L2-ALSH on a Netflix-scale
//! corpus (17,770 items x 300 dims — the paper's Netflix shape) and
//! reports probes-to-recall.
//!
//! Run with: `cargo run --release --example recommend`

use std::time::Instant;

use rangelsh::config::IndexAlgo;
use rangelsh::data::synthetic;
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;

fn main() -> rangelsh::Result<()> {
    // Netflix-shaped MF embeddings (DESIGN.md §3 substitution).
    let items = synthetic::mf_embeddings(17_770, 300, 32, 42);
    // Users from the same factorisation (shared latent basis).
    let users = synthetic::mf_user_queries(500, 300, 32, 42);
    println!(
        "catalogue: {} items x {}d, {} users, norm tail ratio {:.2}",
        items.len(),
        items.dim(),
        users.len(),
        items.norm_stats().tail_ratio()
    );

    // Exact recommendation baseline (and ground truth for recall).
    let t0 = Instant::now();
    let gt = ground_truth(&items, &users, 10);
    let exact_secs = t0.elapsed().as_secs_f64();
    println!(
        "exact top-10 for {} users: {:.2}s ({:.1} users/s)",
        users.len(),
        exact_secs,
        users.len() as f64 / exact_secs
    );

    // Probe/recall comparison at the paper's Netflix operating point
    // (L = 16 bits, m = 32 ranges).
    let cps = geometric_checkpoints(10, items.len(), 4);
    let mut results = Vec::new();
    for (algo, m, label) in [
        (IndexAlgo::RangeLsh, 32, "range_lsh  L=16 m=32"),
        (IndexAlgo::SimpleLsh, 1, "simple_lsh L=16"),
        (IndexAlgo::L2Alsh, 1, "l2_alsh    K=16"),
    ] {
        let res = run_curve(&items, &users, &gt, &cps, &CurveSpec::new(algo, 16, m), label)?;
        results.push(res);
    }
    println!("\n{}", format_probe_table(&results, &[0.5, 0.8, 0.9]));

    // Headline: fraction of the catalogue probed at recall 0.9.
    for r in &results {
        if let Some(probes) = r.curve.probes_to_reach(0.9) {
            let frac = probes as f64 / items.len() as f64;
            println!(
                "{}: reaches 90% recall probing {:.1}% of the catalogue",
                r.label,
                frac * 100.0
            );
        } else {
            println!("{}: never reaches 90% recall", r.label);
        }
    }
    Ok(())
}
