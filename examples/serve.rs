//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//! 1. Build a RANGE-LSH index over an ImageNet-scale corpus
//!    (200K x 128-d, long-tailed norms), bulk-hashing the items through
//!    the **AOT-compiled Pallas sign-hash kernel via PJRT** when
//!    `artifacts/` exists (falls back to the native path otherwise —
//!    codes are bit-identical either way).
//! 2. Serve 10,000 batched top-10 queries through the coordinator:
//!    concurrent clients → dynamic batcher (flush on size/deadline) →
//!    PJRT-batched query hashing → Eq. 12 probe schedule → exact re-rank.
//! 3. Report recall@10 vs exact ground truth, throughput, and latency
//!    percentiles.
//!
//! Run with: `cargo run --release --example serve [-- --native]`

use std::sync::Arc;
use std::time::Duration;

use rangelsh::config::ServeConfig;
use rangelsh::coordinator::server::drive_workload;
use rangelsh::coordinator::{BatchPolicy, QueryParams, SearchEngine};
use rangelsh::data::synthetic;
use rangelsh::eval::exact_topk;
use rangelsh::hash::{ItemHasher, NativeHasher, Projection};
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::MipsIndex;
use rangelsh::runtime::{PjrtHasher, RuntimeHandle, DEFAULT_ARTIFACT_DIR};

fn main() -> rangelsh::Result<()> {
    let native_only = std::env::args().any(|a| a == "--native");
    let (n_items, dim, n_queries) = (200_000usize, 128usize, 10_000usize);

    println!("=== E2E: RANGE-LSH serving on imagenet-sim ({n_items} x {dim}d) ===");
    let items = Arc::new(synthetic::longtail_sift(n_items, dim, 42));
    let queries = synthetic::gaussian_queries(n_queries, dim, 7);
    println!("norm tail ratio: {:.2}", items.norm_stats().tail_ratio());

    // Hashing path: AOT Pallas kernel via PJRT if artifacts exist.
    let proj = Arc::new(Projection::gaussian(dim + 1, 64, 1));
    let artifacts = std::path::Path::new(DEFAULT_ARTIFACT_DIR);
    let hasher: Arc<dyn ItemHasher> = if !native_only && artifacts.join("manifest.json").exists() {
        match RuntimeHandle::load(artifacts).and_then(|rt| PjrtHasher::<u64>::new(rt, proj.clone()))
        {
            Ok(h) => {
                println!("hashing: PJRT (AOT Pallas sign-hash kernel)");
                Arc::new(h)
            }
            Err(e) => {
                println!("hashing: native (PJRT unavailable: {e:#})");
                Arc::new(NativeHasher::with_projection(proj.clone()))
            }
        }
    } else {
        println!("hashing: native");
        Arc::new(NativeHasher::with_projection(proj.clone()))
    };

    // Build the paper's index: 32-bit budget, 64 ranges.
    let t0 = std::time::Instant::now();
    let index = Arc::new(RangeLshIndex::build(
        &items,
        hasher.as_ref(),
        RangeLshParams::new(32, 64),
    )?);
    let build_secs = t0.elapsed().as_secs_f64();
    let stats = index.stats();
    println!(
        "index: built in {build_secs:.2}s — {} buckets over {} ranges, largest bucket {}",
        stats.n_buckets, stats.n_partitions, stats.largest_bucket
    );

    // Serving engine + batched workload.
    let cfg = ServeConfig {
        max_batch: 256,
        deadline_us: 500,
        probe_budget: 4096, // ~2% of the corpus
        top_k: 10,
        // Fused streaming re-rank (the default, spelled out here):
        // Cauchy–Schwarz pruning + schedule early-out, bit-identical
        // answers to the exhaustive oracle — README §"Re-rank cost model".
        rerank: rangelsh::config::RerankMode::Streaming,
        code_bits: 32,
        // No per-query time budget: this driver measures steady-state
        // throughput, so nothing is degraded or shed.
        time_budget_us: 0,
    };
    let engine = Arc::new(SearchEngine::new(index, items.clone(), hasher, cfg)?);
    let policy = BatchPolicy::new(256, Duration::from_micros(500));
    let (results, wall) = drive_workload(engine.clone(), policy, &queries, 32)?;
    let snap = engine.metrics().snapshot();
    println!(
        "served {} queries in {:.2}s — {:.0} qps | p50 {}us p95 {}us p99 {}us | \
         mean probed {:.0} items ({:.2}% of corpus), mean batch {:.1}",
        results.len(),
        wall.as_secs_f64(),
        results.len() as f64 / wall.as_secs_f64(),
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_probed,
        100.0 * snap.mean_probed / n_items as f64,
        snap.mean_batch_rows,
    );

    // Recall vs exact ground truth on a sample (exact GT on all 10K
    // queries x 200K items is the dominant cost, so sample 1,000).
    let sample = 1000.min(n_queries);
    let sample_queries = rangelsh::data::Dataset::from_flat(
        dim,
        queries.flat()[..sample * dim].to_vec(),
    );
    let gt = exact_topk(&items, &sample_queries, 10);
    let mut hits = 0usize;
    for (qi, gt_ids) in gt.iter().enumerate() {
        let got: Vec<u32> = results[qi].iter().map(|r| r.id).collect();
        hits += got.iter().filter(|id| gt_ids.contains(id)).count();
    }
    let recall = hits as f64 / (sample * 10) as f64;
    println!("recall@10 (n={sample} sampled queries): {recall:.4}");

    // Per-request overrides: the same engine serves a high-recall request
    // (exhaustive budget) and a latency-bound one (early-stop at 512
    // candidates) side by side, no rebuild, no second ServeConfig.
    let heavy = QueryParams::new().with_probe_budget(usize::MAX).with_top_k(10);
    let light = QueryParams::new().with_min_candidates(512).with_extend_step(256);
    let q0 = queries.row(0);
    let exact = engine.search_with(q0, &heavy)?;
    let fast = engine.search_with(q0, &light)?;
    println!(
        "per-request params: exhaustive top hit ip={:.3}, early-stop top hit ip={:.3}",
        exact[0].score, fast[0].score
    );
    println!("=== E2E complete ===");
    Ok(())
}
