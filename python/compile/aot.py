"""AOT pipeline: lower every (entry, d) variant to HLO text + manifest.

Interchange format is HLO **text**, not ``HloModuleProto.serialize()``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True`` — the Rust side unwraps with ``to_tuple1()``.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts`` target). Python runs ONCE at build time; the Rust
binary is self-contained afterwards.

Every artifact is self-checked after lowering: the lowered computation is
also executed through jax.jit and compared against the pure-jnp oracle in
``kernels/ref.py`` on random inputs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Dataset dimensionalities compiled by default: 300 (Netflix/Yahoo-style MF
# embeddings) and 128 (SIFT-style descriptors). Extend with --dims.
DEFAULT_DIMS = (300, 128)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def variants(dims, width=model.PROJ_WIDTH):
    """Yield (name, fn, arg_specs) for every artifact to compile.

    ``width`` is the projection-panel width (hash functions per item):
    64 for the paper's original regime, 128/256 for the wide-code
    serving widths. Each artifact directory is compiled at exactly one
    width; the kernel packs ``width / 32`` u32 words per item.
    """
    f32, u32 = jnp.float32, jnp.uint32
    for d in dims:
        yield (
            f"hash_items_d{d}",
            model.hash_items,
            [
                _spec((model.ITEM_BLOCK, d), f32),
                _spec((), f32),
                _spec((d + 1, width), f32),
            ],
        )
        yield (
            f"hash_queries_d{d}",
            model.hash_queries,
            [
                _spec((model.ITEM_BLOCK, d), f32),
                _spec((d + 1, width), f32),
            ],
        )
        # Small-batch query variant: serving batches are usually <= 256
        # queries; hashing them through the 2048-row block wastes 8x the
        # kernel work on padding (see EXPERIMENTS.md §Perf).
        yield (
            f"hash_queries_small_d{d}",
            model.hash_queries,
            [
                _spec((model.QUERY_BLOCK, d), f32),
                _spec((d + 1, width), f32),
            ],
        )
        yield (
            f"score_d{d}",
            model.score,
            [
                _spec((model.QUERY_BLOCK, d), f32),
                _spec((model.ITEM_BLOCK, d), f32),
            ],
        )


def _self_check(name: str, fn, specs) -> None:
    """Execute the jitted entry on random inputs and compare to the oracle."""
    rng = np.random.default_rng(0)
    args = [
        jnp.asarray(rng.standard_normal(s.shape, dtype=np.float32))
        if s.shape
        else jnp.float32(2.5)
        for s in specs
    ]
    out = jax.jit(fn)(*args)[0]
    if name.startswith("hash_items"):
        want = ref.sign_hash_ref(ref.simple_transform_ref(args[0], args[1]), args[2])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    elif name.startswith("hash_queries"):  # covers the _small variant too
        want = ref.sign_hash_ref(ref.query_transform_ref(args[0]), args[1])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    elif name.startswith("score"):
        want = ref.score_ref(args[0], args[1])
        # Accumulation order differs between the Pallas kernel and the
        # oracle matmul; tolerance covers f32 reassociation only.
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)
    else:  # pragma: no cover - defensive
        raise ValueError(f"no oracle for {name}")


def build(
    out_dir: str, dims, width: int = model.PROJ_WIDTH, self_check: bool = True
) -> dict:
    """Lower all variants into ``out_dir``; return the manifest dict.

    ``width`` selects the panel width (and therefore the code width) the
    whole directory is compiled at; the manifest records it as
    ``proj_width`` plus the derived ``code_words`` (u64 words per code,
    1/2/4) the Rust runtime keys its `CodeWord` dispatch off.
    """
    if width not in model.SUPPORTED_WIDTHS:
        raise ValueError(f"width {width} not in {model.SUPPORTED_WIDTHS}")
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "item_block": model.ITEM_BLOCK,
        "query_block": model.QUERY_BLOCK,
        "proj_width": width,
        "code_words": width // 64,
        "entries": [],
    }
    for name, fn, specs in variants(dims, width):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        if self_check:
            _self_check(name, fn, specs)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                ],
            }
        )
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--dims",
        default=",".join(str(d) for d in DEFAULT_DIMS),
        help="comma-separated dataset dimensionalities to compile",
    )
    ap.add_argument(
        "--width",
        type=int,
        default=model.PROJ_WIDTH,
        choices=model.SUPPORTED_WIDTHS,
        help="panel width (hash functions per item); one width per artifact dir",
    )
    ap.add_argument("--no-self-check", action="store_true")
    args = ap.parse_args()
    dims = [int(d) for d in args.dims.split(",") if d]
    manifest = build(
        args.out_dir, dims, width=args.width, self_check=not args.no_self_check
    )
    print(
        f"wrote {len(manifest['entries'])} artifacts to {args.out_dir} "
        f"(width {args.width}, {manifest['code_words']} code words)"
    )


if __name__ == "__main__":
    main()
