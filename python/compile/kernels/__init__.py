"""Layer-1 Pallas kernels for RANGE-LSH.

Two kernels cover the paper's compute hot spots:

- ``sign_hash``: fused ``[B, D] @ [D, L]`` matmul (MXU work) + sign +
  integer bitpack — produces the binary hash codes used by every LSH
  index in the paper (SIMPLE-LSH / RANGE-LSH share it; the projection
  matrix is an argument).
- ``score``: blocked exact inner-product matmul ``[Q, D] @ [D, N]`` —
  ground-truth generation and candidate re-ranking.

Both are lowered with ``interpret=True`` (mandatory on the CPU PJRT
image; real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot execute) and verified against the pure-jnp oracles in
``ref.py`` by the pytest suite.
"""

from .sign_hash import sign_hash, PACK_LANES
from .score import score
from . import ref

__all__ = ["sign_hash", "score", "ref", "PACK_LANES"]
