"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite (and the AOT self-check in
``aot.py``) compares against. They deliberately avoid Pallas so a bug in
the kernel plumbing cannot hide in both implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .sign_hash import PACK_LANES


def sign_hash_ref(xt: jax.Array, proj: jax.Array) -> jax.Array:
    """Oracle for :func:`kernels.sign_hash.sign_hash`.

    Same strictly-positive sign convention and little-endian bit packing
    (bit ``i`` of word ``w`` is hash function ``32*w + i``).
    """
    h = xt.astype(jnp.float32) @ proj.astype(jnp.float32)
    b, width = h.shape
    assert width % PACK_LANES == 0
    bits = (h > 0.0).reshape(b, width // PACK_LANES, PACK_LANES)
    lanes = jnp.arange(PACK_LANES, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << lanes, axis=-1, dtype=jnp.uint32)


def score_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for :func:`kernels.score.score`: exact ``q @ x^T``."""
    return q.astype(jnp.float32) @ x.astype(jnp.float32).T


def simple_transform_ref(x: jax.Array, u: jax.Array) -> jax.Array:
    """SIMPLE-LSH item transform (paper Eq. 8): ``P(x) = [x/U; sqrt(1-||x/U||^2)]``."""
    y = x / u
    tail = jnp.sqrt(jnp.maximum(0.0, 1.0 - jnp.sum(y * y, axis=-1, keepdims=True)))
    return jnp.concatenate([y, tail], axis=-1)


def query_transform_ref(q: jax.Array) -> jax.Array:
    """SIMPLE-LSH query transform (paper Eq. 8): ``P(q) = [q/||q||; 0]``."""
    norm = jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-30)
    y = q / norm
    return jnp.concatenate([y, jnp.zeros_like(y[..., :1])], axis=-1)
