"""Blocked exact inner-product scoring kernel (Pallas, Layer 1).

Computes ``scores = q @ x^T`` for a query block ``q [Q, D]`` against an
item block ``x [N, D]``. Used for ground-truth generation (the paper's
recall metric needs the true top-k) and candidate re-ranking in the
serving engine.

The grid tiles the item axis: each step keeps the full query block plus
one ``[BLOCK_N, D]`` item tile in VMEM and contracts over ``D`` on the
MXU. For the paper's dims (D <= 301) a [256, 301] query block is 308 KB
and a [512, 301] item tile is 617 KB — the whole working set fits VMEM
without K-axis splitting, so no accumulator carry is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _score_kernel(q_ref, x_ref, out_ref):
    """One grid step: score all queries against one item tile."""
    out_ref[...] = jax.lax.dot_general(
        q_ref[...],
        x_ref[...],
        # contract q's dim-1 with x's dim-1 (x is [N, D], not transposed).
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def score(q: jax.Array, x: jax.Array, *, block_n: int | None = None) -> jax.Array:
    """Exact scores ``[Q, N] = q [Q, D] @ x [N, D]^T`` (f32)."""
    qn, d = q.shape
    n, d2 = x.shape
    if d != d2:
        raise ValueError(f"dim mismatch: q has D={d}, x has D={d2}")
    if block_n is None:
        block_n = min(n, DEFAULT_BLOCK_N)
    if n % block_n != 0:
        raise ValueError(f"N={n} not divisible by block_n={block_n}")

    return pl.pallas_call(
        _score_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((qn, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((qn, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        interpret=True,
    )(q, x)
