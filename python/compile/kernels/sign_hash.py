"""Fused sign-random-projection hash kernel (Pallas, Layer 1).

Computes, for a block of (already transformed) vectors ``xt`` of shape
``[B, D]`` and a Gaussian projection panel ``proj`` of shape ``[D, L]``::

    codes[b, w] = sum_{i<32} (xt[b] . proj[:, 32w+i] > 0) << i

i.e. the L sign bits of ``xt @ proj`` packed little-endian (bit ``i`` of
word ``w`` is hash function ``32*w + i``) into ``uint32`` words. The Rust
coordinator masks the packed words down to the effective code length
(RANGE-LSH spends ``log2(m)`` bits of its budget on the range id, so it
uses fewer hash bits than SIMPLE-LSH at equal total code length).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the item
axis; each step keeps one ``[BLOCK_B, D]`` tile plus the full ``[D, L]``
panel resident in VMEM, runs the matmul on the MXU with an f32
accumulator, and packs bits in-register before the HBM write — a 32x
reduction in write traffic versus emitting raw signs. ``interpret=True``
is required for CPU-PJRT execution; the BlockSpec structure is what a
real-TPU build would compile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bits packed per output word. Fixed at 32 (uint32 words). The word
# count scales with the code length: L = 64 packs 2 words, the wide
# serving widths pack 4 (L = 128) and 8 (L = 256) words per item.
PACK_LANES = 32

# Largest panel width a single kernel call will hash (matches the Rust
# side's MAX_CODE_BITS: four u64 = eight u32 words per item).
MAX_WIDTH = 256

# Default item-tile height at L <= 64. 512 rows x (300+1) dims x 4 B =
# 623 KB in VMEM alongside the 304x64x4 = 78 KB projection panel —
# comfortable within a ~16 MB VMEM budget with room for double buffering.
DEFAULT_BLOCK_B = 512


def default_block_b(width: int) -> int:
    """Default tile height for a panel of ``width`` hash functions.

    Halved per doubling of the panel width past 64 so the ``[B, D]``
    tile, the ``[D, L]`` panel, and the ``[B, L]`` matmul accumulator
    stay inside the same VMEM envelope at the wide code widths:
    512 rows at L <= 64, 256 at L = 128, 128 at L = 256. Every value
    divides the 2048-row AOT item block.
    """
    return DEFAULT_BLOCK_B // max(width // 64, 1)


def _pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a ``[..., W, PACK_LANES]`` boolean array into uint32 words."""
    lanes = jnp.arange(PACK_LANES, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << lanes, axis=-1, dtype=jnp.uint32)


def _sign_hash_kernel(xt_ref, proj_ref, out_ref):
    """One grid step: hash a ``[BLOCK_B, D]`` tile of transformed vectors."""
    # MXU matmul, f32 accumulate.
    h = jnp.dot(
        xt_ref[...], proj_ref[...], preferred_element_type=jnp.float32
    )
    block_b, width = h.shape
    # Strictly-positive convention: sign(0) packs as 0. The oracle in
    # ref.py and the Rust native path use the same convention.
    bits = (h > 0.0).reshape(block_b, width // PACK_LANES, PACK_LANES)
    out_ref[...] = _pack_bits(bits)


@functools.partial(jax.jit, static_argnames=("block_b",))
def sign_hash(xt: jax.Array, proj: jax.Array, *, block_b: int | None = None) -> jax.Array:
    """Hash ``xt [B, D]`` against ``proj [D, L]`` → packed codes ``[B, L//32]`` (uint32).

    ``B`` must be divisible by the tile height and ``L`` by ``PACK_LANES``;
    the AOT entry points use fixed padded shapes so this always holds on
    the request path.
    """
    b, d = xt.shape
    d2, width = proj.shape
    if d != d2:
        raise ValueError(f"dim mismatch: xt has D={d}, proj has D={d2}")
    if width % PACK_LANES != 0:
        raise ValueError(f"L={width} must be a multiple of {PACK_LANES}")
    if width > MAX_WIDTH:
        raise ValueError(f"L={width} exceeds the {MAX_WIDTH}-bit code ceiling")
    if block_b is None:
        block_b = min(b, default_block_b(width))
    if b % block_b != 0:
        raise ValueError(f"B={b} not divisible by block_b={block_b}")
    words = width // PACK_LANES

    return pl.pallas_call(
        _sign_hash_kernel,
        grid=(b // block_b,),
        in_specs=[
            # Item tile: march down the batch axis.
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            # Projection panel: resident across all grid steps.
            pl.BlockSpec((d, width), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, words), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, words), jnp.uint32),
        interpret=True,
    )(xt, proj)
