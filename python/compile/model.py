"""Layer-2 JAX compute graphs for RANGE-LSH (build-time only).

Three entry points, each calling the Layer-1 Pallas kernels, each lowered
AOT to HLO text by ``aot.py`` and executed from the Rust coordinator via
PJRT. Python never runs on the request path.

Entry points (shapes fixed per dataset dimensionality ``d``):

- ``hash_items(x [B, d], u [], proj [d+1, L])`` → ``uint32 [B, L/32]``
  SIMPLE-LSH item pipeline: normalise by the (sub-)dataset max norm ``u``
  (RANGE-LSH passes the *local* ``U_j`` — that is the paper's whole
  point), apply the Eq. 8 transform ``[x/u; sqrt(1-||x/u||^2)]``, hash.
- ``hash_queries(q [B, d], proj [d+1, L])`` → ``uint32 [B, L/32]``
  Query pipeline: unit-normalise, append 0, hash. Shared by all ranges
  (the query transform does not depend on ``U_j``).
- ``score(q [Q, d], x [N, d])`` → ``f32 [Q, N]``
  Exact inner products for ground truth / re-ranking.

The Rust runtime pads the final partial block with zeros and discards the
corresponding outputs; zero rows are harmless here (they hash to the sign
pattern of ``proj``'s tail row and are never read back).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import sign_hash, score as score_kernel

# Fixed AOT geometry, shared with the Rust runtime via artifacts/manifest.json.
ITEM_BLOCK = 2048   # rows per hash_items / score item block
QUERY_BLOCK = 256   # rows per score query block
PROJ_WIDTH = 64     # default hash functions per artifact; Rust masks to L_eff

# Panel widths the AOT pipeline will compile (``aot.py --width``). One
# artifact directory holds exactly one width; the manifest's
# ``code_words`` field (width / 64 u64 words) tells the Rust side which
# CodeWord monomorphization the packed u32 outputs feed.
SUPPORTED_WIDTHS = (64, 128, 256)


def simple_transform(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 item transform with normalisation folded in.

    ``u`` is a rank-0 scalar: the global max norm for SIMPLE-LSH, the
    local range max ``U_j`` for RANGE-LSH. Items with ``||x|| <= u`` map
    onto the unit sphere in d+1 dims; the ``max(0, .)`` guards float
    round-off for items with ``||x|| == u`` exactly.
    """
    y = x / u
    tail = jnp.sqrt(jnp.maximum(0.0, 1.0 - jnp.sum(y * y, axis=-1, keepdims=True)))
    return jnp.concatenate([y, tail], axis=-1)


def query_transform(q: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 query transform: unit-normalise, append a zero coordinate.

    The epsilon floor guards all-zero (padding) rows; their codes are
    discarded by the runtime.
    """
    norm = jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-30)
    y = q / norm
    return jnp.concatenate([y, jnp.zeros_like(y[..., :1])], axis=-1)


def hash_items(x: jnp.ndarray, u: jnp.ndarray, proj: jnp.ndarray):
    """AOT entry: transform + sign-RP hash one item block. Returns a 1-tuple."""
    return (sign_hash(simple_transform(x, u), proj),)


def hash_queries(q: jnp.ndarray, proj: jnp.ndarray):
    """AOT entry: transform + sign-RP hash one query block. Returns a 1-tuple."""
    return (sign_hash(query_transform(q), proj),)


def score(q: jnp.ndarray, x: jnp.ndarray):
    """AOT entry: exact inner products for one (query, item) block pair."""
    return (score_kernel(q, x),)
