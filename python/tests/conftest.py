"""Make the suite runnable from any cwd.

Puts `python/` (the `compile` package) and `scripts/` (the `staticcheck`
package) on sys.path so `python3 -m pytest python/tests` works from the
repo root as well as from `python/`.
"""

import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]
for p in (REPO_ROOT / "python", REPO_ROOT / "scripts"):
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))
