impl Metrics {
    pub fn snapshot(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }
}
