pub fn forward(&self) {
    let _a = self.alpha.lock().unwrap();
    let _b = self.beta.lock().unwrap();
}

pub fn backward(&self) {
    let _b = self.beta.lock().unwrap();
    let _a = self.alpha.lock().unwrap();
}
