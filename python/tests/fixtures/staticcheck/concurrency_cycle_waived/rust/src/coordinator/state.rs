impl State {
    pub fn forward(&self) {
        let _a = self.alpha.read().unwrap();
        let _b = self.beta.write().unwrap(); // staticcheck: allow(concurrency, "beta is dropped before alpha is ever re-taken; the pair is proven disjoint")
    }

    pub fn backward(&self) {
        let _b = self.beta.read().unwrap();
        let _a = self.alpha.write().unwrap();
    }
}
