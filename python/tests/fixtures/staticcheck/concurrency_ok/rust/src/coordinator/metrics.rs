impl Metrics {
    pub fn record(&self) {
        self.queries.fetch_add(1, Ordering::Release);
    }

    pub fn snapshot(&self) -> u64 {
        self.queries.load(Ordering::Acquire)
    }
}
