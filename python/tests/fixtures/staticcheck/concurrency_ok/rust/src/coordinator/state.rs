pub fn forward(&self) {
    let _a = self.alpha.lock().unwrap();
    let _b = self.beta.lock().unwrap();
}

pub fn also_forward(&self) {
    let _a = self.alpha.lock().unwrap();
    let _b = self.beta.lock().unwrap();
}
