impl State {
    pub fn forward(&self) {
        let _a = self.alpha.read().unwrap();
        let _b = self.beta.write().unwrap();
    }

    pub fn backward(&self) {
        let _b = self.beta.read().unwrap();
        let _a = self.alpha.write().unwrap();
    }
}
