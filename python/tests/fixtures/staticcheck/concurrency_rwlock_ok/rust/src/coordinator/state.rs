use std::io::{Read, Write};

impl State {
    pub fn forward(&self) {
        let _a = self.alpha.read().unwrap();
        let _b = self.beta.write().unwrap();
    }

    pub fn also_forward(&self) {
        let _a = self.alpha.write().unwrap();
        let _b = self.beta.read().unwrap();
    }

    pub fn io_copy(&mut self, buf: &mut [u8]) {
        let n = self.src.read(buf).unwrap();
        self.dst.write(&buf[..n]).unwrap();
    }
}
