pub fn parse(doc: &TomlDoc) -> Config {
    let sv = Section::of(doc, "serve");
    Config { max_batch: sv.usize_or("max_batch", 256) }
}
