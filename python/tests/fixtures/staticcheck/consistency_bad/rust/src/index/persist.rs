const MAGIC_V1: &[u8; 8] = b"RLSHIDX\x01";
const MAGIC_V9: &[u8; 8] = b"RLSHIDX\x09";

fn load(r: &mut Reader) {
    r.verify_section_crc("header");
    r.verify_section_crc("phantom-section");
}
