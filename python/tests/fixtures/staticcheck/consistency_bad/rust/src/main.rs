fn main() {
    let args = Args::parse(rest, &["verbose"]);
    let _cfg = args.req("config");
    let _secret = args.opt("secret-flag");
}
