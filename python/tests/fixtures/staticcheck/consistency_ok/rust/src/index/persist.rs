const MAGIC_V1: &[u8; 8] = b"RLSHIDX\x01";

fn load(r: &mut Reader) {
    r.verify_section_crc("header");
}
