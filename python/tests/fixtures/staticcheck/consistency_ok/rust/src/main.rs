fn main() {
    let args = Args::parse(rest, &["verbose"]);
    let _cfg = args.req("config");
}
