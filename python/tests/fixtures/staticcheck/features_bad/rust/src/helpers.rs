#[cfg(test)]
pub struct TestOnly;
