pub mod helpers;

use crate::helpers::TestOnly;

#[cfg(feature = "typo-feature")]
pub fn gated() {}

#[cfg(feature = "real-feature")]
pub fn fine() {}

pub fn touch(_t: TestOnly) {}
