pub mod helpers;

#[cfg(feature = "real-feature")]
pub fn gated() {}

#[cfg(any(test, feature = "fault-injection"))]
pub fn chaos_hook() {}

#[cfg(test)]
mod tests {
    use crate::helpers::TestOnly;

    fn touch(_t: TestOnly) {}
}
