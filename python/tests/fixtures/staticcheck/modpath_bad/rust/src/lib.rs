pub mod real;
pub mod missing;

use crate::real::Widget;
use crate::real::no_such_item;
use crate::ghost::Anything;

pub fn touch(_w: Widget) {}
