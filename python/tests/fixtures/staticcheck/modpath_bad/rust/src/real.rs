pub struct Widget;
