pub struct Widget;

pub enum Kind {
    Fast,
    Slow,
}
