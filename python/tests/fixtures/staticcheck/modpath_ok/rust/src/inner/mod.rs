pub mod leaf;
pub use leaf::Widget;
