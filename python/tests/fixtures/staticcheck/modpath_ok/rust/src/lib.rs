pub mod inner;
pub use inner::leaf::Widget;

use crate::inner::leaf::{Widget as W, Kind};
use crate::inner::leaf::Kind::Fast;

pub fn touch(_w: W, _k: Kind) {
    let _ = Fast;
}
