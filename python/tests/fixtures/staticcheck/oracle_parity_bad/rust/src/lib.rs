pub mod table;
