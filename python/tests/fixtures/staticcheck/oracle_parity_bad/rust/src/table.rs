pub struct Table {
    rows: Vec<u32>,
}

impl Table {
    pub fn new() -> Self {
        Table { rows: Vec::new() }
    }

    pub fn probe_fast(&self, q: usize) -> u32 {
        (q as u32).wrapping_mul(3)
    }

    pub fn probe_eager(&self, q: usize) -> u32 {
        let mut acc = 0u32;
        for _ in 0..3 {
            acc = acc.wrapping_add(q as u32);
        }
        acc
    }
}

pub fn scan_oracle(rows: &[u32]) -> u32 {
    rows.iter().copied().fold(0, u32::wrapping_add)
}
