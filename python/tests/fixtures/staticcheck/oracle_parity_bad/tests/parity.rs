use rangelsh::table::Table;

#[test]
fn prop_fast_equals_eager() {
    let t = Table::new();
    assert_eq!(t.probe_fast(3), 9);
}
