pub mod table;
