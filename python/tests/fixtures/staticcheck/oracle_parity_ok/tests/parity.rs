use rangelsh::table::Table;

#[test]
fn prop_fast_equals_eager() {
    let t = Table::new();
    for q in 0..16 {
        assert_eq!(t.probe_fast(q), t.probe_eager(q));
    }
}
