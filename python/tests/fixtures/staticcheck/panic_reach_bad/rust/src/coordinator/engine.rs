use crate::index::table::Table;

pub struct SearchEngine {
    table: Table,
}

impl SearchEngine {
    pub fn search_streaming(&self, q: usize) -> u32 {
        self.table.lookup(q)
    }
}
