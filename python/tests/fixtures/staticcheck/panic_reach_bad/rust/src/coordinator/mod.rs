pub mod engine;
