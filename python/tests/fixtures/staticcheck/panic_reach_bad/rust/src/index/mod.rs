pub mod table;
