pub struct Table {
    rows: Vec<u32>,
}

impl Table {
    pub fn lookup(&self, q: usize) -> u32 {
        self.rows[q]
    }

    pub fn dead_end(&self) -> u32 {
        self.rows[0]
    }
}
