pub mod engine;
