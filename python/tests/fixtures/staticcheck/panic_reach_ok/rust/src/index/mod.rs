pub mod table;
