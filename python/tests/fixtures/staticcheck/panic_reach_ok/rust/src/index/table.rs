pub struct Table {
    rows: Vec<u32>,
}

impl Table {
    // staticcheck: allow(panic-reach, "q is produced by the probe schedule and stays below rows.len()")
    pub fn lookup(&self, q: usize) -> u32 {
        self.rows[q]
    }

    pub fn safe_lookup(&self, q: usize) -> u32 {
        self.rows.get(q).copied().unwrap_or(0)
    }
}
