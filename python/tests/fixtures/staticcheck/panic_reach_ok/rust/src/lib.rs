pub mod coordinator;
pub mod index;
