pub mod engine;
