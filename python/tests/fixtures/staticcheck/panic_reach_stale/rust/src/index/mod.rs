pub mod table;
