pub struct Table {
    rows: Vec<u32>,
}

impl Table {
    // staticcheck: allow(panic-reach, "bounds were checked in an earlier revision")
    pub fn lookup(&self, q: usize) -> u32 {
        self.rows.get(q).copied().unwrap_or(0)
    }
}
