pub fn serve(xs: &[u32], i: usize) -> u32 {
    let first = xs.first().unwrap();
    let second = xs.get(1).expect("needs two");
    if i > xs.len() {
        panic!("out of range");
    }
    first + second + xs[i]
}

// staticcheck: allow(panic, "")
pub fn empty_reason(xs: &[u32]) -> u32 {
    xs[0]
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
