pub fn serve(xs: &[u32], i: usize) -> Option<u32> {
    let first = xs.first()?;
    // staticcheck: allow(panic, "i is clamped to xs.len() - 1 above")
    let picked = xs[i.min(xs.len().checked_sub(1)?)];
    Some(first + picked)
}

pub fn slice_pattern(xs: &[u32]) -> u32 {
    // a slice pattern is not an index expression
    if let [a, b] = xs {
        return a + b;
    }
    0
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
