impl Engine {
    pub fn run(&self) -> u32 {
        // staticcheck: allow(panic, "the index this covered was removed but the waiver lingers")
        self.count
    }
}
