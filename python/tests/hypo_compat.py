"""``hypothesis``, or a seeded-random fallback when it is not installed.

The CI image has hypothesis and gets the real shrinking property runner.
Offline images (no network, no pip) still need the kernel-vs-oracle
signal, so this shim replays each ``@given`` test over a fixed number of
deterministic draws from the declared strategies — no shrinking, but the
same search space and a reproducible failure message naming the draw.

Usage (drop-in)::

    from hypo_compat import given, settings, strategies as st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # offline image: deterministic fallback sweep
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            choices = list(elements)
            return _Strategy(lambda rng: rng.choice(choices))

    def given(**params):
        def deco(fn):
            def wrapper():
                for case in range(_FALLBACK_EXAMPLES):
                    rng = random.Random(0xC0FFEE + case)
                    kwargs = {k: s.draw(rng) for k, s in params.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:  # re-raise naming the draw
                        raise AssertionError(
                            f"fallback case {case} failed with draw {kwargs}: {e}"
                        ) from e

            # No functools.wraps: copying __wrapped__ would make pytest
            # introspect the original signature and demand fixtures for
            # the strategy parameters. Name and doc are enough.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    class settings:  # noqa: N801 - API-compatible no-op
        def __init__(self, *args, **kwargs):
            pass

        @staticmethod
        def register_profile(name, **kwargs):
            pass

        @staticmethod
        def load_profile(name):
            pass
