"""AOT pipeline: manifest round-trip and HLO text sanity."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Small dim keeps the test fast; the default dims are exercised by
    # `make artifacts` + the Rust integration tests.
    manifest = aot.build(str(out), dims=[16], self_check=True)
    return str(out), manifest


def test_manifest_lists_all_entries(built):
    out, manifest = built
    names = {e["name"] for e in manifest["entries"]}
    assert names == {
        "hash_items_d16",
        "hash_queries_d16",
        "hash_queries_small_d16",
        "score_d16",
    }
    assert manifest["format"] == "hlo-text"
    assert manifest["item_block"] == model.ITEM_BLOCK
    assert manifest["query_block"] == model.QUERY_BLOCK
    assert manifest["proj_width"] == model.PROJ_WIDTH
    assert manifest["code_words"] == 1


def test_manifest_json_round_trips(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_hlo_files_exist_and_are_text(built):
    out, manifest = built
    for entry in manifest["entries"]:
        path = os.path.join(out, entry["file"])
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), entry["name"]
        assert "ENTRY" in text
        # The whole point of the text interchange: no serialized protos.
        assert "\x00" not in text


def test_manifest_shapes_match_model_geometry(built):
    out, manifest = built
    by_name = {e["name"]: e for e in manifest["entries"]}
    hi = by_name["hash_items_d16"]["inputs"]
    assert hi[0]["shape"] == [model.ITEM_BLOCK, 16]
    assert hi[1]["shape"] == []           # scalar U_j
    assert hi[2]["shape"] == [17, model.PROJ_WIDTH]
    sc = by_name["score_d16"]["inputs"]
    assert sc[0]["shape"] == [model.QUERY_BLOCK, 16]
    assert sc[1]["shape"] == [model.ITEM_BLOCK, 16]


def test_hlo_entry_layout_mentions_u32_output(built):
    out, manifest = built
    path = os.path.join(out, "hash_items_d16.hlo.txt")
    with open(path) as f:
        head = f.readline()
    # xla_extension 0.5.1 parses this header; codes must be u32-packed.
    assert "u32[2048,2]" in head


def test_wide_width_build_emits_code_words(tmp_path):
    # The multi-word backend: a width-128 artifact dir carries
    # code_words = 2 and 4-u32-word hash outputs, self-checked against
    # the oracle during the build.
    out = str(tmp_path / "wide")
    manifest = aot.build(out, dims=[8], width=128, self_check=True)
    assert manifest["proj_width"] == 128
    assert manifest["code_words"] == 2
    hi = {e["name"]: e for e in manifest["entries"]}["hash_items_d8"]["inputs"]
    assert hi[2]["shape"] == [9, 128]
    with open(os.path.join(out, "hash_items_d8.hlo.txt")) as f:
        head = f.readline()
    assert "u32[2048,4]" in head


def test_build_rejects_unsupported_width():
    with pytest.raises(ValueError, match="width"):
        aot.build("/tmp/unused-artifacts", dims=[8], width=96)
