"""AOT pipeline: manifest round-trip and HLO text sanity."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Small dim keeps the test fast; the default dims are exercised by
    # `make artifacts` + the Rust integration tests.
    manifest = aot.build(str(out), dims=[16], self_check=True)
    return str(out), manifest


def test_manifest_lists_all_entries(built):
    out, manifest = built
    names = {e["name"] for e in manifest["entries"]}
    assert names == {
        "hash_items_d16",
        "hash_queries_d16",
        "hash_queries_small_d16",
        "score_d16",
    }
    assert manifest["format"] == "hlo-text"
    assert manifest["item_block"] == model.ITEM_BLOCK
    assert manifest["query_block"] == model.QUERY_BLOCK
    assert manifest["proj_width"] == model.PROJ_WIDTH


def test_manifest_json_round_trips(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_hlo_files_exist_and_are_text(built):
    out, manifest = built
    for entry in manifest["entries"]:
        path = os.path.join(out, entry["file"])
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), entry["name"]
        assert "ENTRY" in text
        # The whole point of the text interchange: no serialized protos.
        assert "\x00" not in text


def test_manifest_shapes_match_model_geometry(built):
    out, manifest = built
    by_name = {e["name"]: e for e in manifest["entries"]}
    hi = by_name["hash_items_d16"]["inputs"]
    assert hi[0]["shape"] == [model.ITEM_BLOCK, 16]
    assert hi[1]["shape"] == []           # scalar U_j
    assert hi[2]["shape"] == [17, model.PROJ_WIDTH]
    sc = by_name["score_d16"]["inputs"]
    assert sc[0]["shape"] == [model.QUERY_BLOCK, 16]
    assert sc[1]["shape"] == [model.ITEM_BLOCK, 16]


def test_hlo_entry_layout_mentions_u32_output(built):
    out, manifest = built
    path = os.path.join(out, "hash_items_d16.hlo.txt")
    with open(path) as f:
        head = f.readline()
    # xla_extension 0.5.1 parses this header; codes must be u32-packed.
    assert "u32[2048,2]" in head
