"""Kernel-vs-oracle correctness: the CORE signal for Layer 1.

The Pallas kernels must agree with the pure-jnp oracles in
``compile/kernels/ref.py`` bit-for-bit (hash codes) / to f32
reassociation tolerance (scores) across a hypothesis sweep of shapes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, strategies as st

from compile.kernels import sign_hash, score, ref
from compile.kernels.sign_hash import MAX_WIDTH, PACK_LANES, default_block_b

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _randn(rng, shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# sign_hash
# ---------------------------------------------------------------------------

@given(
    blocks=st.integers(1, 4),
    block_b=st.sampled_from([1, 2, 8, 16]),
    d=st.integers(2, 48),
    words=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sign_hash_matches_ref_across_shapes(blocks, block_b, d, words, seed):
    rng = np.random.default_rng(seed)
    b, width = blocks * block_b, words * PACK_LANES
    xt = _randn(rng, (b, d))
    proj = _randn(rng, (d, width))
    got = sign_hash(xt, proj, block_b=block_b)
    want = ref.sign_hash_ref(xt, proj)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sign_hash_bit_order_is_little_endian():
    # One vector, hand-built projection: hash j is positive iff j is even.
    d, width = 3, 64
    xt = jnp.ones((1, d), jnp.float32)
    cols = np.tile(np.where(np.arange(width) % 2 == 0, 1.0, -1.0), (d, 1))
    proj = jnp.asarray(cols, jnp.float32)
    got = np.asarray(sign_hash(xt, proj, block_b=1))
    # bits 0,2,4,... set in each 32-bit word => 0x55555555.
    assert got.tolist() == [[0x5555_5555, 0x5555_5555]]


def test_sign_hash_zero_is_negative_convention():
    # sign(0) must pack as 0 (strictly-positive convention, shared with
    # ref.py and the Rust native path).
    xt = jnp.zeros((1, 4), jnp.float32)
    proj = jnp.zeros((4, PACK_LANES), jnp.float32)
    got = np.asarray(sign_hash(xt, proj, block_b=1))
    assert got.tolist() == [[0]]


def test_sign_hash_rejects_bad_shapes():
    xt = jnp.zeros((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="dim mismatch"):
        sign_hash(xt, jnp.zeros((5, PACK_LANES), jnp.float32))
    with pytest.raises(ValueError, match="multiple"):
        sign_hash(xt, jnp.zeros((3, 17), jnp.float32))
    with pytest.raises(ValueError, match="divisible"):
        sign_hash(xt, jnp.zeros((3, PACK_LANES), jnp.float32), block_b=3)


def test_sign_hash_default_block_divides_paper_shapes():
    # The AOT geometry (2048-row blocks) must be divisible by the default tile.
    rng = np.random.default_rng(0)
    xt = _randn(rng, (2048, 31))
    proj = _randn(rng, (31, 64))
    got = sign_hash(xt, proj)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sign_hash_ref(xt, proj))
    )


def test_sign_hash_deterministic():
    rng = np.random.default_rng(7)
    xt, proj = _randn(rng, (32, 9)), _randn(rng, (9, 32))
    a = np.asarray(sign_hash(xt, proj, block_b=8))
    b = np.asarray(sign_hash(xt, proj, block_b=8))
    np.testing.assert_array_equal(a, b)


def test_sign_hash_block_size_invariance():
    # Tiling is an implementation detail: codes must not depend on block_b.
    rng = np.random.default_rng(11)
    xt, proj = _randn(rng, (64, 17)), _randn(rng, (17, 64))
    a = np.asarray(sign_hash(xt, proj, block_b=8))
    b = np.asarray(sign_hash(xt, proj, block_b=64))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# multi-word (wide-code) sign_hash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [128, 256])
def test_sign_hash_multiword_matches_ref(width):
    # The wide serving widths: 4 (L=128) / 8 (L=256) u32 words per item.
    rng = np.random.default_rng(width)
    xt = _randn(rng, (64, 24))
    proj = _randn(rng, (24, width))
    got = sign_hash(xt, proj, block_b=16)
    assert got.shape == (64, width // PACK_LANES)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sign_hash_ref(xt, proj))
    )


def test_sign_hash_multiword_bit_order_spans_words():
    # Hash function j lands in bit j % 32 of u32 word j // 32, across all
    # eight words of an L=256 panel — the little-endian convention the
    # Rust CodeWord packing relies on.
    d, width = 3, 256
    xt = jnp.ones((1, d), jnp.float32)
    cols = np.tile(np.where(np.arange(width) % 2 == 0, 1.0, -1.0), (d, 1))
    got = np.asarray(sign_hash(xt, jnp.asarray(cols, jnp.float32), block_b=1))
    assert got.tolist() == [[0x5555_5555] * (width // PACK_LANES)]
    # A single positive hash function at j = 200 sets exactly word 6 bit 8.
    cols = -np.ones((d, width), np.float32)
    cols[:, 200] = 1.0
    got = np.asarray(sign_hash(xt, jnp.asarray(cols), block_b=1))
    want = np.zeros((1, width // PACK_LANES), np.uint32)
    want[0, 200 // PACK_LANES] = np.uint32(1) << (200 % PACK_LANES)
    np.testing.assert_array_equal(got, want)


def test_sign_hash_wide_low_words_agree_with_narrow_panel():
    # A 256-wide panel whose first 64 columns equal a 64-wide panel must
    # reproduce the narrow panel's words exactly in words 0..1.
    rng = np.random.default_rng(21)
    xt = _randn(rng, (32, 12))
    wide = _randn(rng, (12, 256))
    narrow = wide[:, :64]
    a = np.asarray(sign_hash(xt, wide, block_b=8))
    b = np.asarray(sign_hash(xt, narrow, block_b=8))
    np.testing.assert_array_equal(a[:, :2], b)


def test_sign_hash_default_tile_shrinks_with_width():
    # VMEM envelope: the default tile halves per width doubling past 64
    # and always divides the 2048-row AOT item block.
    assert [default_block_b(w) for w in (32, 64, 128, 256)] == [512, 512, 256, 128]
    for w in (64, 128, 256):
        assert 2048 % default_block_b(w) == 0
        rng = np.random.default_rng(w + 1)
        xt, proj = _randn(rng, (2048, 9)), _randn(rng, (9, w))
        got = sign_hash(xt, proj)  # default tile must accept the AOT block
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.sign_hash_ref(xt, proj))
        )


def test_sign_hash_rejects_over_wide_panel():
    xt = jnp.zeros((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="ceiling"):
        sign_hash(xt, jnp.zeros((3, MAX_WIDTH + PACK_LANES), jnp.float32))


# ---------------------------------------------------------------------------
# score
# ---------------------------------------------------------------------------

@given(
    qn=st.integers(1, 16),
    blocks=st.integers(1, 4),
    block_n=st.sampled_from([1, 4, 16]),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matches_ref_across_shapes(qn, blocks, block_n, d, seed):
    rng = np.random.default_rng(seed)
    q = _randn(rng, (qn, d))
    x = _randn(rng, (blocks * block_n, d))
    got = score(q, x, block_n=block_n)
    want = ref.score_ref(q, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_score_identity_blocks():
    # q == x => scores are the Gram matrix; diagonal is squared norms.
    rng = np.random.default_rng(3)
    x = _randn(rng, (16, 8))
    s = np.asarray(score(x, x, block_n=8))
    norms2 = np.sum(np.asarray(x) ** 2, axis=1)
    np.testing.assert_allclose(np.diag(s), norms2, rtol=1e-5)
    np.testing.assert_allclose(s, s.T, rtol=1e-5, atol=1e-6)


def test_score_rejects_bad_shapes():
    q = jnp.zeros((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="dim mismatch"):
        score(q, jnp.zeros((8, 5), jnp.float32))
    with pytest.raises(ValueError, match="divisible"):
        score(q, jnp.zeros((9, 3), jnp.float32), block_n=4)
