"""Layer-2 graph properties: transforms and AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np
from hypo_compat import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("model", max_examples=25, deadline=None)
settings.load_profile("model")


def _randn(rng, shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# transforms (Eq. 8)
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 32),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_simple_transform_lands_on_unit_sphere(b, d, seed):
    # For ||x|| <= u, P(x) = [x/u; sqrt(1-||x/u||^2)] has unit norm.
    rng = np.random.default_rng(seed)
    x = _randn(rng, (b, d))
    u = float(np.linalg.norm(np.asarray(x), axis=1).max()) + 1e-3
    p = model.simple_transform(x, jnp.float32(u))
    assert p.shape == (b, d + 1)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(p), axis=1), np.ones(b), rtol=1e-5
    )


@given(
    b=st.integers(1, 32),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_query_transform_is_unit_norm_with_zero_tail(b, d, seed):
    rng = np.random.default_rng(seed)
    q = _randn(rng, (b, d))
    p = np.asarray(model.query_transform(q))
    assert p.shape == (b, d + 1)
    np.testing.assert_allclose(np.linalg.norm(p, axis=1), np.ones(b), rtol=1e-5)
    np.testing.assert_array_equal(p[:, -1], np.zeros(b))


def test_query_transform_survives_zero_rows():
    # All-zero padding rows must not produce NaNs.
    p = np.asarray(model.query_transform(jnp.zeros((4, 8), jnp.float32)))
    assert np.isfinite(p).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_transform_preserves_inner_product_order(seed):
    # Core identity behind SIMPLE-LSH: P(q).P(x) = q.x / (u * ||q||),
    # so inner-product *order* is preserved by the transform pair.
    rng = np.random.default_rng(seed)
    x = _randn(rng, (16, 12))
    q = _randn(rng, (1, 12))
    u = float(np.linalg.norm(np.asarray(x), axis=1).max())
    px = np.asarray(model.simple_transform(x, jnp.float32(u)))
    pq = np.asarray(model.query_transform(q))
    transformed = (px @ pq.T).ravel()
    raw = (np.asarray(x) @ np.asarray(q).T).ravel()
    np.testing.assert_array_equal(np.argsort(transformed), np.argsort(raw))


def test_transform_at_max_norm_has_zero_tail():
    x = jnp.asarray([[3.0, 4.0]])  # ||x|| = 5
    p = np.asarray(model.simple_transform(x, jnp.float32(5.0)))
    np.testing.assert_allclose(p, [[0.6, 0.8, 0.0]], atol=1e-6)


# ---------------------------------------------------------------------------
# AOT entry points vs oracles (full-pipeline, paper shapes scaled down)
# ---------------------------------------------------------------------------

def test_hash_items_entry_matches_oracle():
    rng = np.random.default_rng(0)
    x = _randn(rng, (model.ITEM_BLOCK, 19))
    u = jnp.float32(float(np.linalg.norm(np.asarray(x), axis=1).max()))
    proj = _randn(rng, (20, model.PROJ_WIDTH))
    (got,) = jax.jit(model.hash_items)(x, u, proj)
    want = ref.sign_hash_ref(ref.simple_transform_ref(x, u), proj)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash_queries_entry_matches_oracle():
    rng = np.random.default_rng(1)
    q = _randn(rng, (model.ITEM_BLOCK, 19))
    proj = _randn(rng, (20, model.PROJ_WIDTH))
    (got,) = jax.jit(model.hash_queries)(q, proj)
    want = ref.sign_hash_ref(ref.query_transform_ref(q), proj)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_score_entry_matches_oracle():
    rng = np.random.default_rng(2)
    q = _randn(rng, (model.QUERY_BLOCK, 19))
    x = _randn(rng, (model.ITEM_BLOCK, 19))
    (got,) = jax.jit(model.score)(q, x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.score_ref(q, x)), rtol=1e-4, atol=1e-4
    )


def test_hash_entries_match_oracle_at_wide_widths():
    # The multi-word backend: the same entry points at panel widths 128
    # and 256 must agree with the oracle word-for-word (4 / 8 u32 words).
    rng = np.random.default_rng(4)
    for width in (128, 256):
        x = _randn(rng, (64, 19))
        u = jnp.float32(float(np.linalg.norm(np.asarray(x), axis=1).max()))
        proj = _randn(rng, (20, width))
        (got,) = jax.jit(model.hash_items)(x, u, proj)
        assert got.shape == (64, width // 32)
        want = ref.sign_hash_ref(ref.simple_transform_ref(x, u), proj)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        (got_q,) = jax.jit(model.hash_queries)(x, proj)
        want_q = ref.sign_hash_ref(ref.query_transform_ref(x), proj)
        np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want_q))


def test_hash_items_padding_rows_are_harmless():
    # Zero rows (runtime padding) must hash without NaN poisoning and not
    # perturb the codes of real rows.
    rng = np.random.default_rng(3)
    d = 19
    real = rng.standard_normal((8, d)).astype(np.float32)
    padded = np.zeros((model.ITEM_BLOCK, d), np.float32)
    padded[:8] = real
    u = jnp.float32(float(np.linalg.norm(real, axis=1).max()))
    proj = _randn(rng, (d + 1, model.PROJ_WIDTH))
    (got,) = jax.jit(model.hash_items)(jnp.asarray(padded), u, proj)
    want = ref.sign_hash_ref(
        ref.simple_transform_ref(jnp.asarray(real), u), proj
    )
    np.testing.assert_array_equal(np.asarray(got)[:8], np.asarray(want))
