"""Golden-file tests for the staticcheck analyzer (scripts/staticcheck).

Each lint gets at least one positive case (a fixture tree seeded with
violations it must flag) and one negative case (a clean tree it must
pass). Fixtures live under fixtures/staticcheck/<case>/ as miniature
repo trees mirroring the real layout (rust/src/…, configs/, README.md).

The final tests run the battery — and the `scripts/check.py` driver —
against the real repository: the tree must stay free of unwaived
findings.
"""

from pathlib import Path

import pytest

import check
from staticcheck import RepoContext
from staticcheck.report import collect_waivers
from staticcheck.tokenizer import tokenize, code_tokens
from staticcheck.lints import modpath, features, panics, consistency, concurrency

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "staticcheck"
REPO_ROOT = Path(__file__).resolve().parents[2]


def run_lint(lint, case):
    return lint.run(RepoContext(FIXTURES / case))


def errors(findings):
    return [f for f in findings if not f.waived]


def waived(findings):
    return [f for f in findings if f.waived]


# -- tokenizer ------------------------------------------------------------


def test_tokenizer_strings_and_comments_hide_code():
    toks = tokenize('let s = "xs[0] // not a comment"; // real comment\nlet i = xs[0];')
    strs = [t for t in toks if t.kind == "str"]
    comments = [t for t in toks if t.kind == "comment"]
    assert len(strs) == 1 and "not a comment" in strs[0].value
    assert len(comments) == 1 and comments[0].value == "// real comment"
    # only the second line's real index expression survives as puncts
    brackets = [t for t in code_tokens(toks) if t.value == "["]
    assert len(brackets) == 1 and brackets[0].line == 2


def test_tokenizer_lifetimes_vs_char_literals():
    toks = tokenize("fn f<'a>(x: &'a str) -> char { 'x' }")
    kinds = {t.value: t.kind for t in toks if t.kind in ("lifetime", "char")}
    assert kinds["'a"] == "lifetime"
    assert kinds["'x'"] == "char"


def test_tokenizer_nested_block_comments_and_raw_strings():
    toks = tokenize('/* outer /* inner */ still comment */ let r = r#"a "quoted" b"#;')
    assert toks[0].kind == "comment" and "still comment" in toks[0].value
    assert any(t.kind == "str" and "quoted" in t.value for t in toks)


# -- waiver grammar -------------------------------------------------------


def test_waiver_parsing_and_coverage():
    src = (
        '// staticcheck: allow(panic, "standalone covers next line")\n'
        "let a = xs[0];\n"
        'let b = xs[1]; // staticcheck: allow(panic, "trailing covers its line")\n'
    )
    waivers, errs = collect_waivers(src, tokenize(src))
    assert not errs
    assert len(waivers) == 2
    standalone, trailing = waivers
    assert standalone.standalone and standalone.covers(2) and not standalone.covers(3)
    assert not trailing.standalone and trailing.covers(3) and not trailing.covers(4)


def test_waiver_empty_reason_is_an_error():
    src = '// staticcheck: allow(panic, "")\nlet a = xs[0];\n'
    waivers, errs = collect_waivers(src, tokenize(src))
    assert not waivers
    assert len(errs) == 1 and "empty reason" in errs[0][1]


# -- lint 1: module/path resolution --------------------------------------


def test_modpath_flags_dangling_mod_and_use():
    found = errors(run_lint(modpath, "modpath_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "mod missing;" in msgs  # no backing file
    assert "crate::real::no_such_item" in msgs
    assert "crate::ghost::Anything" in msgs
    assert len(found) == 3


def test_modpath_clean_tree_passes():
    assert run_lint(modpath, "modpath_ok") == []


# -- lint 2: feature-gate coherence ---------------------------------------


def test_features_flags_undeclared_feature_and_test_only_leak():
    found = errors(run_lint(features, "features_bad"))
    msgs = "\n".join(f.message for f in found)
    assert '"typo-feature"' in msgs
    assert "cfg(test)-only" in msgs and "TestOnly" in msgs
    assert len(found) == 2


def test_features_clean_tree_passes():
    assert run_lint(features, "features_ok") == []


# -- lint 3: panic paths ---------------------------------------------------


def test_panics_flags_unwrap_expect_macro_indexing():
    found = run_lint(panics, "panics_bad")
    errs = errors(found)
    msgs = "\n".join(f.message for f in errs)
    assert ".unwrap()" in msgs
    assert ".expect()" in msgs
    assert "panic!" in msgs
    assert "bare index" in msgs
    assert "empty reason" in msgs  # allow(panic, "") is itself a finding
    # the cfg(test) mod's unwrap is exempt
    assert all("unwrap_is_fine_here" not in f.message for f in errs)
    assert len(errs) == 6
    assert not waived(found)


def test_panics_waived_and_test_code_pass():
    found = run_lint(panics, "panics_ok")
    assert errors(found) == []
    assert len(waived(found)) == 1
    assert "clamped" in waived(found)[0].waive_reason


# -- lint 4: cross-layer consistency --------------------------------------


def test_consistency_flags_drift_in_all_three_layers():
    found = errors(run_lint(consistency, "consistency_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "`ghost_key`" in msgs  # toml key config.rs never parses
    assert "[mystery]" in msgs  # section config.rs never names
    assert "--secret-flag" in msgs  # parsed but undocumented
    assert "--verbose" in msgs  # bool flag parsed but undocumented
    assert "--imaginary-flag" in msgs  # documented but not parsed
    assert "v9" in msgs  # persistence version README misses
    assert '"phantom-section"' in msgs  # checksummed section README misses
    # 8 findings: the unknown [mystery] section is flagged once for the
    # section and once for its key
    assert len(found) == 8


def test_consistency_clean_tree_passes():
    assert run_lint(consistency, "consistency_ok") == []


# -- lint 5: concurrency audit ---------------------------------------------


def test_concurrency_flags_inversion_and_relaxed_snapshot():
    found = errors(run_lint(concurrency, "concurrency_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "lock-order inversion" in msgs
    assert "Relaxed" in msgs and "snapshot" in msgs
    assert len(found) == 2


def test_concurrency_clean_tree_passes():
    assert run_lint(concurrency, "concurrency_ok") == []


# -- the real repository must stay clean ----------------------------------


def test_real_repo_has_no_unwaived_findings(capsys):
    errs, _ = check.run_lints(REPO_ROOT)
    capsys.readouterr()  # silence the lint progress lines
    assert errs == [], "\n".join(f.format() for f in errs)


def test_real_repo_panic_waivers_all_carry_reasons():
    _, waived_findings = check.run_lints(REPO_ROOT)
    assert waived_findings, "the coordinator triage should have waivers"
    assert all(f.waive_reason.strip() for f in waived_findings)


def test_real_repo_indexer_is_not_vacuous():
    repo = RepoContext(REPO_ROOT)
    lib = repo.lib_index()
    mods = list(lib.all_modules())
    assert len(mods) > 50, "the lib crate should index dozens of modules"
    assert sum(len(m.items) for m in mods) > 300
    assert sum(1 for _ in lib.all_uses()) > 200


# -- driver ----------------------------------------------------------------


def test_driver_exits_nonzero_on_seeded_violations(capsys):
    rc = check.main(["--root", str(FIXTURES / "panics_bad"), "--no-bench-schema"])
    capsys.readouterr()
    assert rc == 1


def test_driver_exits_zero_on_clean_tree(capsys):
    rc = check.main(["--root", str(FIXTURES / "panics_ok"), "--no-bench-schema"])
    capsys.readouterr()
    assert rc == 0


@pytest.mark.parametrize("case,lint,clean", [
    ("modpath_bad", modpath, False),
    ("modpath_ok", modpath, True),
    ("features_bad", features, False),
    ("features_ok", features, True),
    ("panics_bad", panics, False),
    ("panics_ok", panics, True),
    ("consistency_bad", consistency, False),
    ("consistency_ok", consistency, True),
    ("concurrency_bad", concurrency, False),
    ("concurrency_ok", concurrency, True),
])
def test_every_lint_fails_its_seeded_fixture_and_passes_clean(case, lint, clean):
    errs = errors(run_lint(lint, case))
    assert (errs == []) == clean
