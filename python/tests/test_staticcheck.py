"""Golden-file tests for the staticcheck analyzer (scripts/staticcheck).

Each lint gets at least one positive case (a fixture tree seeded with
violations it must flag) and one negative case (a clean tree it must
pass). Fixtures live under fixtures/staticcheck/<case>/ as miniature
repo trees mirroring the real layout (rust/src/…, configs/, README.md).

The final tests run the battery — and the `scripts/check.py` driver —
against the real repository: the tree must stay free of unwaived
findings.
"""

from pathlib import Path

import pytest

import check
from staticcheck import RepoContext
from staticcheck.report import collect_waivers
from staticcheck.sarif import to_sarif
from staticcheck.tokenizer import tokenize, code_tokens
from staticcheck.lints import (
    modpath, features, panics, consistency, concurrency,
    panic_reach, oracle_parity,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "staticcheck"
REPO_ROOT = Path(__file__).resolve().parents[2]


def run_lint(lint, case):
    return lint.run(RepoContext(FIXTURES / case))


def errors(findings):
    return [f for f in findings if not f.waived]


def waived(findings):
    return [f for f in findings if f.waived]


# -- tokenizer ------------------------------------------------------------


def test_tokenizer_strings_and_comments_hide_code():
    toks = tokenize('let s = "xs[0] // not a comment"; // real comment\nlet i = xs[0];')
    strs = [t for t in toks if t.kind == "str"]
    comments = [t for t in toks if t.kind == "comment"]
    assert len(strs) == 1 and "not a comment" in strs[0].value
    assert len(comments) == 1 and comments[0].value == "// real comment"
    # only the second line's real index expression survives as puncts
    brackets = [t for t in code_tokens(toks) if t.value == "["]
    assert len(brackets) == 1 and brackets[0].line == 2


def test_tokenizer_lifetimes_vs_char_literals():
    toks = tokenize("fn f<'a>(x: &'a str) -> char { 'x' }")
    kinds = {t.value: t.kind for t in toks if t.kind in ("lifetime", "char")}
    assert kinds["'a"] == "lifetime"
    assert kinds["'x'"] == "char"


def test_tokenizer_nested_block_comments_and_raw_strings():
    toks = tokenize('/* outer /* inner */ still comment */ let r = r#"a "quoted" b"#;')
    assert toks[0].kind == "comment" and "still comment" in toks[0].value
    assert any(t.kind == "str" and "quoted" in t.value for t in toks)


# -- waiver grammar -------------------------------------------------------


def test_waiver_parsing_and_coverage():
    src = (
        '// staticcheck: allow(panic, "standalone covers next line")\n'
        "let a = xs[0];\n"
        'let b = xs[1]; // staticcheck: allow(panic, "trailing covers its line")\n'
    )
    waivers, errs = collect_waivers(src, tokenize(src))
    assert not errs
    assert len(waivers) == 2
    standalone, trailing = waivers
    assert standalone.standalone and standalone.covers(2) and not standalone.covers(3)
    assert not trailing.standalone and trailing.covers(3) and not trailing.covers(4)


def test_waiver_empty_reason_is_an_error():
    src = '// staticcheck: allow(panic, "")\nlet a = xs[0];\n'
    waivers, errs = collect_waivers(src, tokenize(src))
    assert not waivers
    assert len(errs) == 1 and "empty reason" in errs[0][1]


# -- lint 1: module/path resolution --------------------------------------


def test_modpath_flags_dangling_mod_and_use():
    found = errors(run_lint(modpath, "modpath_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "mod missing;" in msgs  # no backing file
    assert "crate::real::no_such_item" in msgs
    assert "crate::ghost::Anything" in msgs
    assert len(found) == 3


def test_modpath_clean_tree_passes():
    assert run_lint(modpath, "modpath_ok") == []


# -- lint 2: feature-gate coherence ---------------------------------------


def test_features_flags_undeclared_feature_and_test_only_leak():
    found = errors(run_lint(features, "features_bad"))
    msgs = "\n".join(f.message for f in found)
    assert '"typo-feature"' in msgs
    assert "cfg(test)-only" in msgs and "TestOnly" in msgs
    assert len(found) == 2


def test_features_clean_tree_passes():
    assert run_lint(features, "features_ok") == []


# -- lint 3: panic paths ---------------------------------------------------


def test_panics_flags_unwrap_expect_macro_indexing():
    found = run_lint(panics, "panics_bad")
    errs = errors(found)
    msgs = "\n".join(f.message for f in errs)
    assert ".unwrap()" in msgs
    assert ".expect()" in msgs
    assert "panic!" in msgs
    assert "bare index" in msgs
    assert "empty reason" in msgs  # allow(panic, "") is itself a finding
    # the cfg(test) mod's unwrap is exempt
    assert all("unwrap_is_fine_here" not in f.message for f in errs)
    assert len(errs) == 6
    assert not waived(found)


def test_panics_waived_and_test_code_pass():
    found = run_lint(panics, "panics_ok")
    assert errors(found) == []
    assert len(waived(found)) == 1
    assert "clamped" in waived(found)[0].waive_reason


# -- lint 4: cross-layer consistency --------------------------------------


def test_consistency_flags_drift_in_all_three_layers():
    found = errors(run_lint(consistency, "consistency_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "`ghost_key`" in msgs  # toml key config.rs never parses
    assert "[mystery]" in msgs  # section config.rs never names
    assert "--secret-flag" in msgs  # parsed but undocumented
    assert "--verbose" in msgs  # bool flag parsed but undocumented
    assert "--imaginary-flag" in msgs  # documented but not parsed
    assert "v9" in msgs  # persistence version README misses
    assert '"phantom-section"' in msgs  # checksummed section README misses
    # 8 findings: the unknown [mystery] section is flagged once for the
    # section and once for its key
    assert len(found) == 8


def test_consistency_clean_tree_passes():
    assert run_lint(consistency, "consistency_ok") == []


# -- lint 5: concurrency audit ---------------------------------------------


def test_concurrency_flags_inversion_and_relaxed_snapshot():
    found = errors(run_lint(concurrency, "concurrency_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "lock-order inversion" in msgs
    assert "Relaxed" in msgs and "snapshot" in msgs
    assert len(found) == 2


def test_concurrency_clean_tree_passes():
    assert run_lint(concurrency, "concurrency_ok") == []


# -- lint 5: RwLock acquisitions + cycle waivers ---------------------------


def test_concurrency_rwlock_inversion_is_flagged():
    found = errors(run_lint(concurrency, "concurrency_rwlock_bad"))
    assert len(found) == 1
    assert "lock-order inversion" in found[0].message
    assert "`alpha`" in found[0].message and "`beta`" in found[0].message


def test_concurrency_io_read_write_are_not_acquisitions():
    # `read(buf)` / `write(&buf[..n])` take arguments, so the io::Read /
    # io::Write methods never count as lock acquisitions.
    assert run_lint(concurrency, "concurrency_rwlock_ok") == []


def test_concurrency_cycle_finding_honors_waiver():
    found = run_lint(concurrency, "concurrency_cycle_waived")
    assert errors(found) == []
    assert len(waived(found)) == 1
    assert "proven disjoint" in waived(found)[0].waive_reason


# -- call graph -------------------------------------------------------------


def test_callgraph_resolves_method_call_across_modules():
    repo = RepoContext(FIXTURES / "panic_reach_bad")
    graph = repo.lib_graph()
    entry = next(n for n in graph.nodes if n.qname == "SearchEngine::search_streaming")
    callees = {graph.nodes[c].qname for c, _ in graph.edges().get(entry.id, [])}
    assert "Table::lookup" in callees


def test_callgraph_trait_method_fans_out_to_every_impl():
    graph = RepoContext(REPO_ROOT).lib_graph()
    impls = [n for n in graph.nodes if n.name == "extend" and n.trait_name == "Prober"]
    assert len(impls) >= 5, "every index prober implements Prober::extend"
    entry = next(n for n in graph.nodes if n.qname == "SearchEngine::search_streaming")
    callees = {graph.nodes[c].qname for c, _ in graph.edges().get(entry.id, [])}
    ext = {q for q in callees if q.endswith("::extend")}
    assert len(ext) >= 5, f"conservative fan-out should reach every impl, got {ext}"


def test_callgraph_witness_path_names_the_entry_point():
    repo = RepoContext(FIXTURES / "panic_reach_bad")
    graph, parent, flagged = panic_reach.analyze(repo)
    assert [n.qname for n in flagged] == ["Table::lookup"]
    path = graph.format_path(parent, flagged[0].id)
    assert path.startswith("SearchEngine::search_streaming")
    assert "Table::lookup" in path


# -- lint 6: interprocedural panic reachability ----------------------------


def test_panic_reach_flags_reachable_panic_with_witness_path():
    found = errors(run_lint(panic_reach, "panic_reach_bad"))
    assert len(found) == 1
    f = found[0]
    assert f.path == "rust/src/index/table.rs"
    assert "Table::lookup" in f.message and "index/slice" in f.message
    assert "SearchEngine::search_streaming -> Table::lookup" in f.message
    # the panicking fn nothing calls is NOT reachable, so not flagged
    assert all("dead_end" not in g.message for g in found)


def test_panic_reach_function_level_waiver_covers_the_body():
    found = run_lint(panic_reach, "panic_reach_ok")
    assert errors(found) == []
    assert len(waived(found)) == 1
    assert "probe schedule" in waived(found)[0].waive_reason


def test_panic_reach_stale_waiver_is_a_finding():
    found = errors(run_lint(panic_reach, "panic_reach_stale"))
    assert len(found) == 1
    assert "stale waiver" in found[0].message
    assert "no remaining may-panic construct" in found[0].message


def test_panics_stale_waiver_is_a_finding():
    found = errors(run_lint(panics, "panics_stale"))
    assert len(found) == 1
    assert "stale waiver" in found[0].message


# -- lint 7: oracle parity --------------------------------------------------


def test_oracle_parity_flags_unmatched_unresolved_and_undeclared():
    found = errors(run_lint(oracle_parity, "oracle_parity_bad"))
    msgs = "\n".join(f.message for f in found)
    assert "no single test matching `prop_fast_equals_eager`" in msgs
    assert "`Table::probe_vanished` resolves to no function" in msgs
    assert "`scan_oracle` looks like a kept oracle" in msgs
    assert len(found) == 3


def test_oracle_parity_matched_pair_passes():
    assert run_lint(oracle_parity, "oracle_parity_ok") == []


def test_oracle_parity_fixture_pair_matches_its_named_test():
    matches = oracle_parity.match_pairs(RepoContext(FIXTURES / "oracle_parity_ok"))
    matched, _, fast_ok, oracle_ok = matches["probe"]
    assert fast_ok and oracle_ok
    assert matched == "prop_fast_equals_eager"


# -- the real repository must stay clean ----------------------------------


def test_real_repo_has_no_unwaived_findings(capsys):
    errs, _, _ = check.run_lints(REPO_ROOT)
    capsys.readouterr()  # silence the lint progress lines
    assert errs == [], "\n".join(f.format() for f in errs)


def test_real_repo_panic_waivers_all_carry_reasons():
    _, waived_findings, _ = check.run_lints(REPO_ROOT)
    assert waived_findings, "the coordinator triage should have waivers"
    assert all(f.waive_reason.strip() for f in waived_findings)


def test_real_repo_indexer_is_not_vacuous():
    repo = RepoContext(REPO_ROOT)
    lib = repo.lib_index()
    mods = list(lib.all_modules())
    assert len(mods) > 50, "the lib crate should index dozens of modules"
    assert sum(len(m.items) for m in mods) > 300
    assert sum(1 for _ in lib.all_uses()) > 200


def test_real_repo_callgraph_is_not_vacuous():
    repo = RepoContext(REPO_ROOT)
    graph = repo.lib_graph()
    assert len(graph.nodes) > 400, "the lib crate defines hundreds of functions"
    assert graph.edge_count() > 1500, "resolution should land thousands of edges"
    assert len(panic_reach.entry_ids(graph)) >= 5, (
        "every serving entry-point family must resolve to concrete functions"
    )


def test_real_repo_panic_reach_triage_is_waived_with_reasons():
    findings = panic_reach.run(RepoContext(REPO_ROOT))
    assert errors(findings) == []
    triage = waived(findings)
    assert len(triage) >= 40, "the serving-reachable panic triage spans ~50 fns"
    assert all(f.waive_reason.strip() for f in triage)
    files = {f.path for f in triage}
    # transitive coverage: the triage reaches beyond the coordinator
    assert any(p.startswith("rust/src/index/") for p in files)
    assert any(p.startswith("rust/src/hash/") for p in files)
    assert any(p.startswith("rust/src/data/") for p in files)
    assert any(p.startswith("rust/src/util/") for p in files)


def test_real_repo_every_oracle_pair_is_witnessed_by_its_named_test():
    matches = oracle_parity.match_pairs(RepoContext(REPO_ROOT))
    assert set(matches) == {
        "lazy-probe", "mih-rank", "streaming-rerank",
        "blocked-hash-items", "blocked-hash-queries",
        "mutated-vs-rebuilt", "tombstone-sessions",
    }
    expected = {
        "lazy-probe": "prop_lazy_probe_stream_equals_eager_stream",
        "streaming-rerank": "prop_streaming_pruned_rerank_equals_exhaustive_oracle",
        "mutated-vs-rebuilt": "prop_mutated_store_answers_equal_freshly_rebuilt_oracle",
        "tombstone-sessions": "prop_tombstone_sessions_equal_oneshot_and_never_leak",
    }
    for name, (matched, pair, fast_ok, oracle_ok) in matches.items():
        assert fast_ok and oracle_ok, f"pair {name}: member did not resolve"
        assert matched is not None, f"pair {name}: no witnessing test"
        if name in expected:
            assert matched == expected[name]
        elif name == "mih-rank":
            assert matched.startswith("prop_mih_") and "counting_sort_oracle" in matched
        else:
            assert matched.startswith("prop_blocked_")


def test_real_repo_waiver_audit_reports_no_stale_waivers(capsys):
    _, _, repo = check.run_lints(REPO_ROOT)
    capsys.readouterr()
    assert len(repo.waiver_log) >= 60, "panic + panic-reach triage alone is ~66"
    stale = [(k, w) for k, w in repo.waiver_log.items() if not w["live"]]
    assert stale == []


# -- SARIF ------------------------------------------------------------------


def test_sarif_structure_errors_suppressions_and_line_clamp():
    findings = run_lint(oracle_parity, "oracle_parity_bad")
    findings += run_lint(panic_reach, "panic_reach_ok")
    from staticcheck.lints import ALL_LINTS

    log = to_sarif(findings, ALL_LINTS)
    assert log["version"] == "2.1.0"
    run = log["runs"][0]
    assert run["tool"]["driver"]["name"] == "staticcheck"
    assert len(run["tool"]["driver"]["rules"]) == len(ALL_LINTS)
    results = run["results"]
    assert len(results) == len(findings)
    by_level = {}
    for r in results:
        by_level.setdefault(r["level"], []).append(r)
    assert len(by_level["error"]) == 3  # oracle_parity_bad's findings
    assert len(by_level["note"]) == 1  # the waived panic-reach finding
    # waived results are suppressed in-source with the waiver reason
    (note,) = by_level["note"]
    assert note["suppressions"][0]["kind"] == "inSource"
    assert "probe schedule" in note["suppressions"][0]["justification"]
    assert all("suppressions" not in r for r in by_level["error"])
    # line-0 manifest findings clamp to SARIF's 1-based startLine
    lines = [
        r["locations"][0]["physicalLocation"]["region"]["startLine"]
        for r in results
    ]
    assert min(lines) == 1


def test_driver_writes_sarif_log(tmp_path, capsys):
    out = tmp_path / "out.sarif"
    rc = check.main([
        "--root", str(FIXTURES / "panic_reach_ok"),
        "--no-bench-schema", "--sarif", str(out),
    ])
    capsys.readouterr()
    assert rc == 0
    import json

    log = json.loads(out.read_text())
    assert log["version"] == "2.1.0"
    assert log["runs"][0]["results"], "the waived finding must be in the log"


# -- driver ----------------------------------------------------------------


def test_driver_list_waived_marks_stale_waivers(capsys):
    rc = check.main([
        "--root", str(FIXTURES / "panic_reach_stale"),
        "--no-bench-schema", "--list-waived",
    ])
    out = capsys.readouterr().out
    assert rc == 1
    assert "STALE" in out


def test_driver_list_waived_marks_live_waivers(capsys):
    rc = check.main([
        "--root", str(FIXTURES / "panic_reach_ok"),
        "--no-bench-schema", "--list-waived",
    ])
    out = capsys.readouterr().out
    assert rc == 0
    assert "— live" in out and "STALE" not in out


def test_driver_exits_nonzero_on_seeded_violations(capsys):
    rc = check.main(["--root", str(FIXTURES / "panics_bad"), "--no-bench-schema"])
    capsys.readouterr()
    assert rc == 1


def test_driver_exits_zero_on_clean_tree(capsys):
    rc = check.main(["--root", str(FIXTURES / "panics_ok"), "--no-bench-schema"])
    capsys.readouterr()
    assert rc == 0


@pytest.mark.parametrize("case,lint,clean", [
    ("modpath_bad", modpath, False),
    ("modpath_ok", modpath, True),
    ("features_bad", features, False),
    ("features_ok", features, True),
    ("panics_bad", panics, False),
    ("panics_ok", panics, True),
    ("consistency_bad", consistency, False),
    ("consistency_ok", consistency, True),
    ("concurrency_bad", concurrency, False),
    ("concurrency_ok", concurrency, True),
    ("concurrency_rwlock_bad", concurrency, False),
    ("concurrency_rwlock_ok", concurrency, True),
    ("concurrency_cycle_waived", concurrency, True),
    ("panics_stale", panics, False),
    ("panic_reach_bad", panic_reach, False),
    ("panic_reach_ok", panic_reach, True),
    ("panic_reach_stale", panic_reach, False),
    ("oracle_parity_bad", oracle_parity, False),
    ("oracle_parity_ok", oracle_parity, True),
])
def test_every_lint_fails_its_seeded_fixture_and_passes_clean(case, lint, clean):
    errs = errors(run_lint(lint, case))
    assert (errs == []) == clean
