//! Tiny benchmark harness (criterion stand-in, offline build): warmup +
//! repeated timing with median/mean/min reporting, and aligned table
//! output for the paper-figure regenerators in `benches/`.

use std::time::{Duration, Instant};

/// Timing summary over repeats.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub reps: usize,
}

impl Timing {
    pub fn per_item(&self, items: usize) -> Duration {
        if items == 0 {
            Duration::ZERO
        } else {
            self.median / items as u32
        }
    }

    /// items / second at the median.
    pub fn throughput(&self, items: usize) -> f64 {
        items as f64 / self.median.as_secs_f64().max(1e-12)
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.3?} (mean {:.3?}, min {:.3?}, n={})",
            self.median, self.mean, self.min, self.reps
        )
    }
}

/// Time `f` with `warmup` discarded runs then `reps` measured runs.
pub fn bench(warmup: usize, reps: usize, mut f: impl FnMut()) -> Timing {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort();
    let mean = times.iter().sum::<Duration>() / reps as u32;
    Timing {
        median: times[reps / 2],
        mean,
        min: times[0],
        reps,
    }
}

/// Time one run of `f`, returning its value and the duration.
pub fn time<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Simple aligned table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, (c, w)) in cells.iter().zip(widths).enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{c:>w$}", w = w));
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let t = bench(1, 9, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.reps, 9);
        assert!(t.min <= t.median);
        assert!(t.median > Duration::ZERO);
    }

    #[test]
    fn throughput_scales() {
        let t = Timing {
            median: Duration::from_millis(100),
            mean: Duration::from_millis(100),
            min: Duration::from_millis(100),
            reps: 1,
        };
        assert!((t.throughput(1000) - 10_000.0).abs() < 1.0);
        assert_eq!(t.per_item(0), Duration::ZERO);
    }

    #[test]
    fn table_aligns_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer-name".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().all(|c| c == '-'), true);
        assert!(lines[3].contains("longer-name"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
