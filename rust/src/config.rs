//! TOML config system: one file describes a full experiment or serving
//! deployment (dataset, index, evaluation, serving). Parsed with the
//! in-tree TOML-subset parser ([`crate::util::toml`]); see `configs/*.toml`
//! for the three paper datasets.

use std::path::Path;
use std::str::FromStr;
use std::time::Duration;

use anyhow::Context;

use crate::data::{synthetic, Dataset};
use crate::hash::MAX_CODE_BITS;
use crate::index::PartitionScheme;
use crate::util::toml::{parse as parse_toml, Section};
use crate::Result;

/// Which MIPS algorithm to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexAlgo {
    /// SIMPLE-LSH (paper §2.3 baseline).
    SimpleLsh,
    /// NORM-RANGING LSH (the paper's contribution).
    RangeLsh,
    /// L2-ALSH (paper §2.2 baseline).
    L2Alsh,
    /// Ranged L2-ALSH (paper §5 extension).
    RangedL2Alsh,
    /// SIGN-ALSH (Shrivastava & Li 2015, the paper's other ALSH baseline).
    SignAlsh,
}

impl FromStr for IndexAlgo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "simple_lsh" => Ok(Self::SimpleLsh),
            "range_lsh" => Ok(Self::RangeLsh),
            "l2_alsh" => Ok(Self::L2Alsh),
            "ranged_l2_alsh" => Ok(Self::RangedL2Alsh),
            "sign_alsh" => Ok(Self::SignAlsh),
            other => anyhow::bail!(
                "unknown algo {other:?} (simple_lsh | range_lsh | l2_alsh | ranged_l2_alsh | sign_alsh)"
            ),
        }
    }
}

impl std::fmt::Display for IndexAlgo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::SimpleLsh => "simple_lsh",
            Self::RangeLsh => "range_lsh",
            Self::L2Alsh => "l2_alsh",
            Self::RangedL2Alsh => "ranged_l2_alsh",
            Self::SignAlsh => "sign_alsh",
        };
        f.write_str(s)
    }
}

/// Synthetic dataset family (DESIGN.md §3 substitutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Netflix/Yahoo-style MF embeddings (mild norm spread).
    MfEmbeddings,
    /// ImageNet-SIFT-style long-tailed norms.
    LongtailSift,
    /// Unit-norm control (RANGE == SIMPLE).
    UniformNorm,
}

impl FromStr for DatasetKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mf_embeddings" => Ok(Self::MfEmbeddings),
            "longtail_sift" => Ok(Self::LongtailSift),
            "uniform_norm" => Ok(Self::UniformNorm),
            other => anyhow::bail!(
                "unknown dataset kind {other:?} (mf_embeddings | longtail_sift | uniform_norm)"
            ),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DatasetConfig {
    pub kind: DatasetKind,
    pub n_items: usize,
    pub dim: usize,
    pub n_queries: usize,
    pub seed: u64,
    /// MF rank (mf_embeddings only).
    pub rank: usize,
    /// Log-normal sigma (longtail_sift only).
    pub sigma: f32,
}

impl DatasetConfig {
    /// Materialise the item set.
    pub fn build_items(&self) -> Dataset {
        match self.kind {
            DatasetKind::MfEmbeddings => {
                synthetic::mf_embeddings(self.n_items, self.dim, self.rank, self.seed)
            }
            DatasetKind::LongtailSift => {
                synthetic::longtail_with_sigma(self.n_items, self.dim, self.sigma, self.seed)
            }
            DatasetKind::UniformNorm => synthetic::uniform_norm(self.n_items, self.dim, self.seed),
        }
    }

    /// Materialise the query set (held-out, seed-offset).
    pub fn build_queries(&self) -> Dataset {
        match self.kind {
            // MF queries are user embeddings from the same factorisation
            // (same latent basis as the items — the paper's setup).
            DatasetKind::MfEmbeddings => {
                synthetic::mf_user_queries(self.n_queries, self.dim, self.rank, self.seed)
            }
            _ => synthetic::gaussian_queries(self.n_queries, self.dim, self.seed ^ 0x5EED_0FF5),
        }
    }
}

#[derive(Debug, Clone)]
pub struct IndexConfig {
    pub algo: IndexAlgo,
    pub code_bits: usize,
    pub n_partitions: usize,
    pub scheme: PartitionScheme,
    pub epsilon: f32,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub top_k: usize,
    /// Probe-budget axis: smallest checkpoint; largest defaults to n.
    pub min_probe: usize,
    pub max_probe: Option<usize>,
    pub checkpoints_per_decade: usize,
    /// Recall targets for summary rows.
    pub recall_targets: Vec<f64>,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            top_k: 10,
            min_probe: 10,
            max_probe: None,
            checkpoints_per_decade: 4,
            recall_targets: vec![0.5, 0.8, 0.9, 0.95],
        }
    }
}

/// How the engine turns probed candidates into ranked answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RerankMode {
    /// Fused streaming re-rank (the default): the probe session is
    /// extended in blocks that feed straight into a
    /// [`crate::runtime::BoundedTopK`]; candidates whose Cauchy–Schwarz
    /// bound `‖q‖·‖x‖` cannot beat the current kth score are skipped
    /// without a dot (rows are read through the range-ordered
    /// [`crate::data::RerankView`]), and the whole query stops early once
    /// the schedule's remaining norm bound `‖q‖·U_j` falls below the kth
    /// score. Results are bit-identical to `Exhaustive`.
    #[default]
    Streaming,
    /// Probe the full budget, then re-rank every candidate
    /// ([`crate::runtime::PjrtScorer::rerank_scored`]). Kept as the
    /// equivalence oracle, and as the mode that keeps SIMPLE-LSH's
    /// batched codes-vector probe scan for uniform one-shot batches.
    Exhaustive,
}

impl FromStr for RerankMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "streaming" => Ok(Self::Streaming),
            "exhaustive" => Ok(Self::Exhaustive),
            other => anyhow::bail!("unknown rerank mode {other:?} (streaming | exhaustive)"),
        }
    }
}

/// Candidate-generation backend for the per-range Hamming ranking (see
/// [`crate::index::mih`] and README §"Candidate generation backends").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProbeBackend {
    /// Width-gated heuristic (the default): multi-index Hamming at
    /// `code_bits >= 128` — where the dense counting-sort scan dominates
    /// query time — counting sort below, where one XOR+POPCNT per bucket
    /// is hard to beat.
    #[default]
    Auto,
    /// Always the dense counting-sort scan (O(#buckets) per query).
    CountingSort,
    /// Always multi-index Hamming chunk tables (sub-linear candidate
    /// generation; identical emitted stream).
    Mih,
}

impl ProbeBackend {
    /// Collapse `Auto` to a concrete backend for an index serving
    /// `code_bits`-bit codes.
    pub fn resolve(self, code_bits: usize) -> ProbeBackend {
        match self {
            Self::Auto => {
                if code_bits >= 128 {
                    Self::Mih
                } else {
                    Self::CountingSort
                }
            }
            other => other,
        }
    }
}

impl FromStr for ProbeBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "counting_sort" => Ok(Self::CountingSort),
            "mih" => Ok(Self::Mih),
            other => {
                anyhow::bail!("unknown probe backend {other:?} (auto | counting_sort | mih)")
            }
        }
    }
}

impl std::fmt::Display for ProbeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Auto => "auto",
            Self::CountingSort => "counting_sort",
            Self::Mih => "mih",
        };
        f.write_str(s)
    }
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max queries hashed per PJRT batch.
    pub max_batch: usize,
    /// Batch flush deadline in microseconds.
    pub deadline_us: u64,
    /// Per-query probe budget.
    pub probe_budget: usize,
    pub top_k: usize,
    /// Re-rank strategy (see [`RerankMode`]); `Streaming` builds a
    /// [`crate::data::RerankView`] at engine construction — one extra
    /// copy of the matrix traded for contiguous candidate reads and
    /// norm-bound pruning on every query.
    pub rerank: RerankMode,
    /// Total code budget L served by the engine (1..=256). Selects the
    /// monomorphized code-word width at index-build time: L <= 64 runs
    /// the original `u64` hot path (PJRT-batchable), wider L runs the
    /// `[u64; 2]` / `[u64; 4]` engines with native hashing. Defaults to
    /// the `[index]` section's `code_bits` when parsed from TOML; when
    /// `rangelsh serve` builds its own index (no `--load`), an explicit
    /// override replaces the index budget at serve time.
    pub code_bits: usize,
    /// Candidate-generation backend (see [`ProbeBackend`]); `Auto`
    /// width-gates — MIH chunk tables at `code_bits >= 128`, counting
    /// sort below. Resolved against the served index's actual code width,
    /// not the config default.
    pub probe_backend: ProbeBackend,
    /// Default per-query wall-clock time budget in microseconds; `0`
    /// means unlimited. A query that exhausts its budget mid-probe
    /// returns the best-so-far top-k tagged
    /// `Degraded { reason: Deadline }` instead of blocking past the
    /// deadline or erroring (README §"Failure model & degraded
    /// serving"). Distinct from [`ServeConfig::deadline_us`], which is
    /// the *batch flush* window, not a per-query bound.
    pub time_budget_us: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 256,
            deadline_us: 500,
            probe_budget: 2048,
            top_k: 10,
            rerank: RerankMode::Streaming,
            code_bits: 64,
            probe_backend: ProbeBackend::Auto,
            time_budget_us: 0,
        }
    }
}

/// Per-request overrides of the serving defaults in [`ServeConfig`],
/// threaded from `ServerHandle::query_with` / `SearchEngine::search_with`
/// down to the probe session — one engine serves recall-targeted eval,
/// adaptive clients, and filtered search side by side instead of
/// hard-freezing k/budget at engine build. `None` fields defer to the
/// engine's [`ServeConfig`]; see [`QueryParams::resolve`] for the
/// clamping rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueryParams {
    /// Results to return (overrides [`ServeConfig::top_k`]).
    pub top_k: Option<usize>,
    /// Hard probe ceiling (overrides [`ServeConfig::probe_budget`]).
    pub probe_budget: Option<usize>,
    /// Early-stop target: stop extending the probe session once this many
    /// candidates are gathered, even though the budget would allow more.
    /// Defaults to the resolved budget (probe all the way).
    pub min_candidates: Option<usize>,
    /// Session chunk size: candidates requested per `Prober::extend` call
    /// between `min_candidates` checks — the timeout-ish knob bounding
    /// how far past the target one chunk can overshoot. Defaults to the
    /// resolved budget (a single one-shot extend).
    pub extend_step: Option<usize>,
    /// Wall-clock budget for this query (overrides
    /// [`ServeConfig::time_budget_us`]; `None` defers to it, and a config
    /// value of `0` means unlimited). Checked between `Prober::extend`
    /// blocks; on expiry the query returns its current best-so-far
    /// results tagged degraded rather than erroring.
    pub time_budget: Option<Duration>,
}

impl QueryParams {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    pub fn with_probe_budget(mut self, budget: usize) -> Self {
        self.probe_budget = Some(budget);
        self
    }

    pub fn with_min_candidates(mut self, min: usize) -> Self {
        self.min_candidates = Some(min);
        self
    }

    pub fn with_extend_step(mut self, step: usize) -> Self {
        self.extend_step = Some(step);
        self
    }

    pub fn with_time_budget(mut self, budget: Duration) -> Self {
        self.time_budget = Some(budget);
        self
    }

    /// True when every field defers to the serving defaults.
    pub fn is_default(&self) -> bool {
        *self == Self::default()
    }

    /// Apply `cfg` defaults and clamp into a consistent operating point:
    /// `top_k >= 1`, `probe_budget >= top_k`, `top_k <= min_candidates <=
    /// probe_budget`, `extend_step >= 1`.
    pub fn resolve(&self, cfg: &ServeConfig) -> ResolvedQueryParams {
        let top_k = self.top_k.unwrap_or(cfg.top_k).max(1);
        let probe_budget = self.probe_budget.unwrap_or(cfg.probe_budget).max(top_k);
        let min_candidates =
            self.min_candidates.unwrap_or(probe_budget).clamp(top_k, probe_budget);
        let extend_step = self.extend_step.unwrap_or(probe_budget).max(1);
        let time_budget = self.time_budget.or(match cfg.time_budget_us {
            0 => None,
            us => Some(Duration::from_micros(us)),
        });
        ResolvedQueryParams { top_k, probe_budget, min_candidates, extend_step, time_budget }
    }
}

/// [`QueryParams`] with the [`ServeConfig`] defaults applied and bounds
/// clamped — what the engine's probe/re-rank path actually runs with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolvedQueryParams {
    pub top_k: usize,
    pub probe_budget: usize,
    pub min_candidates: usize,
    pub extend_step: usize,
    /// `None` = unlimited. The engine anchors the deadline at batch entry
    /// (hashing included); the server additionally subtracts queue wait
    /// before handing jobs to the engine.
    pub time_budget: Option<Duration>,
}

impl ResolvedQueryParams {
    /// A single `extend` covers the whole budget — the classic one-shot
    /// probe, eligible for the batched codes-vector scan.
    pub fn one_shot(&self) -> bool {
        self.min_candidates >= self.probe_budget && self.extend_step >= self.probe_budget
    }
}

/// Top-level experiment config.
#[derive(Debug, Clone)]
pub struct Config {
    pub dataset: DatasetConfig,
    pub index: IndexConfig,
    pub eval: EvalConfig,
    pub serve: ServeConfig,
}

impl Config {
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;

        let ds = Section::of(&doc, "dataset");
        anyhow::ensure!(ds.exists(), "config needs a [dataset] section");
        let dataset = DatasetConfig {
            kind: ds.str_req("kind")?.parse()?,
            n_items: ds.usize_req("n_items")?,
            dim: ds.usize_req("dim")?,
            n_queries: ds.usize_or("n_queries", 1000)?,
            seed: ds.u64_or("seed", 42)?,
            rank: ds.usize_or("rank", 32)?,
            sigma: ds.f64_or("sigma", 0.35)? as f32,
        };

        let ix = Section::of(&doc, "index");
        anyhow::ensure!(ix.exists(), "config needs an [index] section");
        let index = IndexConfig {
            algo: ix.str_req("algo")?.parse()?,
            code_bits: ix.usize_req("code_bits")?,
            n_partitions: ix.usize_or("n_partitions", 32)?,
            scheme: ix.str_or("scheme", "percentile")?.parse()?,
            epsilon: ix.f64_or("epsilon", 0.1)? as f32,
            seed: ix.u64_or("seed", 42)?,
        };

        let ev = Section::of(&doc, "eval");
        let eval_default = EvalConfig::default();
        let eval = EvalConfig {
            top_k: ev.usize_or("top_k", eval_default.top_k)?,
            min_probe: ev.usize_or("min_probe", eval_default.min_probe)?,
            max_probe: match ev.get("max_probe") {
                None => None,
                Some(v) => Some(v.as_usize().context("[eval] max_probe must be an integer")?),
            },
            checkpoints_per_decade: ev
                .usize_or("checkpoints_per_decade", eval_default.checkpoints_per_decade)?,
            recall_targets: match ev.get("recall_targets") {
                None => eval_default.recall_targets,
                Some(v) => v
                    .as_f64_array()
                    .context("[eval] recall_targets must be an array of numbers")?,
            },
        };

        let sv = Section::of(&doc, "serve");
        let serve_default = ServeConfig::default();
        let serve = ServeConfig {
            max_batch: sv.usize_or("max_batch", serve_default.max_batch)?,
            deadline_us: sv.u64_or("deadline_us", serve_default.deadline_us)?,
            probe_budget: sv.usize_or("probe_budget", serve_default.probe_budget)?,
            top_k: sv.usize_or("top_k", serve_default.top_k)?,
            rerank: sv.str_or("rerank", "streaming")?.parse()?,
            // Serving width follows the index budget unless overridden.
            code_bits: sv.usize_or("code_bits", index.code_bits)?,
            probe_backend: sv.str_or("probe_backend", "auto")?.parse()?,
            time_budget_us: sv.u64_or("time_budget_us", serve_default.time_budget_us)?,
        };

        let cfg = Config { dataset, index, eval, serve };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.dataset.n_items >= 1, "n_items must be >= 1");
        anyhow::ensure!(self.dataset.dim >= 1, "dim must be >= 1");
        anyhow::ensure!(
            (1..=MAX_CODE_BITS).contains(&self.index.code_bits),
            "code_bits must be in 1..={MAX_CODE_BITS}, got {}",
            self.index.code_bits
        );
        anyhow::ensure!(self.index.n_partitions >= 1, "n_partitions must be >= 1");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.index.epsilon),
            "epsilon must be in [0,1)"
        );
        anyhow::ensure!(self.serve.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            (1..=MAX_CODE_BITS).contains(&self.serve.code_bits),
            "serve code_bits must be in 1..={MAX_CODE_BITS}, got {}",
            self.serve.code_bits
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
[dataset]
kind = "longtail_sift"
n_items = 1000
dim = 16
n_queries = 50

[index]
algo = "range_lsh"
code_bits = 16
n_partitions = 32

[eval]
top_k = 10
recall_targets = [0.5, 0.9]
"#;

    #[test]
    fn parses_example_toml() {
        let cfg = Config::parse(EXAMPLE).unwrap();
        assert_eq!(cfg.index.algo, IndexAlgo::RangeLsh);
        assert_eq!(cfg.index.n_partitions, 32);
        assert_eq!(cfg.index.epsilon, 0.1); // default
        assert_eq!(cfg.serve.max_batch, 256); // default section
        assert_eq!(cfg.eval.recall_targets, vec![0.5, 0.9]);
    }

    #[test]
    fn builds_datasets_from_config() {
        let cfg = Config::parse(EXAMPLE).unwrap();
        let items = cfg.dataset.build_items();
        let queries = cfg.dataset.build_queries();
        assert_eq!((items.len(), items.dim()), (1000, 16));
        assert_eq!((queries.len(), queries.dim()), (50, 16));
    }

    #[test]
    fn validation_rejects_bad_code_bits() {
        // 65 was the old (u64) ceiling; the wide code words lift it to 256.
        let bad = EXAMPLE.replace("code_bits = 16", "code_bits = 257");
        assert!(Config::parse(&bad).is_err());
        let wide = EXAMPLE.replace("code_bits = 16", "code_bits = 128");
        let cfg = Config::parse(&wide).unwrap();
        assert_eq!(cfg.index.code_bits, 128);
        // Serving width follows the index budget by default.
        assert_eq!(cfg.serve.code_bits, 128);
    }

    #[test]
    fn serve_code_bits_can_be_overridden() {
        let text = format!("{EXAMPLE}\n[serve]\ncode_bits = 64\n");
        let cfg = Config::parse(&text).unwrap();
        assert_eq!(cfg.index.code_bits, 16);
        assert_eq!(cfg.serve.code_bits, 64);
        let bad = format!("{EXAMPLE}\n[serve]\ncode_bits = 300\n");
        assert!(Config::parse(&bad).is_err());
    }

    #[test]
    fn rerank_mode_parses_and_defaults_to_streaming() {
        let cfg = Config::parse(EXAMPLE).unwrap();
        assert_eq!(cfg.serve.rerank, RerankMode::Streaming);
        let text = format!("{EXAMPLE}\n[serve]\nrerank = \"exhaustive\"\n");
        assert_eq!(Config::parse(&text).unwrap().serve.rerank, RerankMode::Exhaustive);
        let bad = format!("{EXAMPLE}\n[serve]\nrerank = \"both\"\n");
        let err = Config::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("rerank mode"));
    }

    #[test]
    fn probe_backend_parses_and_defaults_to_auto() {
        let cfg = Config::parse(EXAMPLE).unwrap();
        assert_eq!(cfg.serve.probe_backend, ProbeBackend::Auto);
        let text = format!("{EXAMPLE}\n[serve]\nprobe_backend = \"mih\"\n");
        assert_eq!(Config::parse(&text).unwrap().serve.probe_backend, ProbeBackend::Mih);
        let text = format!("{EXAMPLE}\n[serve]\nprobe_backend = \"counting_sort\"\n");
        assert_eq!(
            Config::parse(&text).unwrap().serve.probe_backend,
            ProbeBackend::CountingSort
        );
        let bad = format!("{EXAMPLE}\n[serve]\nprobe_backend = \"radix\"\n");
        let err = Config::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("probe backend"));
    }

    #[test]
    fn probe_backend_auto_resolves_on_code_width() {
        assert_eq!(ProbeBackend::Auto.resolve(64), ProbeBackend::CountingSort);
        assert_eq!(ProbeBackend::Auto.resolve(127), ProbeBackend::CountingSort);
        assert_eq!(ProbeBackend::Auto.resolve(128), ProbeBackend::Mih);
        assert_eq!(ProbeBackend::Auto.resolve(256), ProbeBackend::Mih);
        // Explicit choices pass through untouched.
        assert_eq!(ProbeBackend::Mih.resolve(16), ProbeBackend::Mih);
        assert_eq!(ProbeBackend::CountingSort.resolve(256), ProbeBackend::CountingSort);
        for b in [ProbeBackend::Auto, ProbeBackend::CountingSort, ProbeBackend::Mih] {
            assert_eq!(b.to_string().parse::<ProbeBackend>().unwrap(), b);
        }
    }

    #[test]
    fn rejects_unknown_algo() {
        let bad = EXAMPLE.replace("range_lsh", "quantum_lsh");
        let err = Config::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("quantum_lsh"));
    }

    #[test]
    fn missing_sections_report_cleanly() {
        let err = Config::parse("[dataset]\nkind = \"longtail_sift\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("n_items") || format!("{err:#}").contains("dataset"));
        let err2 = Config::parse("").unwrap_err();
        assert!(format!("{err2:#}").contains("[dataset]"));
    }

    #[test]
    fn from_path_reports_missing_file() {
        let err = Config::from_path("/no/such/config.toml").unwrap_err();
        assert!(format!("{err:#}").contains("/no/such/config.toml"));
    }

    #[test]
    fn query_params_resolve_defaults_and_clamps() {
        let cfg = ServeConfig { probe_budget: 2000, top_k: 10, ..Default::default() };
        let rp = QueryParams::default().resolve(&cfg);
        assert_eq!((rp.top_k, rp.probe_budget), (10, 2000));
        assert_eq!((rp.min_candidates, rp.extend_step), (2000, 2000));
        assert!(rp.one_shot());
        assert!(QueryParams::default().is_default());
        assert!(!QueryParams::new().with_top_k(10).is_default());
        // Per-request overrides win over the serving defaults...
        let rp = QueryParams::new().with_top_k(3).with_probe_budget(50).resolve(&cfg);
        assert_eq!((rp.top_k, rp.probe_budget), (3, 50));
        // ... and inconsistent combinations are clamped, not rejected.
        let rp = QueryParams::new().with_top_k(100).with_probe_budget(5).resolve(&cfg);
        assert_eq!(rp.probe_budget, 100);
        let rp = QueryParams::new().with_min_candidates(0).with_extend_step(0).resolve(&cfg);
        assert_eq!(rp.min_candidates, 10); // floor: at least top_k
        assert_eq!(rp.extend_step, 1);
        // An early-stop target below the budget leaves one-shot mode.
        let rp = QueryParams::new().with_min_candidates(64).with_extend_step(16).resolve(&cfg);
        assert!(!rp.one_shot());
        assert_eq!((rp.min_candidates, rp.extend_step), (64, 16));
    }

    #[test]
    fn time_budget_resolves_from_config_and_override() {
        // Default config: unlimited.
        let cfg = ServeConfig::default();
        assert_eq!(QueryParams::default().resolve(&cfg).time_budget, None);
        // Config default applies when the request is silent...
        let cfg = ServeConfig { time_budget_us: 2_500, ..Default::default() };
        let rp = QueryParams::default().resolve(&cfg);
        assert_eq!(rp.time_budget, Some(Duration::from_micros(2_500)));
        // ... and the per-request override wins.
        let rp = QueryParams::new().with_time_budget(Duration::from_millis(7)).resolve(&cfg);
        assert_eq!(rp.time_budget, Some(Duration::from_millis(7)));
        // TOML round trip.
        let text = format!("{EXAMPLE}\n[serve]\ntime_budget_us = 1500\n");
        assert_eq!(Config::parse(&text).unwrap().serve.time_budget_us, 1500);
        assert_eq!(Config::parse(EXAMPLE).unwrap().serve.time_budget_us, 0);
    }

    #[test]
    fn algo_and_kind_round_trip_display() {
        for a in [
            IndexAlgo::SimpleLsh,
            IndexAlgo::RangeLsh,
            IndexAlgo::L2Alsh,
            IndexAlgo::RangedL2Alsh,
            IndexAlgo::SignAlsh,
        ] {
            assert_eq!(a.to_string().parse::<IndexAlgo>().unwrap(), a);
        }
    }
}
