//! Dynamic batching policy: flush a pending batch when it is full or when
//! the oldest request has waited past the deadline (vLLM-router style).
//!
//! The policy is pure (no IO) so it can be property-tested; the async
//! plumbing lives in [`crate::coordinator::server`].

use std::time::{Duration, Instant};

/// Size/deadline flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub deadline: Duration,
    /// Submission-side queue bound: a request arriving at depth
    /// `max_queue` is shed with a typed `Overloaded` error instead of
    /// enqueued. Default `usize::MAX` (no shedding).
    pub max_queue: usize,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Self { max_batch, deadline, max_queue: usize::MAX }
    }

    pub fn with_max_queue(mut self, max_queue: usize) -> Self {
        assert!(max_queue >= 1, "max_queue must be >= 1");
        self.max_queue = max_queue;
        self
    }

    /// Pessimistic wait estimate for a request arriving at queue depth
    /// `depth`, given a per-batch service-time estimate: the current
    /// batch window (up to `deadline`) plus one `batch_service` per
    /// full batch already queued ahead. Pure, so the shedding decision
    /// in the server is unit-testable without a clock.
    pub fn projected_wait(&self, depth: usize, batch_service: Duration) -> Duration {
        let batches_ahead = depth.div_ceil(self.max_batch) as u32;
        self.deadline
            .saturating_add(batch_service.saturating_mul(batches_ahead))
    }

    /// Should a batch of `len` requests, whose oldest arrived at
    /// `oldest`, be flushed at `now`?
    pub fn should_flush(&self, len: usize, oldest: Option<Instant>, now: Instant) -> bool {
        if len >= self.max_batch {
            return true;
        }
        match oldest {
            Some(t0) if len > 0 => now.duration_since(t0) >= self.deadline,
            _ => false,
        }
    }

    /// When must the pending batch flush at the latest? `None` if empty.
    ///
    /// Pure (no IO, no clock): a full batch is due as of its oldest
    /// request's arrival — a time already in the past — rather than "now",
    /// which would make the answer depend on when the question is asked.
    /// Consistency with [`Self::should_flush`]: whenever
    /// `flush_at(len, oldest) <= now`, `should_flush(len, oldest, now)`
    /// is true (property-tested below).
    pub fn flush_at(&self, len: usize, oldest: Option<Instant>) -> Option<Instant> {
        if len == 0 {
            None
        } else if len >= self.max_batch {
            oldest
        } else {
            oldest.map(|t0| t0 + self.deadline)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(4, Duration::from_millis(10))
    }

    #[test]
    fn flushes_on_size() {
        let p = policy();
        let now = Instant::now();
        assert!(p.should_flush(4, Some(now), now));
        assert!(p.should_flush(5, Some(now), now));
        assert!(!p.should_flush(3, Some(now), now));
    }

    #[test]
    fn flushes_on_deadline() {
        let p = policy();
        let t0 = Instant::now();
        let later = t0 + Duration::from_millis(11);
        assert!(p.should_flush(1, Some(t0), later));
        assert!(!p.should_flush(1, Some(t0), t0 + Duration::from_millis(5)));
    }

    #[test]
    fn empty_batch_never_flushes() {
        let p = policy();
        let now = Instant::now();
        assert!(!p.should_flush(0, None, now));
        assert_eq!(p.flush_at(0, None), None);
    }

    #[test]
    fn flush_at_is_oldest_plus_deadline() {
        let p = policy();
        let t0 = Instant::now();
        let at = p.flush_at(2, Some(t0)).unwrap();
        assert_eq!(at, t0 + Duration::from_millis(10));
    }

    #[test]
    fn full_batch_flush_at_is_the_oldest_arrival() {
        // No clock call on the full-batch branch: the due time is the
        // oldest request's own arrival instant, verbatim.
        let p = policy();
        let t0 = Instant::now() - Duration::from_millis(3);
        assert_eq!(p.flush_at(4, Some(t0)), Some(t0));
        assert_eq!(p.flush_at(9, Some(t0)), Some(t0));
    }

    #[test]
    fn flush_at_is_pure() {
        // Same inputs, same answer, regardless of when (or how often) the
        // question is asked — the property the module header promises.
        let p = policy();
        let t0 = Instant::now();
        for len in 0..8 {
            let first = p.flush_at(len, Some(t0));
            std::thread::sleep(Duration::from_millis(2));
            assert_eq!(p.flush_at(len, Some(t0)), first, "len {len}");
            assert_eq!(p.flush_at(len, None), None, "len {len}: no oldest, nothing due");
        }
    }

    #[test]
    fn flush_at_due_implies_should_flush() {
        let p = policy();
        let t0 = Instant::now();
        for len in 1..8 {
            let due = p.flush_at(len, Some(t0)).unwrap();
            for dt in [Duration::ZERO, Duration::from_millis(1), Duration::from_millis(30)] {
                let now = due + dt;
                assert!(
                    p.should_flush(len, Some(t0), now),
                    "len {len}: due at {due:?} but not flushing at {now:?}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn rejects_zero_batch() {
        BatchPolicy::new(0, Duration::from_millis(1));
    }

    #[test]
    fn default_queue_is_unbounded() {
        assert_eq!(policy().max_queue, usize::MAX);
        assert_eq!(policy().with_max_queue(7).max_queue, 7);
    }

    #[test]
    #[should_panic(expected = "max_queue")]
    fn rejects_zero_queue() {
        policy().with_max_queue(0);
    }

    #[test]
    fn projected_wait_grows_with_depth_in_batch_steps() {
        // max_batch = 4, deadline = 10ms.
        let p = policy();
        let svc = Duration::from_millis(2);
        // Empty queue: just the batch window.
        assert_eq!(p.projected_wait(0, svc), Duration::from_millis(10));
        // Depths 1..=4 all fit in one batch ahead.
        for depth in 1..=4 {
            assert_eq!(p.projected_wait(depth, svc), Duration::from_millis(12), "depth {depth}");
        }
        // Depth 5 spills into a second batch.
        assert_eq!(p.projected_wait(5, svc), Duration::from_millis(14));
        // Monotone in depth (pure, so exhaustively checkable).
        let mut prev = Duration::ZERO;
        for depth in 0..64 {
            let w = p.projected_wait(depth, svc);
            assert!(w >= prev, "depth {depth}");
            prev = w;
        }
    }
}
