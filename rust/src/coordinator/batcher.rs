//! Dynamic batching policy: flush a pending batch when it is full or when
//! the oldest request has waited past the deadline (vLLM-router style).
//!
//! The policy is pure (no IO) so it can be property-tested; the async
//! plumbing lives in [`crate::coordinator::server`].

use std::time::{Duration, Instant};

/// Size/deadline flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Self { max_batch, deadline }
    }

    /// Should a batch of `len` requests, whose oldest arrived at
    /// `oldest`, be flushed at `now`?
    pub fn should_flush(&self, len: usize, oldest: Option<Instant>, now: Instant) -> bool {
        if len >= self.max_batch {
            return true;
        }
        match oldest {
            Some(t0) if len > 0 => now.duration_since(t0) >= self.deadline,
            _ => false,
        }
    }

    /// When must the pending batch flush at the latest? `None` if empty.
    pub fn flush_at(&self, len: usize, oldest: Option<Instant>) -> Option<Instant> {
        if len == 0 {
            None
        } else if len >= self.max_batch {
            oldest.map(|_| Instant::now())
        } else {
            oldest.map(|t0| t0 + self.deadline)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(4, Duration::from_millis(10))
    }

    #[test]
    fn flushes_on_size() {
        let p = policy();
        let now = Instant::now();
        assert!(p.should_flush(4, Some(now), now));
        assert!(p.should_flush(5, Some(now), now));
        assert!(!p.should_flush(3, Some(now), now));
    }

    #[test]
    fn flushes_on_deadline() {
        let p = policy();
        let t0 = Instant::now();
        let later = t0 + Duration::from_millis(11);
        assert!(p.should_flush(1, Some(t0), later));
        assert!(!p.should_flush(1, Some(t0), t0 + Duration::from_millis(5)));
    }

    #[test]
    fn empty_batch_never_flushes() {
        let p = policy();
        let now = Instant::now();
        assert!(!p.should_flush(0, None, now));
        assert_eq!(p.flush_at(0, None), None);
    }

    #[test]
    fn flush_at_is_oldest_plus_deadline() {
        let p = policy();
        let t0 = Instant::now();
        let at = p.flush_at(2, Some(t0)).unwrap();
        assert_eq!(at, t0 + Duration::from_millis(10));
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn rejects_zero_batch() {
        BatchPolicy::new(0, Duration::from_millis(1));
    }
}
