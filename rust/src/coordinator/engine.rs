//! The synchronous search core: hash → probe → exact re-rank. Generic
//! over the code word `C` ([`CodeWord`]): `SearchEngine` is the original
//! `u64` engine (PJRT-batchable); `SearchEngine<Code128>` / `<Code256>`
//! serve wide-code indexes through the same path. [`AnyEngine`] picks the
//! narrowest monomorphization for a requested `code_bits` at build time,
//! so the `u64` hot path keeps its exact original codegen.

use std::sync::Arc;

use crate::config::ServeConfig;
use crate::coordinator::metrics::Metrics;
use crate::data::Dataset;
use crate::hash::{Code128, Code256, CodeWord, ItemHasher, NativeHasher, MAX_CODE_BITS};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::{AnyRangeLshIndex, CodeProbe};
use crate::runtime::PjrtScorer;
use crate::{ItemId, Result};

/// One ranked answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    pub id: ItemId,
    /// Exact inner product with the query (post re-rank).
    pub score: f32,
}

/// The query-path core. Thread-safe; clone the `Arc` and share.
///
/// The index must implement [`CodeProbe`] (SIMPLE-LSH or RANGE-LSH): the
/// engine hashes queries *in batches* through `hasher` — the PJRT-backed
/// Pallas kernel in production (`u64` codes), the native panel for tests
/// and for multi-word codes — and probes with the resulting codes, so the
/// Python-free hot path is:
/// `sign-hash kernel → bucket schedule walk → exact re-rank`.
pub struct SearchEngine<C: CodeWord = u64> {
    index: Arc<dyn CodeProbe<C>>,
    dataset: Arc<Dataset>,
    hasher: Arc<dyn ItemHasher<C>>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
}

thread_local! {
    /// Per-worker candidate scratch pool, one buffer per query of the
    /// worker's current chunk: buffers are reused across the chunk's
    /// queries rather than allocated per query (§Perf; pairs with the
    /// `SortScratch` reuse inside the bucket tables). Note the scope:
    /// [`crate::util::par::par_map_cutoff`] spawns fresh scoped threads
    /// per batch, so worker thread-locals live for one `search_batch`
    /// call; only the serial (single-chunk) path reuses them across
    /// calls.
    static CAND_SCRATCH: std::cell::RefCell<Vec<Vec<ItemId>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl<C: CodeWord> SearchEngine<C> {
    pub fn new(
        index: Arc<dyn CodeProbe<C>>,
        dataset: Arc<Dataset>,
        hasher: Arc<dyn ItemHasher<C>>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        anyhow::ensure!(cfg.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(cfg.probe_budget >= cfg.top_k, "budget below top_k");
        Ok(Self {
            index,
            dataset,
            hasher,
            cfg,
            metrics: Arc::new(Metrics::new()),
        })
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.dataset
    }

    /// Search a single query (hashes natively; the batched path is the
    /// production route).
    pub fn search(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        Ok(self.search_batch(query)?.pop().expect("one query in, one out"))
    }

    /// Search a batch of queries laid out row-major (`rows.len()` must be
    /// a multiple of the dataset dim). Hashing is one bulk hasher call
    /// (one or more PJRT blocks); probe + re-rank fan out on the scoped
    /// thread pool, each worker reusing its thread-local candidate buffer.
    pub fn search_batch(&self, rows: &[f32]) -> Result<Vec<Vec<SearchResult>>> {
        let dim = self.dataset.dim();
        anyhow::ensure!(
            !rows.is_empty() && rows.len() % dim == 0,
            "query buffer length {} not a positive multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        let t0 = std::time::Instant::now();
        let codes = self.hasher.hash_queries(rows)?;
        self.metrics.record_batch(n);

        // Fan the batch out in worker-sized chunks: each worker probes
        // its whole chunk through one [`CodeProbe::probe_batch_with_codes`]
        // call — the single-table indexes stream their dense codes vector
        // once per chunk instead of once per query — then re-ranks each
        // query. Each probe costs milliseconds at paper scale, so even
        // tiny batches fan out (chunks of at most 16 queries, cutoff 1).
        let budget = self.cfg.probe_budget;
        let chunk = n.div_ceil(crate::util::par::n_threads()).clamp(1, 16);
        let n_chunks = n.div_ceil(chunk);
        let per_chunk: Vec<Vec<Vec<SearchResult>>> =
            crate::util::par::par_map_cutoff(n_chunks, 1, |ci| {
                let (lo, hi) = (ci * chunk, ((ci + 1) * chunk).min(n));
                CAND_SCRATCH.with(|scratch| {
                    let bufs = &mut *scratch.borrow_mut();
                    if bufs.len() < hi - lo {
                        bufs.resize_with(hi - lo, Vec::new);
                    }
                    for buf in bufs[..hi - lo].iter_mut() {
                        buf.clear();
                    }
                    self.index.probe_batch_with_codes(&codes[lo..hi], budget, &mut bufs[..hi - lo]);
                    let mut scores: Vec<f32> = Vec::with_capacity(self.cfg.top_k);
                    (lo..hi)
                        .map(|qi| {
                            let q = &rows[qi * dim..(qi + 1) * dim];
                            let cands = &mut bufs[qi - lo];
                            let probed = cands.len();
                            // The re-rank already computes every winner's
                            // exact score; reuse them instead of paying
                            // top_k more full-dimension dots per query.
                            PjrtScorer::rerank_scored(
                                &self.dataset,
                                q,
                                cands,
                                self.cfg.top_k,
                                &mut scores,
                            );
                            self.metrics
                                .record_query(t0.elapsed().as_micros() as u64, probed);
                            cands
                                .iter()
                                .zip(scores.iter())
                                .map(|(&id, &score)| SearchResult { id, score })
                                .collect()
                        })
                        .collect()
                })
            });
        Ok(per_chunk.into_iter().flatten().collect())
    }
}

/// A [`SearchEngine`] monomorphized to the narrowest code word that fits
/// the configured `code_bits` — the dispatch point between the config
/// layer (`ServeConfig::code_bits`, 1..=256) and the typed engines. The
/// match happens once at build time; every query thereafter runs fully
/// monomorphized code.
pub enum AnyEngine {
    W64(Arc<SearchEngine<u64>>),
    W128(Arc<SearchEngine<Code128>>),
    W256(Arc<SearchEngine<Code256>>),
}

impl AnyEngine {
    /// Build a native-hashed RANGE-LSH engine at the width selected by
    /// `cfg.code_bits`. `u64` keeps its historical 64-wide panel; wider
    /// engines use a panel exactly as wide as the per-range hash bits.
    pub fn build_native_range(
        items: Arc<Dataset>,
        params: RangeLshParams,
        seed: u64,
        cfg: ServeConfig,
    ) -> Result<AnyEngine> {
        anyhow::ensure!(
            cfg.code_bits >= 1 && cfg.code_bits <= MAX_CODE_BITS,
            "code_bits {} out of range 1..={MAX_CODE_BITS}",
            cfg.code_bits
        );
        anyhow::ensure!(
            params.code_bits == cfg.code_bits,
            "index code_bits {} != serve code_bits {}",
            params.code_bits,
            cfg.code_bits
        );
        if cfg.code_bits <= 64 {
            Ok(AnyEngine::W64(Arc::new(build_arm::<u64>(items, params, seed, cfg, 64)?)))
        } else if cfg.code_bits <= 128 {
            let width = params.hash_bits();
            Ok(AnyEngine::W128(Arc::new(build_arm::<Code128>(items, params, seed, cfg, width)?)))
        } else {
            let width = params.hash_bits();
            Ok(AnyEngine::W256(Arc::new(build_arm::<Code256>(items, params, seed, cfg, width)?)))
        }
    }

    /// Wrap a loaded index of whatever width the file declared, hashing
    /// queries natively with the index's own panel.
    pub fn from_loaded(
        index: AnyRangeLshIndex,
        items: Arc<Dataset>,
        cfg: ServeConfig,
    ) -> Result<AnyEngine> {
        match index {
            AnyRangeLshIndex::W64(i) => {
                let hasher: Arc<NativeHasher<u64>> =
                    Arc::new(NativeHasher::with_projection(i.projection().clone()));
                Ok(AnyEngine::W64(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
            AnyRangeLshIndex::W128(i) => {
                let hasher: Arc<NativeHasher<Code128>> =
                    Arc::new(NativeHasher::with_projection(i.projection().clone()));
                Ok(AnyEngine::W128(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
            AnyRangeLshIndex::W256(i) => {
                let hasher: Arc<NativeHasher<Code256>> =
                    Arc::new(NativeHasher::with_projection(i.projection().clone()));
                Ok(AnyEngine::W256(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
        }
    }

    /// Words per code (1, 2 or 4).
    pub fn code_words(&self) -> usize {
        match self {
            Self::W64(_) => 1,
            Self::W128(_) => 2,
            Self::W256(_) => 4,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        match self {
            Self::W64(e) => e.metrics(),
            Self::W128(e) => e.metrics(),
            Self::W256(e) => e.metrics(),
        }
    }

    pub fn search(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        match self {
            Self::W64(e) => e.search(query),
            Self::W128(e) => e.search(query),
            Self::W256(e) => e.search(query),
        }
    }

    pub fn search_batch(&self, rows: &[f32]) -> Result<Vec<Vec<SearchResult>>> {
        match self {
            Self::W64(e) => e.search_batch(rows),
            Self::W128(e) => e.search_batch(rows),
            Self::W256(e) => e.search_batch(rows),
        }
    }
}

fn build_arm<C: CodeWord>(
    items: Arc<Dataset>,
    params: RangeLshParams,
    seed: u64,
    cfg: ServeConfig,
    width: usize,
) -> Result<SearchEngine<C>> {
    let hasher: Arc<NativeHasher<C>> = Arc::new(NativeHasher::new(items.dim(), width, seed));
    let index: Arc<RangeLshIndex<C>> =
        Arc::new(RangeLshIndex::build(&items, hasher.as_ref(), params)?);
    SearchEngine::new(index, items, hasher, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn engine(budget: usize) -> (Arc<Dataset>, SearchEngine) {
        let d = Arc::new(synthetic::longtail_sift(2000, 16, 0));
        let h = Arc::new(NativeHasher::<u64>::new(16, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 16)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: budget, top_k: 10, ..Default::default() };
        let e = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        (d, e)
    }

    #[test]
    fn search_returns_k_descending_results() {
        let (_, e) = engine(500);
        let q = synthetic::gaussian_queries(1, 16, 2);
        let res = e.search(q.row(0)).unwrap();
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn full_budget_recovers_exact_topk() {
        let (d, e) = engine(usize::MAX);
        let q = synthetic::gaussian_queries(3, 16, 3);
        let gt = crate::eval::exact_topk(&d, &q, 10);
        for qi in 0..q.len() {
            let res = e.search(q.row(qi)).unwrap();
            let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
            assert_eq!(ids, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn batch_matches_single_queries() {
        let (_, e) = engine(300);
        let q = synthetic::gaussian_queries(8, 16, 4);
        let batch = e.search_batch(q.flat()).unwrap();
        assert_eq!(batch.len(), 8);
        for qi in 0..8 {
            let single = e.search(q.row(qi)).unwrap();
            assert_eq!(batch[qi], single, "query {qi}");
        }
    }

    #[test]
    fn batch_over_simple_index_uses_batched_scan_and_matches_single() {
        // SIMPLE-LSH overrides probe_batch_with_codes with the shared
        // codes-vector scan; the engine's chunked batch path must still
        // agree with per-query searches exactly.
        use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
        let d = Arc::new(synthetic::longtail_sift(1500, 16, 20));
        let h = Arc::new(NativeHasher::<u64>::new(16, 64, 21));
        let idx = Arc::new(SimpleLshIndex::build(&d, h.as_ref(), SimpleLshParams::new(16)).unwrap());
        let cfg = ServeConfig { probe_budget: 200, top_k: 10, ..Default::default() };
        let e = SearchEngine::new(idx, d, h, cfg).unwrap();
        let q = synthetic::gaussian_queries(9, 16, 22);
        let batch = e.search_batch(q.flat()).unwrap();
        assert_eq!(batch.len(), 9);
        for qi in 0..9 {
            assert_eq!(batch[qi], e.search(q.row(qi)).unwrap(), "query {qi}");
        }
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let (d, e) = engine(400);
        let q = synthetic::gaussian_queries(1, 16, 5);
        for r in e.search(q.row(0)).unwrap() {
            let want = d.dot(r.id as usize, q.row(0));
            assert!((r.score - want).abs() < 1e-6);
        }
    }

    #[test]
    fn metrics_accumulate() {
        let (_, e) = engine(100);
        let q = synthetic::gaussian_queries(5, 16, 6);
        e.search_batch(q.flat()).unwrap();
        let s = e.metrics().snapshot();
        assert_eq!(s.queries, 5);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_rows, 5.0);
        assert!(s.mean_probed > 0.0);
    }

    #[test]
    fn rejects_misaligned_batch() {
        let (_, e) = engine(100);
        assert!(e.search_batch(&[0.0; 17]).is_err());
        assert!(e.search_batch(&[]).is_err());
    }

    #[test]
    fn rejects_budget_below_top_k() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 0));
        let h = Arc::new(NativeHasher::<u64>::new(8, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: 5, top_k: 10, ..Default::default() };
        assert!(SearchEngine::new(idx, d, h, cfg).is_err());
    }

    #[test]
    fn wide_engine_serves_end_to_end() {
        // code_bits = 128 through the whole path: build → probe → re-rank.
        let d = Arc::new(synthetic::longtail_sift(1500, 16, 7));
        let params = RangeLshParams::new(128, 16);
        let h = Arc::new(NativeHasher::<Code128>::new(16, params.hash_bits(), 8));
        let idx = Arc::new(RangeLshIndex::build(&d, h.as_ref(), params).unwrap());
        let cfg = ServeConfig {
            probe_budget: usize::MAX,
            top_k: 10,
            code_bits: 128,
            ..Default::default()
        };
        let e: SearchEngine<Code128> = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        let q = synthetic::gaussian_queries(4, 16, 9);
        let gt = crate::eval::exact_topk(&d, &q, 10);
        for qi in 0..q.len() {
            let res = e.search(q.row(qi)).unwrap();
            let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
            assert_eq!(ids, gt[qi], "query {qi}: wide engine must recover exact top-k");
        }
    }

    #[test]
    fn any_engine_dispatches_on_code_bits() {
        let d = Arc::new(synthetic::longtail_sift(800, 8, 10));
        for (bits, words) in [(32usize, 1usize), (128, 2), (256, 4)] {
            let cfg = ServeConfig {
                probe_budget: 200,
                top_k: 5,
                code_bits: bits,
                ..Default::default()
            };
            let engine = AnyEngine::build_native_range(
                d.clone(),
                RangeLshParams::new(bits, 8),
                11,
                cfg,
            )
            .unwrap();
            assert_eq!(engine.code_words(), words, "bits {bits}");
            let q = synthetic::gaussian_queries(2, 8, 12);
            let res = engine.search_batch(q.flat()).unwrap();
            assert_eq!(res.len(), 2);
            assert!(res.iter().all(|r| r.len() == 5));
        }
    }

    #[test]
    fn any_engine_rejects_mismatched_bits() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 13));
        let cfg = ServeConfig { code_bits: 64, ..Default::default() };
        assert!(AnyEngine::build_native_range(d, RangeLshParams::new(128, 8), 1, cfg).is_err());
    }
}
