//! The synchronous search core: hash → probe → exact re-rank. Generic
//! over the code word `C` ([`CodeWord`]): `SearchEngine` is the original
//! `u64` engine (PJRT-batchable); `SearchEngine<Code128>` / `<Code256>`
//! serve wide-code indexes through the same path. [`AnyEngine`] picks the
//! narrowest monomorphization for a requested `code_bits` at build time,
//! so the `u64` hot path keeps its exact original codegen.

use std::sync::Arc;
use std::time::Instant;

use crate::config::{ProbeBackend, QueryParams, RerankMode, ResolvedQueryParams, ServeConfig};
use crate::coordinator::fault::{DegradeReason, QueryResponse};
use crate::coordinator::metrics::Metrics;
use crate::data::{Dataset, RerankView};
use crate::hash::{
    Code128, Code256, CodeWord, ItemHasher, NativeHasher, Projection, MAX_CODE_BITS,
};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::{AnyRangeLshIndex, CodeProbe, Prober};
use crate::runtime::{BoundedTopK, PjrtHasher, PjrtScorer, RuntimeHandle};
use crate::{ItemId, Result};

/// One ranked answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    pub id: ItemId,
    /// Exact inner product with the query (post re-rank).
    pub score: f32,
}

/// The query-path core. Thread-safe; clone the `Arc` and share.
///
/// The index must implement [`CodeProbe`] (SIMPLE-LSH or RANGE-LSH): the
/// engine hashes queries *in batches* through `hasher` — the PJRT-backed
/// Pallas kernel in production at any code width (the multi-word kernel
/// packs `width / 32` u32 words per item), the blocked native path when
/// artifacts are absent — and probes with the resulting codes, so the
/// Python-free hot path is:
/// `sign-hash kernel → bucket schedule walk → exact re-rank`.
pub struct SearchEngine<C: CodeWord = u64> {
    index: Arc<dyn CodeProbe<C>>,
    dataset: Arc<Dataset>,
    /// Range-ordered storage for the streaming re-rank (built once at
    /// engine construction when `cfg.rerank` is `Streaming`): candidate
    /// rows are read from this norm-descending permutation instead of
    /// scattering across the original-order matrix.
    view: Option<Arc<RerankView>>,
    hasher: Arc<dyn ItemHasher<C>>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
}

/// Probe-session block size of the fused streaming path when the request
/// is one-shot (no `min_candidates`/`extend_step` semantics to honor):
/// big enough to amortize the session walk, small enough that the
/// Cauchy–Schwarz early-out can stop a query long before the full budget
/// is probed.
const STREAM_BLOCK: usize = 512;

thread_local! {
    /// Per-worker candidate scratch pool, one buffer per query of the
    /// worker's current chunk: buffers are reused across the chunk's
    /// queries rather than allocated per query (§Perf; pairs with the
    /// `SortScratch` reuse inside the bucket tables). Note the scope:
    /// [`crate::util::par::par_map_cutoff`] spawns fresh scoped threads
    /// per batch, so worker thread-locals live for one `search_batch`
    /// call; only the serial (single-chunk) path reuses them across
    /// calls.
    static CAND_SCRATCH: std::cell::RefCell<Vec<Vec<ItemId>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl<C: CodeWord> SearchEngine<C> {
    pub fn new(
        index: Arc<dyn CodeProbe<C>>,
        dataset: Arc<Dataset>,
        hasher: Arc<dyn ItemHasher<C>>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        anyhow::ensure!(cfg.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(cfg.probe_budget >= cfg.top_k, "budget below top_k");
        let view = match cfg.rerank {
            RerankMode::Streaming => Some(Arc::new(RerankView::build(&dataset))),
            RerankMode::Exhaustive => None,
        };
        Self::from_epoch(index, dataset, view, hasher, cfg, Arc::new(Metrics::new()))
    }

    /// Assemble an engine for one index *epoch* — the
    /// [`crate::coordinator::store::MutableStore`] constructor: unlike
    /// [`Self::new`], the re-rank view and the metrics sink are supplied
    /// by the caller, so successive epochs of a mutable store share one
    /// metrics stream and reuse the previous epoch's [`RerankView`] when
    /// the dataset did not change (delete-only epochs).
    pub(crate) fn from_epoch(
        index: Arc<dyn CodeProbe<C>>,
        dataset: Arc<Dataset>,
        view: Option<Arc<RerankView>>,
        hasher: Arc<dyn ItemHasher<C>>,
        cfg: ServeConfig,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        anyhow::ensure!(cfg.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(cfg.probe_budget >= cfg.top_k, "budget below top_k");
        anyhow::ensure!(
            view.is_some() == (cfg.rerank == RerankMode::Streaming),
            "rerank view must be present exactly for streaming engines"
        );
        Ok(Self { index, dataset, view, hasher, cfg, metrics })
    }

    /// The streaming re-rank view, when this engine carries one (epoch
    /// reuse by the mutable store).
    pub(crate) fn view(&self) -> Option<&Arc<RerankView>> {
        self.view.as_ref()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.dataset
    }

    /// Which bulk-hashing backend serves this engine ("native" / "pjrt").
    pub fn hasher_backend(&self) -> &'static str {
        self.hasher.backend()
    }

    /// Search a single query with the serving defaults (hashes natively;
    /// the batched path is the production route).
    pub fn search(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        self.search_with(query, &QueryParams::default())
    }

    /// Search a single query with per-request overrides of the serving
    /// defaults (k, probe budget, early-stop target, extend step).
    pub fn search_with(&self, query: &[f32], params: &QueryParams) -> Result<Vec<SearchResult>> {
        Ok(self.search_full(query, params)?.into_results())
    }

    /// [`Self::search_with`] keeping the full [`QueryResponse`] envelope:
    /// a query whose time budget expires mid-probe returns its
    /// best-so-far results with a `Degraded { reason: Deadline }` tag
    /// instead of erroring or silently presenting a truncated top-k as
    /// complete.
    pub fn search_full(&self, query: &[f32], params: &QueryParams) -> Result<QueryResponse> {
        Ok(self
            .search_batch_full(query, std::slice::from_ref(params))?
            .pop()
            // staticcheck: allow(panic, "search_batch_full returns exactly one response per input query")
            .expect("one query in, one out"))
    }

    /// Search a batch of queries laid out row-major with the serving
    /// defaults (`rows.len()` must be a multiple of the dataset dim).
    pub fn search_batch(&self, rows: &[f32]) -> Result<Vec<Vec<SearchResult>>> {
        self.search_batch_params(rows, &[])
    }

    /// [`Self::search_batch`] with one [`QueryParams`] override applied
    /// to every query of the batch.
    pub fn search_batch_with(
        &self,
        rows: &[f32],
        params: &QueryParams,
    ) -> Result<Vec<Vec<SearchResult>>> {
        self.search_batch_params(rows, std::slice::from_ref(params))
    }

    /// Batched search with per-query parameter overrides. `params` may be
    /// empty (serving defaults for every query), length 1 (one override
    /// for the whole batch), or one entry per query. Hashing is one bulk
    /// hasher call (one or more PJRT blocks); probe + re-rank fan out on
    /// the scoped thread pool, each worker reusing its thread-local
    /// candidate buffers. Uniform one-shot parameterizations keep the
    /// batched codes-vector scan; per-query overrides and early-stop
    /// targets probe through resumable sessions instead.
    pub fn search_batch_params(
        &self,
        rows: &[f32],
        params: &[QueryParams],
    ) -> Result<Vec<Vec<SearchResult>>> {
        Ok(self
            .search_batch_full(rows, params)?
            .into_iter()
            .map(QueryResponse::into_results)
            .collect())
    }

    /// [`Self::search_batch_params`] keeping the per-query
    /// [`QueryResponse`] envelopes. Time budgets (per-request
    /// `QueryParams::time_budget` or the `ServeConfig::time_budget_us`
    /// default) are anchored at batch entry — hashing counts against the
    /// budget — and checked between `Prober::extend` blocks; an expired
    /// query is tagged degraded with whatever its bounded top-k holds.
    pub fn search_batch_full(
        &self,
        rows: &[f32],
        params: &[QueryParams],
    ) -> Result<Vec<QueryResponse>> {
        let dim = self.dataset.dim();
        anyhow::ensure!(
            !rows.is_empty() && rows.len() % dim == 0,
            "query buffer length {} not a positive multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        anyhow::ensure!(
            params.len() <= 1 || params.len() == n,
            "params length {} is neither 0/1 nor the query count {n}",
            params.len()
        );
        let t0 = std::time::Instant::now();
        let codes = self.hasher.hash_queries(rows)?;
        self.metrics.record_batch(n);

        // One resolved parameter set for the whole batch when possible —
        // this is what keeps the batched probe fast path.
        let uniform: Option<ResolvedQueryParams> = match params {
            [] => Some(QueryParams::default().resolve(&self.cfg)),
            [p] => Some(p.resolve(&self.cfg)),
            [first, rest @ ..] if rest.iter().all(|p| p == first) => Some(first.resolve(&self.cfg)),
            _ => None,
        };
        let resolve_at = |qi: usize| -> ResolvedQueryParams {
            match uniform {
                Some(rp) => rp,
                // staticcheck: allow(panic, "non-uniform branch: params.len() == n and qi < n by loop bounds")
                None => params[qi].resolve(&self.cfg),
            }
        };

        // Fan the batch out in worker-sized chunks: each worker probes
        // its whole chunk through one [`CodeProbe::probe_batch_with_codes`]
        // call — the single-table indexes stream their dense codes vector
        // once per chunk instead of once per query — then re-ranks each
        // query. Each probe costs milliseconds at paper scale, so even
        // tiny batches fan out (chunks of at most 16 queries, cutoff 1).
        let chunk = n.div_ceil(crate::util::par::n_threads()).clamp(1, 16);
        let n_chunks = n.div_ceil(chunk);
        let per_chunk: Vec<Vec<QueryResponse>> =
            crate::util::par::par_map_cutoff(n_chunks, 1, |ci| {
                let (lo, hi) = (ci * chunk, ((ci + 1) * chunk).min(n));
                if self.cfg.rerank == RerankMode::Streaming {
                    // Fused probe + re-rank per query: no candidate
                    // materialization, no batched codes-vector scan —
                    // the session blocks feed the accumulator directly.
                    return (lo..hi)
                        .map(|qi| {
                            let rp = resolve_at(qi);
                            // staticcheck: allow(panic, "rows.len() == n * dim is validated at entry; qi < n")
                            let q = &rows[qi * dim..(qi + 1) * dim];
                            // staticcheck: allow(panic, "codes holds one code per query from the batch hash pass; qi < n")
                            self.search_streaming(codes[qi], q, &rp, t0)
                        })
                        .collect();
                }
                CAND_SCRATCH.with(|scratch| {
                    let bufs = &mut *scratch.borrow_mut();
                    if bufs.len() < hi - lo {
                        bufs.resize_with(hi - lo, Vec::new);
                    }
                    // staticcheck: allow(panic, "bufs was resized to at least hi - lo just above")
                    for buf in bufs[..hi - lo].iter_mut() {
                        buf.clear();
                    }
                    // Deadline cut per query of the chunk (None = ran to
                    // completion). The batched codes-vector scan is kept
                    // only for budget-less uniform one-shot requests —
                    // it has no extend boundaries to check a deadline at.
                    let mut cut: Vec<Option<DegradeReason>> = vec![None; hi - lo];
                    match uniform {
                        Some(rp) if rp.one_shot() && rp.time_budget.is_none() => {
                            self.index.probe_batch_with_codes(
                                // staticcheck: allow(panic, "lo < hi <= n == codes.len()")
                                &codes[lo..hi],
                                rp.probe_budget,
                                // staticcheck: allow(panic, "bufs was resized to at least hi - lo just above")
                                &mut bufs[..hi - lo],
                            );
                        }
                        _ => {
                            for qi in lo..hi {
                                let rp = resolve_at(qi);
                                let deadline = rp.time_budget.map(|tb| t0 + tb);
                                // staticcheck: allow(panic, "cut and bufs both have hi - lo entries; qi in lo..hi")
                                cut[qi - lo] =
                                    // staticcheck: allow(panic, "codes[qi]: qi < n; bufs[qi - lo]: qi in lo..hi")
                                    self.probe_one(codes[qi], &rp, deadline, &mut bufs[qi - lo]);
                            }
                        }
                    }
                    let mut scores: Vec<f32> = Vec::new();
                    (lo..hi)
                        .map(|qi| {
                            let rp = resolve_at(qi);
                            // staticcheck: allow(panic, "rows.len() == n * dim is validated at entry; qi < n")
                            let q = &rows[qi * dim..(qi + 1) * dim];
                            // staticcheck: allow(panic, "bufs has hi - lo entries; qi in lo..hi")
                            let cands = &mut bufs[qi - lo];
                            let probed = cands.len();
                            // The re-rank already computes every winner's
                            // exact score; reuse them instead of paying
                            // top_k more full-dimension dots per query.
                            PjrtScorer::rerank_scored(
                                &self.dataset,
                                q,
                                cands,
                                rp.top_k,
                                &mut scores,
                            );
                            self.metrics
                                .record_query(t0.elapsed().as_micros() as u64, probed);
                            let results = cands
                                .iter()
                                .zip(scores.iter())
                                .map(|(&id, &score)| SearchResult { id, score })
                                .collect();
                            // staticcheck: allow(panic, "cut has hi - lo entries; qi in lo..hi")
                            match cut[qi - lo] {
                                Some(reason) => {
                                    self.metrics.record_degraded();
                                    QueryResponse::degraded(results, reason)
                                }
                                None => QueryResponse::complete(results),
                            }
                        })
                        .collect()
                })
            });
        Ok(per_chunk.into_iter().flatten().collect())
    }

    /// Probe one query under resolved per-request params. One-shot
    /// parameterizations take the classic probe; early-stop/chunked ones
    /// open a resumable session and extend it in `extend_step` slices
    /// until `min_candidates` are gathered, the budget is spent, or the
    /// index runs dry. A `deadline` forces the session path even for
    /// one-shot requests (STREAM_BLOCK slices — the candidate stream is
    /// block-size-independent, so the prefix is unchanged) and returns
    /// `Some(Deadline)` when the clock cuts the probe short; `out` then
    /// holds the best-bounded prefix gathered so far.
    fn probe_one(
        &self,
        qcode: C,
        rp: &ResolvedQueryParams,
        deadline: Option<Instant>,
        out: &mut Vec<ItemId>,
    ) -> Option<DegradeReason> {
        if rp.one_shot() && deadline.is_none() {
            self.index.probe_with_code(qcode, rp.probe_budget, out);
            return None;
        }
        let mut session = self.index.prober_with_code(qcode);
        let block = if rp.one_shot() { STREAM_BLOCK } else { rp.extend_step };
        let mut emitted = 0usize;
        let mut spent = 0usize;
        while spent < rp.probe_budget && emitted < rp.min_candidates {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Some(DegradeReason::Deadline);
            }
            let step = block.min(rp.probe_budget - spent);
            let got = session.extend(step, out);
            emitted += got;
            spent += step;
            if got < step {
                break; // index exhausted
            }
        }
        None
    }

    /// Fused probe + re-rank for one query (§Perf, the streaming path):
    /// extend the probe session in blocks and feed each block straight
    /// into a [`BoundedTopK`]. Three savings over probe-then-re-rank:
    /// candidates whose `‖q‖·‖x‖` bound cannot beat the kth score are
    /// never dotted; admitted rows are read from the range-ordered
    /// [`RerankView`] (contiguous per probed range) instead of gathered
    /// across the original matrix; and the whole query stops — further
    /// candidates never even emitted — once the session's remaining norm
    /// bound `‖q‖·U_j` falls below the kth score.
    ///
    /// Results are bit-identical to the exhaustive path: the candidate
    /// stream prefix is block-size-independent (the PR 3 session
    /// contract), the stopping points of adaptive requests mirror
    /// [`Self::probe_one`] exactly (`extend_step` blocks, `min_candidates`
    /// checks), every skipped candidate is provably outside the top-k
    /// (see [`BoundedTopK`]), and view dots are bit-equal to dataset dots.
    ///
    /// Deadline semantics: `rp.time_budget` is anchored at `t0` (batch
    /// entry) and checked at the top of every block — deadline-degraded
    /// answers hold the exact top-k over the probed prefix, never a
    /// half-scored block. A budget already expired at the first check
    /// (e.g. zero) degrades with empty results rather than probing.
    fn search_streaming(
        &self,
        qcode: C,
        q: &[f32],
        rp: &ResolvedQueryParams,
        t0: Instant,
    ) -> QueryResponse {
        thread_local! {
            /// Per-worker block + admitted-candidate scratch (ids, then
            /// (slot, id) pairs surviving admission) — no allocation per
            /// query once a thread is warm.
            static STREAM_SCRATCH: std::cell::RefCell<(Vec<ItemId>, Vec<(usize, ItemId)>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        // staticcheck: allow(panic, "constructor invariant: streaming-mode engines are always built with a RerankView")
        let view = self.view.as_ref().expect("streaming engines carry a RerankView");
        let q_norm = crate::data::dot_slices(q, q).sqrt();
        let mut acc = BoundedTopK::new(rp.top_k, q_norm, self.dataset.dim());
        let mut session = self.index.prober_with_code(qcode);
        // One-shot requests stream in fixed blocks; adaptive requests keep
        // their `extend_step` blocks so the `min_candidates` stopping
        // points (and thus the probed prefix) match `probe_one` exactly.
        let step = if rp.one_shot() { STREAM_BLOCK } else { rp.extend_step };
        let deadline = rp.time_budget.map(|tb| t0 + tb);
        let mut spent = 0usize;
        let mut emitted = 0usize;
        let mut expired = false;
        STREAM_SCRATCH.with(|scratch| {
            let (block, admitted) = &mut *scratch.borrow_mut();
            while spent < rp.probe_budget {
                if let Some(bound) = session.norm_bound() {
                    if !acc.would_admit(bound) {
                        break; // nothing left in the schedule can enter the top-k
                    }
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    expired = true; // return the best-so-far top-k, tagged
                    break;
                }
                let take = step.min(rp.probe_budget - spent);
                block.clear();
                let got = session.extend(take, block);
                admitted.clear();
                for &id in block.iter() {
                    let slot = view.slot_of(id);
                    if acc.offer(view.norm_at(slot)) {
                        admitted.push((slot, id));
                    }
                }
                let mut quads = admitted.chunks_exact(4);
                for quad in quads.by_ref() {
                    let s =
                        // staticcheck: allow(panic, "chunks_exact(4) yields exactly 4-element windows")
                        view.dot4_at([quad[0].0, quad[1].0, quad[2].0, quad[3].0], q);
                    for (i, &(_, id)) in quad.iter().enumerate() {
                        // staticcheck: allow(panic, "dot4_at returns [f32; 4] and i < 4 from the 4-element quad")
                        acc.insert(s[i], id);
                    }
                }
                for &(slot, id) in quads.remainder() {
                    acc.insert(view.dot_at(slot, q), id);
                }
                spent += take;
                emitted += got;
                if got < take {
                    break; // index exhausted
                }
                if !rp.one_shot() && emitted >= rp.min_candidates {
                    break; // early-stop target reached (same as probe_one)
                }
            }
        });
        self.metrics.record_query(t0.elapsed().as_micros() as u64, emitted);
        let results: Vec<SearchResult> = acc
            .into_sorted()
            .into_iter()
            .map(|(score, id)| SearchResult { id, score })
            .collect();
        if expired {
            self.metrics.record_degraded();
            QueryResponse::degraded(results, DegradeReason::Deadline)
        } else {
            QueryResponse::complete(results)
        }
    }
}

/// A [`SearchEngine`] monomorphized to the narrowest code word that fits
/// the configured `code_bits` — the dispatch point between the config
/// layer (`ServeConfig::code_bits`, 1..=256) and the typed engines. The
/// match happens once at build time; every query thereafter runs fully
/// monomorphized code.
pub enum AnyEngine {
    W64(Arc<SearchEngine<u64>>),
    W128(Arc<SearchEngine<Code128>>),
    W256(Arc<SearchEngine<Code256>>),
}

impl AnyEngine {
    /// Build a native-hashed RANGE-LSH engine at the width selected by
    /// `cfg.code_bits`. `u64` keeps its historical 64-wide panel; wider
    /// engines use a panel exactly as wide as the per-range hash bits.
    pub fn build_native_range(
        items: Arc<Dataset>,
        params: RangeLshParams,
        seed: u64,
        cfg: ServeConfig,
    ) -> Result<AnyEngine> {
        Self::build_range_auto(items, params, seed, cfg, None)
    }

    /// [`AnyEngine::build_native_range`] with backend selection: prefer
    /// the AOT Pallas kernel (PJRT) for bulk hashing when `runtime`
    /// holds a loaded artifact directory whose geometry matches the
    /// selected width arm — same dataset dim, manifest `code_words`
    /// equal to the arm's word count, and a panel at least as wide as
    /// the per-range hash bits. Any mismatch (or `runtime == None`)
    /// degrades to the blocked native path, byte-for-byte the engine
    /// `build_native_range` produces.
    ///
    /// When PJRT is selected the engine's panel is the artifact's full
    /// `proj_width` (shared by the native query hasher fallback inside
    /// the index), and the index masks codes down to `hash_bits` —
    /// exactly the convention the `u64` path has always used with its
    /// 64-wide panel.
    pub fn build_range_auto(
        items: Arc<Dataset>,
        params: RangeLshParams,
        seed: u64,
        cfg: ServeConfig,
        runtime: Option<&RuntimeHandle>,
    ) -> Result<AnyEngine> {
        anyhow::ensure!(
            cfg.code_bits >= 1 && cfg.code_bits <= MAX_CODE_BITS,
            "code_bits {} out of range 1..={MAX_CODE_BITS}",
            cfg.code_bits
        );
        anyhow::ensure!(
            params.code_bits == cfg.code_bits,
            "index code_bits {} != serve code_bits {}",
            params.code_bits,
            cfg.code_bits
        );
        if cfg.code_bits <= 64 {
            Ok(AnyEngine::W64(Arc::new(build_arm::<u64>(items, params, seed, cfg, 64, runtime)?)))
        } else if cfg.code_bits <= 128 {
            let width = params.hash_bits();
            Ok(AnyEngine::W128(Arc::new(build_arm::<Code128>(
                items, params, seed, cfg, width, runtime,
            )?)))
        } else {
            let width = params.hash_bits();
            Ok(AnyEngine::W256(Arc::new(build_arm::<Code256>(
                items, params, seed, cfg, width, runtime,
            )?)))
        }
    }

    /// Wrap a loaded index of whatever width the file declared, hashing
    /// queries natively with the index's own panel.
    pub fn from_loaded(
        index: AnyRangeLshIndex,
        items: Arc<Dataset>,
        cfg: ServeConfig,
    ) -> Result<AnyEngine> {
        Self::from_loaded_with(index, items, cfg, None)
    }

    /// [`AnyEngine::from_loaded`] with backend selection: when `runtime`
    /// can hash with the index's stored panel at the file's width (an
    /// index originally built through the PJRT path stores the
    /// artifact-width panel, so geometry matches), queries batch through
    /// the kernel; otherwise native hashing with the same panel —
    /// identical codes either way.
    pub fn from_loaded_with(
        index: AnyRangeLshIndex,
        items: Arc<Dataset>,
        cfg: ServeConfig,
        runtime: Option<&RuntimeHandle>,
    ) -> Result<AnyEngine> {
        match index {
            AnyRangeLshIndex::W64(mut i) => {
                apply_probe_backend(&mut i, &cfg);
                let hasher = pick_hasher::<u64>(runtime, i.projection().clone());
                Ok(AnyEngine::W64(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
            AnyRangeLshIndex::W128(mut i) => {
                apply_probe_backend(&mut i, &cfg);
                let hasher = pick_hasher::<Code128>(runtime, i.projection().clone());
                Ok(AnyEngine::W128(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
            AnyRangeLshIndex::W256(mut i) => {
                apply_probe_backend(&mut i, &cfg);
                let hasher = pick_hasher::<Code256>(runtime, i.projection().clone());
                Ok(AnyEngine::W256(Arc::new(SearchEngine::new(Arc::new(i), items, hasher, cfg)?)))
            }
        }
    }

    /// Which bulk-hashing backend the selected arm runs ("native"/"pjrt").
    pub fn hasher_backend(&self) -> &'static str {
        match self {
            Self::W64(e) => e.hasher_backend(),
            Self::W128(e) => e.hasher_backend(),
            Self::W256(e) => e.hasher_backend(),
        }
    }

    /// Words per code (1, 2 or 4).
    pub fn code_words(&self) -> usize {
        match self {
            Self::W64(_) => 1,
            Self::W128(_) => 2,
            Self::W256(_) => 4,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        match self {
            Self::W64(e) => e.metrics(),
            Self::W128(e) => e.metrics(),
            Self::W256(e) => e.metrics(),
        }
    }

    pub fn search(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        self.search_with(query, &QueryParams::default())
    }

    /// Width-erased [`SearchEngine::search_with`]: per-request overrides
    /// of the serving defaults.
    pub fn search_with(&self, query: &[f32], params: &QueryParams) -> Result<Vec<SearchResult>> {
        match self {
            Self::W64(e) => e.search_with(query, params),
            Self::W128(e) => e.search_with(query, params),
            Self::W256(e) => e.search_with(query, params),
        }
    }

    /// Width-erased [`SearchEngine::search_full`]: the degraded-aware
    /// envelope entry point.
    pub fn search_full(&self, query: &[f32], params: &QueryParams) -> Result<QueryResponse> {
        match self {
            Self::W64(e) => e.search_full(query, params),
            Self::W128(e) => e.search_full(query, params),
            Self::W256(e) => e.search_full(query, params),
        }
    }

    /// Width-erased [`SearchEngine::search_batch_full`].
    pub fn search_batch_full(
        &self,
        rows: &[f32],
        params: &[QueryParams],
    ) -> Result<Vec<QueryResponse>> {
        match self {
            Self::W64(e) => e.search_batch_full(rows, params),
            Self::W128(e) => e.search_batch_full(rows, params),
            Self::W256(e) => e.search_batch_full(rows, params),
        }
    }

    pub fn search_batch(&self, rows: &[f32]) -> Result<Vec<Vec<SearchResult>>> {
        match self {
            Self::W64(e) => e.search_batch(rows),
            Self::W128(e) => e.search_batch(rows),
            Self::W256(e) => e.search_batch(rows),
        }
    }

    /// Width-erased [`SearchEngine::search_batch_with`].
    pub fn search_batch_with(
        &self,
        rows: &[f32],
        params: &QueryParams,
    ) -> Result<Vec<Vec<SearchResult>>> {
        match self {
            Self::W64(e) => e.search_batch_with(rows, params),
            Self::W128(e) => e.search_batch_with(rows, params),
            Self::W256(e) => e.search_batch_with(rows, params),
        }
    }
}

/// Build one width arm. `native_width` is the panel width of the native
/// path (64 for the `u64` arm, `hash_bits` for the wide arms); a
/// matching PJRT runtime overrides it with the artifact's `proj_width`
/// so kernel and panel geometry agree.
fn build_arm<C: CodeWord>(
    items: Arc<Dataset>,
    params: RangeLshParams,
    seed: u64,
    cfg: ServeConfig,
    native_width: usize,
    runtime: Option<&RuntimeHandle>,
) -> Result<SearchEngine<C>> {
    if let Some(rt) = runtime {
        let m = rt.manifest();
        if m.code_words == C::WORDS
            && rt.supports_dim(items.dim())
            && m.proj_width >= params.hash_bits()
        {
            let proj = Arc::new(Projection::gaussian(items.dim() + 1, m.proj_width, seed));
            // `new` re-checks the geometry; a residual mismatch (or the
            // stub backend) falls through to native rather than failing
            // the build — with the reason on stderr so "why not PJRT?"
            // is answerable from the log.
            match PjrtHasher::<C>::new(rt.clone(), proj) {
                Ok(h) => {
                    let hasher: Arc<dyn ItemHasher<C>> = Arc::new(h);
                    let mut index = RangeLshIndex::build(&items, hasher.as_ref(), params)?;
                    apply_probe_backend(&mut index, &cfg);
                    let index: Arc<RangeLshIndex<C>> = Arc::new(index);
                    return SearchEngine::new(index, items, hasher, cfg);
                }
                Err(e) => {
                    eprintln!("[rangelsh] pjrt hasher unavailable, using native: {e:#}");
                }
            }
        }
    }
    let hasher: Arc<NativeHasher<C>> =
        Arc::new(NativeHasher::new(items.dim(), native_width, seed));
    let mut index = RangeLshIndex::build(&items, hasher.as_ref(), params)?;
    apply_probe_backend(&mut index, &cfg);
    let index: Arc<RangeLshIndex<C>> = Arc::new(index);
    SearchEngine::new(index, items, hasher, cfg)
}

/// Attach or drop the index's MIH chunk tables per the configured
/// candidate-generation backend; `Auto` gates on the index's own total
/// code budget (MIH at `code_bits >= 128`). `enable_mih` is a no-op when
/// the tables are already present (e.g. loaded from a `.rlsh` file), so
/// persisted tables are served as-is rather than rebuilt.
fn apply_probe_backend<C: CodeWord>(index: &mut RangeLshIndex<C>, cfg: &ServeConfig) {
    match cfg.probe_backend.resolve(index.params().code_bits) {
        ProbeBackend::Mih => index.enable_mih(),
        _ => index.clear_mih(),
    }
}

/// The query-hashing backend for a loaded index's stored panel: PJRT
/// when the runtime accepts the panel's geometry, native otherwise.
fn pick_hasher<C: CodeWord>(
    runtime: Option<&RuntimeHandle>,
    proj: Arc<Projection>,
) -> Arc<dyn ItemHasher<C>> {
    if let Some(rt) = runtime {
        match PjrtHasher::<C>::new(rt.clone(), proj.clone()) {
            Ok(h) => return Arc::new(h),
            Err(e) => {
                eprintln!("[rangelsh] pjrt hasher unavailable, using native: {e:#}");
            }
        }
    }
    Arc::new(NativeHasher::<C>::with_projection(proj))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn engine(budget: usize) -> (Arc<Dataset>, SearchEngine) {
        let d = Arc::new(synthetic::longtail_sift(2000, 16, 0));
        let h = Arc::new(NativeHasher::<u64>::new(16, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 16)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: budget, top_k: 10, ..Default::default() };
        let e = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        (d, e)
    }

    #[test]
    fn search_returns_k_descending_results() {
        let (_, e) = engine(500);
        let q = synthetic::gaussian_queries(1, 16, 2);
        let res = e.search(q.row(0)).unwrap();
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn full_budget_recovers_exact_topk() {
        let (d, e) = engine(usize::MAX);
        let q = synthetic::gaussian_queries(3, 16, 3);
        let gt = crate::eval::exact_topk(&d, &q, 10);
        for qi in 0..q.len() {
            let res = e.search(q.row(qi)).unwrap();
            let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
            assert_eq!(ids, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn batch_matches_single_queries() {
        let (_, e) = engine(300);
        let q = synthetic::gaussian_queries(8, 16, 4);
        let batch = e.search_batch(q.flat()).unwrap();
        assert_eq!(batch.len(), 8);
        for qi in 0..8 {
            let single = e.search(q.row(qi)).unwrap();
            assert_eq!(batch[qi], single, "query {qi}");
        }
    }

    #[test]
    fn batch_over_simple_index_uses_batched_scan_and_matches_single() {
        // SIMPLE-LSH overrides probe_batch_with_codes with the shared
        // codes-vector scan (an Exhaustive-mode path: streaming probes
        // per-query sessions instead); the engine's chunked batch path
        // must still agree with per-query searches exactly.
        use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
        let d = Arc::new(synthetic::longtail_sift(1500, 16, 20));
        let h = Arc::new(NativeHasher::<u64>::new(16, 64, 21));
        let idx =
            Arc::new(SimpleLshIndex::build(&d, h.as_ref(), SimpleLshParams::new(16)).unwrap());
        let cfg = ServeConfig {
            probe_budget: 200,
            top_k: 10,
            rerank: RerankMode::Exhaustive,
            ..Default::default()
        };
        let e = SearchEngine::new(idx, d, h, cfg).unwrap();
        let q = synthetic::gaussian_queries(9, 16, 22);
        let batch = e.search_batch(q.flat()).unwrap();
        assert_eq!(batch.len(), 9);
        for qi in 0..9 {
            assert_eq!(batch[qi], e.search(q.row(qi)).unwrap(), "query {qi}");
        }
    }

    /// Build streaming + exhaustive twins over one shared index/hasher.
    fn engine_twins(
        d: &Arc<Dataset>,
        budget: usize,
        k: usize,
    ) -> (SearchEngine, SearchEngine) {
        let h = Arc::new(NativeHasher::<u64>::new(d.dim(), 64, 1));
        let idx: Arc<RangeLshIndex> = Arc::new(
            RangeLshIndex::build(d, h.as_ref(), RangeLshParams::new(16, 16)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: budget, top_k: k, ..Default::default() };
        let streaming =
            SearchEngine::new(idx.clone(), d.clone(), h.clone(), cfg.clone()).unwrap();
        let cfg = ServeConfig { rerank: RerankMode::Exhaustive, ..cfg };
        let exhaustive = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        (streaming, exhaustive)
    }

    /// ids and score *bits* must agree — the streaming path's equivalence
    /// contract is bit-exact, not approximate.
    fn assert_results_bit_equal(a: &[SearchResult], b: &[SearchResult], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: lengths");
        for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
            assert_eq!(ra.id, rb.id, "{ctx}: id at {i}");
            assert_eq!(ra.score.to_bits(), rb.score.to_bits(), "{ctx}: score bits at {i}");
        }
    }

    #[test]
    fn streaming_rerank_matches_exhaustive_bitwise() {
        let d = Arc::new(synthetic::longtail_sift(2000, 16, 70));
        let (s, e) = engine_twins(&d, 500, 10);
        let q = synthetic::gaussian_queries(6, 16, 71);
        // Default params, per-request k/budget overrides, and adaptive
        // (min_candidates/extend_step) requests all agree bit for bit.
        let params = [
            QueryParams::default(),
            QueryParams::new().with_top_k(1),
            QueryParams::new().with_top_k(25).with_probe_budget(usize::MAX),
            QueryParams::new().with_probe_budget(64),
            QueryParams::new().with_min_candidates(50).with_extend_step(16),
        ];
        for (pi, p) in params.iter().enumerate() {
            for qi in 0..q.len() {
                assert_results_bit_equal(
                    &s.search_with(q.row(qi), p).unwrap(),
                    &e.search_with(q.row(qi), p).unwrap(),
                    &format!("params {pi} query {qi}"),
                );
            }
        }
        // Batched entry point too (uniform and heterogeneous).
        let sb = s.search_batch(q.flat()).unwrap();
        let eb = e.search_batch(q.flat()).unwrap();
        for qi in 0..q.len() {
            assert_results_bit_equal(&sb[qi], &eb[qi], &format!("batch query {qi}"));
        }
        let hetero: Vec<QueryParams> = (0..q.len())
            .map(|i| params[i % params.len()])
            .collect();
        let sb = s.search_batch_params(q.flat(), &hetero).unwrap();
        let eb = e.search_batch_params(q.flat(), &hetero).unwrap();
        for qi in 0..q.len() {
            assert_results_bit_equal(&sb[qi], &eb[qi], &format!("hetero query {qi}"));
        }
    }

    #[test]
    fn streaming_early_out_stops_probing_whole_queries() {
        // One huge query-aligned item: once it is scored, the schedule's
        // remaining ‖q‖·U_j bound collapses below the kth score and the
        // session is abandoned — most of the index is never even probed.
        let q = synthetic::gaussian_queries(1, 16, 80);
        let base = synthetic::longtail_sift(2000, 16, 81);
        let mut rows: Vec<Vec<f32>> = (0..2000).map(|i| base.row(i).to_vec()).collect();
        rows.push(q.row(0).iter().map(|v| v * 1000.0).collect());
        let d = Arc::new(Dataset::from_rows(&rows));
        let (s, e) = engine_twins(&d, usize::MAX, 1);
        let got = s.search(q.row(0)).unwrap();
        assert_results_bit_equal(&got, &e.search(q.row(0)).unwrap(), "early-out query");
        assert_eq!(got[0].id, 2000, "the planted item must win");
        let probed = s.metrics().snapshot().mean_probed;
        assert!(
            probed < 1500.0,
            "early-out should abandon most of the 2001-item stream, probed {probed}"
        );
        assert_eq!(e.metrics().snapshot().mean_probed, 2001.0, "oracle probes everything");
    }

    #[test]
    fn streaming_handles_all_zero_queries() {
        // ‖q‖ = 0: every bound is 0, nothing may be pruned, and the
        // answers (all scores ±0.0) must still match the oracle bitwise.
        let d = Arc::new(synthetic::longtail_sift(800, 16, 90));
        let (s, e) = engine_twins(&d, usize::MAX, 10);
        let zero = vec![0.0f32; 16];
        let got = s.search(&zero).unwrap();
        assert_eq!(got.len(), 10);
        assert_results_bit_equal(&got, &e.search(&zero).unwrap(), "zero query");
    }

    #[test]
    fn streaming_serves_norm_bound_free_indexes() {
        // SIMPLE-LSH probers report no norm bound (norm_bound = None), so
        // streaming gets per-candidate pruning but no whole-query
        // early-out — and must still match the oracle exactly.
        use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
        let d = Arc::new(synthetic::longtail_sift(1200, 16, 91));
        let h = Arc::new(NativeHasher::<u64>::new(16, 64, 92));
        let idx =
            Arc::new(SimpleLshIndex::build(&d, h.as_ref(), SimpleLshParams::new(16)).unwrap());
        let cfg = ServeConfig { probe_budget: 300, top_k: 5, ..Default::default() };
        let s = SearchEngine::new(idx.clone(), d.clone(), h.clone(), cfg.clone()).unwrap();
        let cfg = ServeConfig { rerank: RerankMode::Exhaustive, ..cfg };
        let e = SearchEngine::new(idx, d, h, cfg).unwrap();
        let q = synthetic::gaussian_queries(5, 16, 93);
        for qi in 0..q.len() {
            assert_results_bit_equal(
                &s.search(q.row(qi)).unwrap(),
                &e.search(q.row(qi)).unwrap(),
                &format!("query {qi}"),
            );
        }
    }

    #[test]
    fn per_request_params_override_serving_defaults() {
        let (d, e) = engine(500);
        let q = synthetic::gaussian_queries(1, 16, 30);
        // k override: fewer results than the engine default of 10.
        let res = e.search_with(q.row(0), &QueryParams::new().with_top_k(3)).unwrap();
        assert_eq!(res.len(), 3);
        // Budget override to exhaustive recovers the exact top-k even
        // though the engine default budget is 500.
        let gt = crate::eval::exact_topk(&d, &q, 10);
        let res = e
            .search_with(q.row(0), &QueryParams::new().with_probe_budget(usize::MAX))
            .unwrap();
        let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, gt[0]);
    }

    #[test]
    fn session_probing_matches_one_shot_results() {
        // extend_step 1 with min_candidates == budget walks the whole
        // budget through a session one candidate at a time; the answers
        // must be identical to the classic one-shot probe.
        let (_, e) = engine(300);
        let q = synthetic::gaussian_queries(4, 16, 31);
        let chunked = QueryParams::new().with_extend_step(1).with_min_candidates(300);
        for qi in 0..q.len() {
            let want = e.search(q.row(qi)).unwrap();
            let got = e.search_with(q.row(qi), &chunked).unwrap();
            assert_eq!(got, want, "query {qi}");
        }
    }

    #[test]
    fn min_candidates_early_stop_is_a_prefix_of_the_stream() {
        // Early stop probes fewer items but the candidates it re-ranks
        // are a prefix of the one-shot probe stream, so every returned id
        // must also be in the full-budget answer's candidate set.
        let (_, e) = engine(400);
        let q = synthetic::gaussian_queries(1, 16, 32);
        let adaptive = QueryParams::new().with_min_candidates(50).with_extend_step(16);
        let res = e.search_with(q.row(0), &adaptive).unwrap();
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        // Determinism: the same request twice gives the same answer.
        assert_eq!(res, e.search_with(q.row(0), &adaptive).unwrap());
    }

    #[test]
    fn heterogeneous_batch_params_match_single_queries() {
        let (_, e) = engine(300);
        let q = synthetic::gaussian_queries(6, 16, 33);
        let params: Vec<QueryParams> = (0..6)
            .map(|i| match i % 3 {
                0 => QueryParams::default(),
                1 => QueryParams::new().with_top_k(1 + i),
                _ => QueryParams::new().with_probe_budget(100 + i),
            })
            .collect();
        let batch = e.search_batch_params(q.flat(), &params).unwrap();
        assert_eq!(batch.len(), 6);
        for (qi, p) in params.iter().enumerate() {
            let single = e.search_with(q.row(qi), p).unwrap();
            assert_eq!(batch[qi], single, "query {qi}");
        }
        // Length-1 params slice applies to the whole batch.
        let uniform = QueryParams::new().with_top_k(2);
        let batch = e.search_batch_with(q.flat(), &uniform).unwrap();
        assert!(batch.iter().all(|r| r.len() == 2));
        // Wrong params length is rejected.
        assert!(e.search_batch_params(q.flat(), &params[..3]).is_err());
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let (d, e) = engine(400);
        let q = synthetic::gaussian_queries(1, 16, 5);
        for r in e.search(q.row(0)).unwrap() {
            let want = d.dot(r.id as usize, q.row(0));
            assert!((r.score - want).abs() < 1e-6);
        }
    }

    #[test]
    fn metrics_accumulate() {
        let (_, e) = engine(100);
        let q = synthetic::gaussian_queries(5, 16, 6);
        e.search_batch(q.flat()).unwrap();
        let s = e.metrics().snapshot();
        assert_eq!(s.queries, 5);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_rows, 5.0);
        assert!(s.mean_probed > 0.0);
    }

    #[test]
    fn rejects_misaligned_batch() {
        let (_, e) = engine(100);
        assert!(e.search_batch(&[0.0; 17]).is_err());
        assert!(e.search_batch(&[]).is_err());
    }

    #[test]
    fn rejects_budget_below_top_k() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 0));
        let h = Arc::new(NativeHasher::<u64>::new(8, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: 5, top_k: 10, ..Default::default() };
        assert!(SearchEngine::new(idx, d, h, cfg).is_err());
    }

    #[test]
    fn wide_engine_serves_end_to_end() {
        // code_bits = 128 through the whole path: build → probe → re-rank.
        let d = Arc::new(synthetic::longtail_sift(1500, 16, 7));
        let params = RangeLshParams::new(128, 16);
        let h = Arc::new(NativeHasher::<Code128>::new(16, params.hash_bits(), 8));
        let idx = Arc::new(RangeLshIndex::build(&d, h.as_ref(), params).unwrap());
        let cfg = ServeConfig {
            probe_budget: usize::MAX,
            top_k: 10,
            code_bits: 128,
            ..Default::default()
        };
        let e: SearchEngine<Code128> = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        let q = synthetic::gaussian_queries(4, 16, 9);
        let gt = crate::eval::exact_topk(&d, &q, 10);
        for qi in 0..q.len() {
            let res = e.search(q.row(qi)).unwrap();
            let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
            assert_eq!(ids, gt[qi], "query {qi}: wide engine must recover exact top-k");
        }
    }

    #[test]
    fn any_engine_dispatches_on_code_bits() {
        let d = Arc::new(synthetic::longtail_sift(800, 8, 10));
        for (bits, words) in [(32usize, 1usize), (128, 2), (256, 4)] {
            let cfg = ServeConfig {
                probe_budget: 200,
                top_k: 5,
                code_bits: bits,
                ..Default::default()
            };
            let engine = AnyEngine::build_native_range(
                d.clone(),
                RangeLshParams::new(bits, 8),
                11,
                cfg,
            )
            .unwrap();
            assert_eq!(engine.code_words(), words, "bits {bits}");
            let q = synthetic::gaussian_queries(2, 8, 12);
            let res = engine.search_batch(q.flat()).unwrap();
            assert_eq!(res.len(), 2);
            assert!(res.iter().all(|r| r.len() == 5));
        }
    }

    #[test]
    fn any_engine_rejects_mismatched_bits() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 13));
        let cfg = ServeConfig { code_bits: 64, ..Default::default() };
        assert!(AnyEngine::build_native_range(d, RangeLshParams::new(128, 8), 1, cfg).is_err());
    }

    #[test]
    fn wide_any_engine_batch_recovers_exact_topk() {
        // code_bits 128/256 through the full batched path: blocked item
        // hashing at build, bulk query hashing, chunked probe + re-rank.
        // Full budget must recover the exact top-k at every width, and
        // the batch must agree with per-query searches exactly.
        let d = Arc::new(synthetic::longtail_sift(1200, 12, 50));
        let q = synthetic::gaussian_queries(6, 12, 51);
        let gt = crate::eval::exact_topk(&d, &q, 5);
        for bits in [128usize, 256] {
            let cfg = ServeConfig {
                probe_budget: usize::MAX,
                top_k: 5,
                code_bits: bits,
                ..Default::default()
            };
            let engine = AnyEngine::build_native_range(
                d.clone(),
                RangeLshParams::new(bits, 8),
                52,
                cfg,
            )
            .unwrap();
            assert_eq!(engine.hasher_backend(), "native", "no artifacts in unit tests");
            let batch = engine.search_batch(q.flat()).unwrap();
            assert_eq!(batch.len(), q.len());
            for qi in 0..q.len() {
                let ids: Vec<ItemId> = batch[qi].iter().map(|r| r.id).collect();
                assert_eq!(ids, gt[qi], "bits {bits} query {qi}");
                assert_eq!(batch[qi], engine.search(q.row(qi)).unwrap(), "bits {bits} q {qi}");
            }
        }
    }

    #[test]
    fn probe_backend_selection_is_answer_invariant() {
        // The MIH backend is a candidate-generation strategy, not a
        // different index: explicit mih / counting_sort / auto engines
        // must return identical answers at every width.
        let d = Arc::new(synthetic::longtail_sift(900, 8, 40));
        let q = synthetic::gaussian_queries(4, 8, 41);
        for bits in [32usize, 128] {
            let engines: Vec<AnyEngine> = [
                ProbeBackend::Auto,
                ProbeBackend::CountingSort,
                ProbeBackend::Mih,
            ]
            .into_iter()
            .map(|backend| {
                let cfg = ServeConfig {
                    probe_budget: 200,
                    top_k: 5,
                    code_bits: bits,
                    probe_backend: backend,
                    ..Default::default()
                };
                AnyEngine::build_native_range(d.clone(), RangeLshParams::new(bits, 8), 42, cfg)
                    .unwrap()
            })
            .collect();
            for qi in 0..q.len() {
                let want = engines[0].search(q.row(qi)).unwrap();
                for (ei, e) in engines.iter().enumerate().skip(1) {
                    assert_eq!(
                        e.search(q.row(qi)).unwrap(),
                        want,
                        "bits {bits} engine {ei} query {qi}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_time_budget_degrades_before_probing() {
        // An already-expired budget must not hang or error: both rerank
        // modes return an (empty) degraded answer tagged Deadline, and
        // the degraded counter ticks.
        let d = Arc::new(synthetic::longtail_sift(2000, 16, 70));
        let (s, e) = engine_twins(&d, usize::MAX, 10);
        let q = synthetic::gaussian_queries(1, 16, 71);
        let p = QueryParams::new().with_time_budget(std::time::Duration::ZERO);
        for (name, engine) in [("streaming", &s), ("exhaustive", &e)] {
            let resp = engine.search_full(q.row(0), &p).unwrap();
            assert!(resp.is_degraded(), "{name}: zero budget must degrade");
            assert_eq!(
                resp.degraded.as_ref().unwrap().reason,
                crate::coordinator::fault::DegradeReason::Deadline,
                "{name}"
            );
            assert!(resp.results.is_empty(), "{name}: nothing probed before expiry");
            assert_eq!(engine.metrics().snapshot().queries_degraded, 1, "{name}");
        }
    }

    #[test]
    fn generous_time_budget_is_answer_invariant() {
        // A budget that never expires must leave answers bit-identical to
        // the budget-less run — the deadline check sits between extend
        // blocks and must not perturb the stream.
        let d = Arc::new(synthetic::longtail_sift(1500, 16, 72));
        let (s, e) = engine_twins(&d, 400, 10);
        let q = synthetic::gaussian_queries(4, 16, 73);
        let generous = QueryParams::new().with_time_budget(std::time::Duration::from_secs(600));
        for engine in [&s, &e] {
            for qi in 0..q.len() {
                let resp = engine.search_full(q.row(qi), &generous).unwrap();
                assert!(!resp.is_degraded(), "query {qi}: 10min budget expired?");
                assert_results_bit_equal(
                    &resp.results,
                    &engine.search(q.row(qi)).unwrap(),
                    &format!("query {qi}"),
                );
            }
        }
    }

    #[test]
    fn deadline_mid_session_returns_probed_prefix_topk() {
        // Expiry at an extend boundary: where exactly the clock cuts the
        // session is wall-clock-dependent, so assert the envelope
        // invariant instead of a fixed cut point — a deadline-tagged
        // answer is a descending top-k of exact scores over the probed
        // prefix, and an untagged answer is the complete one (bit-equal
        // to a budget-less run). With 1µs over 4000 items the degraded
        // branch is what actually executes.
        let d = Arc::new(synthetic::longtail_sift(4000, 16, 74));
        let (s, _) = engine_twins(&d, usize::MAX, 5);
        let q = synthetic::gaussian_queries(1, 16, 75);
        let tight = QueryParams::new()
            .with_extend_step(64)
            .with_min_candidates(usize::MAX >> 1)
            .with_time_budget(std::time::Duration::from_micros(1));
        let resp = s.search_full(q.row(0), &tight).unwrap();
        match &resp.degraded {
            Some(tag) => {
                assert_eq!(tag.reason, crate::coordinator::fault::DegradeReason::Deadline);
                for w in resp.results.windows(2) {
                    assert!(w[0].score >= w[1].score, "degraded prefix top-k must stay sorted");
                }
                for r in &resp.results {
                    let want = d.dot(r.id as usize, q.row(0));
                    assert!((r.score - want).abs() < 1e-6, "degraded scores stay exact");
                }
            }
            None => {
                // Only reachable if the whole stream fit inside 1µs —
                // then the answer must equal the budget-less run.
                let free = QueryParams::new()
                    .with_extend_step(64)
                    .with_min_candidates(usize::MAX >> 1);
                assert_results_bit_equal(
                    &resp.results,
                    &s.search_with(q.row(0), &free).unwrap(),
                    "untagged tight-budget answer",
                );
            }
        }
    }

    #[test]
    fn build_range_auto_without_runtime_equals_native_build() {
        // The selection hook's degrade contract: runtime == None must
        // produce an engine whose answers are identical to the plain
        // native build at every width arm.
        let d = Arc::new(synthetic::longtail_sift(600, 8, 60));
        let q = synthetic::gaussian_queries(3, 8, 61);
        for bits in [32usize, 128] {
            let cfg = ServeConfig {
                probe_budget: 150,
                top_k: 5,
                code_bits: bits,
                ..Default::default()
            };
            let params = RangeLshParams::new(bits, 8);
            let auto =
                AnyEngine::build_range_auto(d.clone(), params, 62, cfg.clone(), None).unwrap();
            let native = AnyEngine::build_native_range(d.clone(), params, 62, cfg).unwrap();
            for qi in 0..q.len() {
                assert_eq!(
                    auto.search(q.row(qi)).unwrap(),
                    native.search(q.row(qi)).unwrap(),
                    "bits {bits} query {qi}"
                );
            }
        }
    }
}
