//! The synchronous search core: hash → probe → exact re-rank.

use std::sync::Arc;

use crate::config::ServeConfig;
use crate::coordinator::metrics::Metrics;
use crate::data::Dataset;
use crate::hash::ItemHasher;
use crate::index::CodeProbe;
use crate::runtime::PjrtScorer;
use crate::{ItemId, Result};

/// One ranked answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    pub id: ItemId,
    /// Exact inner product with the query (post re-rank).
    pub score: f32,
}

/// The query-path core. Thread-safe; clone the `Arc` and share.
///
/// The index must implement [`CodeProbe`] (SIMPLE-LSH or RANGE-LSH): the
/// engine hashes queries *in batches* through `hasher` — the PJRT-backed
/// Pallas kernel in production, the native panel in tests — and probes
/// with the resulting codes, so the Python-free hot path is:
/// `PJRT sign-hash kernel → bucket schedule walk → exact re-rank`.
pub struct SearchEngine {
    index: Arc<dyn CodeProbe>,
    dataset: Arc<Dataset>,
    hasher: Arc<dyn ItemHasher>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
}

impl SearchEngine {
    pub fn new(
        index: Arc<dyn CodeProbe>,
        dataset: Arc<Dataset>,
        hasher: Arc<dyn ItemHasher>,
        cfg: ServeConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        anyhow::ensure!(cfg.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(cfg.probe_budget >= cfg.top_k, "budget below top_k");
        Ok(Self {
            index,
            dataset,
            hasher,
            cfg,
            metrics: Arc::new(Metrics::new()),
        })
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.dataset
    }

    /// Search a single query (hashes natively; the batched path is the
    /// production route).
    pub fn search(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        Ok(self.search_batch(query)?.pop().expect("one query in, one out"))
    }

    /// Search a batch of queries laid out row-major (`rows.len()` must be
    /// a multiple of the dataset dim). Hashing is one bulk hasher call
    /// (one or more PJRT blocks); probe + re-rank fan out on rayon.
    pub fn search_batch(&self, rows: &[f32]) -> Result<Vec<Vec<SearchResult>>> {
        let dim = self.dataset.dim();
        anyhow::ensure!(
            !rows.is_empty() && rows.len() % dim == 0,
            "query buffer length {} not a positive multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        let t0 = std::time::Instant::now();
        let codes = self.hasher.hash_queries(rows)?;
        self.metrics.record_batch(n);

        // Each probe costs milliseconds at paper scale: parallelise even
        // small batches (cutoff 2, not the default 64).
        let results: Vec<Vec<SearchResult>> = crate::util::par::par_map_cutoff(n, 2, |qi| {
            let code = codes[qi];
            let q = &rows[qi * dim..(qi + 1) * dim];
            let budget = self.cfg.probe_budget.min(self.dataset.len());
            let mut cands = Vec::with_capacity(budget);
            self.index.probe_with_code(code, self.cfg.probe_budget, &mut cands);
            let probed = cands.len();
            PjrtScorer::rerank(&self.dataset, q, &mut cands, self.cfg.top_k);
            let out: Vec<SearchResult> = cands
                .into_iter()
                .map(|id| SearchResult {
                    id,
                    score: self.dataset.dot(id as usize, q),
                })
                .collect();
            self.metrics
                .record_query(t0.elapsed().as_micros() as u64, probed);
            out
        });
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn engine(budget: usize) -> (Arc<Dataset>, SearchEngine) {
        let d = Arc::new(synthetic::longtail_sift(2000, 16, 0));
        let h = Arc::new(NativeHasher::new(16, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 16)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: budget, top_k: 10, ..Default::default() };
        let e = SearchEngine::new(idx, d.clone(), h, cfg).unwrap();
        (d, e)
    }

    #[test]
    fn search_returns_k_descending_results() {
        let (_, e) = engine(500);
        let q = synthetic::gaussian_queries(1, 16, 2);
        let res = e.search(q.row(0)).unwrap();
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn full_budget_recovers_exact_topk() {
        let (d, e) = engine(usize::MAX);
        let q = synthetic::gaussian_queries(3, 16, 3);
        let gt = crate::eval::exact_topk(&d, &q, 10);
        for qi in 0..q.len() {
            let res = e.search(q.row(qi)).unwrap();
            let ids: Vec<ItemId> = res.iter().map(|r| r.id).collect();
            assert_eq!(ids, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn batch_matches_single_queries() {
        let (_, e) = engine(300);
        let q = synthetic::gaussian_queries(8, 16, 4);
        let batch = e.search_batch(q.flat()).unwrap();
        assert_eq!(batch.len(), 8);
        for qi in 0..8 {
            let single = e.search(q.row(qi)).unwrap();
            assert_eq!(batch[qi], single, "query {qi}");
        }
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let (d, e) = engine(400);
        let q = synthetic::gaussian_queries(1, 16, 5);
        for r in e.search(q.row(0)).unwrap() {
            let want = d.dot(r.id as usize, q.row(0));
            assert!((r.score - want).abs() < 1e-6);
        }
    }

    #[test]
    fn metrics_accumulate() {
        let (_, e) = engine(100);
        let q = synthetic::gaussian_queries(5, 16, 6);
        e.search_batch(q.flat()).unwrap();
        let s = e.metrics().snapshot();
        assert_eq!(s.queries, 5);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_rows, 5.0);
        assert!(s.mean_probed > 0.0);
    }

    #[test]
    fn rejects_misaligned_batch() {
        let (_, e) = engine(100);
        assert!(e.search_batch(&[0.0; 17]).is_err());
        assert!(e.search_batch(&[]).is_err());
    }

    #[test]
    fn rejects_budget_below_top_k() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 0));
        let h = Arc::new(NativeHasher::new(8, 64, 1));
        let idx = Arc::new(
            RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap(),
        );
        let cfg = ServeConfig { probe_budget: 5, top_k: 10, ..Default::default() };
        assert!(SearchEngine::new(idx, d, h, cfg).is_err());
    }
}
