//! Failure model for the serving path: the degraded-result envelope,
//! typed overload/shard-loss errors, and the deterministic fault
//! injection plan behind the chaos tests.
//!
//! RANGE-LSH's probing schedule visits ranges in decreasing upper-bound
//! order, so a query cut short by a deadline still holds the
//! *best-bounded* candidates seen so far — degradation returns that
//! prefix tagged with a [`Degraded`] marker instead of erroring or
//! silently presenting a truncated top-k as complete. See README
//! §"Failure model & degraded serving".

use std::fmt;
use std::time::Duration;

use crate::coordinator::engine::SearchResult;

/// Why a response carries fewer/worse results than a healthy run would.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeReason {
    /// The query's whole time budget was consumed before probing started
    /// (batcher queue wait ate it); the result set is empty.
    BudgetExhausted,
    /// The wall-clock time budget expired between `Prober::extend`
    /// blocks; the results are the best-so-far bounded top-k.
    Deadline,
    /// One or more shards failed past the retry cap; the merge covers
    /// only the surviving shards (which ones died is in
    /// [`Degraded::lost_shards`]).
    ShardLoss,
}

/// Degradation tag on a [`QueryResponse`]. Ordered by severity
/// (`BudgetExhausted < Deadline < ShardLoss`) so a router merging
/// per-shard responses can keep the worst tag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Degraded {
    pub reason: DegradeReason,
    /// Shard indices missing from the merge ([`DegradeReason::ShardLoss`]
    /// only; empty otherwise).
    pub lost_shards: Vec<usize>,
}

impl Degraded {
    pub fn new(reason: DegradeReason) -> Self {
        Self { reason, lost_shards: Vec::new() }
    }

    pub fn shard_loss(mut lost_shards: Vec<usize>) -> Self {
        lost_shards.sort_unstable();
        Self { reason: DegradeReason::ShardLoss, lost_shards }
    }

    /// Keep the more severe of two tags (shard loss outranks a deadline
    /// expiry on one shard, which outranks queue-wait exhaustion).
    pub fn worst(a: Option<Degraded>, b: Option<Degraded>) -> Option<Degraded> {
        match (a, b) {
            (None, x) | (x, None) => x,
            (Some(a), Some(b)) => Some(if b.reason > a.reason { b } else { a }),
        }
    }
}

/// Result envelope for the fault-aware entry points (`search_full`,
/// `query_full`): the ranked results plus an honest account of whether
/// they are complete. The legacy `Vec<SearchResult>` entry points strip
/// the envelope (callers that never set budgets or tolerate shard loss
/// see no change).
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    pub results: Vec<SearchResult>,
    /// `None` = the full, healthy answer.
    pub degraded: Option<Degraded>,
}

impl QueryResponse {
    pub fn complete(results: Vec<SearchResult>) -> Self {
        Self { results, degraded: None }
    }

    pub fn degraded(results: Vec<SearchResult>, reason: DegradeReason) -> Self {
        Self { results, degraded: Some(Degraded::new(reason)) }
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.is_some()
    }

    pub fn into_results(self) -> Vec<SearchResult> {
        self.results
    }
}

/// Typed rejection from the bounded server queue: admitting the request
/// could not possibly answer it within its time budget (or the queue hit
/// its hard bound). Recover via [`crate::Error::downcast_ref`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OverloadedError {
    /// Jobs queued ahead of the rejected request.
    pub queue_depth: usize,
    /// The wait the shedding policy projected for this depth.
    pub projected_wait: Duration,
    /// The budget that projection exceeded (`None` when the queue hit
    /// its hard depth bound instead).
    pub time_budget: Option<Duration>,
}

impl fmt::Display for OverloadedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "server overloaded: {} queued, projected wait {:?}",
            self.queue_depth, self.projected_wait
        )?;
        if let Some(tb) = self.time_budget {
            write!(f, " exceeds time budget {tb:?}")?;
        }
        Ok(())
    }
}

impl std::error::Error for OverloadedError {}

/// Typed router failure: fewer than `min_shards` shards answered even
/// after retries, so no merge is trustworthy enough to return.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardLossError {
    /// `(shard index, final error)` for every shard that failed.
    pub failed: Vec<(usize, String)>,
    /// Shards that did answer.
    pub responded: usize,
    /// The quorum the router was configured to require.
    pub min_shards: usize,
}

impl fmt::Display for ShardLossError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "shard quorum lost: {} of {} required shards responded ({} failed",
            self.responded,
            self.min_shards,
            self.failed.len()
        )?;
        for (i, (shard, err)) in self.failed.iter().enumerate() {
            write!(f, "{} shard {shard}: {err}", if i == 0 { ":" } else { ";" })?;
        }
        f.write_str(")")
    }
}

impl std::error::Error for ShardLossError {}

/// Deterministic fault injection for the chaos tests: a seeded plan maps
/// `(shard, query index, attempt)` to an optional fault, so every run of
/// a given seed exercises the identical failure pattern. Compiled in
/// only for tests and the `fault-injection` feature — release servers
/// carry no injection branch.
#[cfg(any(test, feature = "fault-injection"))]
pub use self::injection::{CrashPoint, Fault, FaultPlan};

#[cfg(any(test, feature = "fault-injection"))]
mod injection {
    use std::time::Duration;

    /// Named crash sites on the mutable store's durability path (README
    /// §"Mutability & recovery model", crash matrix). Each point marks a
    /// distinct window of the WAL/checkpoint protocol; the chaos tests
    /// crash a store at every point and prove reopen + replay recovers a
    /// state bit-identical to the last *acknowledged* mutation.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum CrashPoint {
        /// After the WAL record is appended and fsynced, before the
        /// in-memory epoch applies it: the mutation is acknowledged-
        /// durable, so recovery must REPLAY it.
        PostWalAppend,
        /// After the mutation's new index structures are built in memory,
        /// before the epoch swap publishes them: on-disk state is
        /// identical to [`CrashPoint::PostWalAppend`]; recovery must
        /// still replay the logged record.
        PreApply,
        /// Inside compaction, after the re-partitioned index is built in
        /// memory but before any checkpoint file is written: disk still
        /// holds the pre-compaction checkpoint + WAL, so recovery
        /// reopens the pre-compaction state.
        MidCompaction,
        /// Inside the checkpoint, after the staged temp files are written
        /// and fsynced but before any rename publishes them: the old
        /// manifest still governs, so recovery reopens the
        /// pre-checkpoint state (the temp siblings are dead bytes).
        PreRename,
    }

    /// One injected misbehaviour at a `(shard, query)` site.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Fault {
        /// Sleep before answering (models a slow replica; succeeds).
        Delay(Duration),
        /// Return an error (models a transient RPC failure; retryable).
        Error,
        /// Panic inside the shard call (models a crashed replica; the
        /// router's `catch_unwind` must contain it).
        Panic,
    }

    /// Seeded, deterministic fault schedule. `fault_for` is a pure
    /// function of `(seed, shard, query, attempt)`: a site draws a fault
    /// with probability `rate_pct`, the fault kind and how many attempts
    /// it persists (1..=`persist_max`) are further hash bits. Scripted
    /// overrides pin exact behaviour at chosen sites for unit tests.
    #[derive(Debug, Clone)]
    pub struct FaultPlan {
        seed: u64,
        rate_pct: u32,
        persist_max: u32,
        delay: Duration,
        /// `(shard, query, fault, attempts it persists)` — wins over the
        /// seeded draw at its site.
        scripted: Vec<(usize, u64, Fault, u32)>,
        /// Crash the mutable store at this durability-path site (see
        /// [`CrashPoint`]); `None` = never crash.
        crash_at: Option<CrashPoint>,
    }

    impl FaultPlan {
        pub fn seeded(seed: u64, rate_pct: u32) -> Self {
            Self {
                seed,
                rate_pct: rate_pct.min(100),
                persist_max: 2,
                delay: Duration::from_micros(200),
                scripted: Vec::new(),
                crash_at: None,
            }
        }

        /// Arm a crash at `point` on the store's durability path. The
        /// "crash" is an error return that abandons the operation with
        /// the disk exactly as a real crash at that site would leave it
        /// — the chaos tests then drop the store and reopen the
        /// directory to exercise recovery.
        pub fn with_crash(mut self, point: CrashPoint) -> Self {
            self.crash_at = Some(point);
            self
        }

        /// Fail (once per matching site) when the plan arms `point`.
        /// Called by [`crate::coordinator::store::MutableStore`] at each
        /// named site; a healthy plan is a no-op.
        pub fn crash_if(&self, point: CrashPoint) -> crate::Result<()> {
            match self.crash_at {
                Some(p) if p == point => {
                    Err(anyhow::anyhow!("injected crash at {point:?}"))
                }
                _ => Ok(()),
            }
        }

        /// Cap on how many consecutive attempts a drawn fault persists.
        /// Above the router's retry budget this manufactures shard loss.
        pub fn with_persistence(mut self, attempts: u32) -> Self {
            self.persist_max = attempts.max(1);
            self
        }

        pub fn with_delay(mut self, delay: Duration) -> Self {
            self.delay = delay;
            self
        }

        /// Pin `fault` at `(shard, query)` for the first `attempts`
        /// attempts (then the site behaves healthily).
        pub fn script(mut self, shard: usize, query: u64, fault: Fault, attempts: u32) -> Self {
            self.scripted.push((shard, query, fault, attempts));
            self
        }

        /// The fault (if any) for attempt number `attempt` (0-based) of
        /// `query` on `shard`.
        pub fn fault_for(&self, shard: usize, query: u64, attempt: u32) -> Option<Fault> {
            for &(s, q, fault, attempts) in &self.scripted {
                if s == shard && q == query {
                    return (attempt < attempts).then_some(fault);
                }
            }
            if self.rate_pct == 0 {
                return None;
            }
            let h = mix(self.seed, shard as u64, query);
            if (h % 100) as u32 >= self.rate_pct {
                return None;
            }
            let persists = 1 + ((h >> 8) % self.persist_max as u64) as u32;
            if attempt >= persists {
                return None;
            }
            Some(match (h >> 40) % 3 {
                0 => Fault::Delay(self.delay),
                1 => Fault::Error,
                _ => Fault::Panic,
            })
        }

        /// Execute the fault for this site, if any: sleep, fail, or
        /// panic (contained by the router's `catch_unwind`).
        // staticcheck: allow(panic-reach, "the panic IS the injected fault: FaultPlan routes it into the router's catch_unwind by design (degraded-serving contract)")
        pub fn apply(&self, shard: usize, query: u64, attempt: u32) -> crate::Result<()> {
            match self.fault_for(shard, query, attempt) {
                None => Ok(()),
                Some(Fault::Delay(d)) => {
                    std::thread::sleep(d);
                    Ok(())
                }
                Some(Fault::Error) => Err(anyhow::anyhow!(
                    "injected transient fault (shard {shard}, query {query}, attempt {attempt})"
                )),
                Some(Fault::Panic) => {
                    panic!("injected panic (shard {shard}, query {query}, attempt {attempt})")
                }
            }
        }
    }

    /// splitmix64-style avalanche over the (seed, shard, query) triple.
    fn mix(seed: u64, shard: u64, query: u64) -> u64 {
        let mut z = seed
            ^ shard.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ query.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degraded_worst_keeps_severity_order() {
        let budget = Some(Degraded::new(DegradeReason::BudgetExhausted));
        let deadline = Some(Degraded::new(DegradeReason::Deadline));
        let loss = Some(Degraded::shard_loss(vec![2, 0]));
        assert_eq!(Degraded::worst(None, None), None);
        assert_eq!(Degraded::worst(budget.clone(), None), budget);
        assert_eq!(Degraded::worst(budget.clone(), deadline.clone()), deadline);
        assert_eq!(Degraded::worst(loss.clone(), deadline.clone()), loss);
        // Shard lists come out sorted.
        assert_eq!(loss.unwrap().lost_shards, vec![0, 2]);
    }

    #[test]
    fn typed_errors_downcast_through_anyhow() {
        let over = OverloadedError {
            queue_depth: 17,
            projected_wait: Duration::from_millis(4),
            time_budget: Some(Duration::from_millis(1)),
        };
        let e = crate::Error::new(over.clone()).context("submitting query");
        assert_eq!(e.downcast_ref::<OverloadedError>(), Some(&over));
        assert!(format!("{e:#}").contains("overloaded"));

        let loss = ShardLossError {
            failed: vec![(1, "injected".into())],
            responded: 1,
            min_shards: 2,
        };
        let e: crate::Error = loss.clone().into();
        assert_eq!(e.downcast_ref::<ShardLossError>(), Some(&loss));
        let msg = format!("{e}");
        assert!(msg.contains("1 of 2"), "unexpected: {msg}");
        assert!(msg.contains("shard 1"), "unexpected: {msg}");
    }

    #[test]
    fn fault_plan_is_deterministic_and_rate_bounded() {
        let plan = FaultPlan::seeded(42, 30);
        let mut faults = 0;
        for shard in 0..4usize {
            for query in 0..200u64 {
                let a = plan.fault_for(shard, query, 0);
                // Same site, same answer — determinism is what makes the
                // chaos property reproducible from a seed.
                assert_eq!(a, plan.fault_for(shard, query, 0));
                faults += usize::from(a.is_some());
            }
        }
        // ~30% of 800 sites; generous tolerance, zero/all would be a bug.
        assert!((100..400).contains(&faults), "fault count {faults} implausible for 30%");
        // Rate 0 injects nothing.
        let calm = FaultPlan::seeded(42, 0);
        assert!((0..200u64).all(|q| calm.fault_for(0, q, 0).is_none()));
    }

    #[test]
    fn fault_plan_persistence_and_scripts() {
        // Default persistence ≤ 2 attempts: every drawn fault clears by
        // attempt 2 (the third try), so retries always win eventually.
        let plan = FaultPlan::seeded(7, 100);
        for query in 0..100u64 {
            assert_eq!(plan.fault_for(0, query, 2), None, "query {query} persisted past cap");
        }
        // Scripted sites override the draw exactly.
        let plan = FaultPlan::seeded(7, 0).script(1, 5, Fault::Error, 2);
        assert_eq!(plan.fault_for(1, 5, 0), Some(Fault::Error));
        assert_eq!(plan.fault_for(1, 5, 1), Some(Fault::Error));
        assert_eq!(plan.fault_for(1, 5, 2), None);
        assert_eq!(plan.fault_for(0, 5, 0), None);
    }
}
