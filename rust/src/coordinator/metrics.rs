//! Serving metrics: lock-free counters + a log-bucketed latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two latency buckets (1µs .. ~17min).
const BUCKETS: usize = 30;

/// Cheap concurrent metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    queries: AtomicU64,
    probed_items: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    /// Queries answered with a `Degraded` tag (deadline expiry,
    /// queue-wait exhaustion, or partial shard merge).
    queries_degraded: AtomicU64,
    /// Shard calls that failed past the retry cap (router).
    shard_failures: AtomicU64,
    /// Shard call retries after a transient failure (router).
    retries: AtomicU64,
    /// Requests rejected `Overloaded` at submission (server).
    shed: AtomicU64,
    /// histogram[i] counts latencies in [2^i, 2^{i+1}) microseconds.
    histogram: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    // staticcheck: allow(panic-reach, "histogram bucket is clamped with .min(BUCKETS - 1) before indexing the fixed-size array")
    pub fn record_query(&self, latency_us: u64, probed: usize) {
        self.queries.fetch_add(1, Ordering::Release);
        self.probed_items.fetch_add(probed as u64, Ordering::Release);
        let bucket = (64 - latency_us.max(1).leading_zeros() - 1).min(BUCKETS as u32 - 1);
        self.histogram[bucket as usize].fetch_add(1, Ordering::Release);
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Release);
        self.batch_rows.fetch_add(rows as u64, Ordering::Release);
    }

    pub fn record_degraded(&self) {
        self.queries_degraded.fetch_add(1, Ordering::Release);
    }

    pub fn record_shard_failure(&self) {
        self.shard_failures.fetch_add(1, Ordering::Release);
    }

    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Release);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Release);
    }

    /// Point-in-time read of every counter. Loads are `Acquire` against
    /// the `Release` bumps above: a snapshot that observes a counter
    /// increment also observes the writes that preceded it, so derived
    /// ratios (mean probed, mean batch rows) never mix a new numerator
    /// with a stale denominator from the same recording thread.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let hist: Vec<u64> = self
            .histogram
            .iter()
            .map(|b| b.load(Ordering::Acquire))
            .collect();
        let total: u64 = hist.iter().sum();
        let pct = |p: f64| -> u64 {
            if total == 0 {
                return 0;
            }
            let target = (total as f64 * p).ceil() as u64;
            let mut seen = 0u64;
            for (i, &c) in hist.iter().enumerate() {
                seen += c;
                if seen >= target {
                    // Upper edge of the bucket, conservative.
                    return 1u64 << (i + 1);
                }
            }
            1u64 << BUCKETS
        };
        let queries = self.queries.load(Ordering::Acquire);
        let batches = self.batches.load(Ordering::Acquire);
        MetricsSnapshot {
            queries,
            mean_probed: if queries == 0 {
                0.0
            } else {
                self.probed_items.load(Ordering::Acquire) as f64 / queries as f64
            },
            batches,
            mean_batch_rows: if batches == 0 {
                0.0
            } else {
                self.batch_rows.load(Ordering::Acquire) as f64 / batches as f64
            },
            queries_degraded: self.queries_degraded.load(Ordering::Acquire),
            shard_failures: self.shard_failures.load(Ordering::Acquire),
            retries: self.retries.load(Ordering::Acquire),
            shed: self.shed.load(Ordering::Acquire),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
        }
    }
}

/// Point-in-time view for reporting.
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub mean_probed: f64,
    pub batches: u64,
    pub mean_batch_rows: f64,
    /// Degraded-serving counters (see README §"Failure model & degraded
    /// serving"): tagged responses, shard calls lost past retries,
    /// retries issued, and requests shed at submission.
    pub queries_degraded: u64,
    pub shard_failures: u64,
    pub retries: u64,
    pub shed: u64,
    /// Latency percentiles (bucket upper bounds, µs).
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.queries, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.mean_probed, 0.0);
    }

    #[test]
    fn percentiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for _ in 0..95 {
            m.record_query(100, 10); // bucket [64,128)
        }
        for _ in 0..5 {
            m.record_query(10_000, 10); // bucket [8192,16384)
        }
        let s = m.snapshot();
        assert_eq!(s.queries, 100);
        assert!(s.p50_us >= 100 && s.p50_us <= 256, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 10_000, "p99 {}", s.p99_us);
        assert_eq!(s.mean_probed, 10.0);
    }

    #[test]
    fn batch_stats_average() {
        let m = Metrics::new();
        m.record_batch(10);
        m.record_batch(30);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch_rows, 20.0);
    }

    #[test]
    fn zero_latency_does_not_panic() {
        let m = Metrics::new();
        m.record_query(0, 0);
        assert_eq!(m.snapshot().queries, 1);
    }

    #[test]
    fn degraded_serving_counters_round_trip() {
        let m = Metrics::new();
        m.record_degraded();
        m.record_degraded();
        m.record_shard_failure();
        m.record_retry();
        m.record_retry();
        m.record_retry();
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(
            (s.queries_degraded, s.shard_failures, s.retries, s.shed),
            (2, 1, 3, 1)
        );
        // Independent of the query counters.
        assert_eq!(s.queries, 0);
    }

    #[test]
    fn snapshot_is_coherent() {
        // Percentiles must be monotone, bracket the recorded latencies,
        // and the histogram mass must equal the query count — the
        // invariants a reader of the serve-loop printout relies on.
        let m = Metrics::new();
        let latencies = [1u64, 3, 7, 50, 120, 900, 4_000, 30_000, 250_000, 2_000_000];
        for (i, &us) in latencies.iter().enumerate() {
            m.record_query(us, i * 11);
        }
        let s = m.snapshot();
        assert_eq!(s.queries, latencies.len() as u64);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us, "{s:?}");
        // Bucket upper edges: p50 covers at least half the samples, p99
        // at least all-but-one, and every percentile is at least the
        // smallest latency and at most 2x the largest (upper-edge slack).
        let max = *latencies.iter().max().unwrap();
        for p in [s.p50_us, s.p95_us, s.p99_us] {
            assert!(p >= 1 && p <= max.next_power_of_two() * 2, "percentile {p} out of range");
        }
        assert!(s.p50_us >= 120, "p50 {} below the true median", s.p50_us);
        assert!(s.p99_us >= 2_000_000, "p99 {} must cover the tail", s.p99_us);
    }
}
