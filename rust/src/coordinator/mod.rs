//! The serving coordinator: a production-shaped query path around the
//! RANGE-LSH index.
//!
//! - [`engine::SearchEngine`] — the synchronous core: hash → probe →
//!   exact re-rank. Query hashing goes through the AOT Pallas kernel
//!   (PJRT) when batched, the native path for singles. Every entry point
//!   takes optional per-request [`QueryParams`] overriding the engine's
//!   `ServeConfig` defaults (k, probe budget, early-stop target).
//! - [`batcher`] / [`server`] — the async front: a tokio request loop with
//!   a dynamic batcher (flush on size or deadline, vLLM-router style) that
//!   amortises PJRT query hashing across concurrent requests.
//! - [`metrics`] — latency histograms and counters (p50/p95/p99, QPS).
//! - [`router`] — a shard router: fan out a query to per-shard engines and
//!   merge top-k (the multi-node story, exercised single-process).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use crate::config::{QueryParams, ResolvedQueryParams};
pub use batcher::BatchPolicy;
pub use engine::{AnyEngine, SearchEngine, SearchResult};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::ShardedRouter;
pub use server::{QueryServer, ServerHandle};
