//! The serving coordinator: a production-shaped query path around the
//! RANGE-LSH index.
//!
//! - [`engine::SearchEngine`] — the synchronous core: hash → probe →
//!   exact re-rank. Query hashing goes through the AOT Pallas kernel
//!   (PJRT) when batched, the native path for singles. Every entry point
//!   takes optional per-request [`QueryParams`] overriding the engine's
//!   `ServeConfig` defaults (k, probe budget, early-stop target).
//! - [`batcher`] / [`server`] — the serving front: a dedicated batcher
//!   thread (plain threads + channels, no async runtime) with a dynamic
//!   batcher (flush on size or deadline, vLLM-router style) that
//!   amortises PJRT query hashing across concurrent requests.
//! - [`metrics`] — latency histograms and counters (p50/p95/p99, QPS).
//! - [`router`] — a shard router: fan out a query to per-shard engines and
//!   merge top-k (the multi-node story, exercised single-process), with
//!   per-shard `catch_unwind` fault isolation, retry/backoff, and a
//!   `min_shards` partial-merge quorum.
//! - [`fault`] — the failure model: the [`fault::QueryResponse`] envelope
//!   with its [`fault::Degraded`] tag, typed overload/shard-loss errors,
//!   and (tests / `fault-injection` feature only) the deterministic
//!   [`fault::FaultPlan`] behind the chaos suite.

pub mod batcher;
pub mod engine;
pub mod fault;
pub mod metrics;
pub mod router;
pub mod server;
pub mod store;

pub use crate::config::{QueryParams, ResolvedQueryParams};
pub use batcher::BatchPolicy;
pub use engine::{AnyEngine, SearchEngine, SearchResult};
pub use fault::{DegradeReason, Degraded, OverloadedError, QueryResponse, ShardLossError};
#[cfg(any(test, feature = "fault-injection"))]
pub use fault::{CrashPoint, Fault, FaultPlan};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{RouterPolicy, Shard, ShardedRouter};
pub use server::{MutationAck, MutationOp, QueryServer, ServerHandle};
pub use store::{AnyStore, MutableConfig, MutableStore};
