//! Shard router: fan a query out to per-shard engines and merge top-k.
//!
//! Single-process stand-in for the multi-node deployment story: each shard
//! owns a horizontal slice of the corpus with its own RANGE-LSH index
//! (norm ranges live *inside* each shard, as Alg. 1 prescribes per
//! sub-dataset owner). Ids are translated back to the global space here.

use std::sync::Arc;

use crate::config::QueryParams;
use crate::coordinator::engine::{SearchEngine, SearchResult};
use crate::hash::CodeWord;
use crate::{ItemId, Result};

/// One shard: a search engine plus its global id offset. Generic over the
/// shard engines' code word (default `u64`); all shards of one router
/// share a width, chosen at build time like everything else.
pub struct Shard<C: CodeWord = u64> {
    pub engine: Arc<SearchEngine<C>>,
    /// Global id of the shard's row 0.
    pub id_offset: ItemId,
}

/// Fan-out/merge router over shards.
pub struct ShardedRouter<C: CodeWord = u64> {
    shards: Vec<Shard<C>>,
    top_k: usize,
}

impl<C: CodeWord> ShardedRouter<C> {
    pub fn new(shards: Vec<Shard<C>>, top_k: usize) -> Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "need at least one shard");
        anyhow::ensure!(top_k >= 1, "top_k must be >= 1");
        Ok(Self { shards, top_k })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Query every shard, merge by exact score, return global-id top-k.
    /// (Algorithm 2's "select the optimal one from the answers of all
    /// sub-datasets", lifted to the shard level.)
    pub fn query(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        self.query_with(query, &QueryParams::default())
    }

    /// [`Self::query`] with per-request overrides: each shard probes and
    /// re-ranks under `params` (its own engine defaults filling the
    /// `None` fields), and the merge keeps `params.top_k` results (the
    /// router's construction-time `top_k` when unset).
    pub fn query_with(&self, query: &[f32], params: &QueryParams) -> Result<Vec<SearchResult>> {
        let top_k = params.top_k.unwrap_or(self.top_k).max(1);
        let mut merged: Vec<SearchResult> = Vec::with_capacity(top_k * self.shards.len());
        for shard in &self.shards {
            let local = shard.engine.search_with(query, params)?;
            merged.extend(local.into_iter().map(|r| SearchResult {
                id: r.id + shard.id_offset,
                score: r.score,
            }));
        }
        merged.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
        merged.truncate(top_k);
        Ok(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::data::{synthetic, Dataset};
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn make_engine(d: Arc<Dataset>) -> Arc<SearchEngine> {
        let h: Arc<NativeHasher> = Arc::new(NativeHasher::new(d.dim(), 64, 1));
        let idx =
            Arc::new(RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap());
        let cfg = ServeConfig { probe_budget: usize::MAX, top_k: 5, ..Default::default() };
        Arc::new(SearchEngine::new(idx, d, h, cfg).unwrap())
    }

    #[test]
    fn sharded_full_probe_matches_global_exact_topk() {
        // Split a corpus in two shards; with unlimited budget the router
        // must reproduce the global exact top-k.
        let full = synthetic::longtail_sift(600, 8, 0);
        let half = 300 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let router = ShardedRouter::new(
            vec![
                Shard { engine: make_engine(d1), id_offset: 0 },
                Shard { engine: make_engine(d2), id_offset: 300 },
            ],
            5,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(5, 8, 2);
        let gt = crate::eval::exact_topk(&full, &q, 5);
        for qi in 0..q.len() {
            let got: Vec<ItemId> = router.query(q.row(qi)).unwrap().iter().map(|r| r.id).collect();
            assert_eq!(got, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn merge_respects_top_k() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 1));
        let router = ShardedRouter::new(
            vec![Shard { engine: make_engine(d), id_offset: 0 }],
            3,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(1, 8, 3);
        assert_eq!(router.query(q.row(0)).unwrap().len(), 3);
    }

    #[test]
    fn per_request_top_k_overrides_router_default() {
        let full = synthetic::longtail_sift(400, 8, 4);
        let half = 200 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let router = ShardedRouter::new(
            vec![
                Shard { engine: make_engine(d1), id_offset: 0 },
                Shard { engine: make_engine(d2), id_offset: 200 },
            ],
            5,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(3, 8, 5);
        let gt = crate::eval::exact_topk(&full, &q, 3);
        let params = QueryParams::new().with_top_k(3);
        for qi in 0..q.len() {
            let got: Vec<ItemId> =
                router.query_with(q.row(qi), &params).unwrap().iter().map(|r| r.id).collect();
            assert_eq!(got, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn rejects_empty_shard_list() {
        assert!(ShardedRouter::<u64>::new(vec![], 5).is_err());
    }
}
