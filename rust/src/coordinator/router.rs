//! Shard router: fan a query out to per-shard engines and merge top-k.
//!
//! Single-process stand-in for the multi-node deployment story: each shard
//! owns a horizontal slice of the corpus with its own RANGE-LSH index
//! (norm ranges live *inside* each shard, as Alg. 1 prescribes per
//! sub-dataset owner). Ids are translated back to the global space here.
//!
//! Fault isolation (README §"Failure model & degraded serving"): every
//! shard call runs under `catch_unwind`, transient failures retry with
//! capped exponential backoff, and when at least
//! [`RouterPolicy::min_shards`] shards answer, the partial merge is
//! returned tagged `Degraded { reason: ShardLoss }` naming the lost
//! shards — never a silently truncated top-k presented as complete.
//! Below the quorum the query fails with a typed
//! [`ShardLossError`](crate::coordinator::fault::ShardLossError). The
//! norm-range partition makes this merge honest: each shard's answer is
//! an exact top-k over its own slice, so the partial merge is exactly
//! the full answer minus the lost slices.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::anyhow;

use crate::config::QueryParams;
use crate::coordinator::engine::{SearchEngine, SearchResult};
#[cfg(any(test, feature = "fault-injection"))]
use crate::coordinator::fault::FaultPlan;
use crate::coordinator::fault::{Degraded, QueryResponse, ShardLossError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::store::MutableStore;
use crate::hash::CodeWord;
use crate::{ItemId, Result};

/// One shard: a search engine plus its global id offset. Generic over the
/// shard engines' code word (default `u64`); all shards of one router
/// share a width, chosen at build time like everything else.
pub struct Shard<C: CodeWord = u64> {
    pub engine: Arc<SearchEngine<C>>,
    /// Global id of the shard's row 0.
    pub id_offset: ItemId,
}

/// Fault-tolerance knobs of the [`ShardedRouter`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterPolicy {
    /// Minimum shards that must answer for a merge to be returned; below
    /// it the query fails with a typed `ShardLossError`. Clamped to the
    /// shard count at construction — the default (`usize::MAX`) therefore
    /// means "all shards", the strict pre-fault-tolerance behaviour.
    pub min_shards: usize,
    /// Retries per shard after the initial attempt (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before retry `r` is `backoff_base * 2^r`, capped at
    /// `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
}

impl Default for RouterPolicy {
    fn default() -> Self {
        Self {
            min_shards: usize::MAX,
            max_retries: 2,
            backoff_base: Duration::from_micros(200),
            backoff_cap: Duration::from_millis(5),
        }
    }
}

/// Fan-out/merge router over shards.
pub struct ShardedRouter<C: CodeWord = u64> {
    shards: Vec<Shard<C>>,
    /// Optional [`MutableStore`] behind each shard (parallel to `shards`).
    /// A store-backed shard serves queries from its store's *current*
    /// epoch (re-resolved per shard call) and accepts routed mutations;
    /// a `None` shard keeps its fixed engine, read-only.
    stores: Vec<Option<Arc<MutableStore<C>>>>,
    top_k: usize,
    policy: RouterPolicy,
    metrics: Arc<Metrics>,
    /// Per-router query counter — the deterministic query index fault
    /// plans key on.
    seq: AtomicU64,
    /// Rotation counter for [`Self::ingest`]'s shard placement.
    ingest_seq: AtomicU64,
    #[cfg(any(test, feature = "fault-injection"))]
    fault_plan: Option<FaultPlan>,
}

impl<C: CodeWord> ShardedRouter<C> {
    pub fn new(shards: Vec<Shard<C>>, top_k: usize) -> Result<Self> {
        Self::with_policy(shards, top_k, RouterPolicy::default())
    }

    /// [`Self::new`] with explicit fault-tolerance knobs; `min_shards`
    /// is clamped into `1..=n_shards`.
    pub fn with_policy(shards: Vec<Shard<C>>, top_k: usize, policy: RouterPolicy) -> Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "need at least one shard");
        anyhow::ensure!(top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(policy.min_shards >= 1, "min_shards must be >= 1");
        let policy =
            RouterPolicy { min_shards: policy.min_shards.min(shards.len()), ..policy };
        let stores = (0..shards.len()).map(|_| None).collect();
        Ok(Self {
            shards,
            stores,
            top_k,
            policy,
            metrics: Arc::new(Metrics::new()),
            seq: AtomicU64::new(0),
            ingest_seq: AtomicU64::new(0),
            #[cfg(any(test, feature = "fault-injection"))]
            fault_plan: None,
        })
    }

    /// Back shard `si` with a mutable store: its queries re-resolve the
    /// store's current epoch per call, and routed mutations
    /// ([`Self::ingest`] / [`Self::delete`]) may land on it. The shard's
    /// fixed engine becomes the fallback only if the store is detached.
    pub fn set_store(&mut self, si: usize, store: Arc<MutableStore<C>>) -> Result<()> {
        anyhow::ensure!(si < self.shards.len(), "shard index {si} out of range");
        // staticcheck: allow(panic, "si < shards.len() is ensured above and stores is built parallel to shards")
        self.stores[si] = Some(store);
        Ok(())
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn policy(&self) -> &RouterPolicy {
        &self.policy
    }

    /// Router-level fault counters (`shard_failures`, `retries`,
    /// `queries_degraded`); per-shard latency lives in each shard
    /// engine's own metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Install a deterministic fault plan: every shard call first runs
    /// `plan.apply(shard, query_index, attempt)`, which may sleep, fail,
    /// or panic. Tests and the `fault-injection` feature only.
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault_plan = plan;
    }

    /// Query every shard, merge by exact score, return global-id top-k.
    /// (Algorithm 2's "select the optimal one from the answers of all
    /// sub-datasets", lifted to the shard level.)
    pub fn query(&self, query: &[f32]) -> Result<Vec<SearchResult>> {
        self.query_with(query, &QueryParams::default())
    }

    /// [`Self::query`] with per-request overrides: each shard probes and
    /// re-ranks under `params` (its own engine defaults filling the
    /// `None` fields), and the merge keeps `params.top_k` results (the
    /// router's construction-time `top_k` when unset). Strips the
    /// degraded envelope; callers that must distinguish a partial merge
    /// from a complete one use [`Self::query_full`].
    pub fn query_with(&self, query: &[f32], params: &QueryParams) -> Result<Vec<SearchResult>> {
        Ok(self.query_full(query, params)?.into_results())
    }

    /// The fault-aware entry point: fan out under `catch_unwind`, retry
    /// transient failures with capped exponential backoff, and merge
    /// whatever quorum survives. Shard-level degradation (e.g. a
    /// deadline expiry inside one shard engine) propagates as the worst
    /// tag; lost shards dominate and are listed in the tag.
    pub fn query_full(&self, query: &[f32], params: &QueryParams) -> Result<QueryResponse> {
        let qi = self.seq.fetch_add(1, Ordering::Relaxed);
        let top_k = params.top_k.unwrap_or(self.top_k).max(1);
        let mut merged: Vec<SearchResult> = Vec::with_capacity(top_k * self.shards.len());
        let mut lost: Vec<usize> = Vec::new();
        let mut failures: Vec<(usize, String)> = Vec::new();
        let mut shard_tag: Option<Degraded> = None;
        for (si, shard) in self.shards.iter().enumerate() {
            match self.query_shard(si, qi, shard, query, params) {
                Ok(resp) => {
                    shard_tag = Degraded::worst(shard_tag, resp.degraded);
                    merged.extend(resp.results.into_iter().map(|r| SearchResult {
                        id: r.id + shard.id_offset,
                        score: r.score,
                    }));
                }
                Err(e) => {
                    self.metrics.record_shard_failure();
                    failures.push((si, format!("{e:#}")));
                    lost.push(si);
                }
            }
        }
        let responded = self.shards.len() - lost.len();
        if responded < self.policy.min_shards {
            return Err(ShardLossError {
                failed: failures,
                responded,
                min_shards: self.policy.min_shards,
            }
            .into());
        }
        merged.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
        merged.truncate(top_k);
        let degraded = if lost.is_empty() {
            shard_tag
        } else {
            // Shard loss subsumes any per-shard deadline tag: the lost
            // list is the actionable fact for the caller.
            Some(Degraded::shard_loss(lost))
        };
        if degraded.is_some() {
            self.metrics.record_degraded();
        }
        Ok(QueryResponse { results: merged, degraded })
    }

    /// One shard call with fault containment: panics become errors via
    /// `catch_unwind`, and failures retry up to `policy.max_retries`
    /// times with exponential backoff. `AssertUnwindSafe` is justified
    /// because a shard engine holds no interior state a query mutates
    /// besides atomics and per-thread scratch that is cleared on entry;
    /// an unwound query leaves the engine servable — and a store
    /// mutation either completed (epoch swapped) or left replayable WAL
    /// records whose re-application is idempotent, so an unwound or
    /// retried mutation cannot corrupt the shard.
    fn query_shard(
        &self,
        si: usize,
        qi: u64,
        shard: &Shard<C>,
        query: &[f32],
        params: &QueryParams,
    ) -> Result<QueryResponse> {
        self.apply_shard(si, qi, || {
            // Store-backed shards answer from the store's current epoch;
            // the fixed engine serves the rest.
            // staticcheck: allow(panic, "si indexes shards in every caller and stores is built parallel to shards")
            let engine = match &self.stores[si] {
                Some(store) => store.current(),
                None => shard.engine.clone(),
            };
            engine.search_full(query, params)
        })
    }

    /// The retry/containment core shared by queries and mutations.
    fn apply_shard<T>(&self, si: usize, qi: u64, f: impl Fn() -> Result<T>) -> Result<T> {
        let mut attempt: u32 = 0;
        loop {
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                self.inject(si, qi, attempt)?;
                f()
            }));
            let err = match outcome {
                Ok(Ok(resp)) => return Ok(resp),
                Ok(Err(e)) => e,
                Err(payload) => anyhow::anyhow!("shard panicked: {}", panic_message(&payload)),
            };
            if attempt >= self.policy.max_retries {
                return Err(err.context(format!("shard {si} failed after {} attempts", attempt + 1)));
            }
            self.metrics.record_retry();
            let backoff = self
                .policy
                .backoff_base
                .saturating_mul(1u32 << attempt.min(16))
                .min(self.policy.backoff_cap);
            std::thread::sleep(backoff);
            attempt += 1;
        }
    }

    /// Ingest rows into one store-backed shard (rotating across all
    /// store-backed shards) under the router's retry policy; returns
    /// *global* ids. Retrying a half-failed ingest is safe: the store's
    /// WAL replay is idempotent, so re-logged rows deduplicate on
    /// recovery. Global ids stay unique as long as shard `id_offset`s
    /// leave growth headroom — offset assignment is the deployment's
    /// contract, exactly as for the initial corpus split.
    pub fn ingest(&self, rows: &[f32]) -> Result<Vec<ItemId>> {
        let backed: Vec<usize> =
            // staticcheck: allow(panic, "stores is built parallel to shards, so 0..shards.len() is in range")
            (0..self.shards.len()).filter(|&si| self.stores[si].is_some()).collect();
        let si = match backed.as_slice() {
            [] => anyhow::bail!("no shard has a mutable store attached"),
            // staticcheck: allow(panic, "the index is reduced mod some.len(), and the empty case matched above")
            some => some[self.ingest_seq.fetch_add(1, Ordering::Relaxed) as usize % some.len()],
        };
        // staticcheck: allow(panic, "si came from `backed`, which only holds indices below stores.len()")
        let Some(store) = self.stores[si].clone() else {
            anyhow::bail!("shard {si} lost its store between selection and apply");
        };
        let qi = self.seq.fetch_add(1, Ordering::Relaxed);
        // staticcheck: allow(panic, "si came from `backed`, which only holds indices below shards.len()")
        let offset = self.shards[si].id_offset;
        let local = self.apply_shard(si, qi, || store.ingest(rows))?;
        Ok(local.into_iter().map(|id| id + offset).collect())
    }

    /// Tombstone global ids, each routed to its owning shard (the shard
    /// with the largest `id_offset <= id`), under the router's retry
    /// policy. Returns the total newly-deleted count. A multi-shard
    /// batch applies shard-by-shard; on a shard failure the earlier
    /// shards' deletes stand (deletes are idempotent — retry the whole
    /// batch safely) and the error names the failed shard.
    pub fn delete(&self, ids: &[ItemId]) -> Result<usize> {
        anyhow::ensure!(!ids.is_empty(), "empty delete batch");
        let mut per_shard: Vec<Vec<ItemId>> = vec![Vec::new(); self.shards.len()];
        for &id in ids {
            let si = self.owner_of(id)?;
            // staticcheck: allow(panic, "owner_of returns a position inside shards and per_shard is sized shards.len()")
            per_shard[si].push(id - self.shards[si].id_offset);
        }
        let mut total = 0;
        for (si, local) in per_shard.into_iter().enumerate() {
            if local.is_empty() {
                continue;
            }
            // staticcheck: allow(panic, "si enumerates per_shard, which is sized shards.len() == stores.len()")
            let Some(store) = self.stores[si].clone() else {
                anyhow::bail!("shard {si} owns ids in this batch but has no mutable store");
            };
            let qi = self.seq.fetch_add(1, Ordering::Relaxed);
            total += self.apply_shard(si, qi, || store.delete(&local))?;
        }
        Ok(total)
    }

    /// The shard owning a global id: largest `id_offset <= id`.
    fn owner_of(&self, id: ItemId) -> Result<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.id_offset <= id)
            .max_by_key(|(_, s)| s.id_offset)
            .map(|(si, _)| si)
            .ok_or_else(|| anyhow!("id {id} precedes every shard's id range"))
    }

    #[cfg(any(test, feature = "fault-injection"))]
    fn inject(&self, si: usize, qi: u64, attempt: u32) -> Result<()> {
        match &self.fault_plan {
            Some(plan) => plan.apply(si, qi, attempt),
            None => Ok(()),
        }
    }

    #[cfg(not(any(test, feature = "fault-injection")))]
    #[inline(always)]
    fn inject(&self, _si: usize, _qi: u64, _attempt: u32) -> Result<()> {
        Ok(())
    }
}

/// Best-effort human-readable panic payload (`&str` and `String` cover
/// everything `panic!` in this codebase produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::data::{synthetic, Dataset};
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn make_engine(d: Arc<Dataset>) -> Arc<SearchEngine> {
        let h: Arc<NativeHasher> = Arc::new(NativeHasher::new(d.dim(), 64, 1));
        let idx =
            Arc::new(RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 4)).unwrap());
        let cfg = ServeConfig { probe_budget: usize::MAX, top_k: 5, ..Default::default() };
        Arc::new(SearchEngine::new(idx, d, h, cfg).unwrap())
    }

    #[test]
    fn sharded_full_probe_matches_global_exact_topk() {
        // Split a corpus in two shards; with unlimited budget the router
        // must reproduce the global exact top-k.
        let full = synthetic::longtail_sift(600, 8, 0);
        let half = 300 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let router = ShardedRouter::new(
            vec![
                Shard { engine: make_engine(d1), id_offset: 0 },
                Shard { engine: make_engine(d2), id_offset: 300 },
            ],
            5,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(5, 8, 2);
        let gt = crate::eval::exact_topk(&full, &q, 5);
        for qi in 0..q.len() {
            let got: Vec<ItemId> = router.query(q.row(qi)).unwrap().iter().map(|r| r.id).collect();
            assert_eq!(got, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn merge_respects_top_k() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 1));
        let router = ShardedRouter::new(
            vec![Shard { engine: make_engine(d), id_offset: 0 }],
            3,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(1, 8, 3);
        assert_eq!(router.query(q.row(0)).unwrap().len(), 3);
    }

    #[test]
    fn per_request_top_k_overrides_router_default() {
        let full = synthetic::longtail_sift(400, 8, 4);
        let half = 200 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let router = ShardedRouter::new(
            vec![
                Shard { engine: make_engine(d1), id_offset: 0 },
                Shard { engine: make_engine(d2), id_offset: 200 },
            ],
            5,
        )
        .unwrap();
        let q = synthetic::gaussian_queries(3, 8, 5);
        let gt = crate::eval::exact_topk(&full, &q, 3);
        let params = QueryParams::new().with_top_k(3);
        for qi in 0..q.len() {
            let got: Vec<ItemId> =
                router.query_with(q.row(qi), &params).unwrap().iter().map(|r| r.id).collect();
            assert_eq!(got, gt[qi], "query {qi}");
        }
    }

    #[test]
    fn rejects_empty_shard_list() {
        assert!(ShardedRouter::<u64>::new(vec![], 5).is_err());
    }

    use crate::coordinator::fault::{DegradeReason, Fault, FaultPlan};

    fn fast_policy(min_shards: usize, max_retries: u32) -> RouterPolicy {
        RouterPolicy {
            min_shards,
            max_retries,
            backoff_base: Duration::from_micros(1),
            backoff_cap: Duration::from_micros(10),
        }
    }

    #[test]
    fn min_shards_clamps_to_shard_count() {
        let d = Arc::new(synthetic::longtail_sift(50, 8, 6));
        let router = ShardedRouter::with_policy(
            vec![Shard { engine: make_engine(d), id_offset: 0 }],
            5,
            RouterPolicy::default(),
        )
        .unwrap();
        assert_eq!(router.policy().min_shards, 1);
    }

    #[test]
    fn retries_recover_from_transient_faults() {
        // Shard 0 fails its first two attempts at query 0; with
        // max_retries = 2 the third attempt succeeds and the answer is
        // identical to the fault-free one.
        let d = Arc::new(synthetic::longtail_sift(200, 8, 7));
        let mut router = ShardedRouter::with_policy(
            vec![Shard { engine: make_engine(d), id_offset: 0 }],
            5,
            fast_policy(1, 2),
        )
        .unwrap();
        router.set_fault_plan(Some(FaultPlan::seeded(1, 0).script(0, 0, Fault::Error, 2)));
        let q = synthetic::gaussian_queries(1, 8, 8);
        let faulted = router.query_full(q.row(0), &QueryParams::default()).unwrap();
        assert!(faulted.degraded.is_none(), "recovered query must not be tagged");
        // Query 1 hits no scripted fault: the clean oracle.
        let clean = router.query_full(q.row(0), &QueryParams::default()).unwrap();
        assert_eq!(faulted.results, clean.results);
        let s = router.metrics().snapshot();
        assert_eq!(s.retries, 2);
        assert_eq!(s.shard_failures, 0);
    }

    #[test]
    fn persistent_failure_exhausts_retries_into_typed_shard_loss() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 9));
        let mut router = ShardedRouter::with_policy(
            vec![Shard { engine: make_engine(d), id_offset: 0 }],
            5,
            fast_policy(1, 2),
        )
        .unwrap();
        router.set_fault_plan(Some(FaultPlan::seeded(2, 0).script(
            0,
            0,
            Fault::Error,
            u32::MAX,
        )));
        let q = synthetic::gaussian_queries(1, 8, 10);
        let err = router.query_full(q.row(0), &QueryParams::default()).unwrap_err();
        let loss = err
            .downcast_ref::<ShardLossError>()
            .expect("quorum failure must carry a typed ShardLossError");
        assert_eq!((loss.responded, loss.min_shards), (0, 1));
        assert_eq!(loss.failed.len(), 1);
        assert_eq!(loss.failed[0].0, 0);
        let s = router.metrics().snapshot();
        assert_eq!(s.shard_failures, 1);
        assert_eq!(s.retries, 2, "retry cap must bound the attempts");
    }

    use crate::coordinator::store::{MutableConfig, MutableStore};
    use crate::util::tmp::TempPath;

    fn make_store(dir: &std::path::Path, d: Arc<Dataset>) -> Arc<MutableStore<u64>> {
        let cfg = ServeConfig {
            probe_budget: usize::MAX,
            top_k: 5,
            code_bits: 16,
            ..Default::default()
        };
        Arc::new(
            MutableStore::create(
                dir,
                d,
                RangeLshParams::new(16, 4),
                7,
                cfg,
                MutableConfig::manual(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn store_backed_shards_route_mutations_and_track_epochs() {
        // Two store-backed shards at offsets 0 and 1000 (headroom for
        // growth). Mutations route by ownership; queries always see the
        // current epochs.
        let full = synthetic::longtail_sift(600, 8, 13);
        let half = 300 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let (t1, t2) = (TempPath::new("router-store-1"), TempPath::new("router-store-2"));
        let (s1, s2) = (make_store(t1.path(), d1), make_store(t2.path(), d2));
        let mut router = ShardedRouter::with_policy(
            vec![
                Shard { engine: s1.current(), id_offset: 0 },
                Shard { engine: s2.current(), id_offset: 1000 },
            ],
            5,
            fast_policy(2, 1),
        )
        .unwrap();
        router.set_store(0, s1.clone()).unwrap();
        router.set_store(1, s2.clone()).unwrap();
        let q = synthetic::gaussian_queries(2, 8, 14);

        // Delete the global winner through the router: it must route to
        // the owning shard and vanish from the merged answer.
        let victim = router.query(q.row(0)).unwrap()[0].id;
        assert_eq!(router.delete(&[victim]).unwrap(), 1);
        assert!(router.query(q.row(0)).unwrap().iter().all(|r| r.id != victim));
        let owner_tombs = if victim >= 1000 { s2.tombstoned_len() } else { s1.tombstoned_len() };
        assert_eq!(owner_tombs, 1, "delete must land on the owning shard");

        // Ingest rotates across the store-backed shards and globalizes
        // the returned ids.
        let extra = synthetic::longtail_sift(4, 8, 15);
        let a = router.ingest(&extra.flat()[..16]).unwrap();
        let b = router.ingest(&extra.flat()[16..]).unwrap();
        assert_eq!(a, vec![300, 301], "first ingest lands on shard 0");
        assert_eq!(b, vec![1300, 1301], "second rotates to shard 1 (offset 1000)");
        assert_eq!(s1.live_len() + s2.live_len(), 603);

        // The merged answer equals each store's current epoch merged
        // by exact score — no stale fixed-engine reads.
        let resp = router.query(q.row(1)).unwrap();
        let mut want: Vec<SearchResult> = Vec::new();
        for (s, off) in [(&s1, 0), (&s2, 1000)] {
            want.extend(s.current().search(q.row(1)).unwrap().into_iter().map(|r| {
                SearchResult { id: r.id + off, score: r.score }
            }));
        }
        want.sort_by(|x, y| y.score.total_cmp(&x.score).then(x.id.cmp(&y.id)));
        want.truncate(5);
        assert_eq!(resp, want);
    }

    #[test]
    fn mutations_on_storeless_shards_fail_typed() {
        let d = Arc::new(synthetic::longtail_sift(100, 8, 16));
        let router =
            ShardedRouter::new(vec![Shard { engine: make_engine(d), id_offset: 0 }], 5).unwrap();
        let err = router.ingest(&[1.0; 8]).unwrap_err();
        assert!(format!("{err:#}").contains("no shard has a mutable store"));
        let err = router.delete(&[3]).unwrap_err();
        assert!(format!("{err:#}").contains("no mutable store"));
    }

    #[test]
    fn mutation_retries_recover_from_transient_faults() {
        // The shard's first two mutation attempts fail via the scripted
        // plan; the third succeeds and the delete lands exactly once.
        let d = Arc::new(synthetic::longtail_sift(200, 8, 17));
        let t = TempPath::new("router-store-retry");
        let store = make_store(t.path(), d);
        let mut router = ShardedRouter::with_policy(
            vec![Shard { engine: store.current(), id_offset: 0 }],
            5,
            fast_policy(1, 2),
        )
        .unwrap();
        router.set_store(0, store.clone()).unwrap();
        router.set_fault_plan(Some(FaultPlan::seeded(4, 0).script(0, 0, Fault::Error, 2)));
        assert_eq!(router.delete(&[7]).unwrap(), 1);
        assert_eq!(store.tombstoned_len(), 1);
        assert_eq!(router.metrics().snapshot().retries, 2);
    }

    #[test]
    fn min_shards_quorum_merges_surviving_shards_as_degraded() {
        // Shard 1 panics persistently; with min_shards = 1 the router
        // isolates the panic and returns shard 0's exact answer tagged
        // ShardLoss naming the lost shard.
        let full = synthetic::longtail_sift(400, 8, 11);
        let half = 200 * 8;
        let d1 = Arc::new(Dataset::from_flat(8, full.flat()[..half].to_vec()));
        let d2 = Arc::new(Dataset::from_flat(8, full.flat()[half..].to_vec()));
        let surviving = make_engine(d1);
        let mut router = ShardedRouter::with_policy(
            vec![
                Shard { engine: Arc::clone(&surviving), id_offset: 0 },
                Shard { engine: make_engine(d2), id_offset: 200 },
            ],
            5,
            fast_policy(1, 0),
        )
        .unwrap();
        router.set_fault_plan(Some(FaultPlan::seeded(3, 0).script(
            1,
            0,
            Fault::Panic,
            u32::MAX,
        )));
        let q = synthetic::gaussian_queries(1, 8, 12);
        let resp = router.query_full(q.row(0), &QueryParams::default()).unwrap();
        let tag = resp.degraded.as_ref().expect("partial merge must be tagged");
        assert_eq!(tag.reason, DegradeReason::ShardLoss);
        assert_eq!(tag.lost_shards, vec![1]);
        let oracle = surviving.search_with(q.row(0), &QueryParams::default()).unwrap();
        assert_eq!(resp.results, oracle, "partial merge must equal the surviving shard");
        let s = router.metrics().snapshot();
        assert_eq!(s.shard_failures, 1);
        assert_eq!(s.queries_degraded, 1);
    }
}
