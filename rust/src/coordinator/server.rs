//! The serving front: a dedicated batcher thread that dynamically batches
//! concurrent queries (flush on size or deadline), runs the engine's
//! batched hash+probe, and answers per-request reply channels.
//!
//! Mutations ([`ServerHandle::ingest`] / [`ServerHandle::delete`]) ride
//! the same channel and the same admission shedder as queries when the
//! server fronts a [`MutableStore`] ([`QueryServer::spawn_mutable`]).
//! The batcher flushes the queries batched *before* a mutation with the
//! pre-mutation epoch, applies the mutation, and serves everything after
//! from the new epoch — single-consumer ordering gives read-your-writes
//! to any client that has seen its mutation acknowledged.
//!
//! Offline build note: this is a plain-thread implementation of the same
//! design a tokio front would have — the batcher is the only consumer of
//! the request channel, request submitters block on a per-request reply
//! channel, and the PJRT hash batch amortises across everything that
//! arrived within the window.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::config::QueryParams;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::engine::{AnyEngine, SearchEngine, SearchResult};
use crate::coordinator::fault::{DegradeReason, OverloadedError, QueryResponse};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::store::MutableStore;
use crate::hash::CodeWord;
use crate::{ItemId, Result};

/// A mutation submitted through the serving front.
#[derive(Debug, Clone)]
pub enum MutationOp {
    /// Row-major, `dim`-aligned rows to append and index.
    Ingest(Vec<f32>),
    /// Ids to tombstone.
    Delete(Vec<ItemId>),
}

/// The acknowledgement for a [`MutationOp`] — returned only after the
/// mutation's WAL records are fsynced (durability) and the new epoch is
/// installed (visibility).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MutationAck {
    /// The ids assigned to the ingested rows, in row order.
    Ingested(Vec<ItemId>),
    /// How many ids were newly tombstoned (idempotent re-deletes excluded).
    Deleted(usize),
}

struct QueryJob {
    query: Vec<f32>,
    /// Per-request overrides of the engine's serving defaults; requests
    /// with different parameters still share the batch's hash pass.
    params: QueryParams,
    reply: mpsc::Sender<Result<QueryResponse>>,
    enqueued: Instant,
}

struct MutateJob {
    op: MutationOp,
    reply: mpsc::Sender<Result<MutationAck>>,
}

enum Job {
    Query(QueryJob),
    Mutate(MutateJob),
}

/// Where the batcher gets its engine: pinned to one immutable engine, or
/// re-resolved from a [`MutableStore`]'s current epoch at every flush.
enum EngineSource<C: CodeWord> {
    Fixed(Arc<SearchEngine<C>>),
    Mutable(Arc<MutableStore<C>>),
}

impl<C: CodeWord> Clone for EngineSource<C> {
    fn clone(&self) -> Self {
        match self {
            Self::Fixed(e) => Self::Fixed(e.clone()),
            Self::Mutable(s) => Self::Mutable(s.clone()),
        }
    }
}

impl<C: CodeWord> EngineSource<C> {
    fn current(&self) -> Arc<SearchEngine<C>> {
        match self {
            Self::Fixed(e) => e.clone(),
            Self::Mutable(s) => s.current(),
        }
    }

    fn apply(&self, op: MutationOp) -> Result<MutationAck> {
        match self {
            Self::Fixed(_) => Err(anyhow!(
                "server fronts an immutable engine; spawn_mutable for ingest/delete"
            )),
            Self::Mutable(store) => match op {
                MutationOp::Ingest(rows) => store.ingest(&rows).map(MutationAck::Ingested),
                MutationOp::Delete(ids) => store.delete(&ids).map(MutationAck::Deleted),
            },
        }
    }
}

/// Cloneable client handle to a running [`QueryServer`]. Generic over the
/// engine's code word (default `u64`); the request/answer types are
/// width-independent.
///
/// `query` blocks the calling thread until the batched answer arrives;
/// spawn client threads (or use [`drive_workload`]) for concurrency.
pub struct ServerHandle<C: CodeWord = u64> {
    tx: Mutex<mpsc::Sender<Job>>,
    source: EngineSource<C>,
    policy: BatchPolicy,
    /// Jobs submitted but not yet picked up by the batcher thread — the
    /// queue depth the load shedder consults. Check-then-increment is
    /// deliberately non-atomic: the bound is a shedding heuristic, and a
    /// rare off-by-few under contention only shifts the shed point.
    depth: Arc<AtomicUsize>,
}

impl<C: CodeWord> Clone for ServerHandle<C> {
    fn clone(&self) -> Self {
        Self {
            // A panicked holder cannot leave a Sender mid-update (clone
            // and send are atomic on the channel), so a poisoned lock is
            // safe to recover rather than propagate.
            tx: Mutex::new(
                self.tx.lock().unwrap_or_else(PoisonError::into_inner).clone(),
            ),
            source: self.source.clone(),
            policy: self.policy,
            depth: self.depth.clone(),
        }
    }
}

impl<C: CodeWord> ServerHandle<C> {
    /// Submit one query and wait for its top-k (serving defaults).
    pub fn query(&self, query: Vec<f32>) -> Result<Vec<SearchResult>> {
        self.query_with(query, QueryParams::default())
    }

    /// Submit one query with per-request overrides (k, probe budget,
    /// early-stop target, time budget) and wait for its answer. Requests
    /// with different parameters batch together — hashing is shared,
    /// probe and re-rank honour each request's own resolved parameters.
    /// Strips the degraded envelope; callers that must distinguish a
    /// deadline-cut answer from a complete one use [`Self::query_full`].
    pub fn query_with(&self, query: Vec<f32>, params: QueryParams) -> Result<Vec<SearchResult>> {
        Ok(self.query_full(query, params)?.into_results())
    }

    /// The deadline-aware entry point. Two admission checks run before
    /// the job is enqueued (README §"Failure model & degraded serving"):
    /// the queue bound (`BatchPolicy::max_queue`) and, when the request
    /// carries a time budget, the projected wait — current batch window
    /// plus one batch-service estimate (the engine's p50) per queued
    /// batch ahead. Either trips a typed [`OverloadedError`] so callers
    /// can back off instead of queueing work that is already dead; a
    /// budget smaller than the batch window is therefore shed
    /// deterministically. Jobs whose budget expires *in* the queue are
    /// answered at flush time with an empty
    /// `Degraded { reason: BudgetExhausted }` response.
    pub fn query_full(&self, query: Vec<f32>, params: QueryParams) -> Result<QueryResponse> {
        let engine = self.source.current();
        let depth = self.depth.load(Ordering::Relaxed);
        let time_budget = params.resolve(engine.config()).time_budget;
        let service = Duration::from_micros(engine.metrics().snapshot().p50_us);
        let projected_wait = self.policy.projected_wait(depth, service);
        if depth >= self.policy.max_queue
            || time_budget.is_some_and(|tb| projected_wait > tb)
        {
            engine.metrics().record_shed();
            return Err(OverloadedError { queue_depth: depth, projected_wait, time_budget }.into());
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send_job(Job::Query(QueryJob {
            query,
            params,
            reply: reply_tx,
            enqueued: Instant::now(),
        }))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("server dropped the reply"))?
    }

    /// Append rows through the serving front; blocks until the mutation
    /// is durable and visible. See [`Self::mutate`] for ordering.
    pub fn ingest(&self, rows: Vec<f32>) -> Result<Vec<ItemId>> {
        match self.mutate(MutationOp::Ingest(rows))? {
            MutationAck::Ingested(ids) => Ok(ids),
            other => Err(anyhow!("mismatched mutation ack: {other:?}")),
        }
    }

    /// Tombstone ids through the serving front; blocks until the delete
    /// is durable and visible. See [`Self::mutate`] for ordering.
    pub fn delete(&self, ids: Vec<ItemId>) -> Result<usize> {
        match self.mutate(MutationOp::Delete(ids))? {
            MutationAck::Deleted(n) => Ok(n),
            other => Err(anyhow!("mismatched mutation ack: {other:?}")),
        }
    }

    /// Submit a mutation through the same queue and admission shedder as
    /// queries (an overloaded server sheds writes exactly like reads —
    /// nothing is logged for a shed mutation, so there is nothing to
    /// replay). The batcher flushes the queries that arrived before the
    /// mutation against the pre-mutation epoch, applies the mutation,
    /// and serves later queries from the new epoch: once this returns
    /// `Ok`, every subsequent query observes the mutation. Errs when the
    /// server fronts an immutable engine ([`QueryServer::spawn`]).
    pub fn mutate(&self, op: MutationOp) -> Result<MutationAck> {
        let depth = self.depth.load(Ordering::Relaxed);
        if depth >= self.policy.max_queue {
            let engine = self.source.current();
            engine.metrics().record_shed();
            let service = Duration::from_micros(engine.metrics().snapshot().p50_us);
            return Err(OverloadedError {
                queue_depth: depth,
                projected_wait: self.policy.projected_wait(depth, service),
                time_budget: None,
            }
            .into());
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send_job(Job::Mutate(MutateJob { op, reply: reply_tx }))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("server dropped the reply"))?
    }

    fn send_job(&self, job: Job) -> Result<()> {
        self.depth.fetch_add(1, Ordering::Relaxed);
        let sent = self
            .tx
            .lock()
            // Same recovery argument as Clone: the Sender is never left
            // in a torn state by a panicked lock holder.
            .unwrap_or_else(PoisonError::into_inner)
            .send(job);
        if sent.is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("server is shut down"));
        }
        Ok(())
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.source.current().metrics().snapshot()
    }
}

/// The dynamic-batching query server.
pub struct QueryServer;

impl QueryServer {
    /// Spawn the batcher thread; returns the client handle. The server
    /// stops when every handle (hence the sender) is dropped. Errs when
    /// the OS refuses the thread — real fallibility at saturation, so
    /// it flows to the caller instead of panicking the serving path.
    pub fn spawn<C: CodeWord>(
        engine: Arc<SearchEngine<C>>,
        policy: BatchPolicy,
    ) -> Result<ServerHandle<C>> {
        Self::spawn_source(EngineSource::Fixed(engine), policy)
    }

    /// [`Self::spawn`] over a [`MutableStore`]: queries are answered from
    /// the store's current epoch, and [`ServerHandle::ingest`] /
    /// [`ServerHandle::delete`] are live.
    pub fn spawn_mutable<C: CodeWord>(
        store: Arc<MutableStore<C>>,
        policy: BatchPolicy,
    ) -> Result<ServerHandle<C>> {
        Self::spawn_source(EngineSource::Mutable(store), policy)
    }

    fn spawn_source<C: CodeWord>(
        source: EngineSource<C>,
        policy: BatchPolicy,
    ) -> Result<ServerHandle<C>> {
        let (tx, rx) = mpsc::channel::<Job>();
        let loop_source = source.clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let loop_depth = depth.clone();
        std::thread::Builder::new()
            .name("rangelsh-batcher".into())
            .spawn(move || batch_loop(loop_source, policy, rx, loop_depth))
            .map_err(|e| anyhow!("spawning batcher thread: {e}"))?;
        Ok(ServerHandle { tx: Mutex::new(tx), source, policy, depth })
    }
}

/// Queue-wait accounting at flush time, pure so it is unit-testable:
/// `None` = the request's whole budget was consumed waiting (answer
/// `BudgetExhausted` without touching the engine); `Some(b)` = run the
/// engine with remaining budget `b` (`Some(remaining)` or budget-less).
fn budget_after_wait(budget: Option<Duration>, wait: Duration) -> Option<Option<Duration>> {
    match budget {
        Some(tb) if wait >= tb => None,
        Some(tb) => Some(Some(tb - wait)),
        None => Some(None),
    }
}

fn batch_loop<C: CodeWord>(
    source: EngineSource<C>,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Job>,
    depth: Arc<AtomicUsize>,
) {
    let mut pending: Vec<QueryJob> = Vec::with_capacity(policy.max_batch);
    // A staged mutation acts as a batch barrier: queries already pending
    // flush first (on the pre-mutation epoch), then the mutation applies,
    // then the loop resumes on the new epoch.
    let mut staged: Option<MutateJob> = None;
    let take = |r: std::result::Result<Job, mpsc::RecvTimeoutError>| {
        // Receipt is what moves a job out of the shedder's queue depth.
        if r.is_ok() {
            depth.fetch_sub(1, Ordering::Relaxed);
        }
        r
    };
    loop {
        // Wait (indefinitely) for the first job of the next batch.
        if pending.is_empty() && staged.is_none() {
            match take(rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected)) {
                Ok(Job::Query(job)) => pending.push(job),
                Ok(Job::Mutate(job)) => staged = Some(job),
                Err(_) => return, // all senders gone
            }
        }
        let mut closed = false;
        // Drain whatever queued up while the previous batch was running —
        // these are "free" batch members, no waiting involved. (Anchoring
        // the deadline at the oldest job's *enqueue* time would make every
        // post-flush batch flush instantly with one member.) A mutation
        // stops the drain: it must not reorder past queries behind it.
        while staged.is_none() && pending.len() < policy.max_batch {
            match take(rx.try_recv().map_err(|e| match e {
                mpsc::TryRecvError::Empty => mpsc::RecvTimeoutError::Timeout,
                mpsc::TryRecvError::Disconnected => mpsc::RecvTimeoutError::Disconnected,
            })) {
                Ok(Job::Query(job)) => pending.push(job),
                Ok(Job::Mutate(job)) => staged = Some(job),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        // Then wait out the remainder of the oldest job's batching window
        // (none left if it already waited through the previous flush, and
        // none at all when a mutation is staged — the barrier flushes now).
        if staged.is_none() && !pending.is_empty() {
            // staticcheck: allow(panic, "pending is non-empty: guarded by the enclosing condition")
            let deadline = (pending[0].enqueued + policy.deadline).max(Instant::now());
            while !closed && pending.len() < policy.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match take(rx.recv_timeout(deadline - now)) {
                    Ok(Job::Query(job)) => pending.push(job),
                    Ok(Job::Mutate(job)) => {
                        staged = Some(job);
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        // Flush against the epoch current *now* — pre-mutation if one is
        // staged. First settle queue-wait accounting: jobs whose time
        // budget was consumed entirely by waiting are answered degraded
        // right here; survivors carry their *remaining* budget into the
        // engine (whose own deadline anchors at batch entry, so the
        // end-to-end bound is enqueue + budget).
        let engine = source.current();
        let now = Instant::now();
        let mut batch: Vec<QueryJob> = Vec::with_capacity(pending.len());
        for mut job in std::mem::take(&mut pending) {
            let wait = now.duration_since(job.enqueued);
            let budget = job.params.resolve(engine.config()).time_budget;
            match budget_after_wait(budget, wait) {
                None => {
                    engine.metrics().record_degraded();
                    engine.metrics().record_query(wait.as_micros() as u64, 0);
                    let _ = job.reply.send(Ok(QueryResponse::degraded(
                        Vec::new(),
                        DegradeReason::BudgetExhausted,
                    )));
                }
                Some(remaining) => {
                    job.params.time_budget = remaining;
                    batch.push(job);
                }
            }
        }
        if !batch.is_empty() {
            let rows: Vec<f32> = batch.iter().flat_map(|j| j.query.iter().copied()).collect();
            let params: Vec<QueryParams> = batch.iter().map(|j| j.params).collect();
            match engine.search_batch_full(&rows, &params) {
                Ok(per_query) => {
                    debug_assert_eq!(per_query.len(), batch.len());
                    for (job, res) in batch.into_iter().zip(per_query) {
                        let _ = job.reply.send(Ok(res));
                    }
                }
                Err(e) => {
                    let msg = format!("batch failed: {e:#}");
                    for job in batch {
                        let _ = job.reply.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        // The barrier: apply the staged mutation after the pre-mutation
        // flush. Its ack (or error) goes straight back to the submitter;
        // the next iteration re-resolves the epoch.
        if let Some(job) = staged.take() {
            let _ = job.reply.send(source.apply(job.op));
        }
        if closed {
            return;
        }
    }
}

/// Drive a width-erased [`AnyEngine`] through [`drive_workload`] — the
/// CLI entry point after the monomorphized dispatch.
pub fn drive_any(
    engine: &AnyEngine,
    policy: BatchPolicy,
    queries: &crate::data::Dataset,
    clients: usize,
) -> Result<(Vec<Vec<SearchResult>>, Duration)> {
    drive_any_with(engine, policy, queries, clients, QueryParams::default())
}

/// [`drive_any`] with one [`QueryParams`] override applied to every
/// request (the CLI's `--k` / `--budget` / `--min-candidates` /
/// `--extend-step` flags).
pub fn drive_any_with(
    engine: &AnyEngine,
    policy: BatchPolicy,
    queries: &crate::data::Dataset,
    clients: usize,
    params: QueryParams,
) -> Result<(Vec<Vec<SearchResult>>, Duration)> {
    match engine {
        AnyEngine::W64(e) => drive_workload_with(e.clone(), policy, queries, clients, params),
        AnyEngine::W128(e) => drive_workload_with(e.clone(), policy, queries, clients, params),
        AnyEngine::W256(e) => drive_workload_with(e.clone(), policy, queries, clients, params),
    }
}

/// Drive `queries` through a fresh server with `clients` concurrent client
/// threads; returns per-query results (in query order) and the wall time.
pub fn drive_workload<C: CodeWord>(
    engine: Arc<SearchEngine<C>>,
    policy: BatchPolicy,
    queries: &crate::data::Dataset,
    clients: usize,
) -> Result<(Vec<Vec<SearchResult>>, Duration)> {
    drive_workload_with(engine, policy, queries, clients, QueryParams::default())
}

/// [`drive_workload`] with one [`QueryParams`] override on every request.
pub fn drive_workload_with<C: CodeWord>(
    engine: Arc<SearchEngine<C>>,
    policy: BatchPolicy,
    queries: &crate::data::Dataset,
    clients: usize,
    params: QueryParams,
) -> Result<(Vec<Vec<SearchResult>>, Duration)> {
    let clients = clients.max(1);
    let handle = QueryServer::spawn(engine, policy)?;
    let n = queries.len();
    let t0 = Instant::now();
    let mut out: Vec<Option<Vec<SearchResult>>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(clients);
    let mut failure: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, block) in out.chunks_mut(chunk).enumerate() {
            let h = handle.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                let base = t * chunk;
                for (i, slot) in block.iter_mut().enumerate() {
                    let qi = base + i;
                    *slot = Some(h.query_with(queries.row(qi).to_vec(), params)?);
                }
                Ok(())
            }));
        }
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    failure.get_or_insert(e);
                }
                // A panicked client is a workload failure, not a process
                // abort: surface it as a typed error like any other.
                Err(_) => {
                    failure.get_or_insert(anyhow!("client worker thread panicked"));
                }
            }
        }
    });
    if let Some(e) = failure {
        return Err(e);
    }
    let wall = t0.elapsed();
    let results: Vec<Vec<SearchResult>> = out
        .into_iter()
        .map(|o| o.ok_or_else(|| anyhow!("client worker left a result slot unfilled")))
        .collect::<Result<_>>()?;
    Ok((results, wall))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn engine() -> Arc<SearchEngine> {
        let d = Arc::new(synthetic::longtail_sift(1000, 8, 0));
        let h: Arc<NativeHasher> = Arc::new(NativeHasher::new(8, 64, 1));
        let idx =
            Arc::new(RangeLshIndex::build(&d, h.as_ref(), RangeLshParams::new(16, 8)).unwrap());
        let cfg = ServeConfig { probe_budget: 200, top_k: 5, ..Default::default() };
        Arc::new(SearchEngine::new(idx, d, h, cfg).unwrap())
    }

    #[test]
    fn serves_concurrent_queries_correctly() {
        let eng = engine();
        let policy = BatchPolicy::new(8, Duration::from_millis(2));
        let q = synthetic::gaussian_queries(32, 8, 2);
        let (results, _) = drive_workload(eng.clone(), policy, &q, 8).unwrap();
        for qi in 0..q.len() {
            // Must match the unbatched engine answer exactly.
            let want = eng.search(q.row(qi)).unwrap();
            assert_eq!(results[qi], want, "query {qi}");
        }
        let snap = eng.metrics().snapshot();
        assert!(snap.batches >= 1);
        assert!(snap.queries >= 32);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let eng = engine();
        // Huge batch size: only the deadline can flush.
        let policy = BatchPolicy::new(10_000, Duration::from_millis(5));
        let handle = QueryServer::spawn(eng, policy).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 3);
        let t0 = Instant::now();
        let res = handle.query(q.row(0).to_vec()).unwrap();
        assert_eq!(res.len(), 5);
        assert!(t0.elapsed() >= Duration::from_millis(5), "flushed too early");
    }

    #[test]
    fn batching_actually_batches_under_concurrency() {
        let eng = engine();
        let policy = BatchPolicy::new(64, Duration::from_millis(20));
        let q = synthetic::gaussian_queries(64, 8, 4);
        let (results, _) = drive_workload(eng.clone(), policy, &q, 16).unwrap();
        assert_eq!(results.len(), 64);
        let snap = eng.metrics().snapshot();
        assert!(
            snap.mean_batch_rows > 1.5,
            "expected real batching, got mean batch {}",
            snap.mean_batch_rows
        );
    }

    #[test]
    fn wide_engine_serves_through_batcher() {
        // The dynamic batcher is width-generic: a 128-bit engine serves
        // the same protocol.
        use crate::coordinator::engine::AnyEngine;
        use crate::coordinator::server::drive_any;
        let d = Arc::new(synthetic::longtail_sift(800, 8, 6));
        let cfg = ServeConfig {
            probe_budget: 200,
            top_k: 5,
            code_bits: 128,
            ..Default::default()
        };
        let engine =
            AnyEngine::build_native_range(d, RangeLshParams::new(128, 8), 3, cfg).unwrap();
        let q = synthetic::gaussian_queries(16, 8, 7);
        let policy = BatchPolicy::new(8, Duration::from_millis(2));
        let (results, _) = drive_any(&engine, policy, &q, 4).unwrap();
        for qi in 0..q.len() {
            assert_eq!(results[qi], engine.search(q.row(qi)).unwrap(), "query {qi}");
        }
    }

    #[test]
    fn per_request_params_batch_together() {
        // Requests with different k/budget share the batcher; each reply
        // honours its own parameters and matches the direct engine call.
        let eng = engine();
        let policy = BatchPolicy::new(16, Duration::from_millis(10));
        let handle = QueryServer::spawn(eng.clone(), policy).unwrap();
        let q = synthetic::gaussian_queries(12, 8, 8);
        let param_for = |qi: usize| match qi % 3 {
            0 => QueryParams::default(),
            1 => QueryParams::new().with_top_k(1 + qi % 4),
            _ => QueryParams::new().with_probe_budget(150 + qi),
        };
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..q.len())
                .map(|qi| {
                    let h = handle.clone();
                    let row = q.row(qi).to_vec();
                    scope.spawn(move || h.query_with(row, param_for(qi)).unwrap())
                })
                .collect();
            for (qi, th) in handles.into_iter().enumerate() {
                let got = th.join().unwrap();
                let want = eng.search_with(q.row(qi), &param_for(qi)).unwrap();
                assert_eq!(got, want, "query {qi}");
            }
        });
        let snap = eng.metrics().snapshot();
        assert!(snap.queries >= 12);
    }

    #[test]
    fn server_survives_handle_drop_and_new_queries() {
        let eng = engine();
        let policy = BatchPolicy::new(4, Duration::from_millis(1));
        let handle = QueryServer::spawn(eng, policy).unwrap();
        let h2 = handle.clone();
        drop(handle);
        let q = synthetic::gaussian_queries(1, 8, 5);
        assert_eq!(h2.query(q.row(0).to_vec()).unwrap().len(), 5);
    }

    #[test]
    fn mutable_server_gives_read_your_writes() {
        use crate::coordinator::store::{MutableConfig, MutableStore};
        use crate::util::tmp::TempPath;
        let dir = TempPath::new("server-mutable");
        let items = Arc::new(synthetic::longtail_sift(500, 8, 20));
        let cfg = ServeConfig {
            probe_budget: usize::MAX,
            top_k: 5,
            code_bits: 16,
            ..Default::default()
        };
        let store = Arc::new(
            MutableStore::<u64>::create(
                dir.path(),
                items,
                RangeLshParams::new(16, 8),
                7,
                cfg,
                MutableConfig::manual(),
            )
            .unwrap(),
        );
        let policy = BatchPolicy::new(8, Duration::from_millis(1));
        let handle = QueryServer::spawn_mutable(store.clone(), policy).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 21);

        // Acked delete: the id is invisible to every later query.
        let victim = handle.query(q.row(0).to_vec()).unwrap()[0].id;
        assert_eq!(handle.delete(vec![victim]).unwrap(), 1);
        let after = handle.query(q.row(0).to_vec()).unwrap();
        assert!(after.iter().all(|r| r.id != victim), "acked delete resurfaced");
        // Acked ingest: the rows are immediately searchable.
        let extra = synthetic::longtail_sift(10, 8, 22);
        let ids = handle.ingest(extra.flat().to_vec()).unwrap();
        assert_eq!(ids, (500..510).collect::<Vec<crate::ItemId>>());
        assert_eq!(store.live_len(), 509);
        // Server answers match the store's current epoch exactly.
        let want = store.current().search(q.row(0)).unwrap();
        assert_eq!(handle.query(q.row(0).to_vec()).unwrap(), want);
    }

    #[test]
    fn fixed_server_rejects_mutations() {
        let eng = engine();
        let policy = BatchPolicy::new(4, Duration::from_millis(1));
        let handle = QueryServer::spawn(eng, policy).unwrap();
        let err = handle.delete(vec![0]).unwrap_err();
        assert!(format!("{err:#}").contains("immutable engine"));
        // The failed mutation leaves the query path healthy.
        let q = synthetic::gaussian_queries(1, 8, 23);
        assert_eq!(handle.query(q.row(0).to_vec()).unwrap().len(), 5);
    }

    #[test]
    fn budget_after_wait_accounts_queue_time() {
        let ms = Duration::from_millis;
        // No budget: always runs, still budget-less.
        assert_eq!(budget_after_wait(None, ms(500)), Some(None));
        // Budget outlives the wait: remainder is exact.
        assert_eq!(budget_after_wait(Some(ms(10)), ms(3)), Some(Some(ms(7))));
        assert_eq!(budget_after_wait(Some(ms(10)), Duration::ZERO), Some(Some(ms(10))));
        // Wait consumed the whole budget (boundary inclusive): expired.
        assert_eq!(budget_after_wait(Some(ms(10)), ms(10)), None);
        assert_eq!(budget_after_wait(Some(ms(10)), ms(11)), None);
    }

    #[test]
    fn budget_below_batch_window_sheds_deterministically() {
        // A time budget smaller than the flush deadline can never be met:
        // the projected wait (>= the batch window) exceeds it at any
        // queue depth, so admission rejects it with a typed Overloaded.
        let eng = engine();
        let policy = BatchPolicy::new(8, Duration::from_millis(10));
        let handle = QueryServer::spawn(eng.clone(), policy).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 9);
        let params = QueryParams::new().with_time_budget(Duration::from_millis(1));
        let err = handle.query_full(q.row(0).to_vec(), params).unwrap_err();
        let over = err
            .downcast_ref::<OverloadedError>()
            .expect("shed must carry a typed OverloadedError");
        assert_eq!(over.queue_depth, 0);
        assert!(over.projected_wait >= Duration::from_millis(10));
        assert_eq!(over.time_budget, Some(Duration::from_millis(1)));
        assert_eq!(handle.metrics().shed, 1);
        // A budget-less request on the same server is admitted fine.
        assert_eq!(handle.query(q.row(0).to_vec()).unwrap().len(), 5);
    }

    #[test]
    fn queue_wait_near_budget_degrades_or_completes_never_lies() {
        // Budget barely above the batch window: depending on scheduling
        // the job either survives the queue (complete or deadline-cut
        // answer) or expires in it (empty BudgetExhausted). Either way
        // the envelope must say what happened — this asserts the
        // invariant, not the timing.
        let eng = engine();
        let policy = BatchPolicy::new(10_000, Duration::from_millis(30));
        let handle = QueryServer::spawn(eng.clone(), policy).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 10);
        let params = QueryParams::new().with_time_budget(Duration::from_millis(31));
        let resp = handle.query_full(q.row(0).to_vec(), params).unwrap();
        match &resp.degraded {
            None => {
                let want = eng.search_with(q.row(0), &QueryParams::default()).unwrap();
                assert_eq!(resp.results, want, "untagged answer must be the complete one");
            }
            Some(tag) => {
                assert!(
                    tag.reason == DegradeReason::BudgetExhausted
                        || tag.reason == DegradeReason::Deadline,
                    "unexpected tag {tag:?}"
                );
                if tag.reason == DegradeReason::BudgetExhausted {
                    assert!(resp.results.is_empty(), "queue expiry never ran the engine");
                }
                assert_eq!(handle.metrics().queries_degraded, 1);
            }
        }
    }

    #[test]
    fn generous_budget_through_server_is_answer_invariant() {
        let eng = engine();
        let policy = BatchPolicy::new(8, Duration::from_millis(2));
        let handle = QueryServer::spawn(eng.clone(), policy).unwrap();
        let q = synthetic::gaussian_queries(4, 8, 11);
        let params = QueryParams::new().with_time_budget(Duration::from_secs(600));
        for qi in 0..q.len() {
            let resp = handle.query_full(q.row(qi).to_vec(), params).unwrap();
            assert!(resp.degraded.is_none(), "query {qi} spuriously degraded");
            let want = eng.search(q.row(qi)).unwrap();
            assert_eq!(resp.results, want, "query {qi}");
        }
        assert_eq!(handle.metrics().shed, 0);
    }
}
