//! The crash-consistent mutable store: a WAL-backed, checkpointed
//! directory serving an online-mutable RANGE-LSH index through epoch
//! handles (README §"Mutability & recovery model").
//!
//! ## Directory layout
//!
//! ```text
//! <dir>/items.rdat   row matrix (append-only, prefix-stable)
//! <dir>/index.rlsh   v3 index snapshot of the last checkpoint
//! <dir>/wal.log      CRC32-framed mutations since that checkpoint
//! <dir>/MANIFEST     epoch, row count, dim, tombstones (checksummed)
//! ```
//!
//! ## Durability protocol
//!
//! Every mutation is appended to the WAL and fsynced *before* it is
//! applied to the in-memory epoch — the `Ok` return of [`MutableStore::
//! ingest`] / [`MutableStore::delete`] is the durability acknowledgement.
//! A checkpoint ([`MutableStore::checkpoint`], also run by compaction)
//! stages `items.rdat` and `index.rlsh` as fsynced siblings, renames them
//! into place, atomically rewrites the manifest, and only then truncates
//! the WAL. [`MutableStore::open`] therefore recovers from a crash at
//! *any* point by loading the last published checkpoint and replaying the
//! WAL idempotently — the result is bit-identical to the state after the
//! last acknowledged mutation (chaos-tested at the [`CrashPoint`] sites).
//!
//! ## Epoch handles
//!
//! Queries go through [`MutableStore::current`], an `Arc`'d
//! [`SearchEngine`] over an immutable `(index, tombstones)` pair wrapped
//! in a [`TombstonedIndex`]. Mutations build the next pair and *replace*
//! the handle; in-flight probe sessions keep borrowing the epoch they
//! were opened on, so a query never observes a half-applied mutation.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use anyhow::Context;

use crate::config::{ProbeBackend, RerankMode, ServeConfig};
use crate::coordinator::engine::{AnyEngine, SearchEngine};
use crate::coordinator::metrics::Metrics;
#[cfg(any(test, feature = "fault-injection"))]
use crate::coordinator::fault::{CrashPoint, FaultPlan};
use crate::data::{load_dataset, save_dataset, Dataset, RerankView};
use crate::hash::{Code128, Code256, CodeWord, ItemHasher, NativeHasher};
use crate::index::mutable::{
    compact_index, indexed_ids, insert_into_index, TombstonedIndex, Tombstones,
};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::{load_any_range_index, save_range_index, AnyRangeLshIndex};
use crate::persist::{load_manifest, save_manifest, Manifest, Wal, WalRecord};
use crate::{ItemId, Result};

const ITEMS_FILE: &str = "items.rdat";
const INDEX_FILE: &str = "index.rlsh";
const WAL_FILE: &str = "wal.log";
const MANIFEST_FILE: &str = "MANIFEST";

/// Fault-injection hook: under tests / the `fault-injection` feature this
/// expands to a `?`-propagated crash check against the store's armed
/// [`FaultPlan`]; release builds compile it away entirely. The injected
/// "crash" is an error return that abandons the operation with the disk
/// exactly as a real crash at that site would leave it.
macro_rules! crash_point {
    ($store:expr, $point:ident) => {
        #[cfg(any(test, feature = "fault-injection"))]
        $store.crash_if(CrashPoint::$point)?;
    };
}

/// Drift thresholds for the compaction trigger. After every applied
/// mutation the store compares the current epoch against the baseline
/// captured at the last compaction (or open): compaction fires when any
/// range overfills, when tombstones pile up, or when the top range's
/// `U_j` has grown stale (README §"Mutability & recovery model").
#[derive(Debug, Clone, Copy)]
pub struct MutableConfig {
    /// Per-range fill trigger: compact when any range holds more than
    /// `(1 + compact_fill) ×` its baseline item count.
    pub compact_fill: f32,
    /// Tombstone trigger: compact when at least this fraction of the
    /// indexed items is tombstoned.
    pub compact_tombstones: f32,
    /// `U_j` staleness trigger: compact when the top range's `u_max` has
    /// grown by more than this factor over its baseline — inserts above
    /// the old maximum norm stretch the top range's normalization and
    /// erode the per-range `U_j` tightness the paper's ranging buys.
    pub compact_u_growth: f32,
    /// Run the drift check (and compaction) automatically after every
    /// mutation; `false` leaves compaction to explicit
    /// [`MutableStore::compact`] calls.
    pub auto_compact: bool,
}

impl Default for MutableConfig {
    fn default() -> Self {
        Self {
            compact_fill: 0.5,
            compact_tombstones: 0.25,
            compact_u_growth: 1.25,
            auto_compact: true,
        }
    }
}

impl MutableConfig {
    /// No automatic compaction — mutations only ever move the epoch.
    pub fn manual() -> Self {
        Self { auto_compact: false, ..Self::default() }
    }
}

/// Width-typed extraction from the width-erased loaded index — the glue
/// that lets a typed [`MutableStore<C>`] open a `.rlsh` file whose width
/// is only known at runtime. Implemented exactly for the three supported
/// code words; a width mismatch is a clear error, not a coercion.
pub trait StoredWidth: CodeWord {
    fn extract(any: AnyRangeLshIndex) -> Result<RangeLshIndex<Self>>;
}

macro_rules! stored_width {
    ($ty:ty, $arm:ident) => {
        impl StoredWidth for $ty {
            fn extract(any: AnyRangeLshIndex) -> Result<RangeLshIndex<Self>> {
                match any {
                    AnyRangeLshIndex::$arm(i) => Ok(i),
                    other => anyhow::bail!(
                        "stored index is {} words per code, this store serves {}",
                        other.code_words(),
                        <$ty as CodeWord>::WORDS
                    ),
                }
            }
        }
    };
}

stored_width!(u64, W64);
stored_width!(Code128, W128);
stored_width!(Code256, W256);

/// Per-range item counts plus the top `u_max` at the last compaction (or
/// open) — what [`MutableConfig`]'s drift thresholds are measured against.
struct DriftBaseline {
    range_lens: Vec<usize>,
    top_u_max: f32,
}

fn baseline_of<C: CodeWord>(index: &RangeLshIndex<C>) -> DriftBaseline {
    DriftBaseline { range_lens: range_lens(index), top_u_max: top_u_max(index) }
}

fn range_lens<C: CodeWord>(index: &RangeLshIndex<C>) -> Vec<usize> {
    let mut lens = Vec::with_capacity(index.n_ranges());
    let _ = index.for_each_range::<std::convert::Infallible>(|part, _| {
        lens.push(part.ids.len());
        Ok(())
    });
    lens
}

fn top_u_max<C: CodeWord>(index: &RangeLshIndex<C>) -> f32 {
    index.u_maxes().last().copied().unwrap_or(0.0)
}

/// One epoch's shared state, swapped wholesale under the store mutex.
struct StoreState<C: CodeWord> {
    engine: Arc<SearchEngine<C>>,
    index: Arc<RangeLshIndex<C>>,
    tombs: Arc<Tombstones>,
    dataset: Arc<Dataset>,
    wal: Wal,
    epoch: u64,
    base: DriftBaseline,
}

/// A directory-backed mutable index: WAL-acknowledged ingest and delete,
/// epoch-handle queries, drift-triggered compaction, crash-consistent
/// reopen. See the module docs for the protocol.
pub struct MutableStore<C: CodeWord = u64> {
    dir: PathBuf,
    cfg: ServeConfig,
    mcfg: MutableConfig,
    metrics: Arc<Metrics>,
    state: Mutex<StoreState<C>>,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Mutex<Option<FaultPlan>>,
}

/// Build one epoch's engine: the tombstone-filtered index over the
/// epoch's dataset, hashed with the index's own stored panel (codes
/// identical to the build path), metrics shared across epochs.
fn epoch_engine<C: CodeWord>(
    index: &Arc<RangeLshIndex<C>>,
    tombs: &Arc<Tombstones>,
    dataset: &Arc<Dataset>,
    view: Option<Arc<RerankView>>,
    cfg: &ServeConfig,
    metrics: &Arc<Metrics>,
) -> Result<Arc<SearchEngine<C>>> {
    let hasher: Arc<dyn ItemHasher<C>> =
        Arc::new(NativeHasher::<C>::with_projection(index.projection().clone()));
    Ok(Arc::new(SearchEngine::from_epoch(
        Arc::new(TombstonedIndex::new(index.clone(), tombs.clone())),
        dataset.clone(),
        view,
        hasher,
        cfg.clone(),
        metrics.clone(),
    )?))
}

/// The re-rank view for a *new* dataset (fresh build when streaming).
fn fresh_view(cfg: &ServeConfig, dataset: &Dataset) -> Option<Arc<RerankView>> {
    match cfg.rerank {
        RerankMode::Streaming => Some(Arc::new(RerankView::build(dataset))),
        RerankMode::Exhaustive => None,
    }
}

impl<C: StoredWidth> MutableStore<C> {
    /// Initialise `dir` as a new store over `items`: build the index,
    /// write the first checkpoint, and leave an empty WAL. Fails if the
    /// directory already holds a store.
    pub fn create(
        dir: impl AsRef<Path>,
        items: Arc<Dataset>,
        params: RangeLshParams,
        seed: u64,
        cfg: ServeConfig,
        mcfg: MutableConfig,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        anyhow::ensure!(
            !dir.join(MANIFEST_FILE).exists(),
            "{} already holds a store (found {MANIFEST_FILE})",
            dir.display()
        );
        anyhow::ensure!(
            params.code_bits == cfg.code_bits,
            "index code_bits {} != serve code_bits {}",
            params.code_bits,
            cfg.code_bits
        );
        // The u64 arm keeps its historical 64-wide panel; wide arms use a
        // panel exactly as wide as the per-range hash bits (the same
        // convention as `AnyEngine::build_native_range`).
        let native_width = if C::WORDS == 1 { 64 } else { params.hash_bits() };
        let hasher: NativeHasher<C> = NativeHasher::new(items.dim(), native_width, seed);
        let mut index = RangeLshIndex::build(&items, &hasher, params)?;
        match cfg.probe_backend.resolve(params.code_bits) {
            ProbeBackend::Mih => index.enable_mih(),
            _ => index.clear_mih(),
        }
        let (wal, _) = Wal::open(dir.join(WAL_FILE))?;
        let index = Arc::new(index);
        let tombs = Arc::new(Tombstones::new());
        let metrics = Arc::new(Metrics::new());
        let view = fresh_view(&cfg, &items);
        let engine = epoch_engine(&index, &tombs, &items, view, &cfg, &metrics)?;
        let base = baseline_of(&index);
        let store = Self {
            dir,
            cfg,
            mcfg,
            metrics,
            state: Mutex::new(StoreState {
                engine,
                index,
                tombs,
                dataset: items,
                wal,
                epoch: 0,
                base,
            }),
            #[cfg(any(test, feature = "fault-injection"))]
            faults: Mutex::new(None),
        };
        store.checkpoint()?;
        Ok(store)
    }

    /// Reopen a store directory: load the last published checkpoint,
    /// replay the WAL idempotently, and serve the recovered epoch. Safe
    /// after a crash at any point of the mutation/checkpoint protocol.
    pub fn open(dir: impl AsRef<Path>, cfg: ServeConfig, mcfg: MutableConfig) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let any = load_any_range_index(dir.join(INDEX_FILE))?;
        Self::open_with_index(dir, C::extract(any)?, cfg, mcfg)
    }

    /// [`Self::open`] with the snapshot already loaded and width-typed
    /// (the dispatch point [`AnyStore::open`] goes through).
    fn open_with_index(
        dir: PathBuf,
        mut index: RangeLshIndex<C>,
        cfg: ServeConfig,
        mcfg: MutableConfig,
    ) -> Result<Self> {
        // Staging leftovers from a checkpoint that crashed pre-rename are
        // dead bytes — the manifest never pointed at them.
        for stale in [
            "items.rdat.stage",
            "items.rdat.stage.tmp",
            "index.rlsh.stage",
            "index.rlsh.stage.tmp",
            "MANIFEST.tmp",
            "wal.log.tmp",
        ] {
            let _ = std::fs::remove_file(dir.join(stale));
        }
        let man = load_manifest(dir.join(MANIFEST_FILE))?;
        let file_ds = load_dataset(dir.join(ITEMS_FILE))?;
        anyhow::ensure!(
            man.dim as usize == file_ds.dim(),
            "manifest dim {} != items dim {}",
            man.dim,
            file_ds.dim()
        );
        // `items.rdat` may run *ahead* of the manifest (a checkpoint that
        // crashed between the items rename and the manifest write): the
        // file is append-only and prefix-stable, so the extra rows are
        // exactly the WAL's logged inserts and replay below reconciles.
        anyhow::ensure!(
            man.n_rows as usize <= file_ds.len(),
            "items file holds {} rows but the manifest claims {}",
            file_ds.len(),
            man.n_rows
        );
        match cfg.probe_backend.resolve(index.params().code_bits) {
            ProbeBackend::Mih => index.enable_mih(),
            _ => index.clear_mih(),
        }
        let (wal, records) = Wal::open(dir.join(WAL_FILE))?;

        let dim = file_ds.dim();
        let indexed = indexed_ids(&index);
        let mut flat = file_ds.flat().to_vec();
        let mut n_rows = file_ds.len();
        // First pass: rows + the inserts the snapshot has not applied.
        let mut pending: Vec<ItemId> = Vec::new();
        for rec in &records {
            if let WalRecord::Insert { id, row } = rec {
                anyhow::ensure!(
                    row.len() == dim,
                    "WAL insert {id} has {} dims, store rows have {dim}",
                    row.len()
                );
                if *id as usize >= n_rows {
                    anyhow::ensure!(
                        *id as usize == n_rows,
                        "WAL insert id {id} leaves a row gap (next row is {n_rows})"
                    );
                    flat.extend_from_slice(row);
                    n_rows += 1;
                }
                if indexed.binary_search(id).is_err() && !pending.contains(id) {
                    pending.push(*id);
                }
            }
        }
        // Tombstones: the manifest's set intersected with what is still
        // indexed (a checkpoint that crashed between the index rename and
        // the manifest write leaves compacted-away ids in the old
        // manifest), plus the WAL's logged deletes — which may target the
        // pending inserts above (insert-then-delete before a checkpoint).
        let mut tombs = Tombstones::new();
        for &id in &man.tombstones {
            if indexed.binary_search(&id).is_ok() {
                tombs.set(id);
            }
        }
        for rec in &records {
            if let WalRecord::Delete { id } = rec {
                if indexed.binary_search(id).is_ok() || pending.contains(id) {
                    tombs.set(*id);
                }
            }
        }
        let dataset = Arc::new(Dataset::from_flat(dim, flat));
        let index = if pending.is_empty() {
            index
        } else {
            insert_into_index(&index, &dataset, &pending)?
        };
        let index = Arc::new(index);
        let tombs = Arc::new(tombs);
        let metrics = Arc::new(Metrics::new());
        let view = fresh_view(&cfg, &dataset);
        let engine = epoch_engine(&index, &tombs, &dataset, view, &cfg, &metrics)?;
        let base = baseline_of(&index);
        Ok(Self {
            dir,
            cfg,
            mcfg,
            metrics,
            state: Mutex::new(StoreState {
                engine,
                index,
                tombs,
                dataset,
                wal,
                epoch: man.epoch,
                base,
            }),
            #[cfg(any(test, feature = "fault-injection"))]
            faults: Mutex::new(None),
        })
    }

    fn lock(&self) -> MutexGuard<'_, StoreState<C>> {
        // A panicking mutation thread leaves consistent state behind (the
        // epoch swap is a handful of Arc stores at the very end), so the
        // store keeps serving rather than poisoning every later caller.
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The current epoch's engine. Clone-and-go: the returned handle keeps
    /// serving a consistent pre-mutation view even while later mutations
    /// swap the store's epoch.
    pub fn current(&self) -> Arc<SearchEngine<C>> {
        self.lock().engine.clone()
    }

    /// Append `rows` (row-major, `dim`-aligned) and index them. The `Ok`
    /// ids are the durability acknowledgement: each row's WAL record is
    /// fsynced before the epoch applies it, so an acknowledged insert
    /// survives any later crash.
    // staticcheck: allow(panic-reach, "ids has one entry per chunks_exact(dim) chunk of the validated buffer, so i < ids.len()")
    pub fn ingest(&self, rows: &[f32]) -> Result<Vec<ItemId>> {
        let mut st = self.lock();
        let dim = st.dataset.dim();
        anyhow::ensure!(
            !rows.is_empty() && rows.len() % dim == 0,
            "ingest buffer length {} not a positive multiple of dim {dim}",
            rows.len()
        );
        let n_new = rows.len() / dim;
        let mut norms = Vec::with_capacity(n_new);
        for row in rows.chunks_exact(dim) {
            // Same per-row expression as `Dataset::from_flat`, so replayed
            // and online norms are bit-identical.
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            anyhow::ensure!(norm.is_finite(), "ingested row has a non-finite norm");
            norms.push(norm);
        }
        let first = st.dataset.len() as ItemId;
        let ids: Vec<ItemId> = (first..first + n_new as ItemId).collect();
        for (i, row) in rows.chunks_exact(dim).enumerate() {
            st.wal.append(&WalRecord::Insert { id: ids[i], row: row.to_vec() })?;
        }
        crash_point!(self, PostWalAppend);

        let mut flat = Vec::with_capacity((st.dataset.len() + n_new) * dim);
        flat.extend_from_slice(st.dataset.flat());
        flat.extend_from_slice(rows);
        let mut all_norms = Vec::with_capacity(st.dataset.len() + n_new);
        all_norms.extend_from_slice(st.dataset.norms());
        all_norms.extend_from_slice(&norms);
        let dataset = Arc::new(Dataset::from_flat_with_norms(dim, flat, all_norms));
        let index = Arc::new(insert_into_index(&st.index, &dataset, &ids)?);
        crash_point!(self, PreApply);

        // The dataset changed, so a streaming epoch rebuilds its view.
        let view = fresh_view(&self.cfg, &dataset);
        let engine = epoch_engine(&index, &st.tombs, &dataset, view, &self.cfg, &self.metrics)?;
        st.dataset = dataset;
        st.index = index;
        st.engine = engine;
        st.epoch += 1;
        self.maybe_compact(&mut st);
        Ok(ids)
    }

    /// Tombstone `ids`. Returns how many were newly deleted (deleting an
    /// already-tombstoned id is an idempotent no-op); an id that was never
    /// indexed — or was already compacted away — is an error, reported
    /// before anything is logged.
    pub fn delete(&self, ids: &[ItemId]) -> Result<usize> {
        let mut st = self.lock();
        anyhow::ensure!(!ids.is_empty(), "empty delete batch");
        let indexed = indexed_ids(&st.index);
        let mut next = (*st.tombs).clone();
        let mut fresh = Vec::new();
        for &id in ids {
            anyhow::ensure!(
                indexed.binary_search(&id).is_ok(),
                "delete of unknown id {id} (never ingested, or already compacted away)"
            );
            if next.set(id) {
                fresh.push(id);
            }
        }
        if fresh.is_empty() {
            return Ok(0);
        }
        for &id in &fresh {
            st.wal.append(&WalRecord::Delete { id })?;
        }
        crash_point!(self, PostWalAppend);
        crash_point!(self, PreApply);

        // Delete-only epoch: the dataset is untouched, so the previous
        // epoch's re-rank view is reused as-is.
        let tombs = Arc::new(next);
        let view = st.engine.view().cloned();
        let engine =
            epoch_engine(&st.index, &tombs, &st.dataset, view, &self.cfg, &self.metrics)?;
        st.tombs = tombs;
        st.engine = engine;
        st.epoch += 1;
        self.maybe_compact(&mut st);
        Ok(fresh.len())
    }

    /// Run the drift check; compact when any threshold trips. Compaction
    /// failure must not fail the already-acknowledged mutation — the
    /// store keeps serving the uncompacted epoch and reports to stderr.
    fn maybe_compact(&self, st: &mut StoreState<C>) {
        if !self.mcfg.auto_compact || !self.drift_exceeded(st) {
            return;
        }
        if let Err(e) = self.compact_locked(st) {
            eprintln!(
                "[rangelsh] auto-compaction failed (serving continues uncompacted): {e:#}"
            );
        }
    }

    /// Has the epoch drifted past any [`MutableConfig`] threshold?
    fn drift_exceeded(&self, st: &StoreState<C>) -> bool {
        let indexed = st.index.len();
        if indexed == 0 || indexed == st.tombs.len() {
            return false; // nothing live to re-partition
        }
        if !st.tombs.is_empty()
            && st.tombs.len() as f32 >= self.mcfg.compact_tombstones * indexed as f32
        {
            return true;
        }
        let lens = range_lens(&st.index);
        for (now, &then) in lens.iter().zip(&st.base.range_lens) {
            if *now as f32 > then.max(1) as f32 * (1.0 + self.mcfg.compact_fill) {
                return true;
            }
        }
        top_u_max(&st.index) > st.base.top_u_max * self.mcfg.compact_u_growth
    }

    /// Re-partition the live items from scratch and checkpoint the result
    /// — drift repair. The new epoch has no tombstones; surviving items
    /// keep their original ids; in-flight sessions on the old epoch keep
    /// their consistent pre-compaction view.
    pub fn compact(&self) -> Result<()> {
        let mut st = self.lock();
        self.compact_locked(&mut st)
    }

    fn compact_locked(&self, st: &mut StoreState<C>) -> Result<()> {
        let (compacted, _live) = compact_index(&st.index, &st.dataset, &st.tombs)?;
        crash_point!(self, MidCompaction);
        let index = Arc::new(compacted);
        let tombs = Arc::new(Tombstones::new());
        self.checkpoint_files(st, &index, &tombs)?;
        // The dataset is unchanged (dead rows stay as unreferenced
        // padding), so the re-rank view carries over.
        let view = st.engine.view().cloned();
        let engine = epoch_engine(&index, &tombs, &st.dataset, view, &self.cfg, &self.metrics)?;
        st.index = index;
        st.tombs = tombs;
        st.engine = engine;
        st.epoch += 1;
        st.base = baseline_of(&st.index);
        Ok(())
    }

    /// Publish the current epoch as the on-disk checkpoint and truncate
    /// the WAL. Crash-safe: see the module docs for the staging order.
    pub fn checkpoint(&self) -> Result<()> {
        let mut st = self.lock();
        let (index, tombs) = (st.index.clone(), st.tombs.clone());
        self.checkpoint_files(&mut st, &index, &tombs)
    }

    /// The checkpoint protocol: stage + fsync both data files, rename
    /// them into place, atomically rewrite the manifest, then truncate
    /// the WAL. A crash between any two steps leaves a state `open`
    /// recovers exactly (each file is either the old or the new version,
    /// and the WAL still holds every uncheckpointed record).
    fn checkpoint_files(
        &self,
        st: &mut StoreState<C>,
        index: &RangeLshIndex<C>,
        tombs: &Tombstones,
    ) -> Result<()> {
        let items_stage = self.dir.join("items.rdat.stage");
        save_dataset(&st.dataset, &items_stage)?;
        File::open(&items_stage)?
            .sync_all()
            .with_context(|| format!("syncing {}", items_stage.display()))?;
        // `save_range_index` stages + fsyncs + renames internally — to the
        // *stage* name, so the live snapshot is untouched until the single
        // rename below.
        let index_stage = self.dir.join("index.rlsh.stage");
        save_range_index(index, &index_stage)?;
        crash_point!(self, PreRename);
        std::fs::rename(&items_stage, self.dir.join(ITEMS_FILE))
            .context("publishing items.rdat")?;
        std::fs::rename(&index_stage, self.dir.join(INDEX_FILE))
            .context("publishing index.rlsh")?;
        crate::persist::sync_dir(&self.dir);
        save_manifest(
            self.dir.join(MANIFEST_FILE),
            &Manifest {
                epoch: st.epoch,
                n_rows: st.dataset.len() as u64,
                dim: st.dataset.dim() as u32,
                tombstones: tombs.ids(),
            },
        )?;
        st.wal.reset()
    }

    /// Mutation epoch counter (resumes from the manifest on open).
    pub fn epoch(&self) -> u64 {
        self.lock().epoch
    }

    /// Items indexed and not tombstoned.
    pub fn live_len(&self) -> usize {
        let st = self.lock();
        st.index.len() - st.tombs.len()
    }

    /// Items currently tombstoned (drops to 0 at each compaction).
    pub fn tombstoned_len(&self) -> usize {
        self.lock().tombs.len()
    }

    /// Rows in the dataset, dead compacted rows included.
    pub fn n_rows(&self) -> usize {
        self.lock().dataset.len()
    }

    /// Row dimensionality.
    pub fn dim(&self) -> usize {
        self.lock().dataset.dim()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Arm (or clear) the deterministic crash plan for the chaos tests.
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        *self.faults.lock().unwrap_or_else(PoisonError::into_inner) = plan;
    }

    #[cfg(any(test, feature = "fault-injection"))]
    fn crash_if(&self, point: CrashPoint) -> Result<()> {
        match self.faults.lock().unwrap_or_else(PoisonError::into_inner).as_ref() {
            Some(plan) => plan.crash_if(point),
            None => Ok(()),
        }
    }
}

/// A [`MutableStore`] monomorphized to the width its `.rlsh` snapshot
/// declares — the dispatch point between the CLI/server layers (which
/// know the width only at runtime) and the typed stores. Mirrors
/// [`AnyEngine`].
pub enum AnyStore {
    W64(Arc<MutableStore<u64>>),
    W128(Arc<MutableStore<Code128>>),
    W256(Arc<MutableStore<Code256>>),
}

impl AnyStore {
    /// Initialise a new store at the width selected by `cfg.code_bits`.
    pub fn create(
        dir: impl AsRef<Path>,
        items: Arc<Dataset>,
        params: RangeLshParams,
        seed: u64,
        cfg: ServeConfig,
        mcfg: MutableConfig,
    ) -> Result<AnyStore> {
        if cfg.code_bits <= 64 {
            Ok(Self::W64(Arc::new(MutableStore::create(dir, items, params, seed, cfg, mcfg)?)))
        } else if cfg.code_bits <= 128 {
            Ok(Self::W128(Arc::new(MutableStore::create(dir, items, params, seed, cfg, mcfg)?)))
        } else {
            Ok(Self::W256(Arc::new(MutableStore::create(dir, items, params, seed, cfg, mcfg)?)))
        }
    }

    /// Reopen a store at whatever width its snapshot declares.
    pub fn open(
        dir: impl AsRef<Path>,
        cfg: ServeConfig,
        mcfg: MutableConfig,
    ) -> Result<AnyStore> {
        let dir = dir.as_ref().to_path_buf();
        match load_any_range_index(dir.join(INDEX_FILE))? {
            AnyRangeLshIndex::W64(i) => Ok(Self::W64(Arc::new(
                MutableStore::open_with_index(dir, i, cfg, mcfg)?,
            ))),
            AnyRangeLshIndex::W128(i) => Ok(Self::W128(Arc::new(
                MutableStore::open_with_index(dir, i, cfg, mcfg)?,
            ))),
            AnyRangeLshIndex::W256(i) => Ok(Self::W256(Arc::new(
                MutableStore::open_with_index(dir, i, cfg, mcfg)?,
            ))),
        }
    }

    /// The current epoch's engine, width-erased for querying.
    pub fn engine(&self) -> AnyEngine {
        match self {
            Self::W64(s) => AnyEngine::W64(s.current()),
            Self::W128(s) => AnyEngine::W128(s.current()),
            Self::W256(s) => AnyEngine::W256(s.current()),
        }
    }

    pub fn ingest(&self, rows: &[f32]) -> Result<Vec<ItemId>> {
        match self {
            Self::W64(s) => s.ingest(rows),
            Self::W128(s) => s.ingest(rows),
            Self::W256(s) => s.ingest(rows),
        }
    }

    pub fn delete(&self, ids: &[ItemId]) -> Result<usize> {
        match self {
            Self::W64(s) => s.delete(ids),
            Self::W128(s) => s.delete(ids),
            Self::W256(s) => s.delete(ids),
        }
    }

    pub fn compact(&self) -> Result<()> {
        match self {
            Self::W64(s) => s.compact(),
            Self::W128(s) => s.compact(),
            Self::W256(s) => s.compact(),
        }
    }

    pub fn checkpoint(&self) -> Result<()> {
        match self {
            Self::W64(s) => s.checkpoint(),
            Self::W128(s) => s.checkpoint(),
            Self::W256(s) => s.checkpoint(),
        }
    }

    /// Words per code (1, 2 or 4).
    pub fn code_words(&self) -> usize {
        match self {
            Self::W64(_) => 1,
            Self::W128(_) => 2,
            Self::W256(_) => 4,
        }
    }

    pub fn epoch(&self) -> u64 {
        match self {
            Self::W64(s) => s.epoch(),
            Self::W128(s) => s.epoch(),
            Self::W256(s) => s.epoch(),
        }
    }

    pub fn live_len(&self) -> usize {
        match self {
            Self::W64(s) => s.live_len(),
            Self::W128(s) => s.live_len(),
            Self::W256(s) => s.live_len(),
        }
    }

    pub fn tombstoned_len(&self) -> usize {
        match self {
            Self::W64(s) => s.tombstoned_len(),
            Self::W128(s) => s.tombstoned_len(),
            Self::W256(s) => s.tombstoned_len(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Self::W64(s) => s.dim(),
            Self::W128(s) => s.dim(),
            Self::W256(s) => s.dim(),
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        match self {
            Self::W64(s) => s.metrics(),
            Self::W128(s) => s.metrics(),
            Self::W256(s) => s.metrics(),
        }
    }

    #[cfg(any(test, feature = "fault-injection"))]
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        match self {
            Self::W64(s) => s.set_fault_plan(plan),
            Self::W128(s) => s.set_fault_plan(plan),
            Self::W256(s) => s.set_fault_plan(plan),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::tmp::TempPath;

    fn small_cfg() -> ServeConfig {
        ServeConfig { probe_budget: usize::MAX, top_k: 5, code_bits: 16, ..Default::default() }
    }

    fn new_store(dir: &Path, n: usize, seed: u64) -> MutableStore<u64> {
        let items = Arc::new(synthetic::longtail_sift(n, 8, seed));
        MutableStore::create(
            dir,
            items,
            RangeLshParams::new(16, 8),
            7,
            small_cfg(),
            MutableConfig::manual(),
        )
        .unwrap()
    }

    fn answers(store: &MutableStore<u64>, queries: &Dataset) -> Vec<Vec<(ItemId, u32)>> {
        let engine = store.current();
        (0..queries.len())
            .map(|qi| {
                engine
                    .search(queries.row(qi))
                    .unwrap()
                    .into_iter()
                    .map(|r| (r.id, r.score.to_bits()))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn create_then_reopen_serves_identical_answers() {
        let dir = TempPath::new("store-reopen");
        let store = new_store(dir.path(), 500, 1);
        let q = synthetic::gaussian_queries(4, 8, 2);
        let want = answers(&store, &q);
        drop(store);
        let reopened: MutableStore<u64> =
            MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
        assert_eq!(answers(&reopened, &q), want);
    }

    #[test]
    fn ingest_is_replayed_without_a_checkpoint() {
        let dir = TempPath::new("store-ingest");
        let store = new_store(dir.path(), 400, 3);
        let extra = synthetic::longtail_sift(30, 8, 4);
        let ids = store.ingest(extra.flat()).unwrap();
        assert_eq!(ids, (400..430).collect::<Vec<ItemId>>());
        assert_eq!(store.live_len(), 430);
        let q = synthetic::gaussian_queries(3, 8, 5);
        let want = answers(&store, &q);
        drop(store); // no checkpoint: recovery must come from the WAL
        let reopened: MutableStore<u64> =
            MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
        assert_eq!(reopened.live_len(), 430);
        assert_eq!(answers(&reopened, &q), want);
    }

    #[test]
    fn delete_hides_ids_and_survives_reopen() {
        let dir = TempPath::new("store-delete");
        let store = new_store(dir.path(), 300, 6);
        let q = synthetic::gaussian_queries(2, 8, 7);
        // Delete the current winners; they must vanish from the answers.
        let victims: Vec<ItemId> = answers(&store, &q)[0].iter().map(|&(id, _)| id).collect();
        assert_eq!(store.delete(&victims).unwrap(), victims.len());
        assert_eq!(store.delete(&victims).unwrap(), 0, "double delete is a no-op");
        let after = answers(&store, &q);
        for row in &after {
            for (id, _) in row {
                assert!(!victims.contains(id), "deleted id {id} surfaced");
            }
        }
        drop(store);
        let reopened: MutableStore<u64> =
            MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
        assert_eq!(answers(&reopened, &q), after);
        assert!(reopened.delete(&[99999]).is_err(), "unknown id must be rejected");
    }

    #[test]
    fn old_epoch_handles_keep_serving_across_mutations() {
        let dir = TempPath::new("store-epoch");
        let store = new_store(dir.path(), 300, 8);
        let q = synthetic::gaussian_queries(1, 8, 9);
        let before = store.current();
        let want = before.search(q.row(0)).unwrap();
        let victim = want[0].id;
        store.delete(&[victim]).unwrap();
        // The pre-delete handle still sees the victim...
        assert_eq!(before.search(q.row(0)).unwrap(), want);
        // ... and the current epoch does not.
        let now = store.current().search(q.row(0)).unwrap();
        assert!(now.iter().all(|r| r.id != victim));
    }

    #[test]
    fn crash_before_apply_recovers_the_acknowledged_mutation() {
        // PostWalAppend and PreApply leave identical disk state: the
        // record is fsynced, so reopen must replay it even though the
        // in-memory apply never happened.
        for point in [CrashPoint::PostWalAppend, CrashPoint::PreApply] {
            let dir = TempPath::new("store-crash-apply");
            let twin_dir = TempPath::new("store-crash-apply-twin");
            let store = new_store(dir.path(), 300, 10);
            let twin = new_store(twin_dir.path(), 300, 10);
            let extra = synthetic::longtail_sift(10, 8, 11);
            store.set_fault_plan(Some(FaultPlan::seeded(0, 0).with_crash(point)));
            let err = store.ingest(extra.flat()).unwrap_err();
            assert!(format!("{err:#}").contains("injected crash"), "{point:?}");
            drop(store);
            twin.ingest(extra.flat()).unwrap(); // the healthy twin
            let reopened: MutableStore<u64> =
                MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
            let q = synthetic::gaussian_queries(3, 8, 12);
            assert_eq!(answers(&reopened, &q), answers(&twin, &q), "{point:?}");
            // Deletes recover through the same protocol.
            reopened.set_fault_plan(Some(FaultPlan::seeded(0, 0).with_crash(point)));
            assert!(reopened.delete(&[5]).is_err(), "{point:?}");
            drop(reopened);
            twin.delete(&[5]).unwrap();
            let reopened: MutableStore<u64> =
                MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
            assert_eq!(answers(&reopened, &q), answers(&twin, &q), "{point:?} delete");
        }
    }

    #[test]
    fn crash_during_compaction_recovers_the_precompaction_state() {
        // MidCompaction writes nothing; PreRename stages but never
        // publishes. Both reopen to the pre-compaction epoch with every
        // acknowledged mutation intact.
        for point in [CrashPoint::MidCompaction, CrashPoint::PreRename] {
            let dir = TempPath::new("store-crash-compact");
            let store = new_store(dir.path(), 300, 13);
            store.delete(&(0..30).collect::<Vec<ItemId>>()).unwrap();
            let q = synthetic::gaussian_queries(3, 8, 14);
            let want = answers(&store, &q);
            store.set_fault_plan(Some(FaultPlan::seeded(0, 0).with_crash(point)));
            let err = store.compact().unwrap_err();
            assert!(format!("{err:#}").contains("injected crash"), "{point:?}");
            drop(store);
            let reopened: MutableStore<u64> =
                MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
            assert_eq!(reopened.tombstoned_len(), 30, "{point:?}");
            assert_eq!(answers(&reopened, &q), want, "{point:?}");
        }
    }

    #[test]
    fn compaction_drops_tombstones_and_preserves_answers() {
        let dir = TempPath::new("store-compact");
        let store = new_store(dir.path(), 400, 15);
        store.delete(&(0..50).collect::<Vec<ItemId>>()).unwrap();
        let q = synthetic::gaussian_queries(3, 8, 16);
        let want = answers(&store, &q);
        store.compact().unwrap();
        assert_eq!(store.tombstoned_len(), 0);
        assert_eq!(store.live_len(), 350);
        assert_eq!(answers(&store, &q), want, "full-budget answers survive compaction");
        // The WAL was truncated: reopen comes straight from the snapshot.
        drop(store);
        let reopened: MutableStore<u64> =
            MutableStore::open(dir.path(), small_cfg(), MutableConfig::manual()).unwrap();
        assert_eq!(reopened.tombstoned_len(), 0);
        assert_eq!(answers(&reopened, &q), want);
    }

    #[test]
    fn auto_compaction_triggers_on_tombstone_drift() {
        let dir = TempPath::new("store-drift");
        let items = Arc::new(synthetic::longtail_sift(200, 8, 17));
        let mcfg = MutableConfig {
            compact_tombstones: 0.1,
            auto_compact: true,
            ..MutableConfig::manual()
        };
        let store: MutableStore<u64> = MutableStore::create(
            dir.path(),
            items,
            RangeLshParams::new(16, 4),
            7,
            small_cfg(),
            mcfg,
        )
        .unwrap();
        store.delete(&(0..30).collect::<Vec<ItemId>>()).unwrap();
        assert_eq!(store.tombstoned_len(), 0, "drift must have compacted");
        assert_eq!(store.live_len(), 170);
    }

    #[test]
    fn any_store_round_trips_width() {
        let dir = TempPath::new("store-any");
        let items = Arc::new(synthetic::longtail_sift(300, 8, 18));
        let cfg = ServeConfig { code_bits: 128, ..small_cfg() };
        let store = AnyStore::create(
            dir.path(),
            items,
            RangeLshParams::new(128, 8),
            7,
            cfg.clone(),
            MutableConfig::manual(),
        )
        .unwrap();
        assert_eq!(store.code_words(), 2);
        let ids = store.ingest(&vec![0.25f32; 16]).unwrap();
        assert_eq!(ids, vec![300, 301]);
        drop(store);
        let reopened = AnyStore::open(dir.path(), cfg, MutableConfig::manual()).unwrap();
        assert_eq!(reopened.code_words(), 2);
        assert_eq!(reopened.live_len(), 302);
        let q = synthetic::gaussian_queries(1, 8, 19);
        assert_eq!(reopened.engine().search(q.row(0)).unwrap().len(), 5);
        // A typed open at the wrong width is a clear error.
        let err = MutableStore::<u64>::open(
            dir.path(),
            ServeConfig { code_bits: 128, ..small_cfg() },
            MutableConfig::manual(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("words per code"));
    }
}
