//! Dense row-major dataset with cached 2-norms.

use crate::util::par;
use crate::ItemId;

/// A dense `n x dim` f32 matrix, one item per row, with cached 2-norms.
///
/// The 2-norms are the central quantity in this paper: SIMPLE-LSH normalises
/// by their global maximum, RANGE-LSH partitions by their percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    dim: usize,
    data: Vec<f32>,
    norms: Vec<f32>,
}

impl Dataset {
    /// Build from a flat row-major buffer. `data.len()` must be a multiple of `dim`.
    pub fn from_flat(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(
            data.len() % dim,
            0,
            "flat buffer length {} not a multiple of dim {}",
            data.len(),
            dim
        );
        let n = data.len() / dim;
        let norms = par::par_map(n, |i| {
            data[i * dim..(i + 1) * dim]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt()
        });
        Self { dim, data, norms }
    }

    /// Build from rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "need at least one row");
        let dim = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dim);
        for r in rows {
            assert_eq!(r.len(), dim, "ragged rows");
            data.extend_from_slice(r);
        }
        Self::from_flat(dim, data)
    }

    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The whole row-major buffer.
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Cached 2-norm of item `i`.
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Global maximum 2-norm `U = max_x ||x||` (SIMPLE-LSH's scaling factor).
    pub fn max_norm(&self) -> f32 {
        self.norms.iter().copied().fold(0.0, f32::max)
    }

    /// Exact inner product `q . row(i)`.
    #[inline]
    pub fn dot(&self, i: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.dim);
        dot_slices(self.row(i), q)
    }

    /// A sub-dataset view materialised from item ids (used by partitioners).
    pub fn gather(&self, ids: &[ItemId]) -> Dataset {
        let mut data = Vec::with_capacity(ids.len() * self.dim);
        for &id in ids {
            data.extend_from_slice(self.row(id as usize));
        }
        Dataset::from_flat(self.dim, data)
    }

    /// Summary statistics of the 2-norm distribution (Fig. 1(b) material).
    pub fn norm_stats(&self) -> NormStats {
        let mut sorted = self.norms.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let n = sorted.len();
        let pct = |p: f64| sorted[((n - 1) as f64 * p) as usize];
        NormStats {
            min: sorted[0],
            p25: pct(0.25),
            median: pct(0.5),
            p75: pct(0.75),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

/// Unrolled inner product (§Perf): eight independent accumulators break
/// the f32 add dependency chain so the compiler can keep SIMD lanes busy —
/// a naive `zip().map().sum()` serialises on add latency. This sits under
/// every exact scan, ground-truth build and candidate re-rank.
#[inline]
pub fn dot_slices(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    let (ah, at) = a.split_at(chunks * 8);
    let (bh, bt) = b.split_at(chunks * 8);
    for (ca, cb) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Percentile summary of a dataset's 2-norm distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormStats {
    pub min: f32,
    pub p25: f32,
    pub median: f32,
    pub p75: f32,
    pub p95: f32,
    pub max: f32,
}

impl NormStats {
    /// Long-tail indicator: how far the max sits above the median.
    /// SIMPLE-LSH degrades when this is large (paper §3.1).
    pub fn tail_ratio(&self) -> f32 {
        self.max / self.median.max(f32::MIN_POSITIVE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_computes_norms() {
        let d = Dataset::from_flat(2, vec![3.0, 4.0, 0.0, 1.0]);
        assert_eq!(d.len(), 2);
        assert_eq!(d.norm(0), 5.0);
        assert_eq!(d.norm(1), 1.0);
        assert_eq!(d.max_norm(), 5.0);
    }

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let d = Dataset::from_rows(&rows);
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn from_flat_rejects_ragged() {
        Dataset::from_flat(3, vec![1.0; 4]);
    }

    #[test]
    fn dot_matches_manual() {
        let d = Dataset::from_flat(3, vec![1.0, 2.0, 3.0]);
        assert_eq!(d.dot(0, &[1.0, 0.5, 2.0]), 1.0 + 1.0 + 6.0);
    }

    #[test]
    fn gather_selects_rows() {
        let d = Dataset::from_flat(1, vec![10.0, 20.0, 30.0]);
        let g = d.gather(&[2, 0]);
        assert_eq!(g.flat(), &[30.0, 10.0]);
    }

    #[test]
    fn norm_stats_ordering() {
        let d = Dataset::from_flat(1, (1..=100).map(|i| i as f32).collect());
        let s = d.norm_stats();
        assert!(s.min <= s.p25 && s.p25 <= s.median && s.median <= s.p75);
        assert!(s.p75 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.tail_ratio() - 2.0).abs() < 0.05);
    }
}
