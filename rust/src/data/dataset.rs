//! Dense row-major dataset with cached 2-norms.

use crate::util::par;
use crate::ItemId;

/// A dense `n x dim` f32 matrix, one item per row, with cached 2-norms.
///
/// The 2-norms are the central quantity in this paper: SIMPLE-LSH normalises
/// by their global maximum, RANGE-LSH partitions by their percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    dim: usize,
    data: Vec<f32>,
    norms: Vec<f32>,
}

impl Dataset {
    /// Build from a flat row-major buffer. `data.len()` must be a multiple of `dim`.
    // staticcheck: allow(panic-reach, "row slices are bounded by n = data.len()/dim, asserted a multiple of dim above them")
    pub fn from_flat(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(
            data.len() % dim,
            0,
            "flat buffer length {} not a multiple of dim {}",
            data.len(),
            dim
        );
        let n = data.len() / dim;
        let norms = par::par_map(n, |i| {
            data[i * dim..(i + 1) * dim]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt()
        });
        Self { dim, data, norms }
    }

    /// Build from a flat row-major buffer plus already-known 2-norms,
    /// skipping the per-row sqrt-sum pass of [`Self::from_flat`]. The
    /// caller vouches that `norms[i]` is exactly the value `from_flat`
    /// would compute for row `i` (checked bit-for-bit in debug builds) —
    /// gathered sub-datasets and permuted views carry the parent's cached
    /// norms through here instead of re-deriving them.
    // staticcheck: allow(panic-reach, "the debug norm-check slices rows i < norms.len(), asserted equal to data.len()/dim")
    pub fn from_flat_with_norms(dim: usize, data: Vec<f32>, norms: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(
            data.len() % dim,
            0,
            "flat buffer length {} not a multiple of dim {}",
            data.len(),
            dim
        );
        assert_eq!(norms.len(), data.len() / dim, "one norm per row");
        #[cfg(debug_assertions)]
        for (i, &nrm) in norms.iter().enumerate() {
            let want: f32 =
                data[i * dim..(i + 1) * dim].iter().map(|v| v * v).sum::<f32>().sqrt();
            debug_assert_eq!(
                nrm.to_bits(),
                want.to_bits(),
                "carried norm for row {i} does not match the recomputed value"
            );
        }
        Self { dim, data, norms }
    }

    /// Build from rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "need at least one row");
        let dim = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dim);
        for r in rows {
            assert_eq!(r.len(), dim, "ragged rows");
            data.extend_from_slice(r);
        }
        Self::from_flat(dim, data)
    }

    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    // staticcheck: allow(panic-reach, "callers pass row ids produced by an index built over this dataset, so i < n_items and the slice lies inside the row-major buffer")
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The whole row-major buffer.
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Cached 2-norm of item `i`.
    // staticcheck: allow(panic-reach, "norms has one cached entry per row; callers pass row ids from the index over this dataset")
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Global maximum 2-norm `U = max_x ||x||` (SIMPLE-LSH's scaling factor).
    pub fn max_norm(&self) -> f32 {
        self.norms.iter().copied().fold(0.0, f32::max)
    }

    /// Exact inner product `q . row(i)`.
    #[inline]
    pub fn dot(&self, i: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.dim);
        dot_slices(self.row(i), q)
    }

    /// Four exact inner products against one query in a single pass
    /// (§Perf): the re-rank hot path scores candidates four rows at a
    /// time so each loaded query chunk is reused fourfold. Per row the
    /// accumulation order is identical to [`Self::dot`], so the results
    /// are bit-for-bit the same floats.
    #[inline]
    // staticcheck: allow(panic-reach, "the four ids are index-produced row ids (i < n_items); Dataset::row slices stay inside the buffer")
    pub fn dot4(&self, ids: [usize; 4], q: &[f32]) -> [f32; 4] {
        debug_assert_eq!(q.len(), self.dim);
        dot4_slices([self.row(ids[0]), self.row(ids[1]), self.row(ids[2]), self.row(ids[3])], q)
    }

    /// A sub-dataset view materialised from item ids (used by partitioners
    /// and the range-ordered [`crate::data::RerankView`]). The gathered
    /// rows keep the parent's cached 2-norms — no sqrt-sum per row.
    // staticcheck: allow(panic-reach, "callers pass ids drawn from this dataset's own partitions/live lists, all < len")
    pub fn gather(&self, ids: &[ItemId]) -> Dataset {
        let mut data = Vec::with_capacity(ids.len() * self.dim);
        let mut norms = Vec::with_capacity(ids.len());
        for &id in ids {
            data.extend_from_slice(self.row(id as usize));
            norms.push(self.norms[id as usize]);
        }
        Dataset::from_flat_with_norms(self.dim, data, norms)
    }

    /// Summary statistics of the 2-norm distribution (Fig. 1(b) material).
    /// Each percentile is an O(n) `select_nth_unstable` on a working copy
    /// instead of a full sort, with the nearest rank rounded half-up
    /// (the old truncating cast read one rank low at small `n`: the
    /// median of [1, 3] was 1, not 3).
    pub fn norm_stats(&self) -> NormStats {
        let mut work = self.norms.clone();
        let n = work.len();
        let mut pct = |p: f64| {
            let idx = ((n - 1) as f64 * p + 0.5).floor() as usize;
            *work.select_nth_unstable_by(idx, |a, b| a.total_cmp(b)).1
        };
        NormStats {
            p25: pct(0.25),
            median: pct(0.5),
            p75: pct(0.75),
            p95: pct(0.95),
            min: self.norms.iter().copied().fold(f32::INFINITY, f32::min),
            max: self.max_norm(),
        }
    }
}

/// Unrolled inner product (§Perf): eight independent accumulators break
/// the f32 add dependency chain so the compiler can keep SIMD lanes busy —
/// a naive `zip().map().sum()` serialises on add latency. This sits under
/// every exact scan, ground-truth build and candidate re-rank.
#[inline]
// staticcheck: allow(panic-reach, "split points sit at chunks*8 <= len and lane indices stay below 8 inside chunks_exact(8) blocks - arithmetic identities with no data dependence")
pub fn dot_slices(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    let (ah, at) = a.split_at(chunks * 8);
    let (bh, bt) = b.split_at(chunks * 8);
    for (ca, cb) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Four inner products against one shared query in a single pass (§Perf):
/// the query chunk is loaded once and multiplied into four rows, quartering
/// the query-side memory traffic of the candidate re-rank. Each row keeps
/// the exact accumulator layout and reduction tree of [`dot_slices`], so
/// `dot4_slices([a, b, c, d], q)` equals
/// `[dot_slices(a, q), ..., dot_slices(d, q)]` bit for bit — re-rank
/// ordering cannot shift between the paths.
#[inline]
// staticcheck: allow(panic-reach, "rows come from Dataset::row so each has length q.len(); every chunk index stays below chunks*8 <= dim")
pub fn dot4_slices(rows: [&[f32]; 4], q: &[f32]) -> [f32; 4] {
    let d = q.len();
    for r in &rows {
        debug_assert_eq!(r.len(), d);
    }
    let chunks = d / 8;
    let head = chunks * 8;
    let mut acc = [[0.0f32; 8]; 4];
    for c in 0..chunks {
        let base = c * 8;
        let qc = &q[base..base + 8];
        for (r, a) in rows.iter().zip(acc.iter_mut()) {
            let rc = &r[base..base + 8];
            for k in 0..8 {
                a[k] += rc[k] * qc[k];
            }
        }
    }
    let mut out = [0.0f32; 4];
    for (o, (r, a)) in out.iter_mut().zip(rows.iter().zip(&acc)) {
        let mut s = (a[0] + a[4]) + (a[1] + a[5]) + (a[2] + a[6]) + (a[3] + a[7]);
        for (x, y) in r[head..].iter().zip(&q[head..]) {
            s += x * y;
        }
        *o = s;
    }
    out
}

/// Percentile summary of a dataset's 2-norm distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormStats {
    pub min: f32,
    pub p25: f32,
    pub median: f32,
    pub p75: f32,
    pub p95: f32,
    pub max: f32,
}

impl NormStats {
    /// Long-tail indicator: how far the max sits above the median.
    /// SIMPLE-LSH degrades when this is large (paper §3.1).
    pub fn tail_ratio(&self) -> f32 {
        self.max / self.median.max(f32::MIN_POSITIVE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_computes_norms() {
        let d = Dataset::from_flat(2, vec![3.0, 4.0, 0.0, 1.0]);
        assert_eq!(d.len(), 2);
        assert_eq!(d.norm(0), 5.0);
        assert_eq!(d.norm(1), 1.0);
        assert_eq!(d.max_norm(), 5.0);
    }

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let d = Dataset::from_rows(&rows);
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn from_flat_rejects_ragged() {
        Dataset::from_flat(3, vec![1.0; 4]);
    }

    #[test]
    fn dot_matches_manual() {
        let d = Dataset::from_flat(3, vec![1.0, 2.0, 3.0]);
        assert_eq!(d.dot(0, &[1.0, 0.5, 2.0]), 1.0 + 1.0 + 6.0);
    }

    #[test]
    fn dot4_is_bitwise_identical_to_dot() {
        // The re-rank path depends on this: scoring through dot4 must not
        // shift any candidate ordering relative to single-row dots.
        for dim in [1usize, 7, 8, 17, 64, 129] {
            let d = crate::data::synthetic::longtail_sift(8, dim, 3);
            let q = crate::data::synthetic::gaussian_queries(1, dim, 4);
            let got = d.dot4([0, 3, 5, 7], q.row(0));
            for (k, &i) in [0usize, 3, 5, 7].iter().enumerate() {
                assert_eq!(got[k].to_bits(), d.dot(i, q.row(0)).to_bits(), "dim {dim} row {i}");
            }
        }
    }

    #[test]
    fn gather_selects_rows() {
        let d = Dataset::from_flat(1, vec![10.0, 20.0, 30.0]);
        let g = d.gather(&[2, 0]);
        assert_eq!(g.flat(), &[30.0, 10.0]);
    }

    #[test]
    fn gather_carries_cached_norms_bit_exactly() {
        let d = crate::data::synthetic::longtail_sift(40, 7, 11);
        let ids: Vec<ItemId> = vec![3, 39, 0, 17, 17, 8];
        let g = d.gather(&ids);
        for (k, &id) in ids.iter().enumerate() {
            assert_eq!(g.norm(k).to_bits(), d.norm(id as usize).to_bits(), "row {k}");
            assert_eq!(g.row(k), d.row(id as usize), "row {k}");
        }
    }

    #[test]
    fn from_flat_with_norms_skips_recompute_but_checks_shape() {
        let data = vec![3.0, 4.0, 0.0, 1.0];
        let d = Dataset::from_flat_with_norms(2, data.clone(), vec![5.0, 1.0]);
        assert_eq!(d.norm(0), 5.0);
        assert_eq!(d, Dataset::from_flat(2, data));
    }

    #[test]
    #[should_panic(expected = "one norm per row")]
    fn from_flat_with_norms_rejects_length_mismatch() {
        Dataset::from_flat_with_norms(2, vec![0.0; 4], vec![0.0; 3]);
    }

    #[test]
    fn norm_stats_percentile_rank_rounds_half_up() {
        // Median of [1, 3]: rank (n-1)*0.5 = 0.5 rounds up to index 1.
        let d = Dataset::from_flat(1, vec![1.0, 3.0]);
        assert_eq!(d.norm_stats().median, 3.0);
        // Odd length: the true middle element, not the one below it.
        let d = Dataset::from_flat(1, vec![5.0, 1.0, 3.0]);
        let s = d.norm_stats();
        assert_eq!(s.median, 3.0);
        assert_eq!((s.min, s.max), (1.0, 5.0));
        // Single row: every percentile is that row.
        let d = Dataset::from_flat(1, vec![2.0]);
        let s = d.norm_stats();
        assert_eq!((s.min, s.p25, s.median, s.p95, s.max), (2.0, 2.0, 2.0, 2.0, 2.0));
    }

    #[test]
    fn norm_stats_ordering() {
        let d = Dataset::from_flat(1, (1..=100).map(|i| i as f32).collect());
        let s = d.norm_stats();
        assert!(s.min <= s.p25 && s.p25 <= s.median && s.median <= s.p75);
        assert!(s.p75 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.tail_ratio() - 2.0).abs() < 0.05);
    }
}
