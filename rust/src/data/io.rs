//! Dataset binary IO: a minimal `.rdat` format (magic, dim, n, f32 LE rows).
//!
//! Used by the CLI (`rangelsh gen-data` → `rangelsh build/eval/serve`) so
//! expensive dataset generation runs once per experiment campaign.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Context};

use super::Dataset;
use crate::Result;

const MAGIC: &[u8; 8] = b"RANGELSH";
const VERSION: u32 = 1;

/// Write `dataset` to `path` in `.rdat` format.
pub fn save_dataset(dataset: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(dataset.dim() as u64).to_le_bytes())?;
    w.write_all(&(dataset.len() as u64).to_le_bytes())?;
    for v in dataset.flat() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a `.rdat` dataset from `path`.
// staticcheck: allow(panic-reach, "byte indices 0..4 come from chunks_exact(4), which only yields full chunks")
pub fn load_dataset(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "{}: not a rangelsh dataset", path.display());
    let mut word = [0u8; 4];
    r.read_exact(&mut word)?;
    let version = u32::from_le_bytes(word);
    ensure!(version == VERSION, "unsupported dataset version {version}");
    let mut qword = [0u8; 8];
    r.read_exact(&mut qword)?;
    let dim = u64::from_le_bytes(qword) as usize;
    r.read_exact(&mut qword)?;
    let n = u64::from_le_bytes(qword) as usize;
    ensure!(dim > 0, "zero dim");
    let mut bytes = vec![0u8; n * dim * 4];
    r.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Dataset::from_flat(dim, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn round_trip() {
        let d = synthetic::longtail_sift(64, 7, 3);
        let tmp = crate::util::tmp::TempPath::new("io-roundtrip");
        save_dataset(&d, tmp.path()).unwrap();
        let back = load_dataset(tmp.path()).unwrap();
        assert_eq!(d, back);
    }

    #[test]
    fn rejects_garbage() {
        let tmp = crate::util::tmp::TempPath::new("io-garbage");
        std::fs::write(tmp.path(), b"not a dataset at all").unwrap();
        assert!(load_dataset(tmp.path()).is_err());
    }

    #[test]
    fn missing_file_errors_with_path() {
        let err = load_dataset("/nonexistent/xyz.rdat").unwrap_err();
        assert!(format!("{err:#}").contains("/nonexistent/xyz.rdat"));
    }
}
