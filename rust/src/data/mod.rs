//! Datasets: dense row-major f32 matrices, synthetic generators, binary IO.
//!
//! The paper evaluates on Netflix / Yahoo!Music ALS embeddings and ImageNet
//! SIFT descriptors. Those exact corpora are not available here, so
//! [`synthetic`] provides generators that reproduce the property the paper's
//! claims actually depend on — the *shape of the 2-norm distribution*
//! (long-tailed for ImageNet, mild spread for the MF embeddings). See
//! DESIGN.md §3 for the substitution argument.

mod dataset;
mod io;
mod rerank_view;
pub mod synthetic;

pub use dataset::{dot4_slices, dot_slices, Dataset, NormStats};
pub use io::{load_dataset, save_dataset};
pub use rerank_view::RerankView;
