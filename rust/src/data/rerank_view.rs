//! [`RerankView`]: a re-rank-optimized storage layout — the dataset
//! permuted into range order, so the exact-scoring stage of a query reads
//! contiguous cache lines instead of scattering gathers across the whole
//! original-order matrix.
//!
//! Slots are ordered by descending 2-norm, ties by descending id — the
//! exact reverse of the partitioner's `(norm asc, id asc)` ranking
//! (`crate::index::partition`). Because both percentile and uniform-range
//! partitioning cut that ranking into contiguous rank intervals, every
//! norm range `S_j` occupies one contiguous, norm-descending slot block
//! here: candidates emitted by a probed range land next to each other,
//! and the high-`U_j` ranges the Eq. 12 schedule visits first sit at the
//! front of the buffer.
//!
//! Two invariants the streaming re-rank leans on:
//! - **Bit-exact rows.** `dot_at(slot_of(id), q)` is the same float as
//!   `Dataset::dot(id, q)` on the original layout (rows are byte copies,
//!   the accumulation order is identical), so a re-rank through the view
//!   cannot shift any candidate ordering.
//! - **Descending norms.** `norm_at(s) >= norm_at(t)` for `s <= t`, so
//!   `norm_at(s)` bounds the norm of every item stored at slot `s` or
//!   later — the per-range prefix maximum of norms is simply the block's
//!   first slot, with no auxiliary table.

use crate::data::Dataset;
use crate::ItemId;

/// A norm-descending, range-contiguous permutation of a [`Dataset`] with
/// id↔slot maps. Costs one extra copy of the matrix; built once per
/// serving engine (see `ServeConfig::rerank`).
pub struct RerankView {
    view: Dataset,
    /// slot → original item id.
    id_of: Vec<ItemId>,
    /// original item id → slot.
    slot_of: Vec<u32>,
}

impl RerankView {
    /// Permute `dataset` into range order. O(n log n) sort of the cached
    /// norms plus one pass over the matrix; the view carries the parent's
    /// norms (no recompute).
    // staticcheck: allow(panic-reach, "id_of is a permutation of 0..n and slot_of has n entries")
    pub fn build(dataset: &Dataset) -> Self {
        let n = dataset.len();
        let dim = dataset.dim();
        let mut id_of: Vec<ItemId> = (0..n as ItemId).collect();
        id_of.sort_unstable_by(|&a, &b| {
            dataset
                .norm(b as usize)
                .total_cmp(&dataset.norm(a as usize))
                .then(b.cmp(&a))
        });
        let mut slot_of = vec![0u32; n];
        let mut data = Vec::with_capacity(n * dim);
        let mut norms = Vec::with_capacity(n);
        for (slot, &id) in id_of.iter().enumerate() {
            slot_of[id as usize] = slot as u32;
            data.extend_from_slice(dataset.row(id as usize));
            norms.push(dataset.norm(id as usize));
        }
        let view = Dataset::from_flat_with_norms(dim, data, norms);
        Self { view, id_of, slot_of }
    }

    pub fn len(&self) -> usize {
        self.id_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_of.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.view.dim()
    }

    /// The permuted storage itself (range-ordered rows, carried norms).
    pub fn dataset(&self) -> &Dataset {
        &self.view
    }

    /// Where the original item `id` lives in the permuted layout.
    #[inline]
    // staticcheck: allow(panic-reach, "slot_of is a permutation table with one entry per item; ids are dataset row ids, so id < n")
    pub fn slot_of(&self, id: ItemId) -> usize {
        self.slot_of[id as usize] as usize
    }

    /// Which original item the permuted `slot` holds.
    #[inline]
    pub fn id_at(&self, slot: usize) -> ItemId {
        self.id_of[slot]
    }

    /// Cached 2-norm of the item at `slot`. By the layout invariant this
    /// also bounds the norm of every item at `slot` or later.
    #[inline]
    pub fn norm_at(&self, slot: usize) -> f32 {
        self.view.norm(slot)
    }

    /// Exact inner product of `q` with the item at `slot` — bit-identical
    /// to [`Dataset::dot`] on the original layout (see module docs).
    #[inline]
    pub fn dot_at(&self, slot: usize, q: &[f32]) -> f32 {
        self.view.dot(slot, q)
    }

    /// Four exact inner products in one pass ([`Dataset::dot4`]).
    #[inline]
    pub fn dot4_at(&self, slots: [usize; 4], q: &[f32]) -> [f32; 4] {
        self.view.dot4(slots, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn maps_are_inverse_bijections() {
        let d = synthetic::longtail_sift(300, 8, 1);
        let v = RerankView::build(&d);
        assert_eq!(v.len(), 300);
        for slot in 0..v.len() {
            assert_eq!(v.slot_of(v.id_at(slot)), slot);
        }
        for id in 0..300u32 {
            assert_eq!(v.id_at(v.slot_of(id)), id);
        }
    }

    #[test]
    fn slots_descend_in_norm_and_carry_bit_exact_norms() {
        let d = synthetic::longtail_sift(500, 8, 2);
        let v = RerankView::build(&d);
        for slot in 0..v.len() {
            assert_eq!(
                v.norm_at(slot).to_bits(),
                d.norm(v.id_at(slot) as usize).to_bits(),
                "slot {slot}"
            );
            if slot > 0 {
                assert!(v.norm_at(slot - 1) >= v.norm_at(slot), "slot {slot} not descending");
            }
        }
    }

    #[test]
    fn view_dots_are_bit_identical_to_original_layout() {
        let d = synthetic::longtail_sift(100, 17, 3);
        let q = synthetic::gaussian_queries(1, 17, 4);
        let v = RerankView::build(&d);
        for id in 0..100u32 {
            assert_eq!(
                v.dot_at(v.slot_of(id), q.row(0)).to_bits(),
                d.dot(id as usize, q.row(0)).to_bits(),
                "id {id}"
            );
        }
    }

    #[test]
    fn duplicated_rows_still_permute_bijectively() {
        // Tie-heavy norms: every row appears twice, so the (norm, id)
        // tie-break does real work.
        let base = synthetic::longtail_sift(50, 4, 5);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for i in 0..50 {
            rows.push(base.row(i).to_vec());
            rows.push(base.row(i).to_vec());
        }
        let d = Dataset::from_rows(&rows);
        let v = RerankView::build(&d);
        for slot in 0..v.len() {
            assert_eq!(v.slot_of(v.id_at(slot)), slot);
        }
    }

    #[test]
    fn partition_ranges_occupy_contiguous_slot_blocks() {
        // The "range order" claim: each percentile/uniform range's members
        // sit in one contiguous slot interval of the view.
        use crate::index::{partition, PartitionScheme};
        let d = synthetic::longtail_sift(400, 8, 6);
        let v = RerankView::build(&d);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            for (j, part) in partition(&d, 16, scheme).unwrap().iter().enumerate() {
                let mut slots: Vec<usize> =
                    part.ids.iter().map(|&id| v.slot_of(id)).collect();
                slots.sort_unstable();
                let lo = slots[0];
                for (off, &s) in slots.iter().enumerate() {
                    assert_eq!(s, lo + off, "{scheme:?} range {j} not contiguous");
                }
                // ... and the block's first slot is the range's prefix max.
                assert_eq!(
                    v.norm_at(lo).to_bits(),
                    part.u_max.to_bits(),
                    "{scheme:?} range {j}"
                );
            }
        }
    }
}
