//! Synthetic dataset generators matching the paper's corpora (DESIGN.md §3).
//!
//! Three generators, one per 2-norm-distribution regime:
//!
//! - [`mf_embeddings`] — Netflix / Yahoo!Music stand-in: low-rank matrix
//!   factorisation embeddings. Norms concentrate (chi-distribution-like),
//!   **no long tail** — the regime where the paper shows RANGE-LSH is still
//!   robust (max norm close to median, see paper §4).
//! - [`longtail_sift`] — ImageNet-SIFT stand-in: uniform directions with
//!   log-normally distributed norms, heavy upper tail — the regime where
//!   SIMPLE-LSH's global normalisation collapses (Fig. 1(b)).
//! - [`uniform_norm`] — control: all items on a sphere, the degenerate case
//!   where RANGE-LSH and SIMPLE-LSH coincide (paper §3.2 discussion).

use super::Dataset;
use crate::util::rng::Rng;

fn randn_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v);
    v
}

/// Matrix-factorisation style embeddings: `X = G1 @ G2` with Gaussian
/// factors of rank `rank`, plus a small dense residual. Matches the ALS
/// embeddings the paper uses for Netflix/Yahoo!Music (d = 300 there):
/// norms are chi-like with mild spread and essentially no tail.
pub fn mf_embeddings(n: usize, dim: usize, rank: usize, seed: u64) -> Dataset {
    mf_vectors(n, dim, rank, seed, 0)
}

/// User-side embeddings from the *same* factorisation as
/// [`mf_embeddings`]`(_, dim, rank, seed)`: identical item-factor basis
/// `G2`, fresh user factors. This is the paper's query workload — user and
/// item vectors share the ALS latent space, so queries have genuinely
/// large inner products with their best items (unlike independent random
/// directions, which make MIPS artificially hard).
pub fn mf_user_queries(n: usize, dim: usize, rank: usize, seed: u64) -> Dataset {
    mf_vectors(n, dim, rank, seed, 0x0A5E_55ED)
}

fn mf_vectors(n: usize, dim: usize, rank: usize, seed: u64, stream_salt: u64) -> Dataset {
    assert!(rank > 0 && rank <= dim, "rank must be in 1..=dim");
    let mut rng = Rng::seed_from_u64(seed);
    let g2 = randn_vec(&mut rng, rank * dim);
    // Users draw their factors from a separate stream so item/user sets
    // differ, but share the g2 basis drawn above.
    if stream_salt != 0 {
        rng = Rng::seed_from_u64(seed ^ stream_salt);
    }
    let scale = 1.0 / (rank as f32).sqrt();
    let mut data = vec![0.0f32; n * dim];
    for i in 0..n {
        let g1 = randn_vec(&mut rng, rank);
        // Per-item popularity factor: MF embeddings of popular items have
        // larger norms; a log-normal with small sigma gives the mild spread
        // observed on Netflix (max/median ~ 2-3, no long tail).
        let pop = rng.lognormal(0.0, 0.25) as f32;
        let row = &mut data[i * dim..(i + 1) * dim];
        for (j, r) in row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for k in 0..rank {
                acc += g1[k] * g2[k * dim + j];
            }
            *r = acc * scale * pop;
        }
    }
    Dataset::from_flat(dim, data)
}

/// SIFT-descriptor style data with a long-tailed 2-norm distribution:
/// directions uniform on the sphere, norms log-normal with `sigma` chosen
/// so the global max is several times the median (Fig. 1(b) regime: after
/// scaling max to 1, the bulk of the mass sits around 0.2–0.4).
pub fn longtail_sift(n: usize, dim: usize, seed: u64) -> Dataset {
    longtail_with_sigma(n, dim, 0.35, seed)
}

/// Long-tail generator with explicit log-normal sigma (ablation knob).
pub fn longtail_with_sigma(n: usize, dim: usize, sigma: f32, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed);
    let mut data = vec![0.0f32; n * dim];
    for i in 0..n {
        let dir = randn_vec(&mut rng, dim);
        let len = dir.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
        let target = if sigma == 0.0 { 1.0 } else { rng.lognormal(0.0, sigma as f64) };
        let s = target as f32 / len;
        for (dst, v) in data[i * dim..(i + 1) * dim].iter_mut().zip(&dir) {
            *dst = v * s;
        }
    }
    Dataset::from_flat(dim, data)
}

/// Control dataset: every item has exactly unit norm. MIPS degenerates to
/// angular search and RANGE-LSH == SIMPLE-LSH (paper §3.2).
pub fn uniform_norm(n: usize, dim: usize, seed: u64) -> Dataset {
    longtail_with_sigma(n, dim, 0.0, seed)
}

/// Query workload: i.i.d. Gaussian directions. SIMPLE-LSH normalises
/// queries to unit norm anyway (Eq. 8), so only direction matters; this
/// matches sampling held-out user embeddings' directions.
pub fn gaussian_queries(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed);
    Dataset::from_flat(dim, randn_vec(&mut rng, n * dim))
}

/// Query workload correlated with the dataset: each query is a noisy copy of
/// a random item (recommendation-style, where user vectors align with item
/// factors). `noise` is the relative perturbation magnitude.
pub fn correlated_queries(dataset: &Dataset, n: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed);
    let dim = dataset.dim();
    let mut data = vec![0.0f32; n * dim];
    for i in 0..n {
        let src = rng.gen_index(dataset.len());
        let base = dataset.row(src);
        let norm = dataset.norm(src).max(1e-12);
        for (j, dst) in data[i * dim..(i + 1) * dim].iter_mut().enumerate() {
            let eps = rng.normal_f32();
            *dst = base[j] + noise * norm * eps / (dim as f32).sqrt();
        }
    }
    Dataset::from_flat(dim, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(longtail_sift(50, 8, 1), longtail_sift(50, 8, 1));
        assert_eq!(mf_embeddings(50, 8, 4, 1), mf_embeddings(50, 8, 4, 1));
        assert_ne!(longtail_sift(50, 8, 1), longtail_sift(50, 8, 2));
    }

    #[test]
    fn longtail_has_long_tail() {
        let d = longtail_sift(20_000, 16, 0);
        let s = d.norm_stats();
        // max should be several times the median — the Fig 1(b) regime.
        assert!(s.tail_ratio() > 2.5, "tail ratio {}", s.tail_ratio());
    }

    #[test]
    fn mf_embeddings_have_mild_spread() {
        let d = mf_embeddings(20_000, 32, 8, 0);
        let s = d.norm_stats();
        assert!(s.tail_ratio() < 8.0, "tail ratio {}", s.tail_ratio());
        assert!(s.tail_ratio() > 1.2);
    }

    #[test]
    fn uniform_norm_is_spherical() {
        let d = uniform_norm(100, 8, 0);
        for i in 0..d.len() {
            assert!((d.norm(i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn longtail_norms_match_targets() {
        // The generator scales directions to hit the sampled norms exactly.
        let d = longtail_with_sigma(1000, 8, 0.5, 3);
        let s = d.norm_stats();
        // Log-normal(0, 0.5): median == 1.
        assert!((s.median - 1.0).abs() < 0.1, "median {}", s.median);
    }

    #[test]
    fn correlated_queries_align_with_items() {
        let d = longtail_sift(200, 16, 0);
        let q = correlated_queries(&d, 50, 0.1, 1);
        assert_eq!(q.len(), 50);
        assert_eq!(q.dim(), 16);
        // A noisy copy of an item should have a large max inner product
        // relative to a random direction's.
        let best: f32 = (0..d.len()).map(|i| d.dot(i, q.row(0))).fold(f32::MIN, f32::max);
        assert!(best > 0.0);
    }

    #[test]
    fn shapes_are_requested() {
        let d = mf_embeddings(17, 5, 2, 9);
        assert_eq!((d.len(), d.dim()), (17, 5));
    }
}
