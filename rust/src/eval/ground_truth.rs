//! Exact MIPS ground truth via parallel linear scan.
//!
//! The recall metric in Fig. 2 needs the true top-k per query. The native
//! path below is rayon-parallel over queries; the PJRT-scored path (same
//! results, MXU-shaped matmuls) lives in [`crate::runtime::scorer`].

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::Dataset;
use crate::util::par;
use crate::ItemId;

/// Min-heap entry so the heap evicts the smallest inner product.
#[derive(PartialEq)]
struct HeapItem(f32, ItemId);

impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want min-at-top.
        other.0.total_cmp(&self.0).then(other.1.cmp(&self.1))
    }
}

/// Exact top-`k` MIPS for every query row, descending inner product.
pub fn exact_topk(dataset: &Dataset, queries: &Dataset, k: usize) -> Vec<Vec<ItemId>> {
    assert_eq!(dataset.dim(), queries.dim(), "dimension mismatch");
    assert!(k >= 1);
    par::par_map(queries.len(), |qi| {
            let q = queries.row(qi);
            let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
            for i in 0..dataset.len() {
                let s = dataset.dot(i, q);
                if heap.len() < k {
                    heap.push(HeapItem(s, i as ItemId));
                } else if let Some(top) = heap.peek() {
                    if s > top.0 {
                        heap.pop();
                        heap.push(HeapItem(s, i as ItemId));
                    }
                }
            }
            let mut v: Vec<HeapItem> = heap.into_vec();
            v.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            v.into_iter().map(|h| h.1).collect()
    })
}

/// The maximum inner product per query — Fig. 1(c)/(d) plot these after
/// the two normalisation schemes. Returns raw (unnormalised) values;
/// divide by `U` or `U_j` per the scheme under study.
pub fn max_inner_products(dataset: &Dataset, queries: &Dataset) -> Vec<f32> {
    par::par_map(queries.len(), |qi| {
        let q = queries.row(qi);
        (0..dataset.len())
            .map(|i| dataset.dot(i, q))
            .fold(f32::MIN, f32::max)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn matches_naive_scan() {
        let d = synthetic::longtail_sift(200, 8, 0);
        let q = synthetic::gaussian_queries(10, 8, 1);
        let got = exact_topk(&d, &q, 5);
        for qi in 0..q.len() {
            let mut scores: Vec<(f32, ItemId)> = (0..d.len())
                .map(|i| (d.dot(i, q.row(qi)), i as ItemId))
                .collect();
            scores.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let want: Vec<ItemId> = scores[..5].iter().map(|&(_, id)| id).collect();
            assert_eq!(got[qi], want, "query {qi}");
        }
    }

    #[test]
    fn k_larger_than_n_returns_all() {
        let d = synthetic::longtail_sift(7, 4, 0);
        let q = synthetic::gaussian_queries(2, 4, 1);
        let got = exact_topk(&d, &q, 50);
        assert!(got.iter().all(|g| g.len() == 7));
    }

    #[test]
    fn results_are_descending_in_inner_product() {
        let d = synthetic::mf_embeddings(100, 8, 4, 2);
        let q = synthetic::gaussian_queries(5, 8, 3);
        for (qi, ids) in exact_topk(&d, &q, 10).iter().enumerate() {
            let scores: Vec<f32> = ids.iter().map(|&id| d.dot(id as usize, q.row(qi))).collect();
            for w in scores.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    fn max_inner_products_agree_with_topk() {
        let d = synthetic::longtail_sift(150, 8, 4);
        let q = synthetic::gaussian_queries(8, 8, 5);
        let tops = exact_topk(&d, &q, 1);
        let mips = max_inner_products(&d, &q);
        for qi in 0..q.len() {
            let s = d.dot(tops[qi][0] as usize, q.row(qi));
            assert!((s - mips[qi]).abs() < 1e-6);
        }
    }
}
