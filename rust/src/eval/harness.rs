//! Experiment harness: build any of the paper's index types from a spec,
//! measure its recall curve, and emit paper-style result rows (used by the
//! `benches/` figure regenerators and the CLI `eval` subcommand).


use crate::config::IndexAlgo;
use crate::data::Dataset;
use crate::eval::{exact_topk, recall_curve, RecallCurve};
use crate::hash::{Code128, Code256, CodeWord, NativeHasher, MAX_CODE_BITS};
use crate::index::l2alsh::{L2AlshIndex, L2AlshParams};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::ranged_l2alsh::{RangedL2AlshIndex, RangedL2AlshParams};
use crate::index::sign_alsh::{SignAlshIndex, SignAlshParams};
use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
use crate::index::{IndexStats, MipsIndex, PartitionScheme};
use crate::{ItemId, Result};

/// What to run: one algorithm at one operating point.
#[derive(Debug, Clone)]
pub struct CurveSpec {
    pub algo: IndexAlgo,
    /// Total code budget L (bits).
    pub code_bits: usize,
    /// Ranges `m` (ignored for unpartitioned algos).
    pub n_partitions: usize,
    pub scheme: PartitionScheme,
    pub epsilon: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl CurveSpec {
    pub fn new(algo: IndexAlgo, code_bits: usize, n_partitions: usize) -> Self {
        Self {
            algo,
            code_bits,
            n_partitions,
            scheme: PartitionScheme::Percentile,
            epsilon: 0.1,
            top_k: 10,
            seed: 7,
        }
    }
}

/// One measured experiment: the curve plus context for table printing.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub label: String,
    pub curve: RecallCurve,
    pub stats: IndexStats,
    pub build_secs: f64,
    pub query_secs: f64,
}

impl ExperimentResult {
    /// Paper-style row: probes needed for a set of recall targets.
    pub fn probes_row(&self, targets: &[f64]) -> Vec<Option<usize>> {
        targets.iter().map(|&t| self.curve.probes_to_reach(t)).collect()
    }
}

/// Build the spec'd index over `dataset`, monomorphized to the narrowest
/// [`CodeWord`] that fits `spec.code_bits` (u64 up to 64 bits — the
/// original codegen — then `Code128` / `Code256`). The floor-hash
/// baselines (L2-ALSH family) key buckets by integer vectors, not packed
/// codes, so any `K` within range works unchanged.
///
/// Wide-code fairness convention (`eval --compare` at L > 64): the
/// floor-hash baselines always get **K = L hashes** — `code_bits` floor
/// hashes against `code_bits` sign bits, at every width (the paper's
/// experiment-code convention, now explicit for Code128/Code256). Each
/// floor hash carries at least as much information as one sign bit
/// (its integer value subsumes the sign), so K = L never *under*-equips
/// the baseline; at wide L it if anything over-equips it, which is the
/// conservative direction for the paper's claim. The
/// `floor_hash_baselines_use_k_equals_l_at_wide_codes` test pins this.
pub fn build_index(dataset: &Dataset, spec: &CurveSpec) -> Result<Box<dyn MipsIndex>> {
    anyhow::ensure!(
        spec.code_bits >= 1 && spec.code_bits <= MAX_CODE_BITS,
        "code_bits {} out of range 1..={MAX_CODE_BITS}",
        spec.code_bits
    );
    Ok(match spec.algo {
        IndexAlgo::SimpleLsh => {
            if spec.code_bits <= 64 {
                // The scalar path keeps its historical 64-wide panel.
                Box::new(build_simple::<u64>(dataset, spec, 64)?)
            } else if spec.code_bits <= 128 {
                Box::new(build_simple::<Code128>(dataset, spec, spec.code_bits)?)
            } else {
                Box::new(build_simple::<Code256>(dataset, spec, spec.code_bits)?)
            }
        }
        IndexAlgo::RangeLsh => {
            if spec.code_bits <= 64 {
                Box::new(build_range::<u64>(dataset, spec, 64)?)
            } else {
                // Match the serving stack (AnyEngine / `rangelsh build`):
                // wide RANGE-LSH panels are exactly hash_bits wide, so the
                // harness measures the same index the engine serves.
                let width = RangeLshParams::new(spec.code_bits, spec.n_partitions).hash_bits();
                if spec.code_bits <= 128 {
                    Box::new(build_range::<Code128>(dataset, spec, width)?)
                } else {
                    Box::new(build_range::<Code256>(dataset, spec, width)?)
                }
            }
        }
        IndexAlgo::L2Alsh => Box::new(L2AlshIndex::build(
            dataset,
            L2AlshParams::recommended(spec.code_bits),
        )?),
        IndexAlgo::RangedL2Alsh => Box::new(RangedL2AlshIndex::build(
            dataset,
            RangedL2AlshParams::recommended(spec.code_bits, spec.n_partitions),
        )?),
        IndexAlgo::SignAlsh => {
            if spec.code_bits <= 64 {
                Box::new(SignAlshIndex::<u64>::build(
                    dataset,
                    SignAlshParams::recommended(spec.code_bits),
                )?)
            } else if spec.code_bits <= 128 {
                Box::new(SignAlshIndex::<Code128>::build(
                    dataset,
                    SignAlshParams::recommended(spec.code_bits),
                )?)
            } else {
                Box::new(SignAlshIndex::<Code256>::build(
                    dataset,
                    SignAlshParams::recommended(spec.code_bits),
                )?)
            }
        }
    })
}

fn build_simple<C: CodeWord>(
    dataset: &Dataset,
    spec: &CurveSpec,
    width: usize,
) -> Result<SimpleLshIndex<C>> {
    let hasher: NativeHasher<C> = NativeHasher::new(dataset.dim(), width, spec.seed);
    SimpleLshIndex::build(dataset, &hasher, SimpleLshParams::new(spec.code_bits))
}

fn build_range<C: CodeWord>(
    dataset: &Dataset,
    spec: &CurveSpec,
    width: usize,
) -> Result<RangeLshIndex<C>> {
    let hasher: NativeHasher<C> = NativeHasher::new(dataset.dim(), width, spec.seed);
    RangeLshIndex::build(
        dataset,
        &hasher,
        RangeLshParams::new(spec.code_bits, spec.n_partitions)
            .with_scheme(spec.scheme)
            .with_epsilon(spec.epsilon),
    )
}

/// Build + measure: the one-call entry used by every figure bench.
/// `ground_truth` may be shared across specs (computed once per dataset).
pub fn run_curve(
    dataset: &Dataset,
    queries: &Dataset,
    ground_truth: &[Vec<ItemId>],
    checkpoints: &[usize],
    spec: &CurveSpec,
    label: impl Into<String>,
) -> Result<ExperimentResult> {
    let t0 = std::time::Instant::now();
    let index = build_index(dataset, spec)?;
    let build_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let curve = recall_curve(index.as_ref(), queries, ground_truth, checkpoints);
    let query_secs = t1.elapsed().as_secs_f64();
    Ok(ExperimentResult {
        label: label.into(),
        curve,
        stats: index.stats(),
        build_secs,
        query_secs,
    })
}

/// Convenience: exact ground truth for `top_k`.
pub fn ground_truth(dataset: &Dataset, queries: &Dataset, top_k: usize) -> Vec<Vec<ItemId>> {
    exact_topk(dataset, queries, top_k)
}

/// Render results as an aligned text table of probes-to-recall targets —
/// the shape of the paper's Fig. 2 comparison, in rows.
pub fn format_probe_table(results: &[ExperimentResult], targets: &[f64]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<28}", "algorithm"));
    for t in targets {
        out.push_str(&format!("  probes@{:.0}%", t * 100.0));
    }
    out.push_str("  buckets  largest\n");
    for r in results {
        out.push_str(&format!("{:<28}", r.label));
        for p in r.probes_row(targets) {
            match p {
                Some(p) => out.push_str(&format!("  {:>10}", p)),
                None => out.push_str(&format!("  {:>10}", "-")),
            }
        }
        out.push_str(&format!(
            "  {:>7}  {:>7}\n",
            r.stats.n_buckets, r.stats.largest_bucket
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::eval::recall::geometric_checkpoints;

    #[test]
    fn harness_runs_all_algorithms() {
        let d = synthetic::longtail_sift(600, 8, 0);
        let q = synthetic::gaussian_queries(10, 8, 1);
        let gt = ground_truth(&d, &q, 5);
        let cps = geometric_checkpoints(10, d.len(), 3);
        for algo in [
            IndexAlgo::SimpleLsh,
            IndexAlgo::RangeLsh,
            IndexAlgo::L2Alsh,
            IndexAlgo::RangedL2Alsh,
            IndexAlgo::SignAlsh,
        ] {
            let spec = CurveSpec::new(algo, 16, 8);
            let res = run_curve(&d, &q, &gt, &cps, &spec, format!("{algo:?}")).unwrap();
            assert!(
                (res.curve.final_recall() - 1.0).abs() < 1e-9,
                "{algo:?}: full probe must reach recall 1, got {}",
                res.curve.final_recall()
            );
            assert!(res.build_secs >= 0.0 && res.query_secs >= 0.0);
        }
    }

    #[test]
    fn harness_runs_wide_code_specs() {
        // The dispatcher must route L > 64 to the multi-word indexes.
        let d = synthetic::longtail_sift(400, 8, 5);
        let q = synthetic::gaussian_queries(8, 8, 6);
        let gt = ground_truth(&d, &q, 5);
        let cps = geometric_checkpoints(10, d.len(), 3);
        for (algo, bits, m) in [
            (IndexAlgo::RangeLsh, 128, 8),
            (IndexAlgo::SimpleLsh, 128, 1),
            (IndexAlgo::RangeLsh, 256, 8),
            (IndexAlgo::SignAlsh, 128, 1),
        ] {
            let spec = CurveSpec::new(algo, bits, m);
            let res = run_curve(&d, &q, &gt, &cps, &spec, format!("{algo} L={bits}")).unwrap();
            assert!(
                (res.curve.final_recall() - 1.0).abs() < 1e-9,
                "{algo} L={bits}: full probe must reach recall 1, got {}",
                res.curve.final_recall()
            );
        }
    }

    #[test]
    fn floor_hash_baselines_use_k_equals_l_at_wide_codes() {
        // The wide-code fairness convention: at L > 64 the L2-ALSH family
        // gets exactly K = L floor hashes, mirroring L sign bits.
        let d = synthetic::longtail_sift(200, 8, 9);
        for bits in [128usize, 256] {
            for algo in [IndexAlgo::L2Alsh, IndexAlgo::RangedL2Alsh] {
                let spec = CurveSpec::new(algo, bits, 4);
                let idx = build_index(&d, &spec).unwrap();
                assert_eq!(
                    idx.stats().hash_bits,
                    bits,
                    "{algo:?} at L={bits} must get K = L floor hashes"
                );
            }
        }
    }

    #[test]
    fn range_beats_simple_on_longtail() {
        // The paper's headline, at test scale: RANGE-LSH needs fewer
        // probes than SIMPLE-LSH at the same recall on long-tailed data.
        let d = synthetic::longtail_sift(4000, 16, 0);
        let q = synthetic::gaussian_queries(30, 16, 1);
        let gt = ground_truth(&d, &q, 10);
        let cps = geometric_checkpoints(10, d.len(), 6);
        let range = run_curve(
            &d, &q, &gt, &cps,
            &CurveSpec::new(IndexAlgo::RangeLsh, 16, 32),
            "range",
        )
        .unwrap();
        let simple = run_curve(
            &d, &q, &gt, &cps,
            &CurveSpec::new(IndexAlgo::SimpleLsh, 16, 1),
            "simple",
        )
        .unwrap();
        let (rp, sp) = (
            range.curve.probes_to_reach(0.8).unwrap_or(usize::MAX),
            simple.curve.probes_to_reach(0.8).unwrap_or(usize::MAX),
        );
        assert!(
            rp < sp,
            "RANGE probes {rp} should be below SIMPLE probes {sp} at recall 0.8"
        );
    }

    #[test]
    fn probe_table_formats() {
        let d = synthetic::longtail_sift(300, 8, 2);
        let q = synthetic::gaussian_queries(5, 8, 3);
        let gt = ground_truth(&d, &q, 5);
        let cps = geometric_checkpoints(10, d.len(), 3);
        let res = run_curve(
            &d, &q, &gt, &cps,
            &CurveSpec::new(IndexAlgo::RangeLsh, 16, 4),
            "range-lsh L=16",
        )
        .unwrap();
        let table = format_probe_table(&[res], &[0.5, 0.9]);
        assert!(table.contains("range-lsh L=16"));
        assert!(table.contains("probes@50%"));
    }
}
