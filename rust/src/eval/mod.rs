//! Evaluation: exact ground truth, probed-items/recall curves (the paper's
//! Fig. 2/3 metric), and the experiment harness that prints paper-style
//! result rows.

pub mod ground_truth;
pub mod harness;
pub mod recall;

pub use ground_truth::{exact_topk, max_inner_products};
pub use harness::{run_curve, CurveSpec, ExperimentResult};
pub use recall::{recall_curve, RecallCurve};
