//! Probed-items vs recall curves — the paper's main empirical metric
//! (Fig. 2: "probed item-recall curve for top 10 MIPS").
//!
//! Recall at probe depth `p` is the fraction of the true top-k found among
//! the first `p` candidates emitted by the index's probing order,
//! averaged over queries.

use crate::data::Dataset;
use crate::index::{MipsIndex, Prober};
use crate::util::par;
use crate::ItemId;

/// A measured probed-items → recall curve (mean over queries).
#[derive(Debug, Clone)]
pub struct RecallCurve {
    /// Probe depths (number of probed items), ascending.
    pub checkpoints: Vec<usize>,
    /// Mean recall@k at each checkpoint.
    pub recalls: Vec<f64>,
}

impl RecallCurve {
    /// Smallest checkpoint reaching `target` recall, if any — the paper's
    /// "probes much less items at the same recall" comparison.
    pub fn probes_to_reach(&self, target: f64) -> Option<usize> {
        self.checkpoints
            .iter()
            .zip(&self.recalls)
            .find(|(_, &r)| r >= target)
            .map(|(&c, _)| c)
    }

    pub fn final_recall(&self) -> f64 {
        self.recalls.last().copied().unwrap_or(0.0)
    }
}

/// Geometric checkpoint grid from `lo` to `hi` (inclusive-ish), the x-axis
/// of Fig. 2.
pub fn geometric_checkpoints(lo: usize, hi: usize, per_decade: usize) -> Vec<usize> {
    assert!(lo >= 1 && hi >= lo && per_decade >= 1);
    let mut out = Vec::new();
    let ratio = 10f64.powf(1.0 / per_decade as f64);
    let mut x = lo as f64;
    while (x as usize) < hi {
        let xi = x.round() as usize;
        if out.last() != Some(&xi) {
            out.push(xi);
        }
        x *= ratio;
    }
    if out.last() != Some(&hi) {
        out.push(hi);
    }
    out
}

/// Measure the recall curve of `index` against exact `ground_truth`
/// (each query's true top-k, any k >= 1). Parallel over queries.
///
/// The budget sweep opens **one probe session per query**
/// ([`MipsIndex::prober`]) and extends it straight to the deepest
/// checkpoint — the whole checkpoint grid is then read off that single
/// candidate stream. (Extending checkpoint-by-checkpoint would work too,
/// but each small-budget extend sorts ranges to a shallow materialization
/// floor that the next checkpoint undercuts, forcing re-sorts; since the
/// sweep always needs the deepest budget anyway, one extend is both the
/// simplest and the cheapest use of the session.)
pub fn recall_curve(
    index: &dyn MipsIndex,
    queries: &Dataset,
    ground_truth: &[Vec<ItemId>],
    checkpoints: &[usize],
) -> RecallCurve {
    assert_eq!(queries.len(), ground_truth.len(), "gt/query count mismatch");
    assert!(!checkpoints.is_empty());
    assert!(checkpoints.windows(2).all(|w| w[0] < w[1]), "checkpoints must ascend");
    let max_probe = *checkpoints.last().unwrap();

    let sums: Vec<f64> = par::par_fold(
        queries.len(),
        || vec![0.0f64; checkpoints.len()],
        |qi, acc| {
            let gt = &ground_truth[qi];
            let k = gt.len().max(1);
            let gt_set: std::collections::HashSet<ItemId> = gt.iter().copied().collect();
            let mut prober = index.prober(queries.row(qi));
            let mut order = Vec::with_capacity(max_probe.min(index.len()));
            prober.extend(max_probe, &mut order);
            // Cumulative hits at each checkpoint of the one stream.
            let mut hits = 0usize;
            let mut pos = 0usize;
            for (ci, &cp) in checkpoints.iter().enumerate() {
                while pos < order.len() && pos < cp {
                    if gt_set.contains(&order[pos]) {
                        hits += 1;
                    }
                    pos += 1;
                }
                acc[ci] += hits as f64 / k as f64;
            }
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
            a
        },
    );

    RecallCurve {
        checkpoints: checkpoints.to_vec(),
        recalls: sums.iter().map(|s| s / queries.len() as f64).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::eval::exact_topk;
    use crate::hash::NativeHasher;
    use crate::index::range::{RangeLshIndex, RangeLshParams};

    fn setup() -> (Dataset, Dataset, Vec<Vec<ItemId>>, RangeLshIndex) {
        let d = synthetic::longtail_sift(800, 8, 0);
        let q = synthetic::gaussian_queries(20, 8, 1);
        let gt = exact_topk(&d, &q, 5);
        let h: NativeHasher = NativeHasher::new(8, 64, 2);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 8)).unwrap();
        (d, q, gt, idx)
    }

    #[test]
    fn recall_is_monotone_and_reaches_one_at_full_probe() {
        let (d, q, gt, idx) = setup();
        let cps = geometric_checkpoints(10, d.len(), 4);
        let curve = recall_curve(&idx, &q, &gt, &cps);
        for w in curve.recalls.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "recall not monotone");
        }
        assert!(
            (curve.final_recall() - 1.0).abs() < 1e-9,
            "probing everything must find everything, got {}",
            curve.final_recall()
        );
    }

    #[test]
    fn recall_bounded_in_unit_interval() {
        let (_, q, gt, idx) = setup();
        let curve = recall_curve(&idx, &q, &gt, &[1, 10, 100]);
        assert!(curve.recalls.iter().all(|&r| (0.0..=1.0).contains(&r)));
    }

    #[test]
    fn probes_to_reach_finds_first_crossing() {
        let c = RecallCurve {
            checkpoints: vec![10, 100, 1000],
            recalls: vec![0.2, 0.8, 1.0],
        };
        assert_eq!(c.probes_to_reach(0.5), Some(100));
        assert_eq!(c.probes_to_reach(0.9), Some(1000));
        assert_eq!(c.probes_to_reach(0.1), Some(10));
        let c2 = RecallCurve { checkpoints: vec![10], recalls: vec![0.3] };
        assert_eq!(c2.probes_to_reach(0.5), None);
    }

    #[test]
    fn geometric_checkpoints_ascend_and_cover() {
        let cps = geometric_checkpoints(10, 5000, 4);
        assert_eq!(*cps.first().unwrap(), 10);
        assert_eq!(*cps.last().unwrap(), 5000);
        assert!(cps.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    #[should_panic(expected = "ascend")]
    fn rejects_unsorted_checkpoints() {
        let (_, q, gt, idx) = setup();
        recall_curve(&idx, &q, &gt, &[100, 10]);
    }
}
