//! Bitpacked hash codes, generic over code width: the [`CodeWord`] trait
//! abstracts one *code word* — `u64` for the paper's original L ≤ 64
//! regime, `[u64; 2]` / `[u64; 4]` for 128/256-bit codes — with Hamming
//! distance via popcount and masking to the effective code length. The
//! whole hash → index → serving stack is generic over `C: CodeWord` and
//! monomorphized at index-build time, so the single-word `u64` hot path
//! keeps its original codegen (one XOR + one POPCNT per bucket).
//!
//! RANGE-LSH spends `ceil(log2 m)` bits of the total code budget on the
//! range id (paper §4: "part of the bits ... encode the index of the
//! sub-datasets"); we keep the range id structurally (items live in their
//! range's bucket table) and mask hash codes to `L - ceil(log2 m)` bits —
//! the same information budget, simpler arithmetic. That accounting is
//! width-independent: [`partition_id_bits`] depends only on `m`.

/// Maximum supported code length in bits (the widest [`CodeWord`] impl).
pub const MAX_CODE_BITS: usize = 256;

/// 128-bit code word: two little-endian `u64` words (bit `j` lives in
/// word `j / 64`, position `j % 64`).
pub type Code128 = [u64; 2];

/// 256-bit code word: four little-endian `u64` words.
pub type Code256 = [u64; 4];

/// One fixed-width hash code word.
///
/// Implementations must be cheap `Copy` values: the bucket tables store
/// them in a dense structure-of-arrays scan vector and popcount every one
/// per query, so `hamming` compiles down to word-wise XOR + POPCNT.
/// Bit order is little-endian across words: hash function `j` sets bit
/// `j % 64` of word `j / 64`, matching the `u64` path exactly when the
/// high words are zero.
pub trait CodeWord:
    Copy + Clone + Eq + std::hash::Hash + Send + Sync + std::fmt::Debug + 'static
{
    /// Number of 64-bit words backing the code.
    const WORDS: usize;
    /// Maximum representable code length in bits (`64 * WORDS`).
    const MAX_BITS: usize;

    /// The all-zero code.
    fn zero() -> Self;

    /// Bitmask selecting the low `bits` bits; `bits` must be in
    /// `1..=MAX_BITS`.
    fn mask(bits: usize) -> Self;

    /// Bitwise AND.
    fn and(self, other: Self) -> Self;

    /// Bitwise XOR.
    fn xor(self, other: Self) -> Self;

    /// Total number of set bits.
    fn count_ones(self) -> u32;

    /// Set bit `j` (little-endian across words).
    fn set_bit(&mut self, j: usize);

    /// Read bit `j`.
    fn get_bit(self, j: usize) -> bool;

    /// The backing words, low word first (persistence layout).
    fn as_words(&self) -> &[u64];

    /// Rebuild from backing words (inverse of [`Self::as_words`]).
    fn from_words(words: &[u64]) -> Self;

    /// Hamming distance between two (equal-length, pre-masked) codes.
    #[inline]
    fn hamming(self, other: Self) -> u32 {
        self.xor(other).count_ones()
    }

    /// Number of *matching* bits `l` out of `bits` — the quantity the
    /// Eq. 12 similarity metric is built on (`l = L - hamming`).
    #[inline]
    fn matches(self, other: Self, bits: usize) -> u32 {
        bits as u32 - self.hamming(other)
    }

    /// Mask to the low `bits` bits.
    #[inline]
    fn masked(self, bits: usize) -> Self {
        self.and(Self::mask(bits))
    }

    /// Pack a sign-projection accumulator: bit `j` is set iff
    /// `acc[j] > 0` (the strictly-positive convention shared with the
    /// Pallas kernel). `acc.len()` is the code length and must fit.
    fn pack_from_signs(acc: &[f32]) -> Self {
        assert!(acc.len() <= Self::MAX_BITS, "{} signs > {} bits", acc.len(), Self::MAX_BITS);
        let mut code = Self::zero();
        for (j, &a) in acc.iter().enumerate() {
            if a > 0.0 {
                code.set_bit(j);
            }
        }
        code
    }
}

impl CodeWord for u64 {
    const WORDS: usize = 1;
    const MAX_BITS: usize = 64;

    #[inline]
    fn zero() -> Self {
        0
    }

    #[inline]
    fn mask(bits: usize) -> Self {
        mask_bits(bits)
    }

    #[inline]
    fn and(self, other: Self) -> Self {
        self & other
    }

    #[inline]
    fn xor(self, other: Self) -> Self {
        self ^ other
    }

    #[inline]
    fn count_ones(self) -> u32 {
        u64::count_ones(self)
    }

    #[inline]
    fn set_bit(&mut self, j: usize) {
        debug_assert!(j < 64);
        *self |= 1u64 << j;
    }

    #[inline]
    fn get_bit(self, j: usize) -> bool {
        debug_assert!(j < 64);
        (self >> j) & 1 == 1
    }

    fn as_words(&self) -> &[u64] {
        std::slice::from_ref(self)
    }

    fn from_words(words: &[u64]) -> Self {
        assert_eq!(words.len(), 1, "u64 code needs exactly one word");
        words[0]
    }
}

impl<const W: usize> CodeWord for [u64; W] {
    const WORDS: usize = W;
    const MAX_BITS: usize = 64 * W;

    #[inline]
    fn zero() -> Self {
        [0u64; W]
    }

    fn mask(bits: usize) -> Self {
        assert!(
            bits >= 1 && bits <= 64 * W,
            "code length {bits} out of range 1..={}",
            64 * W
        );
        let mut m = [0u64; W];
        let full = bits / 64;
        let rem = bits % 64;
        for word in m.iter_mut().take(full) {
            *word = u64::MAX;
        }
        if rem > 0 {
            m[full] = (1u64 << rem) - 1;
        }
        m
    }

    #[inline]
    fn and(mut self, other: Self) -> Self {
        for (a, b) in self.iter_mut().zip(other.iter()) {
            *a &= b;
        }
        self
    }

    #[inline]
    fn xor(mut self, other: Self) -> Self {
        for (a, b) in self.iter_mut().zip(other.iter()) {
            *a ^= b;
        }
        self
    }

    #[inline]
    fn count_ones(self) -> u32 {
        self.iter().map(|w| w.count_ones()).sum()
    }

    #[inline]
    fn set_bit(&mut self, j: usize) {
        debug_assert!(j < 64 * W);
        self[j / 64] |= 1u64 << (j % 64);
    }

    #[inline]
    fn get_bit(self, j: usize) -> bool {
        debug_assert!(j < 64 * W);
        (self[j / 64] >> (j % 64)) & 1 == 1
    }

    fn as_words(&self) -> &[u64] {
        &self[..]
    }

    fn from_words(words: &[u64]) -> Self {
        words
            .try_into()
            .unwrap_or_else(|_| panic!("{}-word code from {} words", W, words.len()))
    }
}

/// Zero-extend a scalar `u64` code into any wider (or equal) code word —
/// the embedding under which the wide path must agree bit-for-bit with
/// the scalar path (checked by `tests/properties.rs`).
pub fn widen<C: CodeWord>(code: u64) -> C {
    let mut words = vec![0u64; C::WORDS];
    words[0] = code;
    C::from_words(&words)
}

/// Bitmask selecting the low `bits` hash bits of a scalar code word.
///
/// `bits == 64` yields the identity mask; `bits == 0` is rejected (an
/// index with zero hash bits cannot rank anything).
pub fn mask_bits(bits: usize) -> u64 {
    assert!(bits >= 1 && bits <= 64, "code length {bits} out of range 1..=64");
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Hamming distance between two (equal-length, pre-masked) scalar codes.
#[inline]
pub fn hamming(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// Number of *matching* bits `l` out of `bits` — the quantity the Eq. 12
/// similarity metric is built on (`l = L - hamming`).
#[inline]
pub fn matches(a: u64, b: u64, bits: usize) -> u32 {
    bits as u32 - hamming(a, b)
}

/// Number of bits needed to address `m` partitions (0 for m == 1).
/// Width-independent: the same accounting applies at L = 16 and L = 256.
pub fn partition_id_bits(m: usize) -> usize {
    assert!(m >= 1);
    (m as u64).next_power_of_two().trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_selects_low_bits() {
        assert_eq!(mask_bits(1), 0b1);
        assert_eq!(mask_bits(11), 0x7FF);
        assert_eq!(mask_bits(32), 0xFFFF_FFFF);
        assert_eq!(mask_bits(64), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_zero() {
        mask_bits(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_over_64() {
        mask_bits(65);
    }

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(0, 0), 0);
        assert_eq!(hamming(u64::MAX, 0), 64);
        assert_eq!(hamming(0b1010, 0b0110), 2);
    }

    #[test]
    fn matches_complements_hamming() {
        let (a, b, bits) = (0b1010u64, 0b0110u64, 8);
        assert_eq!(matches(a, b, bits), 8 - 2);
        assert_eq!(matches(a, a, bits), 8);
    }

    #[test]
    fn partition_id_bits_examples() {
        // Paper §4: 32 sub-datasets cost 5 bits of a 16-bit budget.
        assert_eq!(partition_id_bits(1), 0);
        assert_eq!(partition_id_bits(2), 1);
        assert_eq!(partition_id_bits(32), 5);
        assert_eq!(partition_id_bits(64), 6);
        assert_eq!(partition_id_bits(128), 7);
        assert_eq!(partition_id_bits(33), 6); // round up for non-powers
    }

    #[test]
    fn u64_codeword_matches_free_functions() {
        let (a, b) = (0xDEAD_BEEF_u64, 0x1234_5678_u64);
        assert_eq!(CodeWord::hamming(a, b), hamming(a, b));
        assert_eq!(CodeWord::matches(a, b, 64), matches(a, b, 64));
        assert_eq!(<u64 as CodeWord>::mask(11), mask_bits(11));
        assert_eq!(a.masked(16), a & mask_bits(16));
    }

    #[test]
    fn wide_mask_spans_words() {
        let m = Code128::mask(64);
        assert_eq!(m, [u64::MAX, 0]);
        let m = Code128::mask(65);
        assert_eq!(m, [u64::MAX, 1]);
        let m = Code128::mask(128);
        assert_eq!(m, [u64::MAX, u64::MAX]);
        let m = Code256::mask(130);
        assert_eq!(m, [u64::MAX, u64::MAX, 0b11, 0]);
        assert_eq!(Code256::mask(256), [u64::MAX; 4]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn wide_mask_rejects_over_width() {
        Code128::mask(129);
    }

    #[test]
    fn wide_bit_layout_is_little_endian() {
        let mut c = Code128::zero();
        c.set_bit(0);
        c.set_bit(63);
        c.set_bit(64);
        c.set_bit(127);
        assert_eq!(c, [(1u64 << 63) | 1, (1u64 << 63) | 1]);
        assert!(c.get_bit(64) && !c.get_bit(65));
        assert_eq!(c.count_ones(), 4);
    }

    #[test]
    fn wide_hamming_sums_word_popcounts() {
        let a: Code256 = [u64::MAX, 0, 0b1010, 0];
        let b: Code256 = [0, 0, 0b0110, 0];
        assert_eq!(a.hamming(b), 64 + 2);
        assert_eq!(a.matches(b, 256), 256 - 66);
    }

    #[test]
    fn widen_preserves_low_word() {
        let c = 0xABCD_EF01_2345_6789_u64;
        let w: Code128 = widen(c);
        assert_eq!(w, [c, 0]);
        let w: Code256 = widen(c);
        assert_eq!(w.as_words(), &[c, 0, 0, 0]);
        let s: u64 = widen(c);
        assert_eq!(s, c);
    }

    #[test]
    fn words_round_trip() {
        let w: Code128 = [3, 7];
        assert_eq!(Code128::from_words(w.as_words()), w);
        let s = 42u64;
        assert_eq!(u64::from_words(s.as_words()), s);
    }

    #[test]
    fn pack_from_signs_matches_scalar_convention() {
        // Strictly positive ⇒ bit set; zero and negative ⇒ clear.
        let acc = [1.0f32, -1.0, 0.0, 0.5];
        let s: u64 = CodeWord::pack_from_signs(&acc);
        assert_eq!(s, 0b1001);
        let w: Code128 = CodeWord::pack_from_signs(&acc);
        assert_eq!(w, [0b1001, 0]);
        // A sign past bit 63 lands in the second word.
        let mut acc = vec![-1.0f32; 70];
        acc[69] = 2.0;
        let w: Code128 = CodeWord::pack_from_signs(&acc);
        assert_eq!(w, [0, 1u64 << 5]);
    }
}
