//! Bitpacked hash codes, generic over code width: the [`CodeWord`] trait
//! abstracts one *code word* — `u64` for the paper's original L ≤ 64
//! regime, `[u64; 2]` / `[u64; 4]` for 128/256-bit codes — with Hamming
//! distance via popcount and masking to the effective code length. The
//! whole hash → index → serving stack is generic over `C: CodeWord` and
//! monomorphized at index-build time, so the single-word `u64` hot path
//! keeps its original codegen (one XOR + one POPCNT per bucket).
//!
//! RANGE-LSH spends `ceil(log2 m)` bits of the total code budget on the
//! range id (paper §4: "part of the bits ... encode the index of the
//! sub-datasets"); we keep the range id structurally (items live in their
//! range's bucket table) and mask hash codes to `L - ceil(log2 m)` bits —
//! the same information budget, simpler arithmetic. That accounting is
//! width-independent: [`partition_id_bits`] depends only on `m`.

/// Maximum supported code length in bits (the widest [`CodeWord`] impl).
pub const MAX_CODE_BITS: usize = 256;

/// 128-bit code word: two little-endian `u64` words (bit `j` lives in
/// word `j / 64`, position `j % 64`).
pub type Code128 = [u64; 2];

/// 256-bit code word: four little-endian `u64` words.
pub type Code256 = [u64; 4];

/// One fixed-width hash code word.
///
/// Implementations must be cheap `Copy` values: the bucket tables store
/// them in a dense structure-of-arrays scan vector and popcount every one
/// per query, so `hamming` compiles down to word-wise XOR + POPCNT.
/// Bit order is little-endian across words: hash function `j` sets bit
/// `j % 64` of word `j / 64`, matching the `u64` path exactly when the
/// high words are zero.
pub trait CodeWord:
    Copy + Clone + Eq + std::hash::Hash + Send + Sync + std::fmt::Debug + 'static
{
    /// Number of 64-bit words backing the code.
    const WORDS: usize;
    /// Maximum representable code length in bits (`64 * WORDS`).
    const MAX_BITS: usize;

    /// The all-zero code.
    fn zero() -> Self;

    /// Bitmask selecting the low `bits` bits; `bits` must be in
    /// `1..=MAX_BITS`.
    fn mask(bits: usize) -> Self;

    /// Bitwise AND.
    fn and(self, other: Self) -> Self;

    /// Bitwise XOR.
    fn xor(self, other: Self) -> Self;

    /// Total number of set bits.
    fn count_ones(self) -> u32;

    /// Set bit `j` (little-endian across words).
    fn set_bit(&mut self, j: usize);

    /// Read bit `j`.
    fn get_bit(self, j: usize) -> bool;

    /// The backing words, low word first (persistence layout).
    fn as_words(&self) -> &[u64];

    /// Rebuild from backing words (inverse of [`Self::as_words`]).
    fn from_words(words: &[u64]) -> Self;

    /// Hamming distance between two (equal-length, pre-masked) codes.
    #[inline]
    fn hamming(self, other: Self) -> u32 {
        self.xor(other).count_ones()
    }

    /// Number of *matching* bits `l` out of `bits` — the quantity the
    /// Eq. 12 similarity metric is built on (`l = L - hamming`).
    #[inline]
    fn matches(self, other: Self, bits: usize) -> u32 {
        bits as u32 - self.hamming(other)
    }

    /// Mask to the low `bits` bits.
    #[inline]
    fn masked(self, bits: usize) -> Self {
        self.and(Self::mask(bits))
    }

    /// Pack a sign-projection accumulator: bit `j` is set iff
    /// `acc[j] > 0` (the strictly-positive convention shared with the
    /// Pallas kernel). `acc.len()` is the code length and must fit.
    fn pack_from_signs(acc: &[f32]) -> Self {
        assert!(acc.len() <= Self::MAX_BITS, "{} signs > {} bits", acc.len(), Self::MAX_BITS);
        let mut code = Self::zero();
        for (j, &a) in acc.iter().enumerate() {
            if a > 0.0 {
                code.set_bit(j);
            }
        }
        code
    }
}

impl CodeWord for u64 {
    const WORDS: usize = 1;
    const MAX_BITS: usize = 64;

    #[inline]
    fn zero() -> Self {
        0
    }

    #[inline]
    fn mask(bits: usize) -> Self {
        mask_bits(bits)
    }

    #[inline]
    fn and(self, other: Self) -> Self {
        self & other
    }

    #[inline]
    fn xor(self, other: Self) -> Self {
        self ^ other
    }

    #[inline]
    fn count_ones(self) -> u32 {
        u64::count_ones(self)
    }

    #[inline]
    fn set_bit(&mut self, j: usize) {
        debug_assert!(j < 64);
        *self |= 1u64 << j;
    }

    #[inline]
    fn get_bit(self, j: usize) -> bool {
        debug_assert!(j < 64);
        (self >> j) & 1 == 1
    }

    fn as_words(&self) -> &[u64] {
        std::slice::from_ref(self)
    }

    fn from_words(words: &[u64]) -> Self {
        assert_eq!(words.len(), 1, "u64 code needs exactly one word");
        words[0]
    }
}

impl<const W: usize> CodeWord for [u64; W] {
    const WORDS: usize = W;
    const MAX_BITS: usize = 64 * W;

    #[inline]
    fn zero() -> Self {
        [0u64; W]
    }

    fn mask(bits: usize) -> Self {
        assert!(
            bits >= 1 && bits <= 64 * W,
            "code length {bits} out of range 1..={}",
            64 * W
        );
        let mut m = [0u64; W];
        let full = bits / 64;
        let rem = bits % 64;
        for word in m.iter_mut().take(full) {
            *word = u64::MAX;
        }
        if rem > 0 {
            m[full] = (1u64 << rem) - 1;
        }
        m
    }

    #[inline]
    fn and(mut self, other: Self) -> Self {
        for (a, b) in self.iter_mut().zip(other.iter()) {
            *a &= b;
        }
        self
    }

    #[inline]
    fn xor(mut self, other: Self) -> Self {
        for (a, b) in self.iter_mut().zip(other.iter()) {
            *a ^= b;
        }
        self
    }

    #[inline]
    fn count_ones(self) -> u32 {
        self.iter().map(|w| w.count_ones()).sum()
    }

    #[inline]
    fn set_bit(&mut self, j: usize) {
        debug_assert!(j < 64 * W);
        self[j / 64] |= 1u64 << (j % 64);
    }

    #[inline]
    fn get_bit(self, j: usize) -> bool {
        debug_assert!(j < 64 * W);
        (self[j / 64] >> (j % 64)) & 1 == 1
    }

    fn as_words(&self) -> &[u64] {
        &self[..]
    }

    fn from_words(words: &[u64]) -> Self {
        words
            .try_into()
            .unwrap_or_else(|_| panic!("{}-word code from {} words", W, words.len()))
    }
}

/// 16-bit chunk view of a code word for multi-index hashing
/// ([`crate::index::mih`]): chunk `k` is bits `16k .. 16(k+1)` of the
/// code, little-endian across words (`u64` → 4 chunks, [`Code128`] → 8,
/// [`Code256`] → 16). Blanket-implemented for every [`CodeWord`]; since
/// 16 divides 64 each chunk lives inside one backing word, so extraction
/// is one shift per chunk.
pub trait CodeChunks: CodeWord {
    /// Chunks per full code word (`MAX_BITS / 16`).
    const N_CHUNKS: usize = Self::MAX_BITS / 16;

    /// Chunk `k` of the code (bits `16k .. 16k + 16`).
    #[inline]
    // staticcheck: allow(panic-reach, "k < N_CHUNKS (debug_asserted) implies k/4 < WORDS - as_words() always covers the chunk range")
    fn chunk(&self, k: usize) -> u16 {
        debug_assert!(k < Self::N_CHUNKS);
        (self.as_words()[k / 4] >> (16 * (k % 4))) as u16
    }

    /// All [`Self::N_CHUNKS`] chunks, low chunk first.
    fn chunks(&self) -> ChunkIter<Self> {
        ChunkIter { code: *self, k: 0 }
    }
}

impl<C: CodeWord> CodeChunks for C {}

/// Iterator over a code word's 16-bit chunks (see [`CodeChunks`]).
pub struct ChunkIter<C: CodeWord> {
    code: C,
    k: usize,
}

impl<C: CodeWord> Iterator for ChunkIter<C> {
    type Item = u16;

    fn next(&mut self) -> Option<u16> {
        if self.k >= C::MAX_BITS / 16 {
            return None;
        }
        let c = self.code.chunk(self.k);
        self.k += 1;
        Some(c)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = C::MAX_BITS / 16 - self.k;
        (rem, Some(rem))
    }
}

impl<C: CodeWord> ExactSizeIterator for ChunkIter<C> {}

/// Zero-extend a scalar `u64` code into any wider (or equal) code word —
/// the embedding under which the wide path must agree bit-for-bit with
/// the scalar path (checked by `tests/properties.rs`).
pub fn widen<C: CodeWord>(code: u64) -> C {
    let mut words = vec![0u64; C::WORDS];
    words[0] = code;
    C::from_words(&words)
}

/// Bitmask selecting the low `bits` hash bits of a scalar code word.
///
/// `bits == 64` yields the identity mask; `bits == 0` is rejected (an
/// index with zero hash bits cannot rank anything).
pub fn mask_bits(bits: usize) -> u64 {
    assert!(bits >= 1 && bits <= 64, "code length {bits} out of range 1..=64");
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Hamming distance between two (equal-length, pre-masked) scalar codes.
#[inline]
pub fn hamming(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// Number of *matching* bits `l` out of `bits` — the quantity the Eq. 12
/// similarity metric is built on (`l = L - hamming`).
#[inline]
pub fn matches(a: u64, b: u64, bits: usize) -> u32 {
    bits as u32 - hamming(a, b)
}

/// Number of bits needed to address `m` partitions (0 for m == 1).
/// Width-independent: the same accounting applies at L = 16 and L = 256.
pub fn partition_id_bits(m: usize) -> usize {
    assert!(m >= 1);
    (m as u64).next_power_of_two().trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_selects_low_bits() {
        assert_eq!(mask_bits(1), 0b1);
        assert_eq!(mask_bits(11), 0x7FF);
        assert_eq!(mask_bits(32), 0xFFFF_FFFF);
        assert_eq!(mask_bits(64), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_zero() {
        mask_bits(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_over_64() {
        mask_bits(65);
    }

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(0, 0), 0);
        assert_eq!(hamming(u64::MAX, 0), 64);
        assert_eq!(hamming(0b1010, 0b0110), 2);
    }

    #[test]
    fn matches_complements_hamming() {
        let (a, b, bits) = (0b1010u64, 0b0110u64, 8);
        assert_eq!(matches(a, b, bits), 8 - 2);
        assert_eq!(matches(a, a, bits), 8);
    }

    #[test]
    fn partition_id_bits_examples() {
        // Paper §4: 32 sub-datasets cost 5 bits of a 16-bit budget.
        assert_eq!(partition_id_bits(1), 0);
        assert_eq!(partition_id_bits(2), 1);
        assert_eq!(partition_id_bits(32), 5);
        assert_eq!(partition_id_bits(64), 6);
        assert_eq!(partition_id_bits(128), 7);
        assert_eq!(partition_id_bits(33), 6); // round up for non-powers
    }

    #[test]
    fn u64_codeword_matches_free_functions() {
        let (a, b) = (0xDEAD_BEEF_u64, 0x1234_5678_u64);
        assert_eq!(CodeWord::hamming(a, b), hamming(a, b));
        assert_eq!(CodeWord::matches(a, b, 64), matches(a, b, 64));
        assert_eq!(<u64 as CodeWord>::mask(11), mask_bits(11));
        assert_eq!(a.masked(16), a & mask_bits(16));
    }

    #[test]
    fn wide_mask_spans_words() {
        let m = Code128::mask(64);
        assert_eq!(m, [u64::MAX, 0]);
        let m = Code128::mask(65);
        assert_eq!(m, [u64::MAX, 1]);
        let m = Code128::mask(128);
        assert_eq!(m, [u64::MAX, u64::MAX]);
        let m = Code256::mask(130);
        assert_eq!(m, [u64::MAX, u64::MAX, 0b11, 0]);
        assert_eq!(Code256::mask(256), [u64::MAX; 4]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn wide_mask_rejects_over_width() {
        Code128::mask(129);
    }

    #[test]
    fn wide_bit_layout_is_little_endian() {
        let mut c = Code128::zero();
        c.set_bit(0);
        c.set_bit(63);
        c.set_bit(64);
        c.set_bit(127);
        assert_eq!(c, [(1u64 << 63) | 1, (1u64 << 63) | 1]);
        assert!(c.get_bit(64) && !c.get_bit(65));
        assert_eq!(c.count_ones(), 4);
    }

    #[test]
    fn wide_hamming_sums_word_popcounts() {
        let a: Code256 = [u64::MAX, 0, 0b1010, 0];
        let b: Code256 = [0, 0, 0b0110, 0];
        assert_eq!(a.hamming(b), 64 + 2);
        assert_eq!(a.matches(b, 256), 256 - 66);
    }

    #[test]
    fn widen_preserves_low_word() {
        let c = 0xABCD_EF01_2345_6789_u64;
        let w: Code128 = widen(c);
        assert_eq!(w, [c, 0]);
        let w: Code256 = widen(c);
        assert_eq!(w.as_words(), &[c, 0, 0, 0]);
        let s: u64 = widen(c);
        assert_eq!(s, c);
    }

    #[test]
    fn words_round_trip() {
        let w: Code128 = [3, 7];
        assert_eq!(Code128::from_words(w.as_words()), w);
        let s = 42u64;
        assert_eq!(u64::from_words(s.as_words()), s);
    }

    #[test]
    fn chunks_round_trip_per_width() {
        // Reassembling the 16-bit chunks must reproduce the code exactly,
        // at every width (u64 → 4 chunks, Code128 → 8, Code256 → 16).
        fn check<C: CodeWord>(code: C) {
            let chunks: Vec<u16> = code.chunks().collect();
            assert_eq!(chunks.len(), C::N_CHUNKS);
            assert_eq!(C::N_CHUNKS, C::MAX_BITS / 16);
            let mut rebuilt = C::zero();
            for (k, &c) in chunks.iter().enumerate() {
                for j in 0..16 {
                    if (c >> j) & 1 == 1 {
                        rebuilt.set_bit(16 * k + j);
                    }
                }
            }
            assert_eq!(rebuilt, code);
            // The indexed accessor agrees with the iterator.
            for (k, &c) in chunks.iter().enumerate() {
                assert_eq!(code.chunk(k), c);
            }
        }
        check(0xDEAD_BEEF_0BAD_F00Du64);
        check::<Code128>([0x0123_4567_89AB_CDEF, u64::MAX - 12345]);
        check::<Code256>([u64::MAX, 0, 0x5555_5555_5555_5555, 0xAAAA_0000_FFFF_0001]);
    }

    #[test]
    fn chunk_extraction_examples() {
        // Chunk k covers bits 16k..16k+16, little-endian across words.
        let c = 0x3333_2222_1111_0000u64;
        assert_eq!(c.chunk(0), 0x0000);
        assert_eq!(c.chunk(1), 0x1111);
        assert_eq!(c.chunk(2), 0x2222);
        assert_eq!(c.chunk(3), 0x3333);
        let w: Code128 = [0, 0xBBBB_0000_0000_AAAA];
        assert_eq!(w.chunk(4), 0xAAAA);
        assert_eq!(w.chunk(7), 0xBBBB);
        // A masked code's partial top chunk is zero-extended.
        let c = u64::MAX.masked(43);
        assert_eq!(c.chunk(2), (1 << 11) - 1);
        assert_eq!(c.chunk(3), 0);
    }

    #[test]
    fn pack_from_signs_matches_scalar_convention() {
        // Strictly positive ⇒ bit set; zero and negative ⇒ clear.
        let acc = [1.0f32, -1.0, 0.0, 0.5];
        let s: u64 = CodeWord::pack_from_signs(&acc);
        assert_eq!(s, 0b1001);
        let w: Code128 = CodeWord::pack_from_signs(&acc);
        assert_eq!(w, [0b1001, 0]);
        // A sign past bit 63 lands in the second word.
        let mut acc = vec![-1.0f32; 70];
        acc[69] = 2.0;
        let w: Code128 = CodeWord::pack_from_signs(&acc);
        assert_eq!(w, [0, 1u64 << 5]);
    }
}
