//! Bitpacked hash codes: one `u64` word per item (the paper's max code
//! length is 64), Hamming distance via popcount, and masking to the
//! effective code length.
//!
//! RANGE-LSH spends `ceil(log2 m)` bits of the total code budget on the
//! range id (paper §4: "part of the bits ... encode the index of the
//! sub-datasets"); we keep the range id structurally (items live in their
//! range's bucket table) and mask hash codes to `L - ceil(log2 m)` bits —
//! the same information budget, simpler arithmetic.

/// Bitmask selecting the low `bits` hash bits of a code word.
///
/// `bits == 64` yields the identity mask; `bits == 0` is rejected (an
/// index with zero hash bits cannot rank anything).
pub fn mask_bits(bits: usize) -> u64 {
    assert!(bits >= 1 && bits <= 64, "code length {bits} out of range 1..=64");
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Hamming distance between two (equal-length, pre-masked) codes.
#[inline]
pub fn hamming(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// Number of *matching* bits `l` out of `bits` — the quantity the Eq. 12
/// similarity metric is built on (`l = L - hamming`).
#[inline]
pub fn matches(a: u64, b: u64, bits: usize) -> u32 {
    bits as u32 - hamming(a, b)
}

/// Number of bits needed to address `m` partitions (0 for m == 1).
pub fn partition_id_bits(m: usize) -> usize {
    assert!(m >= 1);
    (m as u64).next_power_of_two().trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_selects_low_bits() {
        assert_eq!(mask_bits(1), 0b1);
        assert_eq!(mask_bits(11), 0x7FF);
        assert_eq!(mask_bits(32), 0xFFFF_FFFF);
        assert_eq!(mask_bits(64), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_zero() {
        mask_bits(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn mask_rejects_over_64() {
        mask_bits(65);
    }

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(0, 0), 0);
        assert_eq!(hamming(u64::MAX, 0), 64);
        assert_eq!(hamming(0b1010, 0b0110), 2);
    }

    #[test]
    fn matches_complements_hamming() {
        let (a, b, bits) = (0b1010u64, 0b0110u64, 8);
        assert_eq!(matches(a, b, bits), 8 - 2);
        assert_eq!(matches(a, a, bits), 8);
    }

    #[test]
    fn partition_id_bits_examples() {
        // Paper §4: 32 sub-datasets cost 5 bits of a 16-bit budget.
        assert_eq!(partition_id_bits(1), 0);
        assert_eq!(partition_id_bits(2), 1);
        assert_eq!(partition_id_bits(32), 5);
        assert_eq!(partition_id_bits(64), 6);
        assert_eq!(partition_id_bits(128), 7);
        assert_eq!(partition_id_bits(33), 6); // round up for non-powers
    }
}
