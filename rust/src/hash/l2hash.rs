//! L2 floor-hash family (paper Eq. 2): `h(x) = floor((a.x + b) / r)` with
//! Gaussian `a` and `b ~ Uniform[0, r]` — the LSH that L2-ALSH reduces to.

use crate::util::rng::Rng;

/// `k` independent Eq. 2 hash functions over `dim_in`-dimensional inputs.
#[derive(Debug, Clone)]
pub struct L2Hash {
    dim_in: usize,
    k: usize,
    r: f32,
    /// Row-major `[k, dim_in]` Gaussian directions.
    a: Vec<f32>,
    /// Uniform offsets in `[0, r)`, one per function.
    b: Vec<f32>,
}

impl L2Hash {
    pub fn new(dim_in: usize, k: usize, r: f32, seed: u64) -> Self {
        assert!(dim_in > 0 && k > 0);
        assert!(r > 0.0, "bucket width r must be positive");
        let mut rng = Rng::seed_from_u64(seed);
        let mut a = vec![0.0f32; k * dim_in];
        rng.fill_normal_f32(&mut a);
        let b = (0..k).map(|_| rng.uniform(0.0, r as f64) as f32).collect();
        Self { dim_in, k, r, a, b }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn dim_in(&self) -> usize {
        self.dim_in
    }

    /// Hash one (already L2-ALSH-transformed) vector into `k` bucket ids.
    pub fn hash(&self, x: &[f32], out: &mut Vec<i32>) {
        debug_assert_eq!(x.len(), self.dim_in);
        out.clear();
        for i in 0..self.k {
            let row = &self.a[i * self.dim_in..(i + 1) * self.dim_in];
            let dot: f32 = row.iter().zip(x).map(|(a, b)| a * b).sum();
            out.push(((dot + self.b[i]) / self.r).floor() as i32);
        }
    }

    /// Number of positions where two hash vectors agree — the ranking
    /// signal for L2-ALSH multi-probing (analogous to `l` in Eq. 12).
    pub fn matches(a: &[i32], b: &[i32]) -> usize {
        a.iter().zip(b).filter(|(x, y)| x == y).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let h1 = L2Hash::new(4, 8, 2.5, 0);
        let h2 = L2Hash::new(4, 8, 2.5, 0);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        h1.hash(&[1.0, -0.5, 0.3, 2.0], &mut a);
        h2.hash(&[1.0, -0.5, 0.3, 2.0], &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn identical_points_always_collide() {
        let h = L2Hash::new(3, 16, 2.5, 1);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        h.hash(&[0.4, 0.5, 0.6], &mut a);
        h.hash(&[0.4, 0.5, 0.6], &mut b);
        assert_eq!(L2Hash::matches(&a, &b), 16);
    }

    #[test]
    fn near_points_collide_more_than_far_points() {
        // Statistical check of the Eq. 3 monotonicity: collision probability
        // decreases with L2 distance.
        let trials = 300;
        let (mut near, mut far) = (0usize, 0usize);
        for seed in 0..trials {
            let h = L2Hash::new(2, 8, 2.5, seed);
            let (mut o, mut n, mut f) = (Vec::new(), Vec::new(), Vec::new());
            h.hash(&[0.0, 0.0], &mut o);
            h.hash(&[0.3, 0.0], &mut n);
            h.hash(&[4.0, 0.0], &mut f);
            near += L2Hash::matches(&o, &n);
            far += L2Hash::matches(&o, &f);
        }
        assert!(near > far, "near {near} <= far {far}");
        // Near pair (d=0.3, r=2.5) should collide most of the time.
        assert!(near as f64 / (trials * 8) as f64 > 0.8);
    }

    #[test]
    fn matches_counts_positions() {
        assert_eq!(L2Hash::matches(&[1, 2, 3], &[1, 9, 3]), 2);
        assert_eq!(L2Hash::matches(&[], &[]), 0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_r() {
        L2Hash::new(2, 2, 0.0, 0);
    }
}
