//! Hashing: bitpacked codes (generic over word count via [`CodeWord`]),
//! Gaussian projections, sign random projection (native path) and the L2
//! floor-hash used by L2-ALSH.
//!
//! The *bit convention* is shared with the Layer-1 Pallas kernel
//! (`python/compile/kernels/sign_hash.py`) and checked bit-for-bit by the
//! runtime integration tests: hash function `j` is the strictly-positive
//! sign of `P(x) . proj[:, j]`, packed little-endian — bit `j % 64` of
//! word `j / 64` of the code (for `u64` codes, simply bit `j`).

pub mod codes;
pub mod l2hash;
pub mod projection;
pub mod sign_rp;

pub use codes::{
    hamming, mask_bits, matches, Code128, Code256, CodeChunks, CodeWord, MAX_CODE_BITS,
};
pub use l2hash::L2Hash;
pub use projection::Projection;
pub use sign_rp::NativeHasher;

use crate::Result;

/// A bulk hasher over raw item/query rows emitting `C`-wide codes: the
/// abstraction that lets the index layer run on either the Rust-native
/// path ([`NativeHasher`], blocked tile sweep) or the AOT-compiled
/// Pallas kernel via PJRT ([`crate::runtime::PjrtHasher`], generic over
/// the code word — the kernel packs `width / 32` u32 words per item,
/// 2/4/8 at L = 64/128/256).
///
/// The parameter defaults to `u64`, so `dyn ItemHasher` keeps meaning the
/// original single-word interface.
///
/// Both implementations share one [`Projection`], so their codes agree
/// bit-for-bit (modulo f32 reassociation on near-zero dot products; the
/// integration suite bounds the disagreement rate).
pub trait ItemHasher<C: CodeWord = u64>: Send + Sync {
    /// The Gaussian panel this hasher projects with. Indexes keep a clone
    /// for query-time hashing, so item codes and query codes always come
    /// from the same panel.
    fn projection(&self) -> &std::sync::Arc<Projection>;

    /// Input dimensionality `d` of raw rows (the transform adds one dim).
    fn dim(&self) -> usize {
        self.projection().dim_in() - 1
    }

    /// Number of hash bits produced per item (<= `C::MAX_BITS`).
    fn width(&self) -> usize {
        self.projection().width()
    }

    /// Hash items: normalise each row by `u` (the global `U` for
    /// SIMPLE-LSH, the local `U_j` for RANGE-LSH — the paper's key knob),
    /// apply the Eq. 8 transform, sign-project. `rows.len()` must be a
    /// multiple of `dim()`.
    fn hash_items(&self, rows: &[f32], u: f32) -> Result<Vec<C>>;

    /// Hash queries: unit-normalise, append 0, sign-project (Eq. 8).
    fn hash_queries(&self, rows: &[f32]) -> Result<Vec<C>>;

    /// Short backend tag for serving logs.
    fn backend(&self) -> &'static str {
        "native"
    }
}
