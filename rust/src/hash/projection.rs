//! Seeded Gaussian projection panels, shared between the native hasher and
//! the PJRT-backed hasher so both produce identical codes.

use crate::hash::codes::MAX_CODE_BITS;
use crate::util::rng::Rng;

/// A `[dim_in, width]` row-major panel of i.i.d. standard normal entries —
/// the `a` vectors of sign random projection (paper Eq. 4), one column per
/// hash function.
///
/// `dim_in` is the *transformed* dimensionality (`d + 1` for the Eq. 8
/// transform). The panel layout matches the AOT artifact's `proj`
/// argument exactly so the same struct feeds both hashing paths.
#[derive(Debug, Clone, PartialEq)]
pub struct Projection {
    dim_in: usize,
    width: usize,
    data: Vec<f32>,
}

impl Projection {
    /// Sample a panel from a seeded RNG (deterministic per seed).
    pub fn gaussian(dim_in: usize, width: usize, seed: u64) -> Self {
        assert!(dim_in > 0 && width > 0);
        assert!(
            width <= MAX_CODE_BITS,
            "codes are packed into at most {MAX_CODE_BITS} bits; width {width} too wide"
        );
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = vec![0.0f32; dim_in * width];
        rng.fill_normal_f32(&mut data);
        Self { dim_in, width, data }
    }

    /// Rebuild from a stored flat panel (index persistence).
    pub fn from_flat(dim_in: usize, width: usize, data: Vec<f32>) -> Self {
        assert!(dim_in > 0 && width > 0 && width <= MAX_CODE_BITS);
        assert_eq!(data.len(), dim_in * width, "panel size mismatch");
        Self { dim_in, width, data }
    }

    pub fn dim_in(&self) -> usize {
        self.dim_in
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Row `k` of the panel: the `k`-th input coordinate's weights across
    /// all hash functions.
    // staticcheck: allow(panic-reach, "k enumerates the dim_in input coordinates and data is allocated dim_in * width at construction")
    pub fn row(&self, k: usize) -> &[f32] {
        &self.data[k * self.width..(k + 1) * self.width]
    }

    /// Flat row-major `[dim_in, width]` buffer (PJRT argument layout).
    pub fn flat(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Projection::gaussian(5, 8, 1);
        let b = Projection::gaussian(5, 8, 1);
        let c = Projection::gaussian(5, 8, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn shape_accessors() {
        let p = Projection::gaussian(3, 4, 0);
        assert_eq!(p.dim_in(), 3);
        assert_eq!(p.width(), 4);
        assert_eq!(p.flat().len(), 12);
        assert_eq!(p.row(2).len(), 4);
    }

    #[test]
    fn entries_look_standard_normal() {
        let p = Projection::gaussian(100, 64, 7);
        let n = p.flat().len() as f64;
        let mean: f64 = p.flat().iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = p.flat().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn accepts_multiword_widths() {
        // 128/256-bit panels back the wide CodeWord paths.
        let p = Projection::gaussian(4, 128, 0);
        assert_eq!(p.width(), 128);
        let p = Projection::gaussian(4, 256, 0);
        assert_eq!(p.width(), 256);
    }

    #[test]
    #[should_panic(expected = "width")]
    fn rejects_width_over_max() {
        Projection::gaussian(4, MAX_CODE_BITS + 1, 0);
    }
}
