//! Native (CPU, parallel) sign-random-projection hasher, generic over the
//! code word width.
//!
//! For `u64` codes it mirrors the Layer-1 Pallas kernel exactly — same
//! Eq. 8 transforms, same strictly-positive sign convention, same
//! little-endian bit packing — so the two paths are interchangeable and
//! cross-checkable. The wide instantiations ([`Code128`]/[`Code256`])
//! extend the identical convention across words: hash function `j` sets
//! bit `j % 64` of word `j / 64`, so a wide code whose high words are
//! zero agrees bit-for-bit with the scalar path (property-tested).
//!
//! Bulk item hashing is *blocked* (`hash_items_blocked`): tiles of
//! `BLOCK_ROWS` transformed rows are swept against the projection
//! panel per pass — the native analogue of the Pallas kernel's
//! `[BLOCK_B, D] @ [D, L]` tiling — with the original per-item path kept
//! as the bit-for-bit oracle (`hash_items_unblocked`).

use std::marker::PhantomData;
use std::sync::Arc;

use super::codes::{CodeWord, MAX_CODE_BITS};
use super::{ItemHasher, Projection};
use crate::transform::simple::{transform_item, transform_query};
use crate::util::par;
use crate::Result;

#[cfg(doc)]
use super::codes::{Code128, Code256};

/// Tile height for the blocked bulk paths ([`NativeHasher::hash_items_blocked`]):
/// per thread, one `[BLOCK_ROWS, dim+1]` transformed tile plus one
/// `[BLOCK_ROWS, width]` f32 accumulator (32 x 256 x 4 B = 32 KB at the
/// widest code — L2-resident), amortising each panel-row load across the
/// whole tile instead of reloading the panel per item.
const BLOCK_ROWS: usize = 32;

thread_local! {
    /// Per-thread Eq. 8 transform buffer shared by the per-item paths
    /// (`hash_query_one`, `hash_queries`, the `*_unblocked` oracles) —
    /// no per-item allocation anywhere on the hashing paths (§Perf).
    static ROW_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
    /// Per-thread blocked-path scratch: (per-row transform buffer,
    /// transformed tile, sign accumulator).
    static TILE_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// CPU sign-RP hasher over a shared [`Projection`], emitting `C`-wide
/// codes. Defaults to the original `u64` single-word path.
///
/// Bulk item hashing runs *blocked* ([`Self::hash_items_blocked`]): the
/// default [`ItemHasher::hash_items`] processes `BLOCK_ROWS`-row tiles
/// against the panel per pass with multi-word sign packing, and the
/// original per-item path is kept as the bit-for-bit cross-check oracle
/// ([`Self::hash_items_unblocked`], property-tested at every width).
pub struct NativeHasher<C: CodeWord = u64> {
    proj: Arc<Projection>,
    _code: PhantomData<fn() -> C>,
}

impl<C: CodeWord> NativeHasher<C> {
    /// Convenience constructor: sample a fresh Gaussian panel for raw
    /// dimensionality `dim` and `width` hash functions
    /// (`width <= C::MAX_BITS`).
    pub fn new(dim: usize, width: usize, seed: u64) -> Self {
        Self::with_projection(Arc::new(Projection::gaussian(dim + 1, width, seed)))
    }

    /// Share an existing panel (e.g. with a [`crate::runtime::PjrtHasher`]).
    pub fn with_projection(proj: Arc<Projection>) -> Self {
        assert!(
            proj.width() <= C::MAX_BITS,
            "panel width {} exceeds code word capacity {}",
            proj.width(),
            C::MAX_BITS
        );
        Self { proj, _code: PhantomData }
    }

    /// Hash a single query without allocating (§Perf): the per-query hot
    /// path in the indexes — the Eq. 8 transform writes into a reusable
    /// thread-local buffer and the code is returned by value, vs
    /// [`ItemHasher::hash_queries`] which allocates a `Vec` per call.
    /// Same panel, same bit convention, identical codes.
    pub fn hash_query_one(&self, query: &[f32]) -> Result<C> {
        let dim = self.proj.dim_in() - 1;
        anyhow::ensure!(
            query.len() == dim,
            "query length {} != dim {dim}",
            query.len()
        );
        Ok(ROW_SCRATCH.with(|b| {
            let buf = &mut *b.borrow_mut();
            transform_query(query, buf);
            self.hash_transformed(buf)
        }))
    }

    /// Sign-project one already-transformed row into a packed code.
    ///
    /// Accumulates all `width` dot products in a single pass over the input
    /// coordinates (row-major panel ⇒ unit-stride inner loop, auto-vectorised).
    // staticcheck: allow(panic-reach, "width <= MAX_CODE_BITS is a Projection construction invariant, so acc[..width] stays inside the fixed array")
    fn hash_transformed(&self, xt: &[f32]) -> C {
        let width = self.proj.width();
        debug_assert_eq!(xt.len(), self.proj.dim_in());
        let mut acc = [0.0f32; MAX_CODE_BITS];
        let acc = &mut acc[..width];
        for (k, &v) in xt.iter().enumerate() {
            let row = self.proj.row(k);
            for (a, &w) in acc.iter_mut().zip(row) {
                *a += v * w;
            }
        }
        // Strictly-positive convention, matching the Pallas kernel.
        C::pack_from_signs(acc)
    }

    /// Validate a bulk row buffer; returns the row count.
    fn check_rows(&self, rows: &[f32]) -> Result<usize> {
        let dim = self.proj.dim_in() - 1;
        anyhow::ensure!(
            rows.len() % dim == 0,
            "row buffer length {} not a multiple of dim {dim}",
            rows.len()
        );
        Ok(rows.len() / dim)
    }

    /// Blocked bulk item hashing (§Perf) — the default wide-code batch
    /// path and the native twin of the Pallas kernel's tiling: each
    /// worker transforms a `BLOCK_ROWS`-row tile into a per-thread
    /// buffer, then accumulates the whole tile against each panel row in
    /// one pass (the panel row is loaded once per *tile* instead of once
    /// per item) before multi-word sign packing.
    ///
    /// Bit-for-bit identical to [`Self::hash_items_unblocked`] at every
    /// width: per (row, hash function) the f32 additions happen in the
    /// same coordinate order, so no reassociation can flip a sign.
    pub fn hash_items_blocked(&self, rows: &[f32], u: f32) -> Result<Vec<C>> {
        self.hash_rows_blocked(rows, Some(u))
    }

    /// Blocked query hashing: same tiling with the Eq. 8 query transform
    /// (unit-normalise, zero tail). Identical codes to
    /// [`ItemHasher::hash_queries`].
    pub fn hash_queries_blocked(&self, rows: &[f32]) -> Result<Vec<C>> {
        self.hash_rows_blocked(rows, None)
    }

    // staticcheck: allow(panic-reach, "check_rows pins rows.len() == n*dim and every tile row index is < n")
    fn hash_rows_blocked(&self, rows: &[f32], u: Option<f32>) -> Result<Vec<C>> {
        let n = self.check_rows(rows)?;
        let dim = self.proj.dim_in() - 1;
        let din = dim + 1;
        let width = self.proj.width();
        let n_tiles = n.div_ceil(BLOCK_ROWS);
        // One tile is a substantial unit of work (a [32, width] panel
        // sweep), so fan out even small batches.
        let tiles: Vec<Vec<C>> = par::par_map_cutoff(n_tiles, 2, |t| {
            let lo = t * BLOCK_ROWS;
            let hi = ((t + 1) * BLOCK_ROWS).min(n);
            let b_rows = hi - lo;
            TILE_SCRATCH.with(|s| {
                let (rbuf, xt, acc) = &mut *s.borrow_mut();
                // Transform the tile into the per-thread buffer.
                xt.clear();
                xt.reserve(b_rows * din);
                for i in lo..hi {
                    let row = &rows[i * dim..(i + 1) * dim];
                    match u {
                        Some(u) => transform_item(row, u, rbuf),
                        None => transform_query(row, rbuf),
                    }
                    xt.extend_from_slice(rbuf);
                }
                // Panel sweep: one pass over the dim+1 coordinates,
                // each panel row applied to every tile row while hot.
                acc.clear();
                acc.resize(b_rows * width, 0.0);
                for k in 0..din {
                    let prow = self.proj.row(k);
                    for b in 0..b_rows {
                        let v = xt[b * din + k];
                        let dst = &mut acc[b * width..(b + 1) * width];
                        for (a, &w) in dst.iter_mut().zip(prow) {
                            *a += v * w;
                        }
                    }
                }
                (0..b_rows)
                    .map(|b| C::pack_from_signs(&acc[b * width..(b + 1) * width]))
                    .collect()
            })
        });
        Ok(tiles.into_iter().flatten().collect())
    }

    /// The original per-item bulk path, kept as the cross-check oracle
    /// for the blocked path (and for the PJRT kernel, transitively).
    /// Same codes as [`Self::hash_items_blocked`], bit for bit.
    pub fn hash_items_unblocked(&self, rows: &[f32], u: f32) -> Result<Vec<C>> {
        let n = self.check_rows(rows)?;
        let dim = self.proj.dim_in() - 1;
        Ok(par::par_map(n, |i| {
            ROW_SCRATCH.with(|b| {
                let buf = &mut *b.borrow_mut();
                transform_item(&rows[i * dim..(i + 1) * dim], u, buf);
                self.hash_transformed(buf)
            })
        }))
    }

    /// Per-item query oracle, the [`Self::hash_items_unblocked`] twin.
    // staticcheck: allow(panic-reach, "check_rows validates rows.len() as a multiple of the query dim before the per-row slices")
    pub fn hash_queries_unblocked(&self, rows: &[f32]) -> Result<Vec<C>> {
        let n = self.check_rows(rows)?;
        let dim = self.proj.dim_in() - 1;
        Ok(par::par_map(n, |i| {
            ROW_SCRATCH.with(|b| {
                let buf = &mut *b.borrow_mut();
                transform_query(&rows[i * dim..(i + 1) * dim], buf);
                self.hash_transformed(buf)
            })
        }))
    }
}

impl<C: CodeWord> ItemHasher<C> for NativeHasher<C> {
    fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }

    /// Bulk item hashing — the blocked tile path (see
    /// [`NativeHasher::hash_items_blocked`]).
    fn hash_items(&self, rows: &[f32], u: f32) -> Result<Vec<C>> {
        self.hash_items_blocked(rows, u)
    }

    /// Per-item with per-thread transform scratch: serving batches are
    /// small enough that the tile sweep's setup does not pay for itself
    /// on the query side, but the former per-item `Vec` allocation is
    /// gone (the thread-local row buffer is reused across a worker's rows).
    fn hash_queries(&self, rows: &[f32]) -> Result<Vec<C>> {
        self.hash_queries_unblocked(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::codes::Code128;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let d = synthetic::longtail_sift(32, 8, 0);
        let u = d.max_norm();
        let h1: NativeHasher = NativeHasher::new(8, 64, 1);
        let h2: NativeHasher = NativeHasher::new(8, 64, 1);
        let h3: NativeHasher = NativeHasher::new(8, 64, 2);
        assert_eq!(h1.hash_items(d.flat(), u).unwrap(), h2.hash_items(d.flat(), u).unwrap());
        assert_ne!(h1.hash_items(d.flat(), u).unwrap(), h3.hash_items(d.flat(), u).unwrap());
    }

    #[test]
    fn query_hash_is_scale_invariant() {
        // Queries are unit-normalised first, so scaling cannot change codes.
        let h: NativeHasher = NativeHasher::new(4, 32, 0);
        let q: Vec<f32> = vec![0.3, -0.7, 0.2, 0.9];
        let q2: Vec<f32> = q.iter().map(|v| v * 42.0).collect();
        assert_eq!(h.hash_queries(&q).unwrap(), h.hash_queries(&q2).unwrap());
    }

    #[test]
    fn item_codes_depend_on_u() {
        // The normalisation constant changes the transform tail, hence codes
        // (this is the entire RANGE-LSH mechanism).
        let d = synthetic::longtail_sift(64, 8, 1);
        let h: NativeHasher = NativeHasher::new(8, 64, 0);
        let a = h.hash_items(d.flat(), d.max_norm()).unwrap();
        let b = h.hash_items(d.flat(), d.max_norm() * 10.0).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn collision_rate_tracks_angular_similarity() {
        // Statistical check of Eq. 4: P[h(x)=h(y)] = 1 - theta/pi, per bit.
        // Pick two unit vectors at 60 degrees: expected per-bit collision 2/3.
        // Transformed space: use queries (tail 0) so the angle is exact.
        let a = vec![1.0f32, 0.0];
        let b = vec![0.5f32, 3f32.sqrt() / 2.0];
        let mut agree = 0u32;
        // Average over many independent panels.
        let trials = 200;
        for seed in 0..trials {
            let h: NativeHasher = NativeHasher::new(2, 64, seed);
            let ca = h.hash_queries(&a).unwrap()[0];
            let cb = h.hash_queries(&b).unwrap()[0];
            agree += 64 - crate::hash::hamming(ca, cb);
        }
        let rate = agree as f64 / (trials as f64 * 64.0);
        assert!((rate - 2.0 / 3.0).abs() < 0.02, "collision rate {rate}");
    }

    #[test]
    fn rejects_ragged_buffer() {
        let h: NativeHasher = NativeHasher::new(4, 16, 0);
        assert!(h.hash_items(&[0.0; 7], 1.0).is_err());
        assert!(h.hash_queries(&[0.0; 9]).is_err());
    }

    #[test]
    fn hash_query_one_matches_bulk_path() {
        let h: NativeHasher = NativeHasher::new(6, 64, 13);
        let q = synthetic::gaussian_queries(5, 6, 14);
        for i in 0..q.len() {
            assert_eq!(
                h.hash_query_one(q.row(i)).unwrap(),
                h.hash_queries(q.row(i)).unwrap()[0],
                "query {i}"
            );
        }
        assert!(h.hash_query_one(&[0.0; 5]).is_err(), "wrong dim must be rejected");
        // Wide codes share the path.
        let hw: NativeHasher<Code128> = NativeHasher::new(6, 128, 15);
        assert_eq!(
            hw.hash_query_one(q.row(0)).unwrap(),
            hw.hash_queries(q.row(0)).unwrap()[0]
        );
    }

    #[test]
    fn width_masks_unused_bits() {
        // width < 64 must leave high bits zero.
        let h: NativeHasher = NativeHasher::new(4, 16, 5);
        let codes = h.hash_queries(&[0.1, 0.2, 0.3, 0.4]).unwrap();
        assert_eq!(codes[0] >> 16, 0);
    }

    #[test]
    fn wide_low_words_agree_with_scalar_path() {
        // A 64-wide panel hashed into Code128 must equal the u64 path in
        // word 0 and leave word 1 zero (shared bit convention).
        let d = synthetic::longtail_sift(50, 8, 3);
        let u = d.max_norm();
        let proj = Arc::new(Projection::gaussian(9, 64, 7));
        let scalar: NativeHasher = NativeHasher::with_projection(proj.clone());
        let wide: NativeHasher<Code128> = NativeHasher::with_projection(proj);
        let a = scalar.hash_items(d.flat(), u).unwrap();
        let b = wide.hash_items(d.flat(), u).unwrap();
        for (s, w) in a.iter().zip(&b) {
            assert_eq!(w, &[*s, 0]);
        }
    }

    #[test]
    fn wide_panel_uses_high_words() {
        // A 128-wide panel must populate bits past 63 for generic inputs.
        let h: NativeHasher<Code128> = NativeHasher::new(8, 128, 11);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        let code = h.hash_queries(&q).unwrap()[0];
        // With 64 fair sign bits in the high word, all-zero is 2^-64.
        assert_ne!(code[1], 0, "high word never set by 128-bit panel");
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn rejects_panel_wider_than_code_word() {
        let proj = Arc::new(Projection::gaussian(4, 128, 0));
        let _h: NativeHasher<u64> = NativeHasher::with_projection(proj);
    }

    /// Blocked == per-item, bit for bit, at one width. Row counts cover
    /// sub-tile, exact-tile, and ragged multi-tile shapes.
    fn check_blocked_matches_unblocked<C: CodeWord>(width: usize, seed: u64) {
        let dim = 10;
        let h: NativeHasher<C> = NativeHasher::new(dim, width, seed);
        for n in [1usize, 7, BLOCK_ROWS, BLOCK_ROWS + 1, 3 * BLOCK_ROWS + 5] {
            let d = synthetic::longtail_sift(n, dim, seed ^ n as u64);
            let u = d.max_norm();
            assert_eq!(
                h.hash_items_blocked(d.flat(), u).unwrap(),
                h.hash_items_unblocked(d.flat(), u).unwrap(),
                "items width {width} n {n}"
            );
            let q = synthetic::gaussian_queries(n, dim, seed ^ ((n as u64) << 8));
            assert_eq!(
                h.hash_queries_blocked(q.flat()).unwrap(),
                h.hash_queries_unblocked(q.flat()).unwrap(),
                "queries width {width} n {n}"
            );
        }
    }

    #[test]
    fn blocked_path_matches_per_item_oracle_at_every_width() {
        check_blocked_matches_unblocked::<u64>(64, 41);
        check_blocked_matches_unblocked::<Code128>(128, 42);
        check_blocked_matches_unblocked::<crate::hash::Code256>(256, 43);
        // Panels narrower than the word also go through the same tiling.
        check_blocked_matches_unblocked::<u64>(16, 44);
        check_blocked_matches_unblocked::<Code128>(123, 45);
    }

    #[test]
    fn trait_hash_items_is_the_blocked_path() {
        // The ItemHasher entry point must be the blocked path (codes are
        // identical either way; this pins the routing via an empty-buffer
        // sanity call plus value equality on a real batch).
        let h: NativeHasher = NativeHasher::new(6, 64, 3);
        let d = synthetic::longtail_sift(70, 6, 4);
        let u = d.max_norm();
        assert_eq!(
            h.hash_items(d.flat(), u).unwrap(),
            h.hash_items_blocked(d.flat(), u).unwrap()
        );
        assert!(h.hash_items(&[], u).unwrap().is_empty());
        assert!(h.hash_queries(&[]).unwrap().is_empty());
    }

    #[test]
    fn blocked_rejects_ragged_buffer() {
        let h: NativeHasher = NativeHasher::new(4, 16, 0);
        assert!(h.hash_items_blocked(&[0.0; 7], 1.0).is_err());
        assert!(h.hash_queries_blocked(&[0.0; 9]).is_err());
    }
}
