//! Native (CPU, parallel) sign-random-projection hasher, generic over the
//! code word width.
//!
//! For `u64` codes it mirrors the Layer-1 Pallas kernel exactly — same
//! Eq. 8 transforms, same strictly-positive sign convention, same
//! little-endian bit packing — so the two paths are interchangeable and
//! cross-checkable. The wide instantiations ([`Code128`]/[`Code256`])
//! extend the identical convention across words: hash function `j` sets
//! bit `j % 64` of word `j / 64`, so a wide code whose high words are
//! zero agrees bit-for-bit with the scalar path (property-tested).

use std::marker::PhantomData;
use std::sync::Arc;

use super::codes::{CodeWord, MAX_CODE_BITS};
use super::{ItemHasher, Projection};
use crate::transform::simple::{transform_item, transform_query};
use crate::util::par;
use crate::Result;

#[cfg(doc)]
use super::codes::{Code128, Code256};

/// CPU sign-RP hasher over a shared [`Projection`], emitting `C`-wide
/// codes. Defaults to the original `u64` single-word path.
pub struct NativeHasher<C: CodeWord = u64> {
    proj: Arc<Projection>,
    _code: PhantomData<fn() -> C>,
}

impl<C: CodeWord> NativeHasher<C> {
    /// Convenience constructor: sample a fresh Gaussian panel for raw
    /// dimensionality `dim` and `width` hash functions
    /// (`width <= C::MAX_BITS`).
    pub fn new(dim: usize, width: usize, seed: u64) -> Self {
        Self::with_projection(Arc::new(Projection::gaussian(dim + 1, width, seed)))
    }

    /// Share an existing panel (e.g. with a [`crate::runtime::PjrtHasher`]).
    pub fn with_projection(proj: Arc<Projection>) -> Self {
        assert!(
            proj.width() <= C::MAX_BITS,
            "panel width {} exceeds code word capacity {}",
            proj.width(),
            C::MAX_BITS
        );
        Self { proj, _code: PhantomData }
    }

    /// Hash a single query without allocating (§Perf): the per-query hot
    /// path in the indexes — the Eq. 8 transform writes into a reusable
    /// thread-local buffer and the code is returned by value, vs
    /// [`ItemHasher::hash_queries`] which allocates a `Vec` per call.
    /// Same panel, same bit convention, identical codes.
    pub fn hash_query_one(&self, query: &[f32]) -> Result<C> {
        let dim = self.proj.dim_in() - 1;
        anyhow::ensure!(
            query.len() == dim,
            "query length {} != dim {dim}",
            query.len()
        );
        thread_local! {
            static QBUF: std::cell::RefCell<Vec<f32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        Ok(QBUF.with(|b| {
            let buf = &mut *b.borrow_mut();
            transform_query(query, buf);
            self.hash_transformed(buf)
        }))
    }

    /// Sign-project one already-transformed row into a packed code.
    ///
    /// Accumulates all `width` dot products in a single pass over the input
    /// coordinates (row-major panel ⇒ unit-stride inner loop, auto-vectorised).
    fn hash_transformed(&self, xt: &[f32]) -> C {
        let width = self.proj.width();
        debug_assert_eq!(xt.len(), self.proj.dim_in());
        let mut acc = [0.0f32; MAX_CODE_BITS];
        let acc = &mut acc[..width];
        for (k, &v) in xt.iter().enumerate() {
            let row = self.proj.row(k);
            for (a, &w) in acc.iter_mut().zip(row) {
                *a += v * w;
            }
        }
        // Strictly-positive convention, matching the Pallas kernel.
        C::pack_from_signs(acc)
    }
}

impl<C: CodeWord> ItemHasher<C> for NativeHasher<C> {
    fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }

    fn hash_items(&self, rows: &[f32], u: f32) -> Result<Vec<C>> {
        let dim = self.proj.dim_in() - 1;
        anyhow::ensure!(
            rows.len() % dim == 0,
            "row buffer length {} not a multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        Ok(par::par_map(n, |i| {
            let mut buf = Vec::with_capacity(dim + 1);
            transform_item(&rows[i * dim..(i + 1) * dim], u, &mut buf);
            self.hash_transformed(&buf)
        }))
    }

    fn hash_queries(&self, rows: &[f32]) -> Result<Vec<C>> {
        let dim = self.proj.dim_in() - 1;
        anyhow::ensure!(
            rows.len() % dim == 0,
            "row buffer length {} not a multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        Ok(par::par_map(n, |i| {
            let mut buf = Vec::with_capacity(dim + 1);
            transform_query(&rows[i * dim..(i + 1) * dim], &mut buf);
            self.hash_transformed(&buf)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::codes::Code128;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let d = synthetic::longtail_sift(32, 8, 0);
        let u = d.max_norm();
        let h1: NativeHasher = NativeHasher::new(8, 64, 1);
        let h2: NativeHasher = NativeHasher::new(8, 64, 1);
        let h3: NativeHasher = NativeHasher::new(8, 64, 2);
        assert_eq!(h1.hash_items(d.flat(), u).unwrap(), h2.hash_items(d.flat(), u).unwrap());
        assert_ne!(h1.hash_items(d.flat(), u).unwrap(), h3.hash_items(d.flat(), u).unwrap());
    }

    #[test]
    fn query_hash_is_scale_invariant() {
        // Queries are unit-normalised first, so scaling cannot change codes.
        let h: NativeHasher = NativeHasher::new(4, 32, 0);
        let q: Vec<f32> = vec![0.3, -0.7, 0.2, 0.9];
        let q2: Vec<f32> = q.iter().map(|v| v * 42.0).collect();
        assert_eq!(h.hash_queries(&q).unwrap(), h.hash_queries(&q2).unwrap());
    }

    #[test]
    fn item_codes_depend_on_u() {
        // The normalisation constant changes the transform tail, hence codes
        // (this is the entire RANGE-LSH mechanism).
        let d = synthetic::longtail_sift(64, 8, 1);
        let h: NativeHasher = NativeHasher::new(8, 64, 0);
        let a = h.hash_items(d.flat(), d.max_norm()).unwrap();
        let b = h.hash_items(d.flat(), d.max_norm() * 10.0).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn collision_rate_tracks_angular_similarity() {
        // Statistical check of Eq. 4: P[h(x)=h(y)] = 1 - theta/pi, per bit.
        // Pick two unit vectors at 60 degrees: expected per-bit collision 2/3.
        // Transformed space: use queries (tail 0) so the angle is exact.
        let a = vec![1.0f32, 0.0];
        let b = vec![0.5f32, 3f32.sqrt() / 2.0];
        let mut agree = 0u32;
        // Average over many independent panels.
        let trials = 200;
        for seed in 0..trials {
            let h: NativeHasher = NativeHasher::new(2, 64, seed);
            let ca = h.hash_queries(&a).unwrap()[0];
            let cb = h.hash_queries(&b).unwrap()[0];
            agree += 64 - crate::hash::hamming(ca, cb);
        }
        let rate = agree as f64 / (trials as f64 * 64.0);
        assert!((rate - 2.0 / 3.0).abs() < 0.02, "collision rate {rate}");
    }

    #[test]
    fn rejects_ragged_buffer() {
        let h: NativeHasher = NativeHasher::new(4, 16, 0);
        assert!(h.hash_items(&[0.0; 7], 1.0).is_err());
        assert!(h.hash_queries(&[0.0; 9]).is_err());
    }

    #[test]
    fn hash_query_one_matches_bulk_path() {
        let h: NativeHasher = NativeHasher::new(6, 64, 13);
        let q = synthetic::gaussian_queries(5, 6, 14);
        for i in 0..q.len() {
            assert_eq!(
                h.hash_query_one(q.row(i)).unwrap(),
                h.hash_queries(q.row(i)).unwrap()[0],
                "query {i}"
            );
        }
        assert!(h.hash_query_one(&[0.0; 5]).is_err(), "wrong dim must be rejected");
        // Wide codes share the path.
        let hw: NativeHasher<Code128> = NativeHasher::new(6, 128, 15);
        assert_eq!(
            hw.hash_query_one(q.row(0)).unwrap(),
            hw.hash_queries(q.row(0)).unwrap()[0]
        );
    }

    #[test]
    fn width_masks_unused_bits() {
        // width < 64 must leave high bits zero.
        let h: NativeHasher = NativeHasher::new(4, 16, 5);
        let codes = h.hash_queries(&[0.1, 0.2, 0.3, 0.4]).unwrap();
        assert_eq!(codes[0] >> 16, 0);
    }

    #[test]
    fn wide_low_words_agree_with_scalar_path() {
        // A 64-wide panel hashed into Code128 must equal the u64 path in
        // word 0 and leave word 1 zero (shared bit convention).
        let d = synthetic::longtail_sift(50, 8, 3);
        let u = d.max_norm();
        let proj = Arc::new(Projection::gaussian(9, 64, 7));
        let scalar: NativeHasher = NativeHasher::with_projection(proj.clone());
        let wide: NativeHasher<Code128> = NativeHasher::with_projection(proj);
        let a = scalar.hash_items(d.flat(), u).unwrap();
        let b = wide.hash_items(d.flat(), u).unwrap();
        for (s, w) in a.iter().zip(&b) {
            assert_eq!(w, &[*s, 0]);
        }
    }

    #[test]
    fn wide_panel_uses_high_words() {
        // A 128-wide panel must populate bits past 63 for generic inputs.
        let h: NativeHasher<Code128> = NativeHasher::new(8, 128, 11);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        let code = h.hash_queries(&q).unwrap()[0];
        // With 64 fair sign bits in the high word, all-zero is 2^-64.
        assert_ne!(code[1], 0, "high word never set by 128-bit panel");
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn rejects_panel_wider_than_code_word() {
        let proj = Arc::new(Projection::gaussian(4, 128, 0));
        let _h: NativeHasher<u64> = NativeHasher::with_projection(proj);
    }
}
