//! Hash bucket table: packed code → item ids, plus the per-query
//! counting-sort that groups buckets by number of matching bits. Generic
//! over the code word `C` ([`CodeWord`]): `BucketTable` (= `BucketTable<u64>`)
//! is the original single-word table; `BucketTable<Code128>` /
//! `BucketTable<Code256>` lift the 64-bit code ceiling.
//!
//! The counting-sort is how both Hamming ranking (SIMPLE-LSH) and the
//! Eq. 12 metric order (RANGE-LSH) are realised in O(#buckets) per query —
//! "a complexity similar to Hamming distance" as §3.3 requires.
//!
//! Layout (§Perf): buckets are stored structure-of-arrays — a dense
//! `codes` vector (one linear popcount scan per query, cache-friendly and
//! auto-vectorisable) and a flat `items` arena with per-bucket offsets —
//! rather than pointer-chasing a map of Vecs. The hash map only serves
//! exact-bucket lookups (single-probe protocol). Monomorphization keeps
//! the `u64` scan's codegen: `C::matches` inlines to one XOR + POPCNT per
//! word, with the word count a compile-time constant.

use crate::hash::CodeWord;
use crate::index::mih::{MihScratch, MihTable};
use crate::index::traits::{drain_bucket, ProbeStats, Prober};
use crate::util::fxhash::FxHashMap;
use crate::ItemId;

thread_local! {
    /// Shared per-thread [`SortScratch`] pool. Probe sessions take a
    /// scratch here at open and return it on drop, so the one-shot
    /// `probe(...)` wrappers — which open and drop a session within one
    /// call — stay alloc-free once a thread is warm, while long-lived
    /// sessions keep their scratch across `extend` calls as the cursor
    /// state requires.
    static SCRATCH_POOL: std::cell::RefCell<Vec<SortScratch>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

pub(crate) fn take_scratch() -> SortScratch {
    SCRATCH_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default()
}

pub(crate) fn return_scratch(s: SortScratch) {
    SCRATCH_POOL.with(|p| p.borrow_mut().push(s));
}

/// Reusable buffers for [`BucketTable::counting_sort_by_matches`] /
/// [`BucketTable::counting_sort_partial`].
/// Width-independent: the same scratch serves tables of any code width.
#[derive(Debug, Default, Clone)]
pub struct SortScratch {
    /// Bucket indices grouped by match count (the sort output). Only the
    /// slices for levels `floor..` are materialized; lower slots — and
    /// slots past the current table's bucket count (the buffer only ever
    /// grows) — hold stale data from earlier queries.
    pub order: Vec<u32>,
    /// `levels[l]..levels[l+1]` bounds the match-count-`l` slice of `order`.
    /// Always full-length (`bits + 2` entries), so the bounds of every
    /// level stay valid even below the materialization floor.
    pub levels: Vec<u32>,
    /// Lowest match count whose `order` slice was materialized by the
    /// last sort (0 = everything). Levels `floor..=bits` jointly cover at
    /// least the budget the sort was run with, so a budget-respecting
    /// walk never needs to read below it.
    pub floor: u32,
    pub(crate) l_cache: Vec<u32>,
    pub(crate) cursor: Vec<u32>,
    /// `item_hist[l]` = total items (not buckets) at match count `l` —
    /// the histogram that decides the materialization floor.
    pub(crate) item_hist: Vec<u32>,
    /// The budget the last sort materialized for — lets
    /// [`BucketTable::emit_ranked`] check its precondition in debug
    /// builds. Written by both the counting sort and
    /// [`MihTable::rank_partial`].
    pub(crate) sorted_budget: usize,
    /// Buffers for the MIH backend ([`MihTable::rank_partial`]), embedded
    /// here so every scratch pool (single-table, per-range, batch)
    /// carries MIH capability without separate plumbing.
    pub(crate) mih: MihScratch,
}

impl SortScratch {
    /// Empty scratch, usable in `const` thread-local initialisers.
    pub const fn new() -> Self {
        Self {
            order: Vec::new(),
            levels: Vec::new(),
            floor: 0,
            l_cache: Vec::new(),
            cursor: Vec::new(),
            item_hist: Vec::new(),
            sorted_budget: 0,
            mih: MihScratch::new(),
        }
    }
}

/// A single hash table over packed codes masked to `bits` hash bits.
#[derive(Debug, Clone)]
pub struct BucketTable<C: CodeWord = u64> {
    bits: usize,
    /// code → dense bucket index (exact lookups only).
    map: FxHashMap<C, u32>,
    /// Dense bucket codes (scan target of the per-query counting sort).
    codes: Vec<C>,
    /// Bucket `b` owns `items[starts[b] as usize .. starts[b+1] as usize]`.
    starts: Vec<u32>,
    items: Vec<ItemId>,
}

impl<C: CodeWord> BucketTable<C> {
    /// Build from per-item codes. `ids[i]` is the dataset-global id of the
    /// item whose code is `codes[i]` (RANGE-LSH passes each range's ids).
    /// Codes are masked to `bits` internally (`1 <= bits <= C::MAX_BITS`).
    // staticcheck: allow(panic-reach, "bucket handles are dense indices this pass just allocated; counts/bucket_codes grow in lockstep with the map, and codes/ids lengths are asserted equal")
    pub fn build(codes: &[C], ids: Option<&[ItemId]>, bits: usize) -> Self {
        if let Some(ids) = ids {
            assert_eq!(codes.len(), ids.len(), "codes/ids length mismatch");
        }
        let mask = C::mask(bits);
        // Pass 1: assign dense bucket indices and count occupancy.
        let mut map: FxHashMap<C, u32> = FxHashMap::default();
        let mut bucket_codes: Vec<C> = Vec::new();
        let mut counts: Vec<u32> = Vec::new();
        let mut assignment: Vec<u32> = Vec::with_capacity(codes.len());
        for &code in codes {
            let code = code.and(mask);
            let b = *map.entry(code).or_insert_with(|| {
                bucket_codes.push(code);
                counts.push(0);
                (bucket_codes.len() - 1) as u32
            });
            counts[b as usize] += 1;
            assignment.push(b);
        }
        // Pass 2: prefix offsets, then place items into the flat arena.
        let mut starts: Vec<u32> = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0u32;
        starts.push(0);
        for &c in &counts {
            acc += c;
            starts.push(acc);
        }
        let mut cursor: Vec<u32> = starts[..counts.len()].to_vec();
        let mut items = vec![0 as ItemId; codes.len()];
        for (i, &b) in assignment.iter().enumerate() {
            let id = ids.map_or(i as ItemId, |ids| ids[i]);
            items[cursor[b as usize] as usize] = id;
            cursor[b as usize] += 1;
        }
        Self { bits, map, codes: bucket_codes, starts, items }
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn n_items(&self) -> usize {
        self.items.len()
    }

    pub fn n_buckets(&self) -> usize {
        self.codes.len()
    }

    pub fn largest_bucket(&self) -> usize {
        (0..self.n_buckets())
            .map(|b| (self.starts[b + 1] - self.starts[b]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Items of dense bucket `b`.
    #[inline]
    // staticcheck: allow(panic-reach, "starts is a CSR offset array with n_buckets + 1 entries; every caller iterates b < n_buckets")
    pub fn bucket_items(&self, b: usize) -> &[ItemId] {
        &self.items[self.starts[b] as usize..self.starts[b + 1] as usize]
    }

    /// Code of dense bucket `b` (masked to `bits`) — the scan target the
    /// counting sort popcounts and the MIH chunk tables are built from.
    #[inline]
    // staticcheck: allow(panic-reach, "codes has one entry per dense bucket; callers pass b < n_buckets")
    pub fn bucket_code(&self, b: usize) -> C {
        self.codes[b]
    }

    /// Items whose code equals `qcode` exactly (single-probe protocol).
    pub fn exact(&self, qcode: C) -> Option<&[ItemId]> {
        self.map
            .get(&qcode.and(C::mask(self.bits)))
            .map(|&b| self.bucket_items(b as usize))
    }

    /// Counting-sort all buckets by `l` = matching bits against `qcode`:
    /// after the call, buckets with exactly `l` matching bits occupy
    /// `scratch.order[scratch.levels[l] .. scratch.levels[l+1]]`
    /// (`levels.len() == bits + 2`). All buffers live in `scratch` and are
    /// reused — the probe hot path makes no allocations once warm (§Perf).
    pub fn counting_sort_by_matches(&self, qcode: C, scratch: &mut SortScratch) {
        self.counting_sort_partial(qcode, usize::MAX, scratch);
    }

    /// Budget-adaptive counting sort: popcount every bucket once (that
    /// pass is unavoidable — it *is* the histogram), but materialize
    /// `order` only down to the level where the cumulative *item* count
    /// covers `budget`. A budget-100 query on a table holding 100k items
    /// pays the histogram pass plus placement of a handful of buckets,
    /// not placement of every bucket.
    ///
    /// Postcondition: `scratch.floor` is the lowest materialized level;
    /// levels `floor..=bits` jointly hold >= `budget` items (or `floor`
    /// is 0 and everything is materialized). Slices at or above the floor
    /// are identical to what [`Self::counting_sort_by_matches`] produces.
    // staticcheck: allow(panic-reach, "levels is resized to bits + 2 and matches() returns l <= bits; starts[b + 1] is CSR-valid for b < n_buckets")
    pub fn counting_sort_partial(&self, qcode: C, budget: usize, scratch: &mut SortScratch) {
        let q = qcode.and(C::mask(self.bits));
        let n = self.n_buckets();
        let SortScratch { levels, l_cache, item_hist, .. } = scratch;
        levels.clear();
        levels.resize(self.bits + 2, 0);
        item_hist.clear();
        item_hist.resize(self.bits + 1, 0);
        // Pass 1: popcount every bucket exactly once (dense scan,
        // vectorisable), caching `l` and histogramming both buckets and
        // items per level.
        l_cache.clear();
        l_cache.reserve(n);
        for (b, &code) in self.codes.iter().enumerate() {
            let l = code.matches(q, self.bits);
            l_cache.push(l);
            levels[l as usize + 1] += 1;
            item_hist[l as usize] += self.starts[b + 1] - self.starts[b];
        }
        self.finish_sort(budget, scratch);
    }

    /// Shared tail of the single-query and batched sorts: prefix-sum the
    /// level histogram into slice bounds, derive the materialization
    /// floor from the item histogram, and place bucket indices at or
    /// above the floor.
    // staticcheck: allow(panic-reach, "prefix sums index levels[l + 1] for l <= bits with levels sized bits + 2; order placement stays below n_buckets")
    fn finish_sort(&self, budget: usize, scratch: &mut SortScratch) {
        let n = self.n_buckets();
        let SortScratch { order, levels, floor, l_cache, cursor, item_hist, sorted_budget } =
            scratch;
        *sorted_budget = budget;
        // Prefix sum → slice starts per level (full-length: bounds of
        // unmaterialized levels stay valid, their contents stay stale).
        for l in 0..=self.bits {
            levels[l + 1] += levels[l];
        }
        // The histogram alone tells us how deep placement must go: walk
        // levels best-first until the cumulative item count covers the
        // budget. `floor` stays 0 (full sort) when the budget exceeds
        // the table.
        let mut cut = 0u32;
        if budget < self.n_items() {
            let mut covered = 0usize;
            for l in (0..=self.bits).rev() {
                covered += item_hist[l] as usize;
                if covered >= budget {
                    cut = l as u32;
                    break;
                }
            }
        }
        *floor = cut;
        // Pass 2: place bucket indices at or above the floor using the
        // cached `l`s. Grow-only buffer: every slot at or above the floor
        // is overwritten through the cursors and slots below the floor
        // (or beyond this table's bucket count) are never read, so a
        // small-budget sort does not pay an O(n_buckets) memset.
        if order.len() < n {
            order.resize(n, 0);
        }
        cursor.clear();
        cursor.extend_from_slice(levels);
        for (b, &l) in l_cache.iter().enumerate() {
            if l >= cut {
                order[cursor[l as usize] as usize] = b as u32;
                cursor[l as usize] += 1;
            }
        }
    }

    /// Batched counting sort: score `B` query codes in one streaming pass
    /// over the dense `codes` vector — each cache-line-sized block of
    /// bucket codes is XOR+popcounted against every query before moving
    /// on, so the codes vector moves through the memory hierarchy once
    /// per *batch* instead of once per query. Per query, the result in
    /// `scratches[i]` is identical to
    /// `counting_sort_partial(qcodes[i], budget, &mut scratches[i])`.
    // staticcheck: allow(panic-reach, "block bounds satisfy b1 <= n_buckets and level indices are <= bits with levels sized bits + 2")
    pub fn counting_sort_batch(&self, qcodes: &[C], budget: usize, scratches: &mut [SortScratch]) {
        assert_eq!(qcodes.len(), scratches.len(), "one scratch per query");
        let n = self.n_buckets();
        let mask = C::mask(self.bits);
        for s in scratches.iter_mut() {
            s.l_cache.clear();
            s.l_cache.reserve(n);
            s.levels.clear();
            s.levels.resize(self.bits + 2, 0);
            s.item_hist.clear();
            s.item_hist.resize(self.bits + 1, 0);
        }
        // Shared pass 1: one block of codes (8 u64 words = one cache
        // line at width 64) against every query before the next block.
        // Blocks ascend and each query visits b0..b1 in order, so every
        // scratch's `l_cache` is pushed in bucket order — no zero-fill.
        const BLOCK: usize = 8;
        let mut b0 = 0usize;
        while b0 < n {
            let b1 = (b0 + BLOCK).min(n);
            for (&qraw, s) in qcodes.iter().zip(scratches.iter_mut()) {
                let q = qraw.and(mask);
                for b in b0..b1 {
                    let l = self.codes[b].matches(q, self.bits);
                    s.l_cache.push(l);
                    s.levels[l as usize + 1] += 1;
                    s.item_hist[l as usize] += self.starts[b + 1] - self.starts[b];
                }
            }
            b0 = b1;
        }
        // Per-query tail: prefix sums, floor, placement.
        for s in scratches.iter_mut() {
            self.finish_sort(budget, s);
        }
    }

    /// Emit bucket items Hamming-ranked (most matching bits first) from a
    /// prepared scratch, up to `budget` ids — the walk shared by the
    /// single-table indexes (SIMPLE-LSH, SIGN-ALSH). Stops at the
    /// scratch's materialization floor, which by the
    /// [`Self::counting_sort_partial`] postcondition covers any budget no
    /// larger than the one the sort ran with.
    // staticcheck: allow(panic-reach, "the sort postcondition materializes every level down to the floor; slice bounds come from its prefix sums, and take is min(len, remaining)")
    pub fn emit_ranked(&self, scratch: &SortScratch, budget: usize, out: &mut Vec<ItemId>) {
        debug_assert!(
            budget <= scratch.sorted_budget,
            "emit budget {budget} exceeds the sort's materialized budget {}",
            scratch.sorted_budget
        );
        let mut remaining = budget;
        if remaining == 0 {
            return;
        }
        for l in (scratch.floor as usize..=self.bits).rev() {
            let (lo, hi) = (scratch.levels[l] as usize, scratch.levels[l + 1] as usize);
            for &b in &scratch.order[lo..hi] {
                let bucket = self.bucket_items(b as usize);
                let take = bucket.len().min(remaining);
                out.extend_from_slice(&bucket[..take]);
                remaining -= take;
                if remaining == 0 {
                    return;
                }
            }
        }
    }

    /// Group this table's buckets by `l` (compat shim over the counting
    /// sort; prefer [`Self::counting_sort_by_matches`] on hot paths).
    pub fn group_by_matches<'a>(&'a self, qcode: C, groups: &mut Vec<Vec<&'a [ItemId]>>) {
        let mut scratch = SortScratch::default();
        self.counting_sort_by_matches(qcode, &mut scratch);
        groups.clear();
        groups.resize_with(self.bits + 1, Vec::new);
        for l in 0..=self.bits {
            let (lo, hi) = (scratch.levels[l] as usize, scratch.levels[l + 1] as usize);
            for &b in &scratch.order[lo..hi] {
                groups[l].push(self.bucket_items(b as usize));
            }
        }
    }

    /// Iterate all buckets (stats / diagnostics / persistence).
    // staticcheck: allow(panic-reach, "b ranges over 0..n_buckets with CSR-valid starts")
    pub fn buckets(&self) -> impl Iterator<Item = (C, &[ItemId])> {
        (0..self.n_buckets()).map(|b| (self.codes[b], self.bucket_items(b)))
    }

    /// Bucket-size histogram: `hist[k]` = number of buckets holding
    /// exactly `k` items (k capped at `hist.len()-1`). Fig-adjacent
    /// diagnostic for the §3.1/§3.2 balance discussion.
    pub fn occupancy_histogram(&self, max_size: usize) -> Vec<usize> {
        let mut hist = vec![0usize; max_size + 1];
        for b in 0..self.n_buckets() {
            hist[self.bucket_items(b).len().min(max_size)] += 1;
        }
        hist
    }

    /// Open a resumable Hamming-ranked probe session for `qcode` — the
    /// cursor shared by the single-table indexes (SIMPLE-LSH, SIGN-ALSH).
    pub fn prober(&self, qcode: C) -> TableProber<'_, C> {
        TableProber::new(self, qcode, None)
    }

    /// Like [`Self::prober`], but ranking through the MIH backend when
    /// `mih` is present (the table it was built from must be `self`).
    /// The emitted stream is element-for-element identical either way.
    pub fn prober_mih<'a>(
        &'a self,
        qcode: C,
        mih: Option<&'a MihTable<C>>,
    ) -> TableProber<'a, C> {
        TableProber::new(self, qcode, mih)
    }
}

/// Resumable Hamming-ranked probe session over one [`BucketTable`]: the
/// budget-adaptive counting sort plus a `(level, bucket, item)` cursor,
/// so [`Prober::extend`] continues the best-match-first walk where the
/// previous call stopped instead of rescanning. The [`SortScratch`] is
/// taken from the per-thread pool at open and returned on drop.
pub struct TableProber<'a, C: CodeWord> {
    table: &'a BucketTable<C>,
    qcode: C,
    /// MIH backend for the initial ranking, when enabled on the owning
    /// index. Below-floor re-materialization always uses the counting
    /// sort (it is full-depth anyway).
    mih: Option<&'a MihTable<C>>,
    scratch: SortScratch,
    /// Sort runs lazily at the first nonzero `extend`, so `extend(0)` on
    /// a fresh session is a true no-op.
    sorted: bool,
    /// Current match-count level, walking from `bits` down to 0.
    level: usize,
    /// Offset into the current level's `order` slice.
    bucket: usize,
    /// Offset into the current bucket's items.
    item: usize,
    stats: ProbeStats,
    done: bool,
}

impl<'a, C: CodeWord> TableProber<'a, C> {
    fn new(table: &'a BucketTable<C>, qcode: C, mih: Option<&'a MihTable<C>>) -> Self {
        Self {
            table,
            qcode,
            mih,
            scratch: take_scratch(),
            sorted: false,
            level: 0,
            bucket: 0,
            item: 0,
            stats: ProbeStats::default(),
            done: false,
        }
    }
}

impl<C: CodeWord> Drop for TableProber<'_, C> {
    fn drop(&mut self) {
        return_scratch(std::mem::take(&mut self.scratch));
    }
}

impl<C: CodeWord> Prober for TableProber<'_, C> {
    // staticcheck: allow(panic-reach, "level walks bits..floor with levels sized bits + 2; order slices are the sort's own materialized bounds")
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        if additional_budget == 0 || self.done {
            return 0;
        }
        let table = self.table;
        if !self.sorted {
            if let Some(mih) = self.mih {
                self.stats.buckets_scanned +=
                    mih.rank_partial(table, self.qcode, additional_budget, &mut self.scratch);
            } else {
                table.counting_sort_partial(self.qcode, additional_budget, &mut self.scratch);
                self.stats.buckets_scanned += table.n_buckets();
            }
            self.sorted = true;
            self.level = table.bits;
            self.stats.ranges_sorted += 1;
        }
        let mut remaining = additional_budget;
        loop {
            if self.level < self.scratch.floor as usize {
                // Resumed below the materialization floor: re-sort to
                // full depth. Sorting is pure, so the slices already
                // walked are reproduced bit-for-bit, and the floor drops
                // to zero — at most one re-materialization per session.
                table.counting_sort_by_matches(self.qcode, &mut self.scratch);
                self.stats.ranges_resorted += 1;
                self.stats.buckets_scanned += table.n_buckets();
            }
            let lo = self.scratch.levels[self.level] as usize;
            let hi = self.scratch.levels[self.level + 1] as usize;
            while self.bucket < hi - lo {
                let b = self.scratch.order[lo + self.bucket] as usize;
                let finished = drain_bucket(
                    table.bucket_items(b),
                    &mut self.item,
                    &mut remaining,
                    out,
                    &mut self.stats,
                );
                if finished {
                    self.bucket += 1;
                }
                if remaining == 0 {
                    self.stats.items_emitted += additional_budget;
                    return additional_budget;
                }
            }
            self.bucket = 0;
            if self.level == 0 {
                self.done = true;
                break;
            }
            self.level -= 1;
        }
        let emitted = additional_budget - remaining;
        self.stats.items_emitted += emitted;
        emitted
    }

    fn is_exhausted(&self) -> bool {
        self.done
    }

    fn stats(&self) -> ProbeStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::codes::{widen, Code128, Code256};
    use crate::hash::{mask_bits, matches};

    #[test]
    fn build_groups_equal_codes() {
        let t = BucketTable::build(&[0b01u64, 0b01, 0b10], None, 2);
        assert_eq!(t.n_buckets(), 2);
        assert_eq!(t.largest_bucket(), 2);
        assert_eq!(t.exact(0b01).unwrap(), &[0, 1]);
        assert_eq!(t.exact(0b10).unwrap(), &[2]);
        assert!(t.exact(0b11).is_none());
    }

    #[test]
    fn masking_merges_high_bit_differences() {
        // Codes differing only above `bits` collapse into one bucket.
        let t = BucketTable::build(&[0b100_01u64, 0b000_01], None, 2);
        assert_eq!(t.n_buckets(), 1);
        assert_eq!(t.exact(0b01).unwrap().len(), 2);
    }

    #[test]
    fn custom_ids_are_preserved() {
        let t = BucketTable::build(&[7u64, 7], Some(&[100, 200]), 4);
        assert_eq!(t.exact(7).unwrap(), &[100, 200]);
    }

    #[test]
    fn group_by_matches_counts_correctly() {
        // bits=3, query 0b000: code 0b000 -> l=3, 0b001 -> l=2, 0b111 -> l=0.
        let t = BucketTable::build(&[0b000u64, 0b001, 0b111], None, 3);
        let mut groups = Vec::new();
        t.group_by_matches(0b000, &mut groups);
        assert_eq!(groups.len(), 4);
        assert_eq!(groups[3].len(), 1);
        assert_eq!(groups[2].len(), 1);
        assert_eq!(groups[1].len(), 0);
        assert_eq!(groups[0].len(), 1);
        assert_eq!(groups[3][0], &[0]);
        assert_eq!(groups[0][0], &[2]);
    }

    #[test]
    fn group_by_matches_covers_all_buckets() {
        let codes: Vec<u64> = (0..100).map(|i| i * 2654435761 % 1024).collect();
        let t = BucketTable::build(&codes, None, 10);
        let mut groups = Vec::new();
        t.group_by_matches(0x3FF, &mut groups);
        let total: usize = groups.iter().flat_map(|g| g.iter()).map(|s| s.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn counting_sort_levels_are_consistent() {
        let codes: Vec<u64> = (0..500).map(|i| i * 0x9E3779B9 % 4096).collect();
        let t = BucketTable::build(&codes, None, 12);
        let mut scratch = SortScratch::default();
        let q = 0xABCu64;
        t.counting_sort_by_matches(q, &mut scratch);
        assert_eq!(scratch.order.len(), t.n_buckets());
        assert_eq!(scratch.levels.len(), 14);
        assert_eq!(scratch.levels[13] as usize, t.n_buckets());
        // Every bucket appears exactly once, in its own level slice.
        let mut seen = vec![false; t.n_buckets()];
        for l in 0..=12 {
            let (lo, hi) = (scratch.levels[l] as usize, scratch.levels[l + 1] as usize);
            for &b in &scratch.order[lo..hi] {
                assert!(!seen[b as usize]);
                seen[b as usize] = true;
                assert_eq!(matches(t.codes[b as usize], q, 12) as usize, l);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn counting_sort_reuses_buffers() {
        let t = BucketTable::build(&[1u64, 2, 3], None, 4);
        let mut scratch = SortScratch::default();
        scratch.order = vec![9u32; 100];
        scratch.levels = vec![7u32; 100];
        t.counting_sort_by_matches(0, &mut scratch);
        // `order` is grow-only (stale slots past the bucket count are
        // never read); `levels` is exact per table.
        assert!(scratch.order.len() >= 3);
        assert_eq!(scratch.levels.len(), 6);
        assert_eq!(scratch.levels[5] as usize, t.n_buckets());
        // Every bucket placed exactly once in the materialized region.
        let mut seen = [false; 3];
        for &b in &scratch.order[..3] {
            assert!(!seen[b as usize]);
            seen[b as usize] = true;
        }
        // Second query on the same scratch must be consistent too.
        t.counting_sort_by_matches(u64::MAX, &mut scratch);
        assert_eq!(scratch.levels[5] as usize, t.n_buckets());
    }

    #[test]
    fn occupancy_histogram_sums_to_bucket_count() {
        let t = BucketTable::build(&[1u64, 1, 1, 2, 3], None, 4);
        let hist = t.occupancy_histogram(8);
        assert_eq!(hist.iter().sum::<usize>(), t.n_buckets());
        assert_eq!(hist[3], 1); // the triple bucket
        assert_eq!(hist[1], 2);
    }

    #[test]
    fn empty_table() {
        let t = BucketTable::build(&[] as &[u64], None, 8);
        assert_eq!(t.n_buckets(), 0);
        assert_eq!(t.largest_bucket(), 0);
        let mut groups = Vec::new();
        t.group_by_matches(0, &mut groups);
        assert!(groups.iter().all(Vec::is_empty));
    }

    #[test]
    fn bucket_items_match_build_input() {
        let codes = [5u64, 9, 5, 9, 5];
        let t = BucketTable::build(&codes, None, 8);
        let five: Vec<_> = t.exact(5).unwrap().to_vec();
        let nine: Vec<_> = t.exact(9).unwrap().to_vec();
        assert_eq!(five, vec![0, 2, 4]);
        assert_eq!(nine, vec![1, 3]);
    }

    #[test]
    fn wide_table_with_zero_high_words_mirrors_scalar() {
        // Identical codes zero-extended into Code128 must produce the same
        // bucket structure, scan order, and counting-sort levels.
        let scalar_codes: Vec<u64> = (0..200).map(|i| i * 0x9E3779B9 % 4096).collect();
        let wide_codes: Vec<Code128> = scalar_codes.iter().map(|&c| widen(c)).collect();
        let ts = BucketTable::build(&scalar_codes, None, 12);
        let tw = BucketTable::build(&wide_codes, None, 12);
        assert_eq!(ts.n_buckets(), tw.n_buckets());
        assert_eq!(ts.largest_bucket(), tw.largest_bucket());
        let q = 0xABCu64;
        let (mut ss, mut sw) = (SortScratch::default(), SortScratch::default());
        ts.counting_sort_by_matches(q, &mut ss);
        tw.counting_sort_by_matches(widen(q), &mut sw);
        assert_eq!(ss.levels, sw.levels);
        assert_eq!(ss.order, sw.order);
    }

    #[test]
    fn wide_table_distinguishes_high_word_bits() {
        // Two codes equal in the low word but different past bit 64 must
        // land in different buckets once bits > 64.
        let lo: Code128 = [42, 0];
        let hi: Code128 = [42, 1];
        let t = BucketTable::build(&[lo, hi, lo], None, 70);
        assert_eq!(t.n_buckets(), 2);
        assert_eq!(t.exact(lo).unwrap(), &[0, 2]);
        assert_eq!(t.exact(hi).unwrap(), &[1]);
        // ... and with bits <= 64 they merge (the mask cuts the high word).
        let t = BucketTable::build(&[lo, hi, lo], None, 64);
        assert_eq!(t.n_buckets(), 1);
    }

    #[test]
    fn wide_counting_sort_levels_span_wide_bits() {
        let bits = 200usize;
        let q: Code256 = [1, 2, 3, 4];
        let codes: Vec<Code256> =
            (0..50u64).map(|i| [i, i.wrapping_mul(31), i ^ 7, i.rotate_left(9)]).collect();
        let t = BucketTable::build(&codes, None, bits);
        let mut scratch = SortScratch::default();
        t.counting_sort_by_matches(q, &mut scratch);
        assert_eq!(scratch.levels.len(), bits + 2);
        assert_eq!(*scratch.levels.last().unwrap() as usize, t.n_buckets());
        // Every bucket sits in the level slice of its true match count.
        let mut seen = vec![false; t.n_buckets()];
        for l in 0..=bits {
            let (lo, hi) = (scratch.levels[l] as usize, scratch.levels[l + 1] as usize);
            for &b in &scratch.order[lo..hi] {
                assert!(!seen[b as usize]);
                seen[b as usize] = true;
                let code = codes[t.bucket_items(b as usize)[0] as usize];
                assert_eq!(code.masked(bits).matches(q.masked(bits), bits) as usize, l);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn partial_sort_floor_covers_budget() {
        let codes: Vec<u64> = (0..400).map(|i| i * 0x9E3779B9 % 4096).collect();
        let t = BucketTable::build(&codes, None, 12);
        let q = 0x5A5u64;
        let mut full = SortScratch::default();
        t.counting_sort_by_matches(q, &mut full);
        assert_eq!(full.floor, 0);
        for budget in [1usize, 5, 50, 399] {
            let mut part = SortScratch::default();
            t.counting_sort_partial(q, budget, &mut part);
            assert_eq!(part.levels, full.levels, "budget {budget}");
            // Materialized levels jointly cover the budget...
            let covered: usize = (part.floor as usize..=12)
                .flat_map(|l| {
                    let (lo, hi) = (part.levels[l] as usize, part.levels[l + 1] as usize);
                    part.order[lo..hi].iter().map(|&b| t.bucket_items(b as usize).len())
                })
                .sum();
            assert!(covered >= budget, "budget {budget}: covered only {covered}");
            // ... and materialized slices equal the full sort's.
            for l in part.floor as usize..=12 {
                let (lo, hi) = (part.levels[l] as usize, part.levels[l + 1] as usize);
                assert_eq!(part.order[lo..hi], full.order[lo..hi], "budget {budget} level {l}");
            }
        }
        // Budget beyond the table degenerates to the full sort.
        let mut part = SortScratch::default();
        t.counting_sort_partial(q, t.n_items(), &mut part);
        assert_eq!(part.floor, 0);
        assert_eq!(part.order, full.order);
    }

    #[test]
    fn partial_sort_emits_eager_prefix() {
        // emit_ranked over a budget-b partial sort == first b ids of the
        // full-sort emission, element for element.
        let codes: Vec<u64> = (0..300).map(|i| i.wrapping_mul(0x2545F491) % 2048).collect();
        let t = BucketTable::build(&codes, None, 11);
        let q = 0x3C7u64;
        let mut full = SortScratch::default();
        t.counting_sort_by_matches(q, &mut full);
        let mut all = Vec::new();
        t.emit_ranked(&full, usize::MAX, &mut all);
        assert_eq!(all.len(), t.n_items());
        for budget in [0usize, 1, 7, 150, 300, 1000] {
            let mut part = SortScratch::default();
            t.counting_sort_partial(q, budget, &mut part);
            let mut out = Vec::new();
            t.emit_ranked(&part, budget, &mut out);
            assert_eq!(out[..], all[..budget.min(all.len())], "budget {budget}");
        }
    }

    #[test]
    fn batch_sort_matches_single_query_sorts() {
        let codes: Vec<u64> = (0..250).map(|i| i * 0x9E3779B9 % 1024).collect();
        let t = BucketTable::build(&codes, None, 10);
        let qs = [0u64, 0x3FF, 0x155, 0x2AA, 0x123];
        for budget in [3usize, 40, usize::MAX] {
            let mut batch: Vec<SortScratch> = vec![SortScratch::default(); qs.len()];
            t.counting_sort_batch(&qs, budget, &mut batch);
            for (q, b) in qs.iter().zip(&batch) {
                let mut single = SortScratch::default();
                t.counting_sort_partial(*q, budget, &mut single);
                assert_eq!(b.levels, single.levels, "q {q:#x}");
                assert_eq!(b.floor, single.floor, "q {q:#x}");
                for l in single.floor as usize..=10 {
                    let (lo, hi) = (single.levels[l] as usize, single.levels[l + 1] as usize);
                    assert_eq!(b.order[lo..hi], single.order[lo..hi], "q {q:#x} level {l}");
                }
            }
        }
    }

    #[test]
    fn batch_sort_on_empty_table_and_empty_batch() {
        let t = BucketTable::build(&[] as &[u64], None, 8);
        let mut scratches = vec![SortScratch::default()];
        t.counting_sort_batch(&[0u64], 10, &mut scratches);
        let mut out = Vec::new();
        t.emit_ranked(&scratches[0], 10, &mut out);
        assert!(out.is_empty());
        let t = BucketTable::build(&[1u64, 2, 3], None, 8);
        t.counting_sort_batch(&[] as &[u64], 10, &mut []);
    }

    #[test]
    fn table_prober_resumes_the_ranked_stream() {
        let codes: Vec<u64> = (0..300).map(|i| i.wrapping_mul(0x2545F491) % 2048).collect();
        let t = BucketTable::build(&codes, None, 11);
        let q = 0x3C7u64;
        let mut full = SortScratch::default();
        t.counting_sort_by_matches(q, &mut full);
        let mut all = Vec::new();
        t.emit_ranked(&full, usize::MAX, &mut all);
        assert_eq!(all.len(), 300);
        for (b1, b2) in [(0usize, 5usize), (1, 1), (1, 299), (7, 300), (150, 150), (300, 10)] {
            let mut out = Vec::new();
            let mut p = t.prober(q);
            assert_eq!(p.extend(b1, &mut out), b1.min(all.len()));
            p.extend(b2, &mut out);
            assert_eq!(out[..], all[..(b1 + b2).min(all.len())], "b1={b1} b2={b2}");
        }
        // Exhaustion: one short emission, then zeros forever.
        let mut p = t.prober(q);
        let mut out = Vec::new();
        assert_eq!(p.extend(295, &mut out), 295);
        assert!(!p.is_exhausted());
        assert_eq!(p.extend(100, &mut out), 5);
        assert!(p.is_exhausted());
        assert_eq!(p.extend(100, &mut out), 0);
        assert_eq!(out, all);
        // extend(0) on a fresh session does no sorting work at all.
        let mut p = t.prober(q);
        assert_eq!(p.extend(0, &mut out), 0);
        assert_eq!(p.stats(), ProbeStats::default());
    }

    #[test]
    fn scalar_mask_agrees_with_codeword_mask() {
        for bits in [1usize, 7, 32, 63, 64] {
            assert_eq!(<u64 as CodeWord>::mask(bits), mask_bits(bits));
        }
    }
}
