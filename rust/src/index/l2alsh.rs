//! L2-ALSH index (paper §2.2) — the asymmetric-transform baseline.
//!
//! Items are transformed with Eq. 5 and hashed with `K` Eq. 2 floor
//! hashes; buckets are keyed by the integer hash vector. Multi-probing
//! ranks buckets by the number of hash values matching the query's —
//! the integer-hash analogue of Hamming ranking.
//!
//! Code-length accounting: following the paper's experiment code, the
//! fairness convention is one floor-hash per bit of code budget
//! (`K = L`). Each floor hash carries at least as much information as a
//! sign bit, so this convention never *under*-equips the baseline.

use crate::data::Dataset;
use crate::hash::L2Hash;
use crate::index::traits::drain_bucket;
use crate::index::{IndexStats, MipsIndex, ProbeStats, Prober, SingleProbe};
use crate::transform::L2AlshTransform;
use crate::{ItemId, Result};

/// Parameters for [`L2AlshIndex`]. Paper-recommended: `m=3, U=0.83, r=2.5`.
#[derive(Debug, Clone, Copy)]
pub struct L2AlshParams {
    /// Number of floor hashes `K` (= total code bits, see module docs).
    pub k: usize,
    /// Eq. 5 norm powers `m`.
    pub m: usize,
    /// Eq. 5 scaling target `U`.
    pub u: f32,
    /// Eq. 2 bucket width `r`.
    pub r: f32,
    pub seed: u64,
}

impl L2AlshParams {
    /// Paper §4 configuration with code budget `k`.
    pub fn recommended(k: usize) -> Self {
        Self { k, m: 3, u: 0.83, r: 2.5, seed: 0xA15E }
    }
}

struct Bucket {
    key: Box<[i32]>,
    items: Vec<ItemId>,
}

/// A built L2-ALSH index (one table).
pub struct L2AlshIndex {
    buckets: Vec<Bucket>,
    hash: L2Hash,
    transform: L2AlshTransform,
    params: L2AlshParams,
    n_items: usize,
}

impl L2AlshIndex {
    pub fn build(dataset: &Dataset, params: L2AlshParams) -> Result<Self> {
        Self::build_with_max_norm(dataset, None, params, dataset.max_norm())
    }

    /// Build over a subset (`ids = None` means all items) with an explicit
    /// normalisation base — the hook the §5 ranged variant uses to pass
    /// the *local* max norm.
    pub fn build_with_max_norm(
        dataset: &Dataset,
        ids: Option<&[ItemId]>,
        params: L2AlshParams,
        max_norm: f32,
    ) -> Result<Self> {
        anyhow::ensure!(params.k >= 1, "need at least one hash");
        anyhow::ensure!(max_norm > 0.0, "max norm must be positive");
        let transform = L2AlshTransform::new(params.m, params.u);
        let dim_in = transform.dim_out(dataset.dim());
        let hash = L2Hash::new(dim_in, params.k, params.r, params.seed);

        let owned_ids: Vec<ItemId> = match ids {
            Some(ids) => ids.to_vec(),
            None => (0..dataset.len() as ItemId).collect(),
        };
        let keys: Vec<Box<[i32]>> = crate::util::par::par_map(owned_ids.len(), |i| {
            let id = owned_ids[i];
            let (mut tbuf, mut hbuf) = (Vec::new(), Vec::new());
            transform.transform_item(dataset.row(id as usize), max_norm, &mut tbuf);
            hash.hash(&tbuf, &mut hbuf);
            hbuf.into_boxed_slice()
        });

        let mut map: crate::util::fxhash::FxHashMap<Box<[i32]>, Vec<ItemId>> = Default::default();
        for (key, &id) in keys.into_iter().zip(&owned_ids) {
            map.entry(key).or_default().push(id);
        }
        let buckets = map
            .into_iter()
            .map(|(key, items)| Bucket { key, items })
            .collect();
        Ok(Self {
            buckets,
            hash,
            transform,
            params,
            n_items: owned_ids.len(),
        })
    }

    /// Query-side hash vector (Eq. 5 `Q(q)` + Eq. 2).
    pub fn hash_query(&self, query: &[f32], out: &mut Vec<i32>) {
        let mut t = Vec::new();
        self.transform.transform_query(query, &mut t);
        self.hash.hash(&t, out);
    }

    /// Group buckets by match count against `qhash`; `groups[l]` holds
    /// bucket indexes with exactly `l` matching hash values.
    fn group_by_matches(&self, qhash: &[i32]) -> Vec<Vec<usize>> {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.params.k + 1];
        for (bi, b) in self.buckets.iter().enumerate() {
            groups[L2Hash::matches(&b.key, qhash)].push(bi);
        }
        groups
    }

    /// Probe with a precomputed query hash, best match count first.
    pub fn probe_with_hash(&self, qhash: &[i32], budget: usize, out: &mut Vec<ItemId>) {
        let groups = self.group_by_matches(qhash);
        let mut remaining = budget;
        for l in (0..groups.len()).rev() {
            for &bi in &groups[l] {
                if remaining == 0 {
                    return;
                }
                let items = &self.buckets[bi].items;
                let take = items.len().min(remaining);
                out.extend_from_slice(&items[..take]);
                remaining -= take;
            }
        }
    }

    pub fn params(&self) -> &L2AlshParams {
        &self.params
    }

    /// Visit every bucket `(key, items)` — the §5 ranged variant regroups
    /// buckets across ranges through this.
    pub fn for_each_bucket(&self, mut f: impl FnMut(&[i32], &[ItemId])) {
        for b in &self.buckets {
            f(&b.key, &b.items);
        }
    }
}

/// Resumable L2-ALSH probe session: the query hash vector and the
/// per-match-count bucket grouping are computed once at open; `extend`
/// walks the ranked groups (best match count first) from a
/// `(level, bucket, item)` cursor.
struct L2Prober<'a> {
    index: &'a L2AlshIndex,
    groups: Vec<Vec<usize>>,
    /// Current match count, walking from `k` down to 0.
    level: usize,
    bucket: usize,
    item: usize,
    stats: ProbeStats,
    done: bool,
}

impl Prober for L2Prober<'_> {
    // staticcheck: allow(panic-reach, "bucket < groups[level].len() is the loop guard and level follows the finite per-level schedule")
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        if additional_budget == 0 || self.done {
            return 0;
        }
        let index = self.index;
        let mut remaining = additional_budget;
        loop {
            while self.bucket < self.groups[self.level].len() {
                let bi = self.groups[self.level][self.bucket];
                let finished = drain_bucket(
                    &index.buckets[bi].items,
                    &mut self.item,
                    &mut remaining,
                    out,
                    &mut self.stats,
                );
                if finished {
                    self.bucket += 1;
                }
                if remaining == 0 {
                    self.stats.items_emitted += additional_budget;
                    return additional_budget;
                }
            }
            self.bucket = 0;
            if self.level == 0 {
                self.done = true;
                break;
            }
            self.level -= 1;
        }
        let emitted = additional_budget - remaining;
        self.stats.items_emitted += emitted;
        emitted
    }

    fn is_exhausted(&self) -> bool {
        self.done
    }

    fn stats(&self) -> ProbeStats {
        self.stats
    }
}

impl MipsIndex for L2AlshIndex {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        let mut qhash = Vec::new();
        self.hash_query(query, &mut qhash);
        self.probe_with_hash(&qhash, budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        let mut qhash = Vec::new();
        self.hash_query(query, &mut qhash);
        Box::new(L2Prober {
            index: self,
            groups: self.group_by_matches(&qhash),
            level: self.params.k,
            bucket: 0,
            item: 0,
            stats: ProbeStats::default(),
            done: false,
        })
    }

    fn len(&self) -> usize {
        self.n_items
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.n_items,
            n_buckets: self.buckets.len(),
            largest_bucket: self.buckets.iter().map(|b| b.items.len()).max().unwrap_or(0),
            hash_bits: self.params.k,
            n_partitions: 1,
        }
    }
}

impl SingleProbe for L2AlshIndex {
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>) {
        let mut qhash = Vec::new();
        self.hash_query(query, &mut qhash);
        for b in &self.buckets {
            if *b.key == *qhash.as_slice() {
                out.extend_from_slice(&b.items);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn probe_is_exhaustive_and_unique() {
        let d = synthetic::mf_embeddings(300, 8, 4, 0);
        let idx = L2AlshIndex::build(&d, L2AlshParams::recommended(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 1);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), d.len());
    }

    #[test]
    fn probe_order_is_nonincreasing_match_count() {
        let d = synthetic::mf_embeddings(200, 8, 4, 1);
        let idx = L2AlshIndex::build(&d, L2AlshParams::recommended(8)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 2);
        let mut qhash = Vec::new();
        idx.hash_query(q.row(0), &mut qhash);
        let mut out = Vec::new();
        idx.probe_with_hash(&qhash, usize::MAX, &mut out);
        // Recover per-item match counts.
        let mut rank = std::collections::HashMap::new();
        for b in &idx.buckets {
            let l = L2Hash::matches(&b.key, &qhash);
            for &id in &b.items {
                rank.insert(id, l);
            }
        }
        let mut prev = usize::MAX;
        for id in out {
            assert!(rank[&id] <= prev);
            prev = rank[&id];
        }
    }

    #[test]
    fn budget_respected() {
        let d = synthetic::mf_embeddings(100, 8, 4, 2);
        let idx = L2AlshIndex::build(&d, L2AlshParams::recommended(8)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 3);
        let mut out = Vec::new();
        idx.probe(q.row(0), 13, &mut out);
        assert_eq!(out.len(), 13);
    }

    #[test]
    fn subset_build_uses_given_ids() {
        let d = synthetic::mf_embeddings(50, 8, 4, 3);
        let ids: Vec<ItemId> = vec![5, 10, 15];
        let idx =
            L2AlshIndex::build_with_max_norm(&d, Some(&ids), L2AlshParams::recommended(8), 2.0)
                .unwrap();
        assert_eq!(idx.len(), 3);
        let q = synthetic::gaussian_queries(1, 8, 4);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        out.sort_unstable();
        assert_eq!(out, ids);
    }
}
