//! The paper's §3.3 similarity metric: the query-independent sorted
//! `(U_j, l)` structure that defines a probing order over buckets from
//! *different* sub-datasets.
//!
//! For a bucket in range `j` sharing `l` of `L` bits with the query, the
//! estimated inner product is (Eq. 12, with the ε adjustment):
//!
//! `ŝ(j, l) = U_j * cos( π (1-ε) (1 - l/L) )`
//!
//! The ε > 0 term keeps `ŝ` positive down to `l ≈ L[1/2 - ε/(2(1-ε))]`,
//! leaving "room to accommodate the randomness in hashing" — without it, a
//! large-`U_j` bucket that drew an unlucky code (`l < L/2`) would be probed
//! almost last. The structure has `m(L+1)` entries, is sorted once at index
//! build, and is shared by all queries — §3.3's complexity argument.

/// Estimated inner product for a bucket with `l` of `l_bits` matching bits
/// in a range with local max norm `u_j` (Eq. 12 + ε adjustment).
pub fn s_hat(u_j: f32, l: u32, l_bits: usize, epsilon: f32) -> f32 {
    debug_assert!(l as usize <= l_bits);
    let frac = 1.0 - l as f32 / l_bits as f32;
    u_j * (std::f32::consts::PI * (1.0 - epsilon) * frac).cos()
}

/// The pre-sorted `(range, l)` probing schedule.
#[derive(Debug, Clone)]
pub struct MetricOrder {
    /// `(range index j, matching-bit count l)`, best `ŝ` first.
    entries: Vec<(u32, u32)>,
    /// `suffix_umax[p] = max_{i >= p} U_{j_i}` over `entries` (one extra
    /// trailing `0.0` for the exhausted position) — the schedule is
    /// ordered by `ŝ`, not by `U_j`, so a plain "current entry's `U_j`"
    /// would understate what later entries can still deliver.
    suffix_umax: Vec<f32>,
    l_bits: usize,
    epsilon: f32,
}

impl MetricOrder {
    /// Build from the per-range local max norms. Done once at index build
    /// (§3.3: "the sorted structure is common for all queries"). The
    /// `ŝ` sort keys are computed once per entry — m(L+1) cosines — and
    /// the sort compares cached floats, instead of re-evaluating Eq. 12
    /// inside the comparator (O(mL log(mL)) cosine calls).
    // staticcheck: allow(panic-reach, "j enumerates 0..u_maxes.len(), so the key computation indexes in bounds")
    pub fn build(u_maxes: &[f32], l_bits: usize, epsilon: f32) -> Self {
        assert!(l_bits >= 1);
        assert!((0.0..1.0).contains(&epsilon), "epsilon must be in [0,1)");
        let mut keyed: Vec<(f32, u32, u32)> = (0..u_maxes.len() as u32)
            .flat_map(|j| {
                (0..=l_bits as u32).map(move |l| (s_hat(u_maxes[j as usize], l, l_bits, epsilon), j, l))
            })
            .collect();
        // Same total order as comparing s_hat directly: key desc, then
        // range asc, then match count desc.
        keyed.sort_by(|&(sa, ja, la), &(sb, jb, lb)| {
            sb.total_cmp(&sa).then(ja.cmp(&jb)).then(lb.cmp(&la))
        });
        let entries: Vec<(u32, u32)> = keyed.into_iter().map(|(_, j, l)| (j, l)).collect();
        let mut suffix_umax = vec![0.0f32; entries.len() + 1];
        for (i, &(j, _)) in entries.iter().enumerate().rev() {
            suffix_umax[i] = u_maxes[j as usize].max(suffix_umax[i + 1]);
        }
        Self { entries, suffix_umax, l_bits, epsilon }
    }

    /// The probing schedule, best estimated inner product first.
    pub fn entries(&self) -> &[(u32, u32)] {
        &self.entries
    }

    /// Upper bound on the 2-norm of any item in a bucket at schedule
    /// position `pos` or later — the suffix maximum of `U_j`, precomputed
    /// at build. Positions at or past the end return `0.0` (nothing
    /// remains). The streaming re-rank's whole-query early-out compares
    /// `‖q‖ · remaining_u_max(cursor)` against its kth exact score
    /// (`q·x ≤ ‖q‖·‖x‖ ≤ ‖q‖·U_j` for every `x` still unemitted).
    pub fn remaining_u_max(&self, pos: usize) -> f32 {
        self.suffix_umax.get(pos).copied().unwrap_or(0.0)
    }

    pub fn l_bits(&self) -> usize {
        self.l_bits
    }

    pub fn epsilon(&self) -> f32 {
        self.epsilon
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_hat_monotone_in_l() {
        // More matching bits ⇒ higher estimate, for fixed U_j.
        let mut prev = f32::MIN;
        for l in 0..=16 {
            let s = s_hat(1.0, l, 16, 0.1);
            assert!(s > prev, "not monotone at l={l}");
            prev = s;
        }
    }

    #[test]
    fn s_hat_scales_with_u_when_positive() {
        // For l > L/2 the cos is positive, so bigger U_j ⇒ bigger ŝ (§3.3).
        let (l, bits) = (14, 16);
        assert!(s_hat(2.0, l, bits, 0.0) > s_hat(1.0, l, bits, 0.0));
        // ... and for very small l the relation flips (cos < 0).
        assert!(s_hat(2.0, 0, bits, 0.0) < s_hat(1.0, 0, bits, 0.0));
    }

    #[test]
    fn epsilon_extends_the_positive_region() {
        // Paper: with ε, cos(..) < 0 only when l < L[1/2 - ε/(2(1-ε))].
        let bits = 64usize;
        let eps = 0.2f32;
        let threshold = bits as f32 * (0.5 - eps / (2.0 * (1.0 - eps)));
        for l in 0..=bits as u32 {
            let s = s_hat(1.0, l, bits, eps);
            if (l as f32) > threshold + 0.5 {
                assert!(s > 0.0, "l={l} should be positive");
            }
            if (l as f32) < threshold - 0.5 {
                assert!(s < 0.0, "l={l} should be negative");
            }
        }
    }

    #[test]
    fn order_is_sorted_by_s_hat() {
        let us = [0.4f32, 1.0, 0.75];
        let order = MetricOrder::build(&us, 16, 0.1);
        assert_eq!(order.len(), 3 * 17);
        let vals: Vec<f32> = order
            .entries()
            .iter()
            .map(|&(j, l)| s_hat(us[j as usize], l, 16, 0.1))
            .collect();
        for w in vals.windows(2) {
            assert!(w[0] >= w[1], "schedule not descending: {} < {}", w[0], w[1]);
        }
    }

    #[test]
    fn exact_match_in_largest_range_comes_first() {
        let us = [0.3f32, 0.9, 0.6];
        let order = MetricOrder::build(&us, 16, 0.1);
        assert_eq!(order.entries()[0], (1, 16));
    }

    #[test]
    fn interleaving_beats_per_range_exhaustion() {
        // The whole point of Eq. 12: a strong partial match in a big-norm
        // range outranks an exact match in a tiny-norm range.
        let us = [0.05f32, 1.0];
        let order = MetricOrder::build(&us, 16, 0.1);
        let pos_exact_small = order.entries().iter().position(|&e| e == (0, 16)).unwrap();
        let pos_partial_big = order.entries().iter().position(|&e| e == (1, 12)).unwrap();
        assert!(
            pos_partial_big < pos_exact_small,
            "l=12 in U=1.0 range must precede exact match in U=0.05 range"
        );
    }

    #[test]
    fn remaining_u_max_is_the_suffix_maximum() {
        let us = [0.4f32, 1.0, 0.75];
        let order = MetricOrder::build(&us, 8, 0.1);
        let entries = order.entries();
        for p in 0..=entries.len() {
            let want = entries[p..]
                .iter()
                .map(|&(j, _)| us[j as usize])
                .fold(0.0f32, f32::max);
            assert_eq!(order.remaining_u_max(p), want, "position {p}");
            if p > 0 {
                assert!(
                    order.remaining_u_max(p - 1) >= order.remaining_u_max(p),
                    "suffix maxima must be non-increasing"
                );
            }
        }
        assert_eq!(order.remaining_u_max(0), 1.0, "head bound is the global max U_j");
        assert_eq!(order.remaining_u_max(entries.len()), 0.0, "exhausted bound");
        assert_eq!(order.remaining_u_max(entries.len() + 5), 0.0, "past-the-end bound");
    }

    #[test]
    fn single_range_degenerates_to_hamming_order() {
        // With one range, the schedule must be l = L, L-1, ..., 0 — i.e.
        // plain Hamming ranking (RANGE-LSH == SIMPLE-LSH when m=1).
        let order = MetricOrder::build(&[1.0], 8, 0.1);
        let ls: Vec<u32> = order.entries().iter().map(|&(_, l)| l).collect();
        assert_eq!(ls, (0..=8).rev().collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn rejects_epsilon_one() {
        MetricOrder::build(&[1.0], 8, 1.0);
    }
}
