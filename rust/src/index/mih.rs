//! Multi-index Hamming (MIH) probing: sub-linear candidate generation
//! for wide codes (Norouzi, Punjani & Fleet, "Fast Search in Hamming
//! Space with Multi-Index Hashing").
//!
//! The counting sort in [`bucket`](crate::index::bucket) popcounts every
//! bucket of a table per query — O(#buckets) regardless of budget. At
//! L ∈ {128, 256} that dense scan dominates query time. MIH splits each
//! bucket code into 16-bit chunks and builds one inverted table per
//! chunk: a radius-`r` probe around the query's chunk values touches only
//! the buckets whose *some* chunk lies within `r` flips of the query's,
//! and by the pigeonhole principle a code at full Hamming distance `d`
//! has at least one chunk within `floor(d / n_chunks)` of the query — so
//! after probing all chunks at chunk-radius `r`, every bucket at full
//! distance `<= n_chunks * (r + 1) - 1` has been discovered and those
//! distance levels are *complete*. Discovered buckets are verified by one
//! full popcount each, grouped by match count, and materialized into the
//! same [`SortScratch`] level slices the counting sort produces — so the
//! budget walkers ([`TableProber`](crate::index::bucket::TableProber),
//! [`RangeProber`](crate::index::range::RangeProber)), `emit_ranked`, and
//! the streaming re-rank run unchanged on either backend.
//!
//! Tie-order contract (pinned, property-tested): the emitted candidate
//! stream is *element-for-element identical* to the counting sort's —
//! levels descend by match count, buckets within a level ascend by dense
//! bucket index (MIH sorts each finalized level's discovery list), items
//! within a bucket keep arena (build) order.
//!
//! Chunk tables are CSR: one `offsets` array spanning all chunks
//! (`n_chunks * 2^16 + 1` slice bounds) plus a dense `values` array of
//! bucket indices (`n_chunks * n_buckets` entries — every bucket appears
//! once per chunk). Built once at index-build time next to the
//! [`BucketTable`]; persisted as an optional `.rlsh` v2 section.

use std::marker::PhantomData;

use anyhow::{ensure, Result};

use crate::hash::{CodeChunks, CodeWord};
use crate::index::bucket::{BucketTable, SortScratch};

/// Width of one MIH chunk in bits. 16 bits ⇒ 2^16 buckets per chunk
/// table, small enough that a dense CSR `offsets` array (256 KiB per
/// chunk) beats any hash lookup on the probe path.
pub const CHUNK_BITS: usize = 16;

/// Dense buckets per chunk table (`2^CHUNK_BITS`).
const CHUNK_BUCKETS: usize = 1 << CHUNK_BITS;

/// Number of 16-bit chunks covering a `bits`-bit code. The last chunk is
/// partial when `16 ∤ bits` (e.g. 251 hash bits → 16 chunks, last 11
/// bits wide).
pub fn n_chunks(bits: usize) -> usize {
    bits.div_ceil(CHUNK_BITS)
}

/// Width in bits of chunk `k` of a `bits`-bit code.
#[inline]
fn chunk_width(bits: usize, k: usize) -> usize {
    CHUNK_BITS.min(bits - k * CHUNK_BITS)
}

/// Per-chunk inverted bucket tables for one [`BucketTable`], CSR layout.
///
/// Chunk `k`'s bucket `v` owns the dense-bucket-index list
/// `values[offsets[k * 2^16 + v] .. offsets[k * 2^16 + v + 1]]`,
/// ascending (the build scans buckets in ascending order).
#[derive(Debug, Clone)]
pub struct MihTable<C: CodeWord> {
    /// Hash bits of the backing table (codes are pre-masked to this).
    bits: usize,
    /// `n_chunks(bits)`, cached.
    n_chunks: usize,
    /// CSR slice bounds, `n_chunks * 2^16 + 1` entries.
    offsets: Box<[u32]>,
    /// Dense bucket indices, `n_chunks * n_buckets` entries.
    values: Box<[u32]>,
    _code: PhantomData<C>,
}

impl<C: CodeWord> MihTable<C> {
    /// Build the chunk tables for `table` (one histogram + placement pass
    /// over its bucket codes, like the item-arena build itself).
    // staticcheck: allow(panic-reach, "CSR offsets are sized nc*CHUNK_BUCKETS+1 and chunk(k) < CHUNK_BUCKETS by construction")
    pub fn build(table: &BucketTable<C>) -> Self {
        let bits = table.bits();
        let nc = n_chunks(bits);
        let nb = table.n_buckets();
        assert!(nc * nb <= u32::MAX as usize, "MIH table too large for u32 CSR");
        let mut offsets = vec![0u32; nc * CHUNK_BUCKETS + 1].into_boxed_slice();
        // Pass 1: histogram each bucket code's chunks (shifted by one for
        // the prefix sum below).
        for b in 0..nb {
            let code = table.bucket_code(b);
            for k in 0..nc {
                offsets[k * CHUNK_BUCKETS + code.chunk(k) as usize + 1] += 1;
            }
        }
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        // Pass 2: place bucket indices through per-list cursors. Buckets
        // ascend, so every CSR list ends up sorted ascending.
        let mut cursor: Vec<u32> = offsets[..offsets.len() - 1].to_vec();
        let mut values = vec![0u32; nc * nb].into_boxed_slice();
        for b in 0..nb {
            let code = table.bucket_code(b);
            for k in 0..nc {
                let c = &mut cursor[k * CHUNK_BUCKETS + code.chunk(k) as usize];
                values[*c as usize] = b as u32;
                *c += 1;
            }
        }
        Self { bits, n_chunks: nc, offsets, values, _code: PhantomData }
    }

    /// Reassemble from persisted parts, validating the CSR structure
    /// against the freshly rebuilt `table` — a corrupt section yields a
    /// clear error here instead of an out-of-bounds panic on the first
    /// probe.
    // staticcheck: allow(panic-reach, "last().unwrap() follows the ensure! that offsets has nc*CHUNK_BUCKETS + 1 >= 1 entries; all other access is behind the validation chain")
    pub fn from_parts(
        bits: usize,
        offsets: Vec<u32>,
        values: Vec<u32>,
        table: &BucketTable<C>,
    ) -> Result<Self> {
        let nc = n_chunks(bits);
        let nb = table.n_buckets();
        ensure!(bits == table.bits(), "MIH section bits {bits} != table bits {}", table.bits());
        ensure!(
            offsets.len() == nc * CHUNK_BUCKETS + 1,
            "MIH offsets length {} != {} ({nc} chunks)",
            offsets.len(),
            nc * CHUNK_BUCKETS + 1
        );
        ensure!(
            values.len() == nc * nb,
            "MIH values length {} != n_chunks {nc} * n_buckets {nb}",
            values.len()
        );
        ensure!(offsets[0] == 0, "MIH offsets must start at 0");
        ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "MIH offsets are not non-decreasing (corrupt section?)"
        );
        ensure!(
            *offsets.last().unwrap() as usize == values.len(),
            "MIH offsets end {} != values length {}",
            offsets.last().unwrap(),
            values.len()
        );
        ensure!(
            values.iter().all(|&v| (v as usize) < nb),
            "MIH values reference buckets past the table's {nb}"
        );
        Ok(Self {
            bits,
            n_chunks: nc,
            offsets: offsets.into_boxed_slice(),
            values: values.into_boxed_slice(),
            _code: PhantomData,
        })
    }

    /// Hash bits of the backing table.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// CSR slice bounds (persistence).
    pub(crate) fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// CSR bucket-index lists (persistence).
    pub(crate) fn values(&self) -> &[u32] {
        &self.values
    }

    /// Budget-adaptive MIH ranking: fill `scratch` with exactly the level
    /// slices [`BucketTable::counting_sort_partial`] would produce — same
    /// materialization-floor rule, same within-level bucket-ascending
    /// order — but popcount only the buckets discovered by walking
    /// Hamming balls around the query's chunks in increasing radius.
    ///
    /// Returns the number of buckets popcounted (the MIH analogue of the
    /// counting sort's full `n_buckets` scan, for `buckets_scanned`
    /// stats): sub-linear whenever the budget is covered by near levels.
    // staticcheck: allow(panic-reach, "CSR offset/value bounds are validated at build/from_parts; popcount levels are <= bits with levels sized bits + 2")
    pub fn rank_partial(
        &self,
        table: &BucketTable<C>,
        qcode: C,
        budget: usize,
        scratch: &mut SortScratch,
    ) -> usize {
        let bits = self.bits;
        debug_assert_eq!(bits, table.bits(), "MIH table built for a different bit width");
        let n = table.n_buckets();
        let nc = self.n_chunks;
        let q = qcode.masked(bits);

        let SortScratch { order, levels, floor, sorted_budget, mih: ms, .. } = scratch;
        *sorted_budget = budget;
        levels.clear();
        levels.resize(bits + 2, 0);
        ms.reset(n, bits);

        // The floor rule must match the counting sort's: floor 0 (full
        // materialization) when the budget covers the table, else the
        // highest level at which the best-first cumulative item count
        // reaches the budget. Levels become *complete* (all their buckets
        // discovered) in descending order as the chunk radius grows, so
        // the cumulative walk can run incrementally over complete levels.
        let mut cut: Option<usize> = if budget >= table.n_items() { Some(0) } else { None };
        let mut complete_l = bits + 1;
        let mut cum = 0usize;
        let mut found = 0usize;

        // Chunk 0 is the widest (`min(16, bits)` bits), so every bucket's
        // chunk 0 lies within that many flips of the query's — the radius
        // loop always terminates with every bucket discovered.
        for r in 0..=CHUNK_BITS.min(bits) {
            if found < n {
                for k in 0..nc {
                    let wk = chunk_width(bits, k);
                    if r > wk {
                        continue;
                    }
                    let qc = q.chunk(k);
                    let base = k * CHUNK_BUCKETS;
                    for_each_flip_mask(wk as u32, r as u32, |mask| {
                        let v = (qc ^ mask) as usize;
                        let lo = self.offsets[base + v] as usize;
                        let hi = self.offsets[base + v + 1] as usize;
                        for &b in &self.values[lo..hi] {
                            if ms.test_and_set(b) {
                                continue;
                            }
                            // New bucket: verify true distance by one
                            // full popcount, group by match count.
                            let l = table.bucket_code(b as usize).matches(q, bits) as usize;
                            ms.pending[l].push(b);
                            ms.item_hist[l] += table.bucket_items(b as usize).len() as u32;
                            found += 1;
                        }
                    });
                }
            }
            // Pigeonhole: after chunk-radius r, full distances up to
            // `nc * (r + 1) - 1` are complete, i.e. match counts down to
            // `bits - (nc * (r + 1) - 1)`.
            let ball = nc * (r + 1) - 1;
            let new_complete = if found == n || ball >= bits { 0 } else { bits - ball };
            while complete_l > new_complete {
                complete_l -= 1;
                if cut.is_none() {
                    cum += ms.item_hist[complete_l] as usize;
                    if cum >= budget {
                        cut = Some(complete_l);
                    }
                }
            }
            if let Some(f) = cut {
                if complete_l <= f {
                    break;
                }
            }
        }
        debug_assert!(cut.is_some(), "radius loop ended without covering the budget");
        let cut = cut.unwrap_or(0);
        *floor = cut as u32;

        // Materialize levels `cut..=bits`: ascending level start offsets
        // into `order`, each level's buckets sorted ascending (discovery
        // order across chunks and rounds is arbitrary). Levels below the
        // floor keep zeroed bounds — walkers never read them, and a
        // below-floor resume re-sorts to full depth via the counting
        // sort, which reproduces every materialized slice bit-for-bit.
        let total: usize = (cut..=bits).map(|l| ms.pending[l].len()).sum();
        if order.len() < total {
            order.resize(total, 0);
        }
        let mut pos = 0u32;
        for l in cut..=bits {
            levels[l] = pos;
            let pending = &mut ms.pending[l];
            pending.sort_unstable();
            for &b in pending.iter() {
                order[pos as usize] = b;
                pos += 1;
            }
        }
        levels[bits + 1] = pos;
        found
    }
}

/// Reusable per-query buffers for [`MihTable::rank_partial`], embedded in
/// [`SortScratch`] so every existing scratch pool (single-table, per-range,
/// batch) carries MIH capability without new plumbing.
#[derive(Debug, Default, Clone)]
pub struct MihScratch {
    /// Seen-bitmap over dense bucket indices (one bit per bucket).
    seen: Vec<u64>,
    /// Discovered buckets grouped by match count, finalized (sorted and
    /// placed) once their level is pigeonhole-complete.
    pending: Vec<Vec<u32>>,
    /// Items per match count among discovered buckets — the histogram
    /// that decides the materialization floor.
    item_hist: Vec<u32>,
}

impl MihScratch {
    /// Empty scratch, usable in `const` thread-local initialisers.
    pub const fn new() -> Self {
        Self { seen: Vec::new(), pending: Vec::new(), item_hist: Vec::new() }
    }

    /// Prepare for a query over `n` buckets and `bits + 1` match levels;
    /// clears state, reuses buffers.
    fn reset(&mut self, n: usize, bits: usize) {
        self.seen.clear();
        self.seen.resize(n.div_ceil(64), 0);
        for p in self.pending.iter_mut() {
            p.clear();
        }
        if self.pending.len() < bits + 1 {
            self.pending.resize_with(bits + 1, Vec::new);
        }
        self.item_hist.clear();
        self.item_hist.resize(bits + 1, 0);
    }

    /// Mark bucket `b` seen; returns whether it already was.
    #[inline]
    // staticcheck: allow(panic-reach, "reset() sizes the seen bitmap to cover every bucket index; b comes from CSR values validated against n_buckets")
    fn test_and_set(&mut self, b: u32) -> bool {
        let w = (b >> 6) as usize;
        let bit = 1u64 << (b & 63);
        let seen = self.seen[w] & bit != 0;
        self.seen[w] |= bit;
        seen
    }
}

/// Enumerate every `width`-bit mask with exactly `ones` set bits, in
/// increasing numeric order (Gosper's hack). `ones == 0` yields the
/// single zero mask; `ones > width` yields nothing.
fn for_each_flip_mask(width: u32, ones: u32, mut f: impl FnMut(u16)) {
    debug_assert!((1..=CHUNK_BITS as u32).contains(&width));
    if ones > width {
        return;
    }
    if ones == 0 {
        f(0);
        return;
    }
    // u32 arithmetic: the hack transiently overflows 16 bits at the last
    // mask (e.g. width 16, ones 16).
    let limit = 1u32 << width;
    let mut m = (1u32 << ones) - 1;
    while m < limit {
        f(m as u16);
        let c = m & m.wrapping_neg();
        let r = m + c;
        m = (((r ^ m) >> 2) / c) | r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::codes::{widen, Code128, Code256};

    fn table_from_codes<C: CodeWord>(codes: &[C], bits: usize) -> BucketTable<C> {
        BucketTable::build(codes, None, bits)
    }

    /// Oracle comparison: MIH rank + emit equals counting sort + emit,
    /// element for element, and the floors agree.
    fn assert_matches_counting_sort<C: CodeWord>(
        codes: &[C],
        q: C,
        bits: usize,
        budgets: &[usize],
    ) {
        let t = table_from_codes(codes, bits);
        let mih = MihTable::build(&t);
        for &budget in budgets {
            let mut cs = SortScratch::default();
            t.counting_sort_partial(q, budget, &mut cs);
            let mut ms = SortScratch::default();
            mih.rank_partial(&t, q, budget, &mut ms);
            assert_eq!(ms.floor, cs.floor, "floor, budget {budget}");
            let mut want = Vec::new();
            t.emit_ranked(&cs, budget, &mut want);
            let mut got = Vec::new();
            t.emit_ranked(&ms, budget, &mut got);
            assert_eq!(got, want, "budget {budget}");
        }
    }

    fn pseudo_codes<C: CodeWord>(n: u64, bits: usize) -> Vec<C> {
        (0..n)
            .map(|i| {
                let mut w = [0u64; 4];
                let mut s = i.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD1B54A32D192ED03);
                for word in w.iter_mut().take(C::WORDS) {
                    s ^= s >> 27;
                    s = s.wrapping_mul(0x2545F4914F6CDD1D);
                    *word = s;
                }
                C::from_words(&w[..C::WORDS]).masked(bits)
            })
            .collect()
    }

    #[test]
    fn flip_mask_enumeration_is_exhaustive() {
        for width in [1u32, 5, 11, 16] {
            for ones in 0..=width {
                let mut got = Vec::new();
                for_each_flip_mask(width, ones, |m| got.push(m));
                let want: Vec<u16> = (0..1u32 << width)
                    .filter(|v| v.count_ones() == ones)
                    .map(|v| v as u16)
                    .collect();
                assert_eq!(got, want, "width {width} ones {ones}");
            }
            // ones > width yields nothing.
            let mut got = Vec::new();
            for_each_flip_mask(width, width + 1, |m| got.push(m));
            assert!(got.is_empty());
        }
    }

    #[test]
    fn csr_build_round_trips_chunks() {
        // Every bucket must appear exactly once per chunk, in the CSR
        // list of its own chunk value — across all three widths and with
        // a partial last chunk.
        fn check<C: CodeWord>(bits: usize) {
            let codes = pseudo_codes::<C>(300, bits);
            let t = table_from_codes(&codes, bits);
            let mih = MihTable::build(&t);
            let nc = n_chunks(bits);
            assert_eq!(mih.values().len(), nc * t.n_buckets());
            for b in 0..t.n_buckets() {
                let code = t.bucket_code(b);
                for k in 0..nc {
                    let v = code.chunk(k) as usize;
                    let lo = mih.offsets()[k * CHUNK_BUCKETS + v] as usize;
                    let hi = mih.offsets()[k * CHUNK_BUCKETS + v + 1] as usize;
                    assert!(
                        mih.values()[lo..hi].binary_search(&(b as u32)).is_ok(),
                        "bucket {b} missing from chunk {k} list (bits {bits})"
                    );
                }
            }
        }
        check::<u64>(11);
        check::<u64>(64);
        check::<Code128>(123);
        check::<Code256>(251);
    }

    #[test]
    fn csr_lists_cover_empty_and_singleton_buckets() {
        // All items in one bucket: one bucket, one entry per chunk list.
        let t = table_from_codes(&[7u64, 7, 7, 7], 16);
        let mih = MihTable::build(&t);
        assert_eq!(t.n_buckets(), 1);
        assert_eq!(mih.values(), &[0u32]);
        let lo = mih.offsets()[7] as usize;
        let hi = mih.offsets()[8] as usize;
        assert_eq!(&mih.values()[lo..hi], &[0u32]);
        // Empty table: no values, all-zero offsets.
        let t = table_from_codes(&[] as &[u64], 16);
        let mih = MihTable::build(&t);
        assert!(mih.values().is_empty());
        assert!(mih.offsets().iter().all(|&o| o == 0));
        let mut s = SortScratch::default();
        assert_eq!(mih.rank_partial(&t, 0u64, 10, &mut s), 0);
        let mut out = Vec::new();
        t.emit_ranked(&s, 10, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn rank_matches_counting_sort_u64() {
        let codes = pseudo_codes::<u64>(400, 40);
        let n = codes.len();
        let q = 0xA5A5_5A5A_1234u64;
        assert_matches_counting_sort(&codes, q, 40, &[1, 7, n / 2, usize::MAX]);
    }

    #[test]
    fn rank_matches_counting_sort_wide() {
        let codes = pseudo_codes::<Code128>(300, 123);
        let q: Code128 = [0xDEAD_BEEF_0BAD_F00D, 0x0123_4567_89AB_CDEF];
        assert_matches_counting_sort(&codes, q.masked(123), 123, &[1, 7, 150, usize::MAX]);
        let codes = pseudo_codes::<Code256>(200, 251);
        let q: Code256 = [1, u64::MAX, 0x5555_5555_5555_5555, 42];
        assert_matches_counting_sort(&codes, q.masked(251), 251, &[1, 7, 100, usize::MAX]);
    }

    #[test]
    fn rank_matches_counting_sort_tiny_bits() {
        // bits < 16: a single partial chunk, radius loop bounded by bits.
        let codes: Vec<u64> = (0..200).map(|i| i * 0x9E3779B9 % (1 << 11)).collect();
        assert_matches_counting_sort(&codes, 0x3FFu64, 11, &[1, 7, 100, usize::MAX]);
    }

    #[test]
    fn rank_matches_counting_sort_widened_scalar() {
        // Zero-extended scalar codes: wide path agrees with itself and
        // with the scalar oracle through the shared emit order.
        let scalar = pseudo_codes::<u64>(250, 33);
        let wide: Vec<Code128> = scalar.iter().map(|&c| widen(c)).collect();
        let q = 0x1_2345_6789u64;
        assert_matches_counting_sort(&wide, widen(q), 33, &[1, 13, 125, usize::MAX]);
    }

    #[test]
    fn from_parts_validates_structure() {
        let codes = pseudo_codes::<u64>(100, 32);
        let t = table_from_codes(&codes, 32);
        let built = MihTable::build(&t);
        // Faithful parts round-trip.
        let ok = MihTable::from_parts(32, built.offsets().to_vec(), built.values().to_vec(), &t);
        assert!(ok.is_ok());
        // Wrong bits.
        let err = MihTable::from_parts(31, built.offsets().to_vec(), built.values().to_vec(), &t)
            .unwrap_err();
        assert!(format!("{err:#}").contains("bits"), "{err:#}");
        // Truncated offsets.
        let err =
            MihTable::from_parts(32, built.offsets()[..10].to_vec(), built.values().to_vec(), &t)
                .unwrap_err();
        assert!(format!("{err:#}").contains("offsets length"), "{err:#}");
        // Out-of-range bucket index.
        let mut values = built.values().to_vec();
        values[0] = t.n_buckets() as u32;
        let err = MihTable::from_parts(32, built.offsets().to_vec(), values, &t).unwrap_err();
        assert!(format!("{err:#}").contains("past the table"), "{err:#}");
        // Non-monotone offsets.
        let mut offsets = built.offsets().to_vec();
        let last = offsets.len() - 1;
        offsets.swap(1, last);
        let err = MihTable::from_parts(32, offsets, built.values().to_vec(), &t).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-decreasing") || msg.contains("start at 0"), "{msg}");
    }

    #[test]
    fn scanned_buckets_are_sublinear_on_small_budgets() {
        // A budget-1 probe with a matching bucket present must not touch
        // every bucket (the whole point of the backend).
        let mut codes = pseudo_codes::<Code256>(2000, 251);
        let q: Code256 = [3, 5, 7, 9];
        let q = q.masked(251);
        codes.push(q); // guarantee a radius-0 hit
        let t = table_from_codes(&codes, 251);
        let mih = MihTable::build(&t);
        let mut s = SortScratch::default();
        let scanned = mih.rank_partial(&t, q, 1, &mut s);
        assert!(scanned < t.n_buckets() / 2, "scanned {scanned} of {}", t.n_buckets());
        let mut out = Vec::new();
        t.emit_ranked(&s, 1, &mut out);
        assert_eq!(out.len(), 1);
    }
}
