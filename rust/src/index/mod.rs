//! MIPS indexes: the paper's RANGE-LSH plus every baseline it compares to.
//!
//! | Type | Paper section |
//! |---|---|
//! | [`simple::SimpleLshIndex`] | §2.3 (Neyshabur & Srebro's SIMPLE-LSH) |
//! | [`range::RangeLshIndex`] | §3 (the contribution: Alg. 1–2 + Eq. 12) |
//! | [`l2alsh::L2AlshIndex`] | §2.2 (Shrivastava & Li's L2-ALSH) |
//! | [`sign_alsh::SignAlshIndex`] | §1/§2.3 lineage (Shrivastava & Li's SIGN-ALSH) |
//! | [`ranged_l2alsh::RangedL2AlshIndex`] | §5 (partitioning applied to L2-ALSH) |
//! | [`multitable::MultiTable`] | supplementary (multi-table single-probe) |
//!
//! All indexes expose the same [`MipsIndex`] probing interface: given a
//! query and a probe budget, emit candidate item ids in the index's probing
//! order — one-shot via [`MipsIndex::probe`], or as a resumable session via
//! [`MipsIndex::prober`] ([`Prober::extend`] continues the walk without
//! rescanning). Recall curves (Fig. 2/3) are computed from that order by
//! [`crate::eval`].

pub mod bucket;
pub mod l2alsh;
pub mod metric;
pub mod mih;
pub mod multitable;
pub mod mutable;
pub mod partition;
pub mod persist;
pub mod range;
pub mod ranged_l2alsh;
pub mod sign_alsh;
pub mod simple;
mod traits;

pub use bucket::{BucketTable, SortScratch, TableProber};
pub use mih::MihTable;
pub use mutable::{TombstoneProber, Tombstones, TombstonedIndex};
pub use metric::MetricOrder;
pub use partition::{partition, Partition, PartitionScheme};
pub use persist::{load_any_range_index, load_range_index, save_range_index, AnyRangeLshIndex};
pub use range::RangeProber;
pub use traits::{
    BufferedProber, CodeProbe, IndexStats, MipsIndex, ProbeStats, Prober, SingleProbe,
};
