//! Multi-table single-probe protocol (paper supplementary): build `T`
//! independent tables (fresh projection per table) and probe only the
//! query's exact bucket in each — the classical LSH theory setting, as
//! opposed to the single-table multi-probe regime of Fig. 2.

use crate::data::Dataset;
use crate::hash::NativeHasher;
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
use crate::index::{BufferedProber, IndexStats, MipsIndex, Prober, SingleProbe};
use crate::{ItemId, Result};

/// `T` independent single-probe tables of any [`SingleProbe`] index type
/// — including the wide-code instantiations (`SimpleLshIndex<Code128>`
/// etc.); see the `wide_tables_compose` test.
pub struct MultiTable<T: SingleProbe> {
    tables: Vec<T>,
    n_items: usize,
}

impl<T: SingleProbe> MultiTable<T> {
    /// Build `t` tables via `build_one(table_seed)`.
    pub fn build_with(
        n_items: usize,
        t: usize,
        mut build_one: impl FnMut(u64) -> Result<T>,
    ) -> Result<Self> {
        anyhow::ensure!(t >= 1, "need at least one table");
        let tables = (0..t as u64)
            .map(|i| build_one(0x7AB1E ^ (i.wrapping_mul(0x9E3779B97F4A7C15))))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { tables, n_items })
    }

    pub fn n_tables(&self) -> usize {
        self.tables.len()
    }

    /// Union of the exact-bucket probes across tables, deduplicated,
    /// ordered by first table that surfaced each candidate.
    pub fn probe_union(&self, query: &[f32], out: &mut Vec<ItemId>) {
        let mut seen = std::collections::HashSet::new();
        let mut scratch = Vec::new();
        for table in &self.tables {
            scratch.clear();
            table.probe_exact(query, &mut scratch);
            for &id in &scratch {
                if seen.insert(id) {
                    out.push(id);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.n_items
    }

    pub fn is_empty(&self) -> bool {
        self.n_items == 0
    }
}

/// Multi-table SIMPLE-LSH (supplementary baseline).
pub fn simple_multitable(
    dataset: &Dataset,
    code_bits: usize,
    t: usize,
) -> Result<MultiTable<SimpleLshIndex>> {
    MultiTable::build_with(dataset.len(), t, |seed| {
        let hasher: NativeHasher = NativeHasher::new(dataset.dim(), code_bits.max(1), seed);
        SimpleLshIndex::build(dataset, &hasher, SimpleLshParams::new(code_bits))
    })
}

/// Multi-table RANGE-LSH (supplementary: the paper's method under the
/// classical multi-table protocol).
pub fn range_multitable(
    dataset: &Dataset,
    params: RangeLshParams,
    t: usize,
) -> Result<MultiTable<RangeLshIndex>> {
    MultiTable::build_with(dataset.len(), t, |seed| {
        let width = params.hash_bits().max(1);
        let hasher: NativeHasher = NativeHasher::new(dataset.dim(), width, seed);
        RangeLshIndex::build(dataset, &hasher, params)
    })
}

/// Adapter exposing a [`MultiTable`] through [`MipsIndex`] (budget applies
/// to the deduplicated union).
pub struct MultiTableIndex<T: SingleProbe>(pub MultiTable<T>);

impl<T: SingleProbe> MipsIndex for MultiTableIndex<T> {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        self.prober(query).extend(budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        // The union is not incremental (dedup needs every table's exact
        // bucket), so the session buffers it once and streams from the
        // cursor — the rank order is first-table-that-surfaced-it.
        let mut all = Vec::new();
        self.0.probe_union(query, &mut all);
        Box::new(BufferedProber::new(all))
    }

    fn len(&self) -> usize {
        self.0.len()
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.0.len(),
            n_buckets: 0,
            largest_bucket: 0,
            hash_bits: 0,
            n_partitions: self.0.n_tables(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn union_is_deduplicated() {
        let d = synthetic::longtail_sift(300, 8, 0);
        let mt = simple_multitable(&d, 8, 4).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 1);
        let mut out = Vec::new();
        mt.probe_union(q.row(0), &mut out);
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), out.len());
    }

    #[test]
    fn more_tables_never_shrink_the_candidate_set() {
        let d = synthetic::longtail_sift(500, 8, 1);
        let q = synthetic::gaussian_queries(8, 8, 2);
        let mut prev_total = 0usize;
        for t in [1usize, 4, 16] {
            let mt = simple_multitable(&d, 10, t).unwrap();
            let mut total = 0usize;
            for qi in 0..q.len() {
                let mut out = Vec::new();
                mt.probe_union(q.row(qi), &mut out);
                total += out.len();
            }
            assert!(
                total >= prev_total,
                "candidates shrank: {prev_total} -> {total} at T={t}"
            );
            prev_total = total;
        }
    }

    #[test]
    fn range_multitable_builds() {
        let d = synthetic::longtail_sift(300, 8, 2);
        let mt = range_multitable(&d, RangeLshParams::new(12, 8), 3).unwrap();
        assert_eq!(mt.n_tables(), 3);
        let q = synthetic::gaussian_queries(1, 8, 3);
        let mut out = Vec::new();
        mt.probe_union(q.row(0), &mut out);
        // sanity: ids in range
        assert!(out.iter().all(|&id| (id as usize) < d.len()));
    }

    #[test]
    fn wide_tables_compose() {
        // MultiTable is generic over the index type, so 128-bit tables
        // plug in through the same build_with hook.
        use crate::hash::{Code128, NativeHasher};
        use crate::index::simple::{SimpleLshIndex, SimpleLshParams};
        let d = synthetic::longtail_sift(300, 8, 9);
        let mt: MultiTable<SimpleLshIndex<Code128>> =
            MultiTable::build_with(d.len(), 3, |seed| {
                let h: NativeHasher<Code128> = NativeHasher::new(d.dim(), 96, seed);
                SimpleLshIndex::build(&d, &h, SimpleLshParams::new(96))
            })
            .unwrap();
        assert_eq!(mt.n_tables(), 3);
        let q = synthetic::gaussian_queries(1, 8, 10);
        let mut out = Vec::new();
        mt.probe_union(q.row(0), &mut out);
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), out.len());
        assert!(out.iter().all(|&id| (id as usize) < d.len()));
    }

    #[test]
    fn tables_use_distinct_projections() {
        // With identical seeds the union would equal a single table's
        // probe; distinct seeds should (overwhelmingly) yield more.
        let d = synthetic::longtail_sift(2000, 8, 3);
        let q = synthetic::gaussian_queries(16, 8, 4);
        let one = simple_multitable(&d, 12, 1).unwrap();
        let many = simple_multitable(&d, 12, 8).unwrap();
        let (mut total1, mut total8) = (0usize, 0usize);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            one.probe_union(q.row(qi), &mut a);
            many.probe_union(q.row(qi), &mut b);
            total1 += a.len();
            total8 += b.len();
        }
        assert!(total8 > total1, "8 tables ({total8}) should surface more than 1 ({total1})");
    }
}
