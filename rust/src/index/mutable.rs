//! Online mutability for RANGE-LSH: tombstone deletes, in-place inserts,
//! and re-partitioning compaction — the pure (no-IO) index layer under
//! [`crate::coordinator::store::MutableStore`] (README §"Mutability &
//! recovery model").
//!
//! The paper's index is build-once; this module makes it *maintained*
//! without giving up the immutable probing core:
//!
//! - an epoch is an immutable `Arc<RangeLshIndex<C>>` plus an immutable
//!   [`Tombstones`] set, wrapped in a [`TombstonedIndex`] that filters the
//!   probe stream. In-flight [`Prober`] sessions borrow the epoch they
//!   were opened on, so a concurrent mutation (which only *replaces* the
//!   current epoch `Arc`) never changes what they see;
//! - [`insert_into_index`] routes each new item to the existing range
//!   whose `[_, u_max]` covers its norm and rebuilds only the touched
//!   ranges' tables — untouched ranges are structurally shared with the
//!   previous epoch (`Arc` clones), so an insert is O(touched ranges),
//!   not O(index);
//! - deletes never touch the index at all: a tombstoned id is filtered
//!   at the probe-stream choke point ([`TombstoneProber`]), which every
//!   consumer — `BoundedTopK` admission, `RerankView` scoring, candidate
//!   buffers — sits downstream of, so a deleted id can never surface;
//! - [`compact_index`] re-partitions the live items from scratch
//!   (restoring the paper's per-range `U_j` invariant after drift) while
//!   keeping every surviving item's *original* id.

use std::sync::Arc;

use crate::data::Dataset;
use crate::hash::{CodeWord, ItemHasher, NativeHasher};
use crate::index::mih::MihTable;
use crate::index::partition::{partition, Partition};
use crate::index::range::{RangeLshIndex, RangeProber, SubIndex};
use crate::index::{BucketTable, CodeProbe, IndexStats, MipsIndex, ProbeStats, Prober};
use crate::{ItemId, Result};

/// An immutable set of deleted ids: a fixed-capacity bitmap plus a count.
/// Each delete epoch clones the previous set and marks the new ids — the
/// set is shared (`Arc`) between the epoch handle and every in-flight
/// session opened on that epoch.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Tombstones {
    words: Vec<u64>,
    count: usize,
}

impl Tombstones {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a list of ids (the manifest's tombstone section).
    pub fn from_ids(ids: &[ItemId]) -> Self {
        let mut t = Self::new();
        for &id in ids {
            t.set(id);
        }
        t
    }

    /// Mark `id` deleted. Returns `true` if it was live before.
    // staticcheck: allow(panic-reach, "words is resized to w+1 immediately before the access")
    pub fn set(&mut self, id: ItemId) -> bool {
        let (w, b) = (id as usize / 64, id as usize % 64);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let fresh = self.words[w] & (1 << b) == 0;
        if fresh {
            self.words[w] |= 1 << b;
            self.count += 1;
        }
        fresh
    }

    #[inline]
    pub fn contains(&self, id: ItemId) -> bool {
        let (w, b) = (id as usize / 64, id as usize % 64);
        self.words.get(w).is_some_and(|word| word & (1 << b) != 0)
    }

    /// Number of tombstoned ids.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The tombstoned ids, ascending (the manifest serialization order).
    pub fn ids(&self) -> Vec<ItemId> {
        let mut out = Vec::with_capacity(self.count);
        for (w, &word) in self.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push((w * 64) as ItemId + b as ItemId);
                bits &= bits - 1;
            }
        }
        out
    }
}

/// One epoch's queryable view: an immutable index plus the tombstones in
/// force at that epoch. Implements the same [`MipsIndex`] + [`CodeProbe`]
/// interface as the raw index, so it drops into
/// [`crate::coordinator::SearchEngine`] unchanged — the engine's probe
/// stream, `BoundedTopK` admission, and `RerankView` scoring all consume
/// candidates downstream of the tombstone filter and therefore can never
/// see a deleted id.
pub struct TombstonedIndex<C: CodeWord = u64> {
    inner: Arc<RangeLshIndex<C>>,
    tombs: Arc<Tombstones>,
}

impl<C: CodeWord> TombstonedIndex<C> {
    pub fn new(inner: Arc<RangeLshIndex<C>>, tombs: Arc<Tombstones>) -> Self {
        Self { inner, tombs }
    }

    pub fn inner(&self) -> &Arc<RangeLshIndex<C>> {
        &self.inner
    }

    pub fn tombstones(&self) -> &Arc<Tombstones> {
        &self.tombs
    }

    /// Live (indexed and not tombstoned) item count.
    pub fn live_len(&self) -> usize {
        self.inner.len() - self.tombs.len()
    }

    /// Open a filtered session over a precomputed code (concrete form).
    pub fn session(&self, qcode: C) -> TombstoneProber<'_, C> {
        TombstoneProber {
            inner: self.inner.session(qcode),
            tombs: &self.tombs,
            block: Vec::new(),
        }
    }
}

/// The probe-stream choke point of the delete path: wraps a
/// [`RangeProber`] and drops tombstoned ids from its output, *refilling*
/// the dropped slots from the underlying walk so the [`Prober`] contract
/// is preserved exactly — `extend` returns fewer than requested only when
/// the underlying index ran out during the call, and `0` thereafter.
/// Downstream consumers (the engine's `got < step` exhaustion checks, the
/// streaming re-rank's block loop) therefore need no changes.
pub struct TombstoneProber<'a, C: CodeWord = u64> {
    inner: RangeProber<'a, C>,
    tombs: &'a Tombstones,
    /// Pre-filter staging buffer, reused across `extend` calls.
    block: Vec<ItemId>,
}

impl<C: CodeWord> Prober for TombstoneProber<'_, C> {
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        if additional_budget == 0 {
            return 0;
        }
        let mut emitted = 0usize;
        // Fill-gap loop: every tombstoned candidate the filter drops is
        // replaced by asking the underlying walk for more, until the
        // budget is met in *live* candidates or the index runs dry.
        while emitted < additional_budget {
            let want = additional_budget - emitted;
            self.block.clear();
            let got = self.inner.extend(want, &mut self.block);
            for &id in &self.block {
                if !self.tombs.contains(id) {
                    out.push(id);
                    emitted += 1;
                }
            }
            if got < want {
                break; // underlying index exhausted
            }
        }
        emitted
    }

    fn is_exhausted(&self) -> bool {
        self.inner.is_exhausted()
    }

    /// Instrumentation of the *underlying* walk: `items_emitted` counts
    /// candidates the walk produced, including the tombstoned ones this
    /// filter absorbed (they were genuinely probed work).
    fn stats(&self) -> ProbeStats {
        self.inner.stats()
    }

    /// The underlying bound is over every un-emitted indexed item, a
    /// superset of the un-emitted *live* items — still sound for the
    /// streaming early-out.
    fn norm_bound(&self) -> Option<f32> {
        self.inner.norm_bound()
    }
}

impl<C: CodeWord> MipsIndex for TombstonedIndex<C> {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        self.probe_with_code(self.inner.hash_query(query), budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        Box::new(self.session(self.inner.hash_query(query)))
    }

    /// Live item count (tombstoned ids are not probeable).
    fn len(&self) -> usize {
        self.live_len()
    }

    fn stats(&self) -> IndexStats {
        IndexStats { n_items: self.live_len(), ..self.inner.stats() }
    }
}

impl<C: CodeWord> CodeProbe<C> for TombstonedIndex<C> {
    fn probe_with_code(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>) {
        self.session(qcode).extend(budget, out);
    }

    fn prober_with_code(&self, qcode: C) -> Box<dyn Prober + '_> {
        Box::new(self.session(qcode))
    }
}

/// Route each id in `new_ids` (rows already appended to `dataset`) into
/// the index and return the next epoch. Routing picks the first range
/// (ascending `u_max`) whose `u_max` covers the item's norm; an item above
/// every `u_max` lands in the top range and *grows* its `u_max` — that
/// range is then re-hashed in full, because its codes are normalized by
/// `U_j`. Every other touched range keeps its existing items' codes
/// (reconstructed from its bucket table, never re-hashed) and appends the
/// new items' codes. Untouched ranges are shared with `index` by `Arc`.
///
/// The per-range MIH tables, when attached, are rebuilt for touched
/// ranges and shared for the rest, so the configured probe backend
/// survives mutation.
// staticcheck: allow(panic-reach, "per-range vectors are sized subs.len(), j indexes subs, and MIH tables are parallel to subs")
pub fn insert_into_index<C: CodeWord>(
    index: &RangeLshIndex<C>,
    dataset: &Dataset,
    new_ids: &[ItemId],
) -> Result<RangeLshIndex<C>> {
    let params = *index.params();
    let hash_bits = params.hash_bits();
    let subs = index.shared_subs();
    anyhow::ensure!(!subs.is_empty(), "cannot insert into an empty index");
    for &id in new_ids {
        anyhow::ensure!((id as usize) < dataset.len(), "insert id {id} beyond dataset");
        anyhow::ensure!(
            dataset.norm(id as usize).is_finite(),
            "item {id} has a non-finite norm"
        );
    }
    // The item hasher: the index's own panel, hashed natively — identical
    // codes to the build-time path (PJRT-built indexes store the same
    // panel, and the backends are code-identical by contract).
    let hasher: NativeHasher<C> = NativeHasher::with_projection(index.projection().clone());

    // Route: first range (ascending norm order) with norm <= u_max, else
    // the top range (growing its u_max).
    let top = subs.len() - 1;
    let mut per_range: Vec<Vec<ItemId>> = vec![Vec::new(); subs.len()];
    for &id in new_ids {
        let norm = dataset.norm(id as usize);
        let j = subs
            .iter()
            .position(|s| norm <= s.part.u_max)
            .unwrap_or(top);
        per_range[j].push(id);
    }

    let old_mih = index.mih_tables();
    let mut new_subs = Vec::with_capacity(subs.len());
    let mut new_mih: Option<Vec<Arc<MihTable<C>>>> =
        old_mih.map(|_| Vec::with_capacity(subs.len()));
    for (j, sub) in subs.iter().enumerate() {
        if per_range[j].is_empty() {
            // Untouched: share the previous epoch's table (and MIH) verbatim.
            new_subs.push(sub.clone());
            if let (Some(acc), Some(old)) = (new_mih.as_mut(), old_mih) {
                acc.push(old[j].clone());
            }
            continue;
        }
        let added = &per_range[j];
        let new_max =
            added.iter().map(|&id| dataset.norm(id as usize)).fold(sub.part.u_max, f32::max);
        let new_min =
            added.iter().map(|&id| dataset.norm(id as usize)).fold(sub.part.u_min, f32::min);
        let mut ids = Vec::with_capacity(sub.part.ids.len() + added.len());
        let mut codes = Vec::with_capacity(sub.part.ids.len() + added.len());
        if new_max > sub.part.u_max {
            // u_max grew (only reachable for the top range): every code in
            // the range is normalized by U_j, so the whole range re-hashes.
            ids.extend_from_slice(&sub.part.ids);
            ids.extend_from_slice(added);
            let rows = dataset.gather(&ids);
            codes = hasher.hash_items(rows.flat(), new_max)?;
        } else {
            // U_j unchanged: existing items keep their codes — read back
            // from the bucket table (one shared code per bucket) instead
            // of re-hashing the whole range.
            for (code, bucket_ids) in sub.table.buckets() {
                for &id in bucket_ids {
                    ids.push(id);
                    codes.push(code);
                }
            }
            let rows = dataset.gather(added);
            codes.extend(hasher.hash_items(rows.flat(), new_max)?);
            ids.extend_from_slice(added);
        }
        let table = BucketTable::build(&codes, Some(&ids), hash_bits);
        if let Some(acc) = new_mih.as_mut() {
            acc.push(Arc::new(MihTable::build(&table)));
        }
        let part = Partition { ids, u_max: new_max, u_min: new_min };
        new_subs.push(Arc::new(SubIndex { part, table }));
    }
    RangeLshIndex::from_shared(
        params,
        index.projection().clone(),
        index.len() + new_ids.len(),
        new_subs,
        new_mih,
    )
}

/// Re-partition the live items from scratch — the drift-repair step. The
/// surviving items keep their **original** ids: the live set is gathered
/// into a dense scratch dataset, partitioned and hashed exactly as a
/// fresh [`RangeLshIndex::build`] over those rows would be, and the dense
/// positions are mapped back through the (monotonic) live-id list. The
/// result is bit-identical to building a fresh index over the live rows
/// (property-tested), with MIH tables re-attached iff `index` had them.
///
/// Returns the compacted index and the ascending live-id list.
// staticcheck: allow(panic-reach, "partition ids are dense positions into `dense`, which has live.len() rows")
pub fn compact_index<C: CodeWord>(
    index: &RangeLshIndex<C>,
    dataset: &Dataset,
    tombs: &Tombstones,
) -> Result<(RangeLshIndex<C>, Vec<ItemId>)> {
    let mut live: Vec<ItemId> = Vec::with_capacity(index.len());
    index.for_each_range::<std::convert::Infallible>(|part, _| {
        live.extend(part.ids.iter().copied().filter(|&id| !tombs.contains(id)));
        Ok(())
    })?;
    live.sort_unstable();
    anyhow::ensure!(!live.is_empty(), "compaction would empty the index");

    let params = *index.params();
    let dense = dataset.gather(&live); // dense position i <-> original live[i]
    let hasher: NativeHasher<C> = NativeHasher::with_projection(index.projection().clone());
    let parts = partition(&dense, params.n_partitions, params.scheme)?;
    let mut ranges = Vec::with_capacity(parts.len());
    for part in parts {
        let rows = dense.gather(&part.ids);
        let codes = hasher.hash_items(rows.flat(), part.u_max)?;
        let ids: Vec<ItemId> = part.ids.iter().map(|&i| live[i as usize]).collect();
        ranges.push((Partition { ids, u_max: part.u_max, u_min: part.u_min }, codes));
    }
    let mut compacted =
        RangeLshIndex::from_parts(params, index.projection().clone(), live.len(), ranges)?;
    if index.has_mih() {
        compacted.enable_mih();
    }
    Ok((compacted, live))
}

/// The ascending list of ids currently indexed (live or tombstoned) —
/// used at store open to reconcile the dataset against the index: a
/// dataset row that is *not* indexed is a dead row left behind by an
/// earlier compaction.
pub fn indexed_ids<C: CodeWord>(index: &RangeLshIndex<C>) -> Vec<ItemId> {
    let mut out = Vec::with_capacity(index.len());
    let _ = index.for_each_range::<std::convert::Infallible>(|part, _| {
        out.extend_from_slice(&part.ids);
        Ok(())
    });
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::index::range::RangeLshParams;

    fn build(d: &Dataset, bits: usize, m: usize) -> RangeLshIndex {
        let h: NativeHasher = NativeHasher::new(d.dim(), 64, 99);
        RangeLshIndex::build(d, &h, RangeLshParams::new(bits, m)).unwrap()
    }

    fn grown(base: &Dataset, extra: &Dataset) -> (Dataset, Vec<ItemId>) {
        let mut flat = base.flat().to_vec();
        flat.extend_from_slice(extra.flat());
        let mut norms = base.norms().to_vec();
        norms.extend_from_slice(extra.norms());
        let ids = (base.len() as ItemId..(base.len() + extra.len()) as ItemId).collect();
        (Dataset::from_flat_with_norms(base.dim(), flat, norms), ids)
    }

    #[test]
    fn tombstones_set_contains_and_enumerate() {
        let mut t = Tombstones::new();
        assert!(t.is_empty());
        assert!(t.set(130));
        assert!(t.set(0));
        assert!(t.set(63));
        assert!(!t.set(130), "double delete is not fresh");
        assert_eq!(t.len(), 3);
        assert!(t.contains(0) && t.contains(63) && t.contains(130));
        assert!(!t.contains(64) && !t.contains(1000));
        assert_eq!(t.ids(), vec![0, 63, 130]);
        assert_eq!(Tombstones::from_ids(&t.ids()), t);
    }

    #[test]
    fn tombstoned_ids_never_surface_at_any_budget() {
        let d = synthetic::longtail_sift(600, 8, 1);
        let idx = Arc::new(build(&d, 16, 8));
        let mut tombs = Tombstones::new();
        for id in (0..600).step_by(3) {
            tombs.set(id);
        }
        let view = TombstonedIndex::new(idx.clone(), Arc::new(tombs));
        let q = synthetic::gaussian_queries(2, 8, 2);
        for qi in 0..q.len() {
            let qcode = idx.hash_query(q.row(qi));
            for budget in [1usize, 7, 100, usize::MAX] {
                let mut out = Vec::new();
                view.probe_with_code(qcode, budget, &mut out);
                assert!(out.iter().all(|&id| id % 3 != 0), "q={qi} budget={budget}");
                assert_eq!(out.len(), budget.min(view.live_len()), "q={qi} budget={budget}");
            }
        }
    }

    #[test]
    fn filtered_stream_is_the_unfiltered_stream_minus_tombstones() {
        // The fill-gap filter must be order-preserving: the live stream is
        // exactly the raw stream with tombstoned ids removed, at every
        // budget and across resumed sessions.
        let d = synthetic::longtail_sift(500, 8, 3);
        let idx = Arc::new(build(&d, 16, 8));
        let mut tombs = Tombstones::new();
        for id in [0u32, 5, 17, 200, 201, 202, 499] {
            tombs.set(id);
        }
        let tombs = Arc::new(tombs);
        let view = TombstonedIndex::new(idx.clone(), tombs.clone());
        let q = synthetic::gaussian_queries(1, 8, 4);
        let qcode = idx.hash_query(q.row(0));
        let mut raw = Vec::new();
        idx.probe_with_code(qcode, usize::MAX, &mut raw);
        let want: Vec<ItemId> =
            raw.iter().copied().filter(|&id| !tombs.contains(id)).collect();
        let mut full = Vec::new();
        view.probe_with_code(qcode, usize::MAX, &mut full);
        assert_eq!(full, want);
        // Resumed sessions emit the same stream in pieces, and the
        // exhaustion contract holds: short return exactly at dry-up.
        let mut session = view.session(qcode);
        let mut chunks = Vec::new();
        loop {
            let got = session.extend(7, &mut chunks);
            if got < 7 {
                assert!(session.is_exhausted());
                assert_eq!(session.extend(7, &mut chunks), 0, "post-exhaustion extends are 0");
                break;
            }
        }
        assert_eq!(chunks, want);
    }

    #[test]
    fn insert_routes_to_covering_range_and_preserves_stream_of_old_items() {
        let base = synthetic::longtail_sift(400, 8, 5);
        let idx = build(&base, 16, 8);
        let extra = synthetic::longtail_sift(60, 8, 6);
        let (dataset, new_ids) = grown(&base, &extra);
        let mutated = insert_into_index(&idx, &dataset, &new_ids).unwrap();
        assert_eq!(mutated.len(), 460);
        // Every id probes out exactly once.
        let q = synthetic::gaussian_queries(1, 8, 7);
        let qcode = mutated.hash_query(q.row(0));
        let mut out = Vec::new();
        mutated.probe_with_code(qcode, usize::MAX, &mut out);
        assert_eq!(out.len(), 460);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 460);
        // Ranges stay norm-sound: each indexed item's norm lies within
        // its range's [u_min, u_max].
        mutated
            .for_each_range::<std::convert::Infallible>(|part, _| {
                for &id in &part.ids {
                    let n = dataset.norm(id as usize);
                    assert!(n >= part.u_min && n <= part.u_max, "id {id} outside its range");
                }
                Ok(())
            })
            .unwrap();
    }

    #[test]
    fn insert_above_top_range_grows_u_max_and_rehashes() {
        let base = synthetic::longtail_sift(300, 8, 8);
        let idx = build(&base, 16, 4);
        let old_top = *idx.u_maxes().last().unwrap();
        // One row with double the max norm: guaranteed above every u_max.
        let argmax = (0..base.len())
            .max_by(|&a, &b| base.norm(a).total_cmp(&base.norm(b)))
            .unwrap();
        let big: Vec<f32> = base.row(argmax).iter().map(|v| v * 2.0).collect();
        let extra = Dataset::from_rows(&[big]);
        let (dataset, new_ids) = grown(&base, &extra);
        assert!(dataset.norm(300) > old_top);
        let mutated = insert_into_index(&idx, &dataset, &new_ids).unwrap();
        let new_top = *mutated.u_maxes().last().unwrap();
        assert_eq!(new_top.to_bits(), dataset.norm(300).to_bits());
        // The stream still covers everything exactly once.
        let q = synthetic::gaussian_queries(1, 8, 9);
        let mut out = Vec::new();
        mutated.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), 301);
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 301);
    }

    #[test]
    fn insert_shares_untouched_ranges_structurally() {
        let base = synthetic::longtail_sift(800, 8, 10);
        let idx = build(&base, 16, 16);
        // One median-norm row: routes into exactly one existing range.
        let mid = base.len() / 2;
        let extra = Dataset::from_rows(&[base.row(mid).to_vec()]);
        let (dataset, new_ids) = grown(&base, &extra);
        let mutated = insert_into_index(&idx, &dataset, &new_ids).unwrap();
        let shared = idx
            .shared_subs()
            .iter()
            .zip(mutated.shared_subs())
            .filter(|(a, b)| Arc::ptr_eq(a, b))
            .count();
        assert_eq!(shared, idx.n_ranges() - 1, "exactly one range may be rebuilt");
    }

    #[test]
    fn insert_rebuilds_mih_only_for_touched_ranges() {
        let base = synthetic::longtail_sift(600, 8, 11);
        let mut idx = build(&base, 16, 8);
        idx.enable_mih();
        let mid = base.len() / 2;
        let extra = Dataset::from_rows(&[base.row(mid).to_vec()]);
        let (dataset, new_ids) = grown(&base, &extra);
        let mutated = insert_into_index(&idx, &dataset, &new_ids).unwrap();
        assert!(mutated.has_mih(), "probe backend must survive mutation");
        let (old_t, new_t) = (idx.mih_tables().unwrap(), mutated.mih_tables().unwrap());
        let shared = old_t.iter().zip(new_t).filter(|(a, b)| Arc::ptr_eq(a, b)).count();
        assert_eq!(shared, idx.n_ranges() - 1);
        // And the MIH stream still matches the counting sort's.
        let q = synthetic::gaussian_queries(1, 8, 12);
        let qcode = mutated.hash_query(q.row(0));
        let mut got = Vec::new();
        mutated.probe_with_code(qcode, usize::MAX, &mut got);
        let mut plain = insert_into_index(&idx, &dataset, &new_ids).unwrap();
        plain.clear_mih();
        let mut want = Vec::new();
        plain.probe_with_code(qcode, usize::MAX, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn compaction_matches_fresh_build_over_live_rows() {
        let base = synthetic::longtail_sift(500, 8, 13);
        let idx = build(&base, 16, 8);
        let mut tombs = Tombstones::new();
        for id in (0..500).step_by(7) {
            tombs.set(id);
        }
        let (compacted, live) = compact_index(&idx, &base, &tombs).unwrap();
        assert_eq!(live.len(), compacted.len());
        assert!(live.windows(2).all(|w| w[0] < w[1]), "live ids ascend");
        // Bit-identical to a fresh build over the gathered live rows,
        // modulo the dense->original id mapping.
        let dense = base.gather(&live);
        let h: NativeHasher = NativeHasher::with_projection(idx.projection().clone());
        let fresh = RangeLshIndex::build(&dense, &h, *idx.params()).unwrap();
        let q = synthetic::gaussian_queries(2, 8, 14);
        for qi in 0..q.len() {
            let qcode = compacted.hash_query(q.row(qi));
            let (mut got, mut want) = (Vec::new(), Vec::new());
            compacted.probe_with_code(qcode, usize::MAX, &mut got);
            fresh.probe_with_code(qcode, usize::MAX, &mut want);
            let want_mapped: Vec<ItemId> =
                want.iter().map(|&i| live[i as usize]).collect();
            assert_eq!(got, want_mapped, "q={qi}");
        }
        // No tombstoned id survives compaction.
        assert!(live.iter().all(|&id| !tombs.contains(id)));
    }

    #[test]
    fn compaction_keeps_mih_attachment() {
        let base = synthetic::longtail_sift(300, 8, 15);
        let mut idx = build(&base, 16, 4);
        idx.enable_mih();
        let mut tombs = Tombstones::new();
        tombs.set(3);
        let (compacted, _) = compact_index(&idx, &base, &tombs).unwrap();
        assert!(compacted.has_mih());
        idx.clear_mih();
        let (compacted, _) = compact_index(&idx, &base, &tombs).unwrap();
        assert!(!compacted.has_mih());
    }

    #[test]
    fn compacting_everything_away_is_an_error() {
        let base = synthetic::longtail_sift(50, 8, 16);
        let idx = build(&base, 16, 2);
        let mut tombs = Tombstones::new();
        for id in 0..50 {
            tombs.set(id);
        }
        assert!(compact_index(&idx, &base, &tombs).is_err());
    }

    #[test]
    fn indexed_ids_reports_every_id_once() {
        let base = synthetic::longtail_sift(200, 8, 17);
        let idx = build(&base, 16, 4);
        let ids = indexed_ids(&idx);
        assert_eq!(ids, (0..200).collect::<Vec<ItemId>>());
    }

    #[test]
    fn insert_rejects_out_of_range_and_non_finite() {
        let base = synthetic::longtail_sift(100, 8, 18);
        let idx = build(&base, 16, 4);
        assert!(insert_into_index(&idx, &base, &[100]).is_err(), "id beyond dataset");
        let mut flat = base.flat().to_vec();
        flat.extend(std::iter::repeat(f32::NAN).take(8));
        let bad = Dataset::from_flat(8, flat);
        assert!(insert_into_index(&idx, &bad, &[100]).is_err(), "non-finite norm");
    }
}
