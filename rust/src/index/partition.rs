//! Norm-based dataset partitioning (paper Algorithm 1, lines 3–4, plus the
//! uniform-range alternative evaluated in Fig. 3(a)).

use crate::data::Dataset;
use crate::{ItemId, Result};

/// How to split the 2-norm axis into ranges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionScheme {
    /// Algorithm 1: rank items by norm, cut at percentiles — every range
    /// holds (almost) the same number of items. Ties broken by item id
    /// (the "arbitrary" tie-break the paper calls for).
    Percentile,
    /// Fig. 3(a) alternative: split `[min_norm, max_norm]` into `m` equal
    /// intervals; ranges may be unbalanced, empty ranges are dropped.
    UniformRange,
}

impl std::str::FromStr for PartitionScheme {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "percentile" => Ok(Self::Percentile),
            "uniform_range" | "uniform" => Ok(Self::UniformRange),
            other => anyhow::bail!("unknown partition scheme {other:?} (percentile | uniform_range)"),
        }
    }
}

/// One norm range: its member ids and the local max norm `U_j` — the
/// normalisation constant that replaces the global `U` (the paper's core
/// mechanism).
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub ids: Vec<ItemId>,
    /// `U_j = max_{x in S_j} ||x||`.
    pub u_max: f32,
    /// Smallest norm in the range (the §5 extension's `u_{j-1}` bound).
    pub u_min: f32,
}

/// Split `dataset` into at most `m` non-empty norm ranges, ordered by
/// ascending norm. The last range always contains the global-max-norm item,
/// so exactly one range has `U_j == U` (the Theorem 1 condition with
/// `n^beta = 1`).
///
/// Every norm must be finite: a NaN norm would silently fall into range 0
/// through `uniform_range`'s saturating `as usize` cast and then corrupt
/// the `u_max`/`u_min` invariants (`f32::max`/`min` ignore NaN), and an
/// infinite norm breaks the interval arithmetic — both are rejected here
/// with an error naming the first offending item.
pub fn partition(dataset: &Dataset, m: usize, scheme: PartitionScheme) -> Result<Vec<Partition>> {
    assert!(m >= 1, "need at least one partition");
    let n = dataset.len();
    if let Some(bad) = dataset.norms().iter().position(|nrm| !nrm.is_finite()) {
        anyhow::bail!(
            "item {bad} has non-finite 2-norm {}: partitioning requires finite norms \
             (check the dataset for NaN/inf coordinates)",
            dataset.norm(bad)
        );
    }
    if n == 0 {
        return Ok(Vec::new());
    }
    Ok(match scheme {
        PartitionScheme::Percentile => percentile(dataset, m),
        PartitionScheme::UniformRange => uniform_range(dataset, m),
    })
}

// staticcheck: allow(panic-reach, "rank slices end at hi = (j+1)n/m <= n, and lo >= hi ranges are skipped")
fn percentile(dataset: &Dataset, m: usize) -> Vec<Partition> {
    let n = dataset.len();
    // Rank by (norm, id): stable under ties, as Algorithm 1 requires.
    let mut order: Vec<ItemId> = (0..n as ItemId).collect();
    order.sort_unstable_by(|&a, &b| {
        dataset
            .norm(a as usize)
            .total_cmp(&dataset.norm(b as usize))
            .then(a.cmp(&b))
    });
    // Algorithm 1 line 4: S_j holds ranks [(j-1)n/m, jn/m).
    let mut out = Vec::with_capacity(m);
    for j in 0..m {
        let lo = j * n / m;
        let hi = (j + 1) * n / m;
        if lo >= hi {
            continue; // m > n leaves some ranges empty
        }
        let ids = order[lo..hi].to_vec();
        out.push(make_partition(dataset, ids));
    }
    out
}

// staticcheck: allow(panic-reach, "the bucket index is clamped to m-1 and buckets has m entries (partition ensures m >= 1)")
fn uniform_range(dataset: &Dataset, m: usize) -> Vec<Partition> {
    let n = dataset.len();
    let max = dataset.max_norm();
    let min = dataset.norms().iter().copied().fold(f32::INFINITY, f32::min);
    let span = (max - min).max(f32::MIN_POSITIVE);
    let mut buckets: Vec<Vec<ItemId>> = vec![Vec::new(); m];
    for i in 0..n {
        let t = ((dataset.norm(i) - min) / span * m as f32) as usize;
        buckets[t.min(m - 1)].push(i as ItemId);
    }
    buckets
        .into_iter()
        .filter(|b| !b.is_empty())
        .map(|ids| make_partition(dataset, ids))
        .collect()
}

fn make_partition(dataset: &Dataset, ids: Vec<ItemId>) -> Partition {
    let mut u_max = 0.0f32;
    let mut u_min = f32::INFINITY;
    for &id in &ids {
        let nrm = dataset.norm(id as usize);
        u_max = u_max.max(nrm);
        u_min = u_min.min(nrm);
    }
    Partition { ids, u_max, u_min }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn check_is_partition(parts: &[Partition], n: usize) {
        let mut seen = vec![false; n];
        for p in parts {
            for &id in &p.ids {
                assert!(!seen[id as usize], "item {id} assigned twice");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some item unassigned");
    }

    #[test]
    fn percentile_is_balanced_partition() {
        let d = synthetic::longtail_sift(1000, 8, 0);
        let parts = partition(&d, 32, PartitionScheme::Percentile).unwrap();
        assert_eq!(parts.len(), 32);
        check_is_partition(&parts, 1000);
        for p in &parts {
            // 1000/32 = 31.25: sizes must be 31 or 32.
            assert!(p.ids.len() == 31 || p.ids.len() == 32, "size {}", p.ids.len());
        }
    }

    #[test]
    fn ranges_are_norm_ordered() {
        let d = synthetic::longtail_sift(500, 8, 1);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            let parts = partition(&d, 8, scheme).unwrap();
            for w in parts.windows(2) {
                assert!(
                    w[0].u_max <= w[1].u_min + 1e-6,
                    "{scheme:?}: ranges overlap: {} vs {}",
                    w[0].u_max,
                    w[1].u_min
                );
            }
        }
    }

    #[test]
    fn last_range_owns_global_max() {
        let d = synthetic::longtail_sift(500, 8, 2);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            let parts = partition(&d, 16, scheme).unwrap();
            let last = parts.last().unwrap();
            assert_eq!(last.u_max, d.max_norm(), "{scheme:?}");
            // Exactly one range attains U (paper: "very often only the
            // sub-dataset that contains the items with the largest 2-norms").
            let attaining = parts.iter().filter(|p| p.u_max == d.max_norm()).count();
            assert_eq!(attaining, 1, "{scheme:?}");
        }
    }

    #[test]
    fn uniform_range_covers_all_items() {
        let d = synthetic::mf_embeddings(777, 8, 4, 3);
        let parts = partition(&d, 32, PartitionScheme::UniformRange).unwrap();
        check_is_partition(&parts, 777);
        assert!(parts.len() <= 32);
    }

    #[test]
    fn handles_ties_in_norms() {
        // All-equal norms: percentile partitioning must still split evenly
        // ("ties are broken arbitrarily", Alg. 1).
        let d = synthetic::uniform_norm(100, 8, 0);
        let parts = partition(&d, 10, PartitionScheme::Percentile).unwrap();
        assert_eq!(parts.len(), 10);
        check_is_partition(&parts, 100);
        for p in &parts {
            assert_eq!(p.ids.len(), 10);
        }
    }

    #[test]
    fn m_larger_than_n_drops_empty_ranges() {
        let d = synthetic::longtail_sift(5, 4, 0);
        let parts = partition(&d, 16, PartitionScheme::Percentile).unwrap();
        assert_eq!(parts.len(), 5); // one item each, empties dropped
        check_is_partition(&parts, 5);
    }

    #[test]
    fn single_partition_is_whole_dataset() {
        let d = synthetic::longtail_sift(50, 4, 0);
        let parts = partition(&d, 1, PartitionScheme::Percentile).unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].ids.len(), 50);
        assert_eq!(parts[0].u_max, d.max_norm());
    }

    #[test]
    fn rejects_non_finite_norms() {
        // Regression: a NaN-norm item used to fall silently into range 0
        // through uniform_range's saturating `as usize` cast, and
        // make_partition's f32::max/min then ignored the NaN — leaving
        // corrupt u_max/u_min invariants instead of an error.
        let mut flat = vec![1.0f32; 4 * 6];
        flat[9] = f32::NAN; // item 2
        let d = Dataset::from_flat(4, flat);
        for scheme in [PartitionScheme::Percentile, PartitionScheme::UniformRange] {
            let err = partition(&d, 4, scheme).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("non-finite"), "{scheme:?}: {msg}");
            assert!(msg.contains("item 2"), "{scheme:?} must name the item: {msg}");
        }
        // Infinite coordinates are rejected the same way.
        let mut flat = vec![1.0f32; 4 * 3];
        flat[0] = f32::INFINITY;
        let d = Dataset::from_flat(4, flat);
        assert!(partition(&d, 2, PartitionScheme::UniformRange).is_err());
        // All-finite data still partitions fine (m = 1 fast path too).
        let d = Dataset::from_flat(4, vec![1.0; 4 * 3]);
        assert_eq!(partition(&d, 1, PartitionScheme::Percentile).unwrap().len(), 1);
    }

    #[test]
    fn u_bounds_are_consistent() {
        let d = synthetic::longtail_sift(200, 8, 4);
        for p in partition(&d, 8, PartitionScheme::UniformRange).unwrap() {
            assert!(p.u_min <= p.u_max);
            for &id in &p.ids {
                let nrm = d.norm(id as usize);
                assert!(nrm >= p.u_min && nrm <= p.u_max);
            }
        }
    }
}
