//! Index persistence: save a built [`RangeLshIndex`] to disk (`.rlsh`) and
//! load it back without re-hashing the corpus — the build-once/serve-many
//! deployment flow (`rangelsh build` → `rangelsh serve --load`).
//!
//! ## Format versions
//!
//! - **v1** (`RLSHIDX\x01`, legacy): single-word `u64` codes, no width
//!   header. Still readable; always loads as a `RangeLshIndex<u64>`.
//! - **v2** (`RLSHIDX\x02`): adds a `code_words` header (u32: 1, 2 or 4)
//!   right after the magic; per-range codes are stored as a flat little-
//!   endian `u64` word array, `code_words` words per item. Written by
//!   [`save_range_index`] for every width.
//!
//! Loading a wide (v2, `code_words > 1`) file through the scalar
//! [`load_range_index`] fails with a clear error naming the stored width;
//! [`load_any_range_index`] dispatches on the header and returns the
//! matching monomorphized index wrapped in [`AnyRangeLshIndex`].
//!
//! Layout after the header (all little-endian): params, projection panel,
//! then per range: `U_j`, `u_min`, and the `(code, id)` pairs of its
//! bucket table. Codes are stored masked; the table is rebuilt on load
//! (cheap — it is a single grouping pass).
//!
//! ## Optional MIH section
//!
//! After the ranges, v2 files may carry the prebuilt multi-index Hamming
//! chunk tables (see [`crate::index::mih`]): a tag byte (0 = absent,
//! 1 = present; clean EOF = absent, which is what v1 and older v2 files
//! hit), then `n_ranges` (u32), the per-range hash bit width (u32), and
//! per range the CSR `offsets` / `values` arrays. The section is
//! validated against the header on load (range count, bit width, CSR
//! structure) and rejected with a clear error on any mismatch; files
//! without it simply load without MIH tables — callers that want MIH
//! rebuild them via [`RangeLshIndex::enable_mih`].

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context};

use crate::hash::{Code128, Code256, CodeWord, Projection, MAX_CODE_BITS};
use crate::index::mih::MihTable;
use crate::index::partition::{Partition, PartitionScheme};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::MipsIndex;
use crate::util::bytes::*;
use crate::Result;

const MAGIC_V1: &[u8; 8] = b"RLSHIDX\x01";
const MAGIC_V2: &[u8; 8] = b"RLSHIDX\x02";

/// A loaded RANGE-LSH index of whatever code width the file declares.
pub enum AnyRangeLshIndex {
    W64(RangeLshIndex<u64>),
    W128(RangeLshIndex<Code128>),
    W256(RangeLshIndex<Code256>),
}

impl AnyRangeLshIndex {
    /// Words per code (1, 2 or 4).
    pub fn code_words(&self) -> usize {
        match self {
            Self::W64(_) => 1,
            Self::W128(_) => 2,
            Self::W256(_) => 4,
        }
    }

    /// The underlying index as a probing trait object (any width).
    pub fn as_mips(&self) -> &dyn MipsIndex {
        match self {
            Self::W64(i) => i,
            Self::W128(i) => i,
            Self::W256(i) => i,
        }
    }
}

/// Write `index` to `path` (always the v2 format, with the width header).
pub fn save_range_index<C: CodeWord>(
    index: &RangeLshIndex<C>,
    path: impl AsRef<Path>,
) -> Result<()> {
    let path = path.as_ref();
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    w.write_all(MAGIC_V2)?;
    write_u32(&mut w, C::WORDS as u32)?;
    write_params_and_ranges(index, &mut w)?;
    write_mih_section(index, &mut w)?;
    w.flush()?;
    Ok(())
}

/// Append the optional MIH section: present iff the index has its chunk
/// tables built (`enable_mih`), so a plain counting-sort index costs one
/// tag byte and an MIH index serves straight from the file without the
/// O(n · n_chunks) rebuild.
fn write_mih_section<C: CodeWord>(
    index: &RangeLshIndex<C>,
    w: &mut impl Write,
) -> Result<()> {
    let Some(tables) = index.mih_tables() else {
        write_u8(w, 0)?;
        return Ok(());
    };
    write_u8(w, 1)?;
    write_u32(w, tables.len() as u32)?;
    write_u32(w, index.params().hash_bits() as u32)?;
    for t in tables {
        write_u32s(w, t.offsets())?;
        write_u32s(w, t.values())?;
    }
    Ok(())
}

fn write_params_and_ranges<C: CodeWord>(
    index: &RangeLshIndex<C>,
    w: &mut impl Write,
) -> Result<()> {
    let p = index.params();
    write_u32(w, p.code_bits as u32)?;
    write_u32(w, p.n_partitions as u32)?;
    write_u8(w, match p.scheme {
        PartitionScheme::Percentile => 0,
        PartitionScheme::UniformRange => 1,
    })?;
    write_f32(w, p.epsilon)?;
    write_u64(w, index.len() as u64)?;
    // Projection panel.
    let proj = index.projection();
    write_u32(w, proj.dim_in() as u32)?;
    write_u32(w, proj.width() as u32)?;
    write_f32s(w, proj.flat())?;
    // Ranges.
    write_u32(w, index.n_ranges() as u32)?;
    index.for_each_range(|part, table| -> Result<()> {
        write_f32(w, part.u_max)?;
        write_f32(w, part.u_min)?;
        // (code, ids) per bucket, flattened as aligned arrays; codes as
        // C::WORDS little-endian u64 words each.
        let mut words = Vec::with_capacity(part.ids.len() * C::WORDS);
        let mut ids = Vec::with_capacity(part.ids.len());
        for (code, items) in table.buckets() {
            for &id in items {
                words.extend_from_slice(code.as_words());
                ids.push(id);
            }
        }
        write_u64s(w, &words)?;
        write_u32s(w, &ids)?;
        Ok(())
    })?;
    Ok(())
}

/// Load an index previously written by [`save_range_index`] with `u64`
/// codes (v1 or single-word v2). Wide files fail with an error naming the
/// stored width — use [`load_any_range_index`] for those.
pub fn load_range_index(path: impl AsRef<Path>) -> Result<RangeLshIndex<u64>> {
    match load_any_range_index(&path)? {
        AnyRangeLshIndex::W64(index) => Ok(index),
        other => anyhow::bail!(
            "{}: index stores {}-bit codes ({} words per code); \
             load it with load_any_range_index / a matching code_bits config",
            path.as_ref().display(),
            other.code_words() * 64,
            other.code_words()
        ),
    }
}

/// Load an index of any code width, dispatching on the file header.
pub fn load_any_range_index(path: impl AsRef<Path>) -> Result<AnyRangeLshIndex> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated header", path.display()))?;
    let code_words = if &magic == MAGIC_V1 {
        1 // legacy single-word format, no width header
    } else if &magic == MAGIC_V2 {
        read_u32(&mut r)? as usize
    } else {
        anyhow::bail!("{}: not a rangelsh index", path.display());
    };
    match code_words {
        1 => Ok(AnyRangeLshIndex::W64(read_body::<u64>(&mut r, path)?)),
        2 => Ok(AnyRangeLshIndex::W128(read_body::<Code128>(&mut r, path)?)),
        4 => Ok(AnyRangeLshIndex::W256(read_body::<Code256>(&mut r, path)?)),
        other => anyhow::bail!(
            "{}: unsupported code width {} words (supported: 1, 2, 4)",
            path.display(),
            other
        ),
    }
}

fn read_body<C: CodeWord>(r: &mut impl Read, path: &Path) -> Result<RangeLshIndex<C>> {
    let code_bits = read_u32(r)? as usize;
    let n_partitions = read_u32(r)? as usize;
    let scheme = match read_u8(r)? {
        0 => PartitionScheme::Percentile,
        1 => PartitionScheme::UniformRange,
        other => anyhow::bail!("unknown partition scheme tag {other}"),
    };
    let epsilon = read_f32(r)?;
    let n_items = read_u64(r)? as usize;
    let dim_in = read_u32(r)? as usize;
    let width = read_u32(r)? as usize;
    // Validate header fields here so corrupt files fail with a Result
    // error instead of tripping downstream asserts (Projection::from_flat,
    // MetricOrder::build, partition_id_bits) and aborting the process.
    ensure!(
        n_partitions >= 1,
        "{}: implausible partition count 0 (corrupt header?)",
        path.display()
    );
    ensure!(
        (0.0..1.0).contains(&epsilon),
        "{}: implausible epsilon {epsilon} (corrupt header?)",
        path.display()
    );
    ensure!(
        dim_in >= 1 && width >= 1 && width <= MAX_CODE_BITS,
        "{}: implausible projection shape {dim_in} x {width} (corrupt header?)",
        path.display()
    );
    let flat = read_f32s(r)?;
    ensure!(flat.len() == dim_in * width, "projection size mismatch");
    let proj = Arc::new(Projection::from_flat(dim_in, width, flat));
    let n_ranges = read_u32(r)? as usize;
    let params = RangeLshParams::new(code_bits, n_partitions)
        .with_scheme(scheme)
        .with_epsilon(epsilon);
    let mut ranges = Vec::with_capacity(n_ranges);
    for _ in 0..n_ranges {
        let u_max = read_f32(r)?;
        let u_min = read_f32(r)?;
        let words = read_u64s(r)?;
        let ids = read_u32s(r)?;
        ensure!(
            words.len() == ids.len() * C::WORDS,
            "{}: code words not a multiple of {} per id",
            path.display(),
            C::WORDS
        );
        let codes: Vec<C> = words.chunks_exact(C::WORDS).map(C::from_words).collect();
        ranges.push((Partition { ids, u_max, u_min }, codes));
    }
    let mut index = RangeLshIndex::from_parts(params, proj, n_items, ranges)?;
    read_mih_section(r, path, &mut index)?;
    Ok(index)
}

/// Read the optional trailing MIH section. A clean EOF right after the
/// ranges means the section is absent (v1 files and v2 files written
/// before the section existed) — not an error.
fn read_mih_section<C: CodeWord>(
    r: &mut impl Read,
    path: &Path,
    index: &mut RangeLshIndex<C>,
) -> Result<()> {
    let mut tag = [0u8; 1];
    match r.read_exact(&mut tag) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
        Err(e) => return Err(e.into()),
        Ok(()) => {}
    }
    match tag[0] {
        0 => Ok(()),
        1 => {
            let sect_ranges = read_u32(r)? as usize;
            let sect_bits = read_u32(r)? as usize;
            ensure!(
                sect_ranges == index.n_ranges(),
                "{}: MIH section covers {sect_ranges} ranges but the index has {} \
                 (corrupt section?)",
                path.display(),
                index.n_ranges()
            );
            let hash_bits = index.params().hash_bits();
            ensure!(
                sect_bits == hash_bits,
                "{}: MIH section built for {sect_bits}-bit codes but the header's \
                 code_bits implies {hash_bits} hash bits per range (corrupt section?)",
                path.display()
            );
            let mut tables = Vec::with_capacity(sect_ranges);
            for j in 0..sect_ranges {
                let offsets = read_u32s(r)?;
                let values = read_u32s(r)?;
                let table = MihTable::from_parts(sect_bits, offsets, values, index.sub_table(j))
                    .with_context(|| format!("{}: MIH section, range {j}", path.display()))?;
                tables.push(table);
            }
            index.set_mih(tables)
        }
        other => anyhow::bail!("{}: unknown MIH section tag {other}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::MipsIndex;
    use crate::util::tmp::TempPath;

    fn build_one() -> (crate::data::Dataset, RangeLshIndex<u64>) {
        let d = synthetic::longtail_sift(600, 8, 0);
        let h: NativeHasher = NativeHasher::new(8, 64, 7);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 8)).unwrap();
        (d, idx)
    }

    fn build_wide() -> (crate::data::Dataset, RangeLshIndex<Code128>) {
        let d = synthetic::longtail_sift(400, 8, 1);
        let params = RangeLshParams::new(128, 8);
        let h: NativeHasher<Code128> = NativeHasher::new(8, params.hash_bits(), 7);
        let idx = RangeLshIndex::build(&d, &h, params).unwrap();
        (d, idx)
    }

    /// Write `index` in the legacy v1 layout (no width header, plain u64
    /// codes) — what pre-refactor builds produced.
    fn save_v1(index: &RangeLshIndex<u64>, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC_V1)?;
        write_params_and_ranges(index, &mut w)?;
        w.flush()?;
        Ok(())
    }

    #[test]
    fn round_trip_preserves_probe_behaviour() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = load_range_index(tmp.path()).unwrap();

        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.n_ranges(), idx.n_ranges());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let (sa, sb) = (idx.stats(), loaded.stats());
        assert_eq!(sa.n_buckets, sb.n_buckets);
        assert_eq!(sa.largest_bucket, sb.largest_bucket);

        // Probe results must be identical (same codes, same schedule; the
        // arena order is preserved by the (code, id) pair flattening).
        let q = synthetic::gaussian_queries(5, 8, 1);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Existing single-word index files round-trip through the new
        // reader (satellite: back-compat path).
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-v1");
        save_v1(&idx, tmp.path()).unwrap();
        let loaded = load_range_index(tmp.path()).unwrap();
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let q = synthetic::gaussian_queries(3, 8, 2);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 50, &mut a);
            loaded.probe(q.row(qi), 50, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn wide_round_trip_preserves_probe_behaviour() {
        let (_, idx) = build_wide();
        let tmp = TempPath::new("rlsh-wide");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = match load_any_range_index(tmp.path()).unwrap() {
            AnyRangeLshIndex::W128(i) => i,
            other => panic!("expected 128-bit index, got {} words", other.code_words()),
        };
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let (sa, sb) = (idx.stats(), loaded.stats());
        assert_eq!(sa.n_buckets, sb.n_buckets);
        // L = 128, m = 8 ⇒ 3 id bits ⇒ 125 hash bits per range.
        assert_eq!(sa.hash_bits, 125);
        assert_eq!(sb.hash_bits, 125);
        let q = synthetic::gaussian_queries(5, 8, 3);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn scalar_loader_rejects_wide_files_with_clear_error() {
        // Satellite: the failure path must name the stored width instead
        // of corrupting or panicking.
        let (_, idx) = build_wide();
        let tmp = TempPath::new("rlsh-wide-err");
        save_range_index(&idx, tmp.path()).unwrap();
        let err = load_range_index(tmp.path()).expect_err("u64 loader must refuse a wide file");
        let msg = format!("{err:#}");
        assert!(msg.contains("128-bit"), "unhelpful error: {msg}");
    }

    #[test]
    fn rejects_garbage_files() {
        let tmp = TempPath::new("rlsh-garbage");
        std::fs::write(tmp.path(), b"definitely not an index").unwrap();
        assert!(load_range_index(tmp.path()).is_err());
        assert!(load_any_range_index(tmp.path()).is_err());
    }

    #[test]
    fn rejects_unsupported_word_count() {
        // A v2 header claiming 3 words per code is invalid.
        let tmp = TempPath::new("rlsh-badwidth");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&3u32.to_le_bytes());
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_any_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported code width"));
    }

    #[test]
    fn rejects_corrupt_projection_header() {
        // A plausible-looking v2 file whose projection width is zero must
        // fail with a Result error, not trip an assert.
        let tmp = TempPath::new("rlsh-badproj");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // code_words
        bytes.extend_from_slice(&16u32.to_le_bytes()); // code_bits
        bytes.extend_from_slice(&8u32.to_le_bytes()); // n_partitions
        bytes.push(0); // scheme tag
        bytes.extend_from_slice(&0.1f32.to_le_bytes()); // epsilon
        bytes.extend_from_slice(&100u64.to_le_bytes()); // n_items
        bytes.extend_from_slice(&9u32.to_le_bytes()); // dim_in
        bytes.extend_from_slice(&0u32.to_le_bytes()); // width 0: implausible
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_any_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("projection shape"), "{err:#}");
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_range_index("/no/such/index.rlsh")
            .err()
            .expect("loading a missing file must fail");
        assert!(format!("{err:#}").contains("/no/such/index.rlsh"));
    }

    #[test]
    fn mih_section_round_trips() {
        let (_, mut idx) = build_wide();
        idx.enable_mih();
        let tmp = TempPath::new("rlsh-mih");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = match load_any_range_index(tmp.path()).unwrap() {
            AnyRangeLshIndex::W128(i) => i,
            other => panic!("expected 128-bit index, got {} words", other.code_words()),
        };
        // The chunk tables came from the file, not a rebuild — and the
        // probe stream through them matches the saved index's.
        assert!(loaded.has_mih());
        let q = synthetic::gaussian_queries(5, 8, 4);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn files_without_mih_section_load_without_tables() {
        // v2 without the section (tag 0) and v1 (clean EOF) both load
        // MIH-less; callers rebuild via enable_mih when they want it.
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-nomih");
        save_range_index(&idx, tmp.path()).unwrap();
        assert!(!load_range_index(tmp.path()).unwrap().has_mih());
        let tmp_v1 = TempPath::new("rlsh-nomih-v1");
        save_v1(&idx, tmp_v1.path()).unwrap();
        assert!(!load_range_index(tmp_v1.path()).unwrap().has_mih());
    }

    /// A saved MIH-less v2 file with its trailing `0` tag stripped, ready
    /// for a hand-built MIH section to be appended.
    fn v2_bytes_without_tail_tag(idx: &RangeLshIndex<u64>) -> Vec<u8> {
        let tmp = TempPath::new("rlsh-tailless");
        save_range_index(idx, tmp.path()).unwrap();
        let mut bytes = std::fs::read(tmp.path()).unwrap();
        assert_eq!(bytes.pop(), Some(0), "expected an absent-MIH tag byte");
        bytes
    }

    #[test]
    fn rejects_mih_section_disagreeing_with_header() {
        let (_, idx) = build_one();
        let base = v2_bytes_without_tail_tag(&idx);
        let hash_bits = idx.params().hash_bits() as u32;

        // Range count mismatch.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&((idx.n_ranges() as u32) + 1).to_le_bytes());
        bad.extend_from_slice(&hash_bits.to_le_bytes());
        let tmp = TempPath::new("rlsh-mih-ranges");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("ranges"), "{err:#}");

        // Bit width mismatch vs what the header's code_bits implies.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&(idx.n_ranges() as u32).to_le_bytes());
        bad.extend_from_slice(&(hash_bits + 1).to_le_bytes());
        let tmp = TempPath::new("rlsh-mih-bits");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("hash bits"), "{err:#}");

        // Structurally broken CSR arrays surface the per-range context.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&(idx.n_ranges() as u32).to_le_bytes());
        bad.extend_from_slice(&hash_bits.to_le_bytes());
        write_u32s(&mut bad, &[0u32]).unwrap(); // offsets: wrong length
        write_u32s(&mut bad, &[]).unwrap(); // values
        let tmp = TempPath::new("rlsh-mih-csr");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("MIH section, range 0"), "{msg}");
        assert!(msg.contains("offsets length"), "{msg}");

        // An unknown tag byte is a clean error too, not a panic.
        let mut bad = base;
        bad.push(7);
        let tmp = TempPath::new("rlsh-mih-tag");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("MIH section tag"), "{err:#}");
    }
}
