//! Index persistence: save a built [`RangeLshIndex`] to disk (`.rlsh`) and
//! load it back without re-hashing the corpus — the build-once/serve-many
//! deployment flow (`rangelsh build` → `rangelsh serve --load`).
//!
//! ## Format versions
//!
//! - **v1** (`RLSHIDX\x01`, legacy): single-word `u64` codes, no width
//!   header. Still readable; always loads as a `RangeLshIndex<u64>`.
//! - **v2** (`RLSHIDX\x02`): adds a `code_words` header (u32: 1, 2 or 4)
//!   right after the magic; per-range codes are stored as a flat little-
//!   endian `u64` word array, `code_words` words per item.
//! - **v3** (`RLSHIDX\x03`): same payload as v2 split into four
//!   CRC32-trailed sections — *header* (magic through `n_items`),
//!   *projection*, *ranges*, *MIH* — each followed by the little-endian
//!   digest of its bytes. Written by [`save_range_index`] for every
//!   width, atomically: the file is staged as a `.tmp` sibling, fsynced,
//!   and renamed into place, so a crashed save never leaves a torn
//!   `.rlsh` behind.
//!
//! On load, a checksum mismatch in a *required* section (header,
//! projection, ranges) fails with an error naming the section; a bad
//! *MIH* section — optional acceleration state — is dropped with a
//! warning and the index loads without tables, which callers rebuild via
//! [`RangeLshIndex::enable_mih`] (rebuild-on-demand). Every version is
//! read to strict EOF: bytes past the last section are trailing garbage
//! and rejected, not silently ignored.
//!
//! Loading a wide (`code_words > 1`) file through the scalar
//! [`load_range_index`] fails with a clear error naming the stored width;
//! [`load_any_range_index`] dispatches on the header and returns the
//! matching monomorphized index wrapped in [`AnyRangeLshIndex`].
//!
//! Layout after the header (all little-endian): params, projection panel,
//! then per range: `U_j`, `u_min`, and the `(code, id)` pairs of its
//! bucket table. Codes are stored masked; the table is rebuilt on load
//! (cheap — it is a single grouping pass).
//!
//! ## Optional MIH section
//!
//! After the ranges, v2/v3 files may carry the prebuilt multi-index
//! Hamming chunk tables (see [`crate::index::mih`]): a tag byte (0 =
//! absent, 1 = present; in v1/v2 a clean EOF also means absent), then
//! `n_ranges` (u32), the per-range hash bit width (u32), and per range
//! the CSR `offsets` / `values` arrays. The section is validated against
//! the header on load (range count, bit width, CSR structure); v1/v2
//! files reject a malformed section outright, v3 files degrade it to
//! rebuild-on-demand as described above.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{ensure, Context};

use crate::hash::{Code128, Code256, CodeWord, Projection, MAX_CODE_BITS};
use crate::index::mih::MihTable;
use crate::index::partition::{Partition, PartitionScheme};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::MipsIndex;
use crate::util::bytes::*;
use crate::Result;

const MAGIC_V1: &[u8; 8] = b"RLSHIDX\x01";
const MAGIC_V2: &[u8; 8] = b"RLSHIDX\x02";
const MAGIC_V3: &[u8; 8] = b"RLSHIDX\x03";

/// A loaded RANGE-LSH index of whatever code width the file declares.
pub enum AnyRangeLshIndex {
    W64(RangeLshIndex<u64>),
    W128(RangeLshIndex<Code128>),
    W256(RangeLshIndex<Code256>),
}

impl AnyRangeLshIndex {
    /// Words per code (1, 2 or 4).
    pub fn code_words(&self) -> usize {
        match self {
            Self::W64(_) => 1,
            Self::W128(_) => 2,
            Self::W256(_) => 4,
        }
    }

    /// The underlying index as a probing trait object (any width).
    pub fn as_mips(&self) -> &dyn MipsIndex {
        match self {
            Self::W64(i) => i,
            Self::W128(i) => i,
            Self::W256(i) => i,
        }
    }
}

/// Write `index` to `path` (always the v3 format: width header, four
/// CRC32-trailed sections). The write is atomic: bytes are staged in a
/// `.tmp` sibling, fsynced, and renamed over `path` — a crash mid-save
/// leaves the previous file (or nothing) in place, never a torn index.
pub fn save_range_index<C: CodeWord>(
    index: &RangeLshIndex<C>,
    path: impl AsRef<Path>,
) -> Result<()> {
    let path = path.as_ref();
    let tmp = tmp_sibling(path);
    match write_v3(index, &tmp) {
        Ok(()) => std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display())),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// `<path>.tmp`, next to the target so the rename stays within one
/// filesystem (rename across mount points is not atomic — or possible).
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

fn write_v3<C: CodeWord>(index: &RangeLshIndex<C>, tmp: &Path) -> Result<()> {
    let file =
        File::create(tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let mut w = HashingWriter::new(BufWriter::new(file));
    // Header section (the magic and width are covered by its digest).
    w.write_all(MAGIC_V3)?;
    write_u32(&mut w, C::WORDS as u32)?;
    write_params(index, &mut w)?;
    w.emit_section_crc()?;
    write_projection(index, &mut w)?;
    w.emit_section_crc()?;
    write_ranges(index, &mut w)?;
    w.emit_section_crc()?;
    write_mih_section(index, &mut w)?;
    w.emit_section_crc()?;
    w.flush()?;
    // Durability before the rename publishes the file.
    w.get_ref().get_ref().sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    Ok(())
}

/// Append the optional MIH section: present iff the index has its chunk
/// tables built (`enable_mih`), so a plain counting-sort index costs one
/// tag byte and an MIH index serves straight from the file without the
/// O(n · n_chunks) rebuild.
fn write_mih_section<C: CodeWord>(
    index: &RangeLshIndex<C>,
    w: &mut impl Write,
) -> Result<()> {
    let Some(tables) = index.mih_tables() else {
        write_u8(w, 0)?;
        return Ok(());
    };
    write_u8(w, 1)?;
    write_u32(w, tables.len() as u32)?;
    write_u32(w, index.params().hash_bits() as u32)?;
    for t in tables {
        write_u32s(w, t.offsets())?;
        write_u32s(w, t.values())?;
    }
    Ok(())
}

fn write_params<C: CodeWord>(index: &RangeLshIndex<C>, w: &mut impl Write) -> Result<()> {
    let p = index.params();
    write_u32(w, p.code_bits as u32)?;
    write_u32(w, p.n_partitions as u32)?;
    write_u8(w, match p.scheme {
        PartitionScheme::Percentile => 0,
        PartitionScheme::UniformRange => 1,
    })?;
    write_f32(w, p.epsilon)?;
    write_u64(w, index.len() as u64)?;
    Ok(())
}

fn write_projection<C: CodeWord>(index: &RangeLshIndex<C>, w: &mut impl Write) -> Result<()> {
    let proj = index.projection();
    write_u32(w, proj.dim_in() as u32)?;
    write_u32(w, proj.width() as u32)?;
    write_f32s(w, proj.flat())
}

fn write_ranges<C: CodeWord>(index: &RangeLshIndex<C>, w: &mut impl Write) -> Result<()> {
    write_u32(w, index.n_ranges() as u32)?;
    index.for_each_range(|part, table| -> Result<()> {
        write_f32(w, part.u_max)?;
        write_f32(w, part.u_min)?;
        // (code, ids) per bucket, flattened as aligned arrays; codes as
        // C::WORDS little-endian u64 words each.
        let mut words = Vec::with_capacity(part.ids.len() * C::WORDS);
        let mut ids = Vec::with_capacity(part.ids.len());
        for (code, items) in table.buckets() {
            for &id in items {
                words.extend_from_slice(code.as_words());
                ids.push(id);
            }
        }
        write_u64s(w, &words)?;
        write_u32s(w, &ids)?;
        Ok(())
    })
}

/// The v1/v2 body: params, projection, ranges back to back with no
/// checksums (kept for the legacy-writer test helpers).
#[cfg(test)]
fn write_params_and_ranges<C: CodeWord>(
    index: &RangeLshIndex<C>,
    w: &mut impl Write,
) -> Result<()> {
    write_params(index, w)?;
    write_projection(index, w)?;
    write_ranges(index, w)
}

/// Load an index previously written by [`save_range_index`] with `u64`
/// codes (v1 or single-word v2). Wide files fail with an error naming the
/// stored width — use [`load_any_range_index`] for those.
pub fn load_range_index(path: impl AsRef<Path>) -> Result<RangeLshIndex<u64>> {
    match load_any_range_index(&path)? {
        AnyRangeLshIndex::W64(index) => Ok(index),
        other => anyhow::bail!(
            "{}: index stores {}-bit codes ({} words per code); \
             load it with load_any_range_index / a matching code_bits config",
            path.as_ref().display(),
            other.code_words() * 64,
            other.code_words()
        ),
    }
}

/// Load an index of any code width, dispatching on the file header.
pub fn load_any_range_index(path: impl AsRef<Path>) -> Result<AnyRangeLshIndex> {
    let path = path.as_ref();
    let mut r = HashingReader::new(BufReader::new(
        File::open(path).with_context(|| format!("opening {}", path.display()))?,
    ));
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated header", path.display()))?;
    let (version, code_words) = if &magic == MAGIC_V1 {
        (1u8, 1) // legacy single-word format, no width header
    } else if &magic == MAGIC_V2 {
        (2, read_u32(&mut r)? as usize)
    } else if &magic == MAGIC_V3 {
        (3, read_u32(&mut r)? as usize)
    } else {
        anyhow::bail!("{}: not a rangelsh index", path.display());
    };
    match code_words {
        1 => Ok(AnyRangeLshIndex::W64(read_body::<u64, _>(&mut r, path, version)?)),
        2 => Ok(AnyRangeLshIndex::W128(read_body::<Code128, _>(&mut r, path, version)?)),
        4 => Ok(AnyRangeLshIndex::W256(read_body::<Code256, _>(&mut r, path, version)?)),
        other => anyhow::bail!(
            "{}: unsupported code width {} words (supported: 1, 2, 4)",
            path.display(),
            other
        ),
    }
}

fn read_body<C: CodeWord, R: Read>(
    r: &mut HashingReader<R>,
    path: &Path,
    version: u8,
) -> Result<RangeLshIndex<C>> {
    let checksummed = version >= 3;
    let code_bits = read_u32(r)? as usize;
    let n_partitions = read_u32(r)? as usize;
    let scheme_tag = read_u8(r)?;
    let epsilon = read_f32(r)?;
    let n_items = read_u64(r)? as usize;
    if checksummed {
        // Verify before interpreting: a corrupt v3 header fails here with
        // the section named, not on a downstream plausibility check.
        r.verify_section_crc("header")
            .with_context(|| path.display().to_string())?;
    }
    let scheme = match scheme_tag {
        0 => PartitionScheme::Percentile,
        1 => PartitionScheme::UniformRange,
        other => anyhow::bail!("unknown partition scheme tag {other}"),
    };
    // Validate header fields here so corrupt (v1/v2, checksum-less) files
    // fail with a Result error instead of tripping downstream asserts
    // (Projection::from_flat, MetricOrder::build, partition_id_bits) and
    // aborting the process.
    ensure!(
        n_partitions >= 1,
        "{}: implausible partition count 0 (corrupt header?)",
        path.display()
    );
    ensure!(
        (0.0..1.0).contains(&epsilon),
        "{}: implausible epsilon {epsilon} (corrupt header?)",
        path.display()
    );
    let dim_in = read_u32(r)? as usize;
    let width = read_u32(r)? as usize;
    ensure!(
        dim_in >= 1 && width >= 1 && width <= MAX_CODE_BITS,
        "{}: implausible projection shape {dim_in} x {width} (corrupt header?)",
        path.display()
    );
    let flat =
        read_f32s(r).with_context(|| format!("{}: projection section", path.display()))?;
    ensure!(flat.len() == dim_in * width, "projection size mismatch");
    if checksummed {
        r.verify_section_crc("projection")
            .with_context(|| path.display().to_string())?;
    }
    let proj = Arc::new(Projection::from_flat(dim_in, width, flat));
    let n_ranges = read_u32(r)? as usize;
    let params = RangeLshParams::new(code_bits, n_partitions)
        .with_scheme(scheme)
        .with_epsilon(epsilon);
    let mut ranges = Vec::with_capacity(n_ranges);
    for j in 0..n_ranges {
        let u_max = read_f32(r)?;
        let u_min = read_f32(r)?;
        let words = read_u64s(r)
            .with_context(|| format!("{}: ranges section, range {j}", path.display()))?;
        let ids = read_u32s(r)
            .with_context(|| format!("{}: ranges section, range {j}", path.display()))?;
        ensure!(
            words.len() == ids.len() * C::WORDS,
            "{}: code words not a multiple of {} per id",
            path.display(),
            C::WORDS
        );
        let codes: Vec<C> = words.chunks_exact(C::WORDS).map(C::from_words).collect();
        ranges.push((Partition { ids, u_max, u_min }, codes));
    }
    if checksummed {
        r.verify_section_crc("ranges")
            .with_context(|| path.display().to_string())?;
    }
    let mut index = RangeLshIndex::from_parts(params, proj, n_items, ranges)?;
    if checksummed {
        // v3: the MIH section is optional acceleration state — any defect
        // in it (bad checksum, structural mismatch, truncation) degrades
        // to loading without tables, rebuilt on demand via `enable_mih`.
        // The stream position is indeterminate after a failed read, so
        // the strict-EOF check only runs when the section parsed.
        match read_mih_checked(r, path, &mut index) {
            Ok(()) => ensure_eof(r, path)?,
            Err(e) => eprintln!(
                "warning: {}: dropping MIH section ({e:#}); \
                 tables will be rebuilt on demand",
                path.display()
            ),
        }
    } else {
        read_mih_section(r, path, &mut index)?;
        ensure_eof(r, path)?;
    }
    Ok(index)
}

/// Strict end-of-file: any byte past the last section is trailing
/// garbage — a truncated download glued to another file, a partial
/// overwrite — and the load refuses it rather than silently ignoring it.
fn ensure_eof(r: &mut impl Read, path: &Path) -> Result<()> {
    let mut probe = [0u8; 1];
    match r.read_exact(&mut probe) {
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => Ok(()),
        Ok(()) => anyhow::bail!(
            "{}: trailing garbage after the index payload",
            path.display()
        ),
        Err(e) => Err(e.into()),
    }
}

/// Read the optional trailing MIH section of a v1/v2 file. A clean EOF
/// right after the ranges means the section is absent (v1 files and v2
/// files written before the section existed) — not an error.
// staticcheck: allow(panic-reach, "tag is a [u8; 1] and the index is the constant 0")
fn read_mih_section<C: CodeWord>(
    r: &mut impl Read,
    path: &Path,
    index: &mut RangeLshIndex<C>,
) -> Result<()> {
    let mut tag = [0u8; 1];
    match r.read_exact(&mut tag) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
        Err(e) => return Err(e.into()),
        Ok(()) => {}
    }
    match tag[0] {
        0 => Ok(()),
        1 => {
            let tables = read_mih_tables(r, path, index)?;
            index.set_mih(tables)
        }
        other => anyhow::bail!("{}: unknown MIH section tag {other}", path.display()),
    }
}

/// Read the v3 MIH section: the tag byte is mandatory and the section is
/// CRC-verified *before* the tables are installed, so a torn section
/// never half-installs.
fn read_mih_checked<C: CodeWord, R: Read>(
    r: &mut HashingReader<R>,
    path: &Path,
    index: &mut RangeLshIndex<C>,
) -> Result<()> {
    match read_u8(r)? {
        0 => {
            r.verify_section_crc("MIH")?;
            Ok(())
        }
        1 => {
            let tables = read_mih_tables(r, path, index)?;
            r.verify_section_crc("MIH")?;
            index.set_mih(tables)
        }
        other => anyhow::bail!("{}: unknown MIH section tag {other}", path.display()),
    }
}

fn read_mih_tables<C: CodeWord>(
    r: &mut impl Read,
    path: &Path,
    index: &RangeLshIndex<C>,
) -> Result<Vec<MihTable<C>>> {
    let sect_ranges = read_u32(r)? as usize;
    let sect_bits = read_u32(r)? as usize;
    ensure!(
        sect_ranges == index.n_ranges(),
        "{}: MIH section covers {sect_ranges} ranges but the index has {} \
         (corrupt section?)",
        path.display(),
        index.n_ranges()
    );
    let hash_bits = index.params().hash_bits();
    ensure!(
        sect_bits == hash_bits,
        "{}: MIH section built for {sect_bits}-bit codes but the header's \
         code_bits implies {hash_bits} hash bits per range (corrupt section?)",
        path.display()
    );
    let mut tables = Vec::with_capacity(sect_ranges);
    for j in 0..sect_ranges {
        let offsets = read_u32s(r)?;
        let values = read_u32s(r)?;
        let table = MihTable::from_parts(sect_bits, offsets, values, index.sub_table(j))
            .with_context(|| format!("{}: MIH section, range {j}", path.display()))?;
        tables.push(table);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::MipsIndex;
    use crate::util::tmp::TempPath;

    fn build_one() -> (crate::data::Dataset, RangeLshIndex<u64>) {
        let d = synthetic::longtail_sift(600, 8, 0);
        let h: NativeHasher = NativeHasher::new(8, 64, 7);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 8)).unwrap();
        (d, idx)
    }

    fn build_wide() -> (crate::data::Dataset, RangeLshIndex<Code128>) {
        let d = synthetic::longtail_sift(400, 8, 1);
        let params = RangeLshParams::new(128, 8);
        let h: NativeHasher<Code128> = NativeHasher::new(8, params.hash_bits(), 7);
        let idx = RangeLshIndex::build(&d, &h, params).unwrap();
        (d, idx)
    }

    /// Write `index` in the legacy v1 layout (no width header, plain u64
    /// codes) — what pre-refactor builds produced.
    fn save_v1(index: &RangeLshIndex<u64>, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC_V1)?;
        write_params_and_ranges(index, &mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Write `index` in the legacy v2 layout (width header, no checksums,
    /// no MIH section) — what pre-v3 builds produced.
    fn save_v2(index: &RangeLshIndex<u64>, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC_V2)?;
        write_u32(&mut w, 1)?;
        write_params_and_ranges(index, &mut w)?;
        w.flush()?;
        Ok(())
    }

    #[test]
    fn round_trip_preserves_probe_behaviour() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh");
        save_range_index(&idx, tmp.path()).unwrap();
        // The atomic save staged through a sibling and renamed: no .tmp
        // left behind.
        assert!(!tmp_sibling(tmp.path()).exists(), "stale staging file");
        let loaded = load_range_index(tmp.path()).unwrap();

        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.n_ranges(), idx.n_ranges());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let (sa, sb) = (idx.stats(), loaded.stats());
        assert_eq!(sa.n_buckets, sb.n_buckets);
        assert_eq!(sa.largest_bucket, sb.largest_bucket);

        // Probe results must be identical (same codes, same schedule; the
        // arena order is preserved by the (code, id) pair flattening).
        let q = synthetic::gaussian_queries(5, 8, 1);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Existing single-word index files round-trip through the new
        // reader (satellite: back-compat path).
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-v1");
        save_v1(&idx, tmp.path()).unwrap();
        let loaded = load_range_index(tmp.path()).unwrap();
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let q = synthetic::gaussian_queries(3, 8, 2);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 50, &mut a);
            loaded.probe(q.row(qi), 50, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn wide_round_trip_preserves_probe_behaviour() {
        let (_, idx) = build_wide();
        let tmp = TempPath::new("rlsh-wide");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = match load_any_range_index(tmp.path()).unwrap() {
            AnyRangeLshIndex::W128(i) => i,
            other => panic!("expected 128-bit index, got {} words", other.code_words()),
        };
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let (sa, sb) = (idx.stats(), loaded.stats());
        assert_eq!(sa.n_buckets, sb.n_buckets);
        // L = 128, m = 8 ⇒ 3 id bits ⇒ 125 hash bits per range.
        assert_eq!(sa.hash_bits, 125);
        assert_eq!(sb.hash_bits, 125);
        let q = synthetic::gaussian_queries(5, 8, 3);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn scalar_loader_rejects_wide_files_with_clear_error() {
        // Satellite: the failure path must name the stored width instead
        // of corrupting or panicking.
        let (_, idx) = build_wide();
        let tmp = TempPath::new("rlsh-wide-err");
        save_range_index(&idx, tmp.path()).unwrap();
        let err = load_range_index(tmp.path()).expect_err("u64 loader must refuse a wide file");
        let msg = format!("{err:#}");
        assert!(msg.contains("128-bit"), "unhelpful error: {msg}");
    }

    #[test]
    fn rejects_garbage_files() {
        let tmp = TempPath::new("rlsh-garbage");
        std::fs::write(tmp.path(), b"definitely not an index").unwrap();
        assert!(load_range_index(tmp.path()).is_err());
        assert!(load_any_range_index(tmp.path()).is_err());
    }

    #[test]
    fn rejects_unsupported_word_count() {
        // A v2 header claiming 3 words per code is invalid.
        let tmp = TempPath::new("rlsh-badwidth");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&3u32.to_le_bytes());
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_any_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported code width"));
    }

    #[test]
    fn rejects_corrupt_projection_header() {
        // A plausible-looking v2 file whose projection width is zero must
        // fail with a Result error, not trip an assert.
        let tmp = TempPath::new("rlsh-badproj");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // code_words
        bytes.extend_from_slice(&16u32.to_le_bytes()); // code_bits
        bytes.extend_from_slice(&8u32.to_le_bytes()); // n_partitions
        bytes.push(0); // scheme tag
        bytes.extend_from_slice(&0.1f32.to_le_bytes()); // epsilon
        bytes.extend_from_slice(&100u64.to_le_bytes()); // n_items
        bytes.extend_from_slice(&9u32.to_le_bytes()); // dim_in
        bytes.extend_from_slice(&0u32.to_le_bytes()); // width 0: implausible
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_any_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("projection shape"), "{err:#}");
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_range_index("/no/such/index.rlsh")
            .err()
            .expect("loading a missing file must fail");
        assert!(format!("{err:#}").contains("/no/such/index.rlsh"));
    }

    #[test]
    fn mih_section_round_trips() {
        let (_, mut idx) = build_wide();
        idx.enable_mih();
        let tmp = TempPath::new("rlsh-mih");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = match load_any_range_index(tmp.path()).unwrap() {
            AnyRangeLshIndex::W128(i) => i,
            other => panic!("expected 128-bit index, got {} words", other.code_words()),
        };
        // The chunk tables came from the file, not a rebuild — and the
        // probe stream through them matches the saved index's.
        assert!(loaded.has_mih());
        let q = synthetic::gaussian_queries(5, 8, 4);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn files_without_mih_section_load_without_tables() {
        // v3 without tables (tag 0), v2 (clean EOF) and v1 (clean EOF)
        // all load MIH-less; callers rebuild via enable_mih on demand.
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-nomih");
        save_range_index(&idx, tmp.path()).unwrap();
        assert!(!load_range_index(tmp.path()).unwrap().has_mih());
        let tmp_v2 = TempPath::new("rlsh-nomih-v2");
        save_v2(&idx, tmp_v2.path()).unwrap();
        assert!(!load_range_index(tmp_v2.path()).unwrap().has_mih());
        let tmp_v1 = TempPath::new("rlsh-nomih-v1");
        save_v1(&idx, tmp_v1.path()).unwrap();
        assert!(!load_range_index(tmp_v1.path()).unwrap().has_mih());
    }

    #[test]
    fn legacy_v2_files_still_load() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-v2");
        save_v2(&idx, tmp.path()).unwrap();
        let loaded = load_range_index(tmp.path()).unwrap();
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let q = synthetic::gaussian_queries(3, 8, 5);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 50, &mut a);
            loaded.probe(q.row(qi), 50, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn rejects_trailing_garbage_every_version() {
        // Regression: padded buffers (a partial overwrite, a glued-on
        // download) must be rejected, not silently accepted. Zero padding
        // is the sneakiest case for v1/v2 — its first byte looks like an
        // absent-MIH tag — so both paddings are exercised per version.
        let (_, idx) = build_one();
        let save_as: [(&str, fn(&RangeLshIndex<u64>, &Path) -> Result<()>); 3] = [
            ("v1", save_v1),
            ("v2", save_v2),
            ("v3", |i, p| save_range_index(i, p)),
        ];
        for (version, save) in save_as {
            let tmp = TempPath::new("rlsh-padded");
            save(&idx, tmp.path()).unwrap();
            let clean = std::fs::read(tmp.path()).unwrap();
            for (kind, pad) in [("zeros", &[0u8; 8][..]), ("text", b"garbage!")] {
                let mut padded = clean.clone();
                padded.extend_from_slice(pad);
                std::fs::write(tmp.path(), &padded).unwrap();
                let err = load_range_index(tmp.path())
                    .expect_err(&format!("{version}+{kind} padding must be rejected"));
                let msg = format!("{err:#}");
                assert!(
                    msg.contains("trailing garbage") || msg.contains("MIH"),
                    "{version}+{kind}: unhelpful error: {msg}"
                );
            }
            // The pristine bytes still load.
            std::fs::write(tmp.path(), &clean).unwrap();
            load_range_index(tmp.path())
                .unwrap_or_else(|e| panic!("{version} clean reload: {e:#}"));
        }
    }

    #[test]
    fn bit_flip_in_required_section_names_the_section() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-flip");
        save_range_index(&idx, tmp.path()).unwrap();
        let clean = std::fs::read(tmp.path()).unwrap();
        // v3 layout offsets: header = magic 8 + code_words 4 + params 21,
        // CRC at 33..37; projection floats start at 53; the MIH-less tail
        // is ranges CRC (4) + tag (1) + MIH CRC (4) = last 9 bytes.
        assert!(clean.len() > 110, "layout assumption broken: {}", clean.len());
        let cases = [
            (16usize, "header"), // n_partitions field
            (100, "projection"), // inside the float panel
            (clean.len() - 10, "ranges"), // last payload byte of ranges
        ];
        for (offset, section) in cases {
            let mut bad = clean.clone();
            bad[offset] ^= 0x40;
            std::fs::write(tmp.path(), &bad).unwrap();
            let err = load_range_index(tmp.path())
                .expect_err(&format!("bit flip at {offset} must be rejected"));
            let msg = format!("{err:#}");
            assert!(msg.contains(section), "flip at {offset}: wrong section in: {msg}");
            assert!(msg.contains("checksum mismatch"), "flip at {offset}: {msg}");
        }
    }

    #[test]
    fn corrupt_mih_section_degrades_to_rebuild_on_demand() {
        // A bit flip in the optional MIH section must not kill the load:
        // the index comes back MIH-less and probes exactly like a fresh
        // build without tables.
        let (_, mut idx) = build_one();
        idx.enable_mih();
        let tmp = TempPath::new("rlsh-mihflip");
        save_range_index(&idx, tmp.path()).unwrap();
        let clean = std::fs::read(tmp.path()).unwrap();
        let (_, oracle) = build_one(); // same seeds, no MIH
        for offset in [clean.len() - 3, clean.len() - 20] {
            let mut bad = clean.clone();
            bad[offset] ^= 0x08;
            std::fs::write(tmp.path(), &bad).unwrap();
            let loaded = load_range_index(tmp.path())
                .unwrap_or_else(|e| panic!("MIH flip at {offset} must degrade, got: {e:#}"));
            assert!(!loaded.has_mih(), "flip at {offset}: corrupt tables installed");
            let q = synthetic::gaussian_queries(3, 8, 6);
            for qi in 0..q.len() {
                let (mut a, mut b) = (Vec::new(), Vec::new());
                oracle.probe(q.row(qi), 50, &mut a);
                loaded.probe(q.row(qi), 50, &mut b);
                assert_eq!(a, b, "flip at {offset}, query {qi}");
            }
        }
    }

    #[test]
    fn truncated_file_names_the_failing_section() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh-trunc");
        save_range_index(&idx, tmp.path()).unwrap();
        let clean = std::fs::read(tmp.path()).unwrap();
        // Mid-projection cut.
        std::fs::write(tmp.path(), &clean[..60]).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("projection"), "{err:#}");
        // Mid-ranges cut (drop the 9-byte tail plus some range payload).
        std::fs::write(tmp.path(), &clean[..clean.len() - 40]).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("ranges"), "{err:#}");
        // A cut inside the header is still an error (io-level is fine).
        std::fs::write(tmp.path(), &clean[..20]).unwrap();
        assert!(load_range_index(tmp.path()).is_err());
    }

    /// A MIH-less v2 file's bytes (no tag at all — legacy clean-EOF
    /// layout), ready for a hand-built MIH section to be appended.
    fn v2_bytes_without_mih(idx: &RangeLshIndex<u64>) -> Vec<u8> {
        let tmp = TempPath::new("rlsh-tailless");
        save_v2(idx, tmp.path()).unwrap();
        std::fs::read(tmp.path()).unwrap()
    }

    #[test]
    fn rejects_mih_section_disagreeing_with_header() {
        let (_, idx) = build_one();
        let base = v2_bytes_without_mih(&idx);
        let hash_bits = idx.params().hash_bits() as u32;

        // Range count mismatch.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&((idx.n_ranges() as u32) + 1).to_le_bytes());
        bad.extend_from_slice(&hash_bits.to_le_bytes());
        let tmp = TempPath::new("rlsh-mih-ranges");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("ranges"), "{err:#}");

        // Bit width mismatch vs what the header's code_bits implies.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&(idx.n_ranges() as u32).to_le_bytes());
        bad.extend_from_slice(&(hash_bits + 1).to_le_bytes());
        let tmp = TempPath::new("rlsh-mih-bits");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("hash bits"), "{err:#}");

        // Structurally broken CSR arrays surface the per-range context.
        let mut bad = base.clone();
        bad.push(1);
        bad.extend_from_slice(&(idx.n_ranges() as u32).to_le_bytes());
        bad.extend_from_slice(&hash_bits.to_le_bytes());
        write_u32s(&mut bad, &[0u32]).unwrap(); // offsets: wrong length
        write_u32s(&mut bad, &[]).unwrap(); // values
        let tmp = TempPath::new("rlsh-mih-csr");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("MIH section, range 0"), "{msg}");
        assert!(msg.contains("offsets length"), "{msg}");

        // An unknown tag byte is a clean error too, not a panic.
        let mut bad = base;
        bad.push(7);
        let tmp = TempPath::new("rlsh-mih-tag");
        std::fs::write(tmp.path(), &bad).unwrap();
        let err = load_range_index(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("MIH section tag"), "{err:#}");
    }
}
