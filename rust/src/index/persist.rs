//! Index persistence: save a built [`RangeLshIndex`] to disk (`.rlsh`) and
//! load it back without re-hashing the corpus — the build-once/serve-many
//! deployment flow (`rangelsh build` → `rangelsh serve --load`).
//!
//! Format (all little-endian): magic, version, params, projection panel,
//! then per range: `U_j`, `u_min`, and the `(code, id)` pairs of its
//! bucket table. Codes are stored masked; the table is rebuilt on load
//! (cheap — it is a single grouping pass).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context};

use crate::hash::Projection;
use crate::index::partition::{Partition, PartitionScheme};
use crate::index::range::{RangeLshIndex, RangeLshParams};
use crate::index::MipsIndex;
use crate::util::bytes::*;
use crate::Result;

const MAGIC: &[u8; 8] = b"RLSHIDX\x01";

/// Write `index` to `path`.
pub fn save_range_index(index: &RangeLshIndex, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    w.write_all(MAGIC)?;
    let p = index.params();
    write_u32(&mut w, p.code_bits as u32)?;
    write_u32(&mut w, p.n_partitions as u32)?;
    write_u8(&mut w, match p.scheme {
        PartitionScheme::Percentile => 0,
        PartitionScheme::UniformRange => 1,
    })?;
    write_f32(&mut w, p.epsilon)?;
    write_u64(&mut w, index.len() as u64)?;
    // Projection panel.
    let proj = index.projection();
    write_u32(&mut w, proj.dim_in() as u32)?;
    write_u32(&mut w, proj.width() as u32)?;
    write_f32s(&mut w, proj.flat())?;
    // Ranges.
    write_u32(&mut w, index.n_ranges() as u32)?;
    index.for_each_range(|part, table| -> Result<()> {
        write_f32(&mut w, part.u_max)?;
        write_f32(&mut w, part.u_min)?;
        // (code, ids) per bucket, flattened as aligned arrays.
        let mut codes = Vec::with_capacity(part.ids.len());
        let mut ids = Vec::with_capacity(part.ids.len());
        for (code, items) in table.buckets() {
            for &id in items {
                codes.push(code);
                ids.push(id);
            }
        }
        write_u64s(&mut w, &codes)?;
        write_u32s(&mut w, &ids)?;
        Ok(())
    })?;
    w.flush()?;
    Ok(())
}

/// Load an index previously written by [`save_range_index`].
pub fn load_range_index(path: impl AsRef<Path>) -> Result<RangeLshIndex> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "{}: not a rangelsh index", path.display());
    let code_bits = read_u32(&mut r)? as usize;
    let n_partitions = read_u32(&mut r)? as usize;
    let scheme = match read_u8(&mut r)? {
        0 => PartitionScheme::Percentile,
        1 => PartitionScheme::UniformRange,
        other => anyhow::bail!("unknown partition scheme tag {other}"),
    };
    let epsilon = read_f32(&mut r)?;
    let n_items = read_u64(&mut r)? as usize;
    let dim_in = read_u32(&mut r)? as usize;
    let width = read_u32(&mut r)? as usize;
    let flat = read_f32s(&mut r)?;
    ensure!(flat.len() == dim_in * width, "projection size mismatch");
    let proj = Arc::new(Projection::from_flat(dim_in, width, flat));
    let n_ranges = read_u32(&mut r)? as usize;
    let params = RangeLshParams::new(code_bits, n_partitions)
        .with_scheme(scheme)
        .with_epsilon(epsilon);
    let mut ranges = Vec::with_capacity(n_ranges);
    for _ in 0..n_ranges {
        let u_max = read_f32(&mut r)?;
        let u_min = read_f32(&mut r)?;
        let codes = read_u64s(&mut r)?;
        let ids = read_u32s(&mut r)?;
        ensure!(codes.len() == ids.len(), "codes/ids length mismatch");
        ranges.push((Partition { ids, u_max, u_min }, codes));
    }
    RangeLshIndex::from_parts(params, proj, n_items, ranges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::NativeHasher;
    use crate::index::MipsIndex;
    use crate::util::tmp::TempPath;

    fn build_one() -> (crate::data::Dataset, RangeLshIndex) {
        let d = synthetic::longtail_sift(600, 8, 0);
        let h = NativeHasher::new(8, 64, 7);
        let idx = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 8)).unwrap();
        (d, idx)
    }

    #[test]
    fn round_trip_preserves_probe_behaviour() {
        let (_, idx) = build_one();
        let tmp = TempPath::new("rlsh");
        save_range_index(&idx, tmp.path()).unwrap();
        let loaded = load_range_index(tmp.path()).unwrap();

        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.n_ranges(), idx.n_ranges());
        assert_eq!(loaded.u_maxes(), idx.u_maxes());
        let (sa, sb) = (idx.stats(), loaded.stats());
        assert_eq!(sa.n_buckets, sb.n_buckets);
        assert_eq!(sa.largest_bucket, sb.largest_bucket);

        // Probe results must be identical (same codes, same schedule; the
        // arena order is preserved by the (code, id) pair flattening).
        let q = synthetic::gaussian_queries(5, 8, 1);
        for qi in 0..q.len() {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            idx.probe(q.row(qi), 100, &mut a);
            loaded.probe(q.row(qi), 100, &mut b);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn rejects_garbage_files() {
        let tmp = TempPath::new("rlsh-garbage");
        std::fs::write(tmp.path(), b"definitely not an index").unwrap();
        assert!(load_range_index(tmp.path()).is_err());
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_range_index("/no/such/index.rlsh")
            .err()
            .expect("loading a missing file must fail");
        assert!(format!("{err:#}").contains("/no/such/index.rlsh"));
    }
}
