//! NORM-RANGING LSH (paper §3, Algorithms 1–2) — the contribution.
//! Generic over the code word `C` ([`CodeWord`]): `RangeLshIndex` is the
//! original `u64` (L ≤ 64) index; `RangeLshIndex<Code128>` / `<Code256>`
//! serve the high-recall regimes the 64-bit ceiling used to rule out.
//!
//! Index building (Alg. 1): rank items by 2-norm, cut into `m` ranges,
//! normalise each range by its **local** max norm `U_j`, and build an
//! independent SIMPLE-LSH table per range. Because `U_j ≪ U` for most
//! ranges on long-tailed data, the transformed inner products stay large
//! and the `sqrt(1-||x||²)` coordinate stays small — restoring both the
//! theoretical ρ (Theorem 1) and bucket balance (§3.2).
//!
//! Query processing (Alg. 2 + §3.3): hash the query once (the Eq. 8 query
//! transform does not depend on `U_j`, so one code serves all ranges),
//! group each range's buckets by matching-bit count `l`, then walk the
//! pre-sorted `(U_j, l)` schedule of [`MetricOrder`] — buckets from
//! different ranges interleave by estimated inner product `ŝ` (Eq. 12),
//! not raw Hamming distance.
//!
//! §Perf — budget-adaptive lazy probing: a range's buckets are counting-
//! sorted only when the schedule first touches that range, with the
//! budget still remaining at that moment, so a small-budget query never
//! scans the low-`U_j` ranges the schedule would not reach. The paper's
//! §3.3 complexity argument prices a query by the candidates actually
//! probed; the eager all-ranges sort ([`RangeLshIndex::probe_with_code_eager`],
//! kept as the equivalence oracle) paid O(total buckets) regardless.
//!
//! Code-length accounting: with `m` ranges, `ceil(log2 m)` bits of the
//! total budget address the range (paper §4), so each range's table uses
//! `L - ceil(log2 m)` hash bits. At equal total code length the comparison
//! against SIMPLE-LSH is fair. The arithmetic is width-independent; at
//! L > 64 the per-range budget stays large (e.g. L=128, m=64 ⇒ 122 hash
//! bits) instead of being squeezed toward zero.

use std::sync::Arc;

use crate::data::Dataset;
use crate::hash::codes::partition_id_bits;
use crate::hash::{CodeWord, ItemHasher, NativeHasher, Projection};
use crate::index::mih::MihTable;
use crate::index::partition::{partition, Partition, PartitionScheme};
use crate::index::traits::drain_bucket;
use crate::index::{
    BucketTable, CodeProbe, IndexStats, MetricOrder, MipsIndex, ProbeStats, Prober, SingleProbe,
};
use crate::{ItemId, Result};

#[cfg(doc)]
use crate::hash::{Code128, Code256};

/// Parameters for [`RangeLshIndex`].
#[derive(Debug, Clone, Copy)]
pub struct RangeLshParams {
    /// Total code budget L in bits, *including* the range-id bits.
    pub code_bits: usize,
    /// Number of norm ranges `m`.
    pub n_partitions: usize,
    /// Partitioning scheme (Alg. 1 percentile, or Fig. 3(a) uniform).
    pub scheme: PartitionScheme,
    /// Eq. 12 adjustment ε ∈ [0, 1): probing-order slack for hash noise.
    pub epsilon: f32,
}

impl RangeLshParams {
    /// Paper defaults: percentile partitioning, ε = 0.1.
    pub fn new(code_bits: usize, n_partitions: usize) -> Self {
        Self {
            code_bits,
            n_partitions,
            scheme: PartitionScheme::Percentile,
            epsilon: 0.1,
        }
    }

    pub fn with_scheme(mut self, scheme: PartitionScheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn with_epsilon(mut self, epsilon: f32) -> Self {
        self.epsilon = epsilon;
        self
    }

    /// Hash bits left after paying for the range id:
    /// `L_hash = code_bits - ceil(log2 m)` (e.g. 16-bit budget, 32 ranges
    /// ⇒ 11 hash bits — the paper's §4 example; 128-bit budget, 32 ranges
    /// ⇒ 123 hash bits). Width-independent arithmetic.
    pub fn hash_bits(&self) -> usize {
        self.code_bits.saturating_sub(partition_id_bits(self.n_partitions))
    }
}

/// One norm range's index: ids, local max norm, bucket table.
///
/// `Arc`-shared between index epochs (see [`crate::index::mutable`]): a
/// mutation that touches one range clones `m` `Arc`s and rebuilds only the
/// touched range's table, so the untouched ranges are structurally shared
/// between the pre- and post-mutation indexes.
pub(crate) struct SubIndex<C: CodeWord> {
    pub(crate) part: Partition,
    pub(crate) table: BucketTable<C>,
}

/// A built NORM-RANGING LSH index over `C`-wide codes.
pub struct RangeLshIndex<C: CodeWord = u64> {
    subs: Vec<Arc<SubIndex<C>>>,
    order: MetricOrder,
    proj: Arc<Projection>,
    /// Query hasher over the shared panel, built once at index build —
    /// the query path allocates neither a hasher nor a code vector.
    qhasher: NativeHasher<C>,
    params: RangeLshParams,
    n_items: usize,
    /// Per-range MIH chunk tables (the sub-linear candidate-generation
    /// backend), present iff [`Self::enable_mih`] ran — probers use them
    /// automatically when attached. Aligned with `subs`; `Arc`-shared
    /// across epochs like the sub-indexes themselves.
    mih: Option<Vec<Arc<MihTable<C>>>>,
}

impl<C: CodeWord> RangeLshIndex<C> {
    /// Build per Algorithm 1. `hasher` does the bulk hashing (native or
    /// PJRT); each range is hashed with its own `U_j`.
    pub fn build(
        dataset: &Dataset,
        hasher: &dyn ItemHasher<C>,
        params: RangeLshParams,
    ) -> Result<Self> {
        anyhow::ensure!(params.n_partitions >= 1, "need at least one partition");
        let hash_bits = params.hash_bits();
        anyhow::ensure!(
            hash_bits >= 1,
            "code budget {} too small for {} partitions ({} id bits)",
            params.code_bits,
            params.n_partitions,
            partition_id_bits(params.n_partitions)
        );
        anyhow::ensure!(
            hash_bits <= hasher.width(),
            "hash bits {hash_bits} exceed hasher width {}",
            hasher.width()
        );
        anyhow::ensure!(
            hash_bits <= C::MAX_BITS,
            "hash bits {hash_bits} exceed the {}-bit code word",
            C::MAX_BITS
        );
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        anyhow::ensure!(dataset.max_norm() > 0.0, "dataset max norm must be positive");

        let parts = partition(dataset, params.n_partitions, params.scheme)?;
        let mut subs = Vec::with_capacity(parts.len());
        for part in parts {
            // Alg. 1 lines 6–7: normalise S_j by U_j, SIMPLE-LSH-index it.
            let rows = dataset.gather(&part.ids);
            let codes = hasher.hash_items(rows.flat(), part.u_max)?;
            let table = BucketTable::build(&codes, Some(&part.ids), hash_bits);
            subs.push(Arc::new(SubIndex { part, table }));
        }
        let u_maxes: Vec<f32> = subs.iter().map(|s| s.part.u_max).collect();
        let order = MetricOrder::build(&u_maxes, hash_bits, params.epsilon);
        let proj = hasher.projection().clone();
        Ok(Self {
            subs,
            order,
            qhasher: NativeHasher::with_projection(proj.clone()),
            proj,
            params,
            n_items: dataset.len(),
            mih: None,
        })
    }

    /// Hash one query through the cached hasher (alloc-free: the Eq. 8
    /// transform reuses a thread-local buffer).
    pub fn hash_query(&self, query: &[f32]) -> C {
        self.qhasher.hash_query_one(query).expect("query row length matches index dim")
    }

    pub fn params(&self) -> &RangeLshParams {
        &self.params
    }

    /// Number of non-empty ranges actually built.
    pub fn n_ranges(&self) -> usize {
        self.subs.len()
    }

    /// Local max norms `U_j`, ascending range order (Fig. 1(d) material).
    pub fn u_maxes(&self) -> Vec<f32> {
        self.subs.iter().map(|s| s.part.u_max).collect()
    }

    pub fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }

    /// The §3.3 probing schedule (exposed for tests/diagnostics).
    pub fn metric_order(&self) -> &MetricOrder {
        &self.order
    }

    /// Visit every range's partition + bucket table (index persistence).
    pub fn for_each_range<E>(
        &self,
        mut f: impl FnMut(&Partition, &BucketTable<C>) -> std::result::Result<(), E>,
    ) -> std::result::Result<(), E> {
        for sub in &self.subs {
            f(&sub.part, &sub.table)?;
        }
        Ok(())
    }

    /// Reassemble an index from persisted parts: params, shared panel,
    /// and per range its partition plus the *masked* per-item codes
    /// aligned with `partition.ids`. Rebuilds tables and the metric
    /// schedule; used by [`crate::index::persist::load_range_index`].
    pub fn from_parts(
        params: RangeLshParams,
        proj: Arc<Projection>,
        n_items: usize,
        ranges: Vec<(Partition, Vec<C>)>,
    ) -> Result<Self> {
        let hash_bits = params.hash_bits();
        anyhow::ensure!(hash_bits >= 1, "bad params: zero hash bits");
        anyhow::ensure!(hash_bits <= C::MAX_BITS, "bad params: hash bits exceed code word");
        let total: usize = ranges.iter().map(|(p, _)| p.ids.len()).sum();
        anyhow::ensure!(total == n_items, "ranges hold {total} items, expected {n_items}");
        let mut subs = Vec::with_capacity(ranges.len());
        for (part, codes) in ranges {
            anyhow::ensure!(codes.len() == part.ids.len(), "codes/ids mismatch");
            let table = BucketTable::build(&codes, Some(&part.ids), hash_bits);
            subs.push(Arc::new(SubIndex { part, table }));
        }
        let u_maxes: Vec<f32> = subs.iter().map(|s| s.part.u_max).collect();
        let order = MetricOrder::build(&u_maxes, hash_bits, params.epsilon);
        let qhasher = NativeHasher::with_projection(proj.clone());
        Ok(Self { subs, order, proj, qhasher, params, n_items, mih: None })
    }

    /// Assemble an epoch from already-built, `Arc`-shared range
    /// sub-indexes (the [`crate::index::mutable`] mutation path): only the
    /// ranges a mutation touched carry fresh tables; the rest are the
    /// previous epoch's `Arc`s verbatim. The metric schedule is rebuilt
    /// (it is a few hundred bytes), and the optional MIH tables must be
    /// aligned with `subs` when present.
    pub(crate) fn from_shared(
        params: RangeLshParams,
        proj: Arc<Projection>,
        n_items: usize,
        subs: Vec<Arc<SubIndex<C>>>,
        mih: Option<Vec<Arc<MihTable<C>>>>,
    ) -> Result<Self> {
        let hash_bits = params.hash_bits();
        anyhow::ensure!(hash_bits >= 1, "bad params: zero hash bits");
        let total: usize = subs.iter().map(|s| s.part.ids.len()).sum();
        anyhow::ensure!(total == n_items, "ranges hold {total} items, expected {n_items}");
        if let Some(tables) = &mih {
            anyhow::ensure!(
                tables.len() == subs.len(),
                "MIH tables ({}) not aligned with ranges ({})",
                tables.len(),
                subs.len()
            );
        }
        let u_maxes: Vec<f32> = subs.iter().map(|s| s.part.u_max).collect();
        let order = MetricOrder::build(&u_maxes, hash_bits, params.epsilon);
        let qhasher = NativeHasher::with_projection(proj.clone());
        Ok(Self { subs, order, proj, qhasher, params, n_items, mih })
    }

    /// The `Arc`-shared range sub-indexes, ascending norm order (the
    /// mutation layer clones these to assemble the next epoch).
    pub(crate) fn shared_subs(&self) -> &[Arc<SubIndex<C>>] {
        &self.subs
    }

    /// Enable the MIH candidate-generation backend
    /// ([`crate::index::mih`]): build the per-range chunk tables if
    /// absent. Idempotent; probers use the tables whenever present, and
    /// the emitted candidate stream is element-for-element identical to
    /// the counting sort's (property-tested).
    pub fn enable_mih(&mut self) {
        if self.mih.is_none() {
            self.mih =
                Some(self.subs.iter().map(|s| Arc::new(MihTable::build(&s.table))).collect());
        }
    }

    /// Drop the MIH tables: probing falls back to the counting sort.
    pub fn clear_mih(&mut self) {
        self.mih = None;
    }

    /// Whether MIH tables are attached.
    pub fn has_mih(&self) -> bool {
        self.mih.is_some()
    }

    /// Per-range MIH tables, range order (persistence + mutation layer).
    pub(crate) fn mih_tables(&self) -> Option<&[Arc<MihTable<C>>]> {
        self.mih.as_deref()
    }

    /// Attach loaded MIH tables, one per range in range order
    /// (persistence; each table is already validated against its range's
    /// rebuilt bucket table).
    pub(crate) fn set_mih(&mut self, tables: Vec<MihTable<C>>) -> Result<()> {
        anyhow::ensure!(
            tables.len() == self.subs.len(),
            "MIH section holds {} tables for {} ranges",
            tables.len(),
            self.subs.len()
        );
        self.mih = Some(tables.into_iter().map(Arc::new).collect());
        Ok(())
    }

    /// One range's bucket table (persistence/tests/diagnostics).
    // staticcheck: allow(panic-reach, "j enumerates this index's own range count at every call site (persistence and diagnostics)")
    pub(crate) fn sub_table(&self, j: usize) -> &BucketTable<C> {
        &self.subs[j].table
    }
}

impl<C: CodeWord> MipsIndex for RangeLshIndex<C> {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        self.probe_with_code(self.hash_query(query), budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        Box::new(self.session(self.hash_query(query)))
    }

    fn len(&self) -> usize {
        self.n_items
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.n_items,
            n_buckets: self.subs.iter().map(|s| s.table.n_buckets()).sum(),
            largest_bucket: self
                .subs
                .iter()
                .map(|s| s.table.largest_bucket())
                .max()
                .unwrap_or(0),
            hash_bits: self.params.hash_bits(),
            n_partitions: self.subs.len(),
        }
    }
}

/// Probe session scratch: one sort buffer per range plus the lazy
/// probing state (which ranges have been sorted for the session's query).
#[derive(Default)]
struct ProbeScratch {
    per_sub: Vec<crate::index::bucket::SortScratch>,
    sorted: Vec<bool>,
}

impl ProbeScratch {
    /// Size for `m` ranges and mark every range unsorted (one memset of
    /// `m` bytes per query — negligible next to even a single bucket scan).
    fn reset(&mut self, m: usize) {
        if self.per_sub.len() < m {
            self.per_sub.resize_with(m, Default::default);
        }
        self.sorted.clear();
        self.sorted.resize(m, false);
    }
}

thread_local! {
    /// Per-thread [`ProbeScratch`] pool: a session takes a scratch at
    /// open and returns it on drop, so the one-shot probe wrappers —
    /// which open and drop a session within one call — make no
    /// allocations once a thread is warm (§Perf), while long-lived
    /// sessions keep their scratch alive across `extend` calls. The
    /// scratch is width-independent, so every `C` instantiation shares
    /// the pool.
    static SCRATCH_POOL: std::cell::RefCell<Vec<ProbeScratch>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

fn take_probe_scratch(m: usize) -> ProbeScratch {
    let mut sc = SCRATCH_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    sc.reset(m);
    sc
}

fn return_probe_scratch(sc: ProbeScratch) {
    SCRATCH_POOL.with(|p| p.borrow_mut().push(sc));
}

/// Resumable RANGE-LSH probe session (§3.3 + §Perf): keeps the lazy
/// `(U_j, l)` schedule cursor and every range's budget-adaptive
/// [`crate::index::SortScratch`] alive across [`Prober::extend`] calls,
/// so asking for the *next* batch of candidates continues the walk where
/// the previous call stopped — no range is rescanned, and ranges the
/// schedule has not reached stay untouched. Created by
/// [`RangeLshIndex::session`] (or the boxed trait forms
/// [`MipsIndex::prober`] / [`CodeProbe::prober_with_code`]).
pub struct RangeProber<'a, C: CodeWord = u64> {
    index: &'a RangeLshIndex<C>,
    qcode: C,
    scratch: ProbeScratch,
    /// Position in the pre-sorted `(U_j, l)` schedule.
    sched_pos: usize,
    /// Offset into the current schedule entry's `order` slice.
    bucket: usize,
    /// Offset into the current bucket's items.
    item: usize,
    stats: ProbeStats,
    done: bool,
}

impl<'a, C: CodeWord> RangeProber<'a, C> {
    fn new(index: &'a RangeLshIndex<C>, qcode: C) -> Self {
        Self {
            index,
            qcode,
            scratch: take_probe_scratch(index.subs.len()),
            sched_pos: 0,
            bucket: 0,
            item: 0,
            stats: ProbeStats::default(),
            done: false,
        }
    }
}

impl<C: CodeWord> Drop for RangeProber<'_, C> {
    fn drop(&mut self) {
        return_probe_scratch(std::mem::take(&mut self.scratch));
    }
}

impl<C: CodeWord> Prober for RangeProber<'_, C> {
    /// Budget-adaptive lazy walk. Range `j` is counting-sorted only when
    /// the schedule *first* touches it, with the budget still remaining
    /// at that moment, and each sort materializes only the levels that
    /// budget can reach ([`BucketTable::counting_sort_partial`]) — so a
    /// small request sorts one or two ranges instead of all `m`.
    ///
    /// Within one `extend`, the walk never reads below a range's
    /// materialization floor: the schedule visits a fixed range's levels
    /// in strictly descending order (`ŝ` is strictly increasing in `l`
    /// for fixed `U_j`), so reaching a level below the floor would mean
    /// the >= budget items above it were all emitted and the call already
    /// returned. Across `extend` calls the floor *can* be undercut — a
    /// resumed session carries more budget than the range was sorted for
    /// — and the walk then re-sorts that range to full depth, dropping
    /// its floor to zero, so each range re-materializes at most once per
    /// session. Sorting is pure, so the re-materialized slices agree
    /// bit-for-bit with the earlier walk, and the candidate stream
    /// remains element-for-element the eager oracle's
    /// ([`RangeLshIndex::probe_with_code_eager`], property-tested).
    // staticcheck: allow(panic-reach, "sched_pos < entries.len() is the loop guard and (j, l) come from the schedule built over this index's ranges and levels")
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        if additional_budget == 0 || self.done {
            return 0;
        }
        let index = self.index;
        let entries = index.order.entries();
        let mut remaining = additional_budget;
        while self.sched_pos < entries.len() {
            let (j, l) = entries[self.sched_pos];
            let (j, l) = (j as usize, l as usize);
            let sub = &index.subs[j];
            if !self.scratch.sorted[j] {
                // First touch: rank this range's buckets for the budget
                // still remaining — through the MIH chunk tables when
                // attached (popcounting only the buckets the Hamming-ball
                // walk discovers), else the dense counting sort. Both fill
                // the same level slices, so the walk below is shared.
                if let Some(mih) = index.mih.as_deref() {
                    self.stats.buckets_scanned += mih[j].rank_partial(
                        &sub.table,
                        self.qcode,
                        remaining,
                        &mut self.scratch.per_sub[j],
                    );
                } else {
                    sub.table.counting_sort_partial(
                        self.qcode,
                        remaining,
                        &mut self.scratch.per_sub[j],
                    );
                    self.stats.buckets_scanned += sub.table.n_buckets();
                }
                self.scratch.sorted[j] = true;
                self.stats.ranges_sorted += 1;
            }
            if l < self.scratch.per_sub[j].floor as usize {
                // Session resumed below this range's floor: re-sort to
                // full depth (floor drops to zero, so this happens at
                // most once per range per session — see the method docs).
                sub.table.counting_sort_by_matches(self.qcode, &mut self.scratch.per_sub[j]);
                self.stats.ranges_resorted += 1;
                self.stats.buckets_scanned += sub.table.n_buckets();
            }
            let s = &self.scratch.per_sub[j];
            let lo = s.levels[l] as usize;
            let hi = s.levels[l + 1] as usize;
            while self.bucket < hi - lo {
                let b = self.scratch.per_sub[j].order[lo + self.bucket] as usize;
                let finished = drain_bucket(
                    sub.table.bucket_items(b),
                    &mut self.item,
                    &mut remaining,
                    out,
                    &mut self.stats,
                );
                if finished {
                    self.bucket += 1;
                }
                if remaining == 0 {
                    self.stats.items_emitted += additional_budget;
                    return additional_budget;
                }
            }
            self.bucket = 0;
            self.sched_pos += 1;
        }
        self.done = true;
        let emitted = additional_budget - remaining;
        self.stats.items_emitted += emitted;
        emitted
    }

    fn is_exhausted(&self) -> bool {
        self.done
    }

    fn stats(&self) -> ProbeStats {
        self.stats
    }

    /// Suffix maximum of `U_j` over the remaining schedule. Valid
    /// mid-bucket too: a partially drained bucket belongs to the entry at
    /// `sched_pos`, whose `U_j` the suffix maximum includes.
    fn norm_bound(&self) -> Option<f32> {
        Some(self.index.order.remaining_u_max(self.sched_pos))
    }
}

impl<C: CodeWord> RangeLshIndex<C> {
    /// Open a resumable probe session over a precomputed code — the
    /// concrete-type form of [`CodeProbe::prober_with_code`] (no box),
    /// used by the one-shot wrappers and the hotpath bench.
    pub fn session(&self, qcode: C) -> RangeProber<'_, C> {
        RangeProber::new(self, qcode)
    }

    /// One-shot probe with instrumentation: a fresh session extended once
    /// by `budget` (the session *is* the probe implementation; this
    /// wrapper exists for callers that want the final [`ProbeStats`]).
    pub fn probe_with_code_stats(
        &self,
        qcode: C,
        budget: usize,
        out: &mut Vec<ItemId>,
    ) -> ProbeStats {
        let mut session = self.session(qcode);
        session.extend(budget, out);
        session.stats()
    }

    /// The pre-lazy-refactor eager probe: counting-sort **every** range up
    /// front, then walk the schedule. Kept as the equivalence oracle for
    /// [`CodeProbe::probe_with_code`] (property tests assert the streams
    /// are identical at every budget, one-shot or resumed) and as the
    /// baseline the hotpath bench's eager-vs-lazy probe-budget rows
    /// measure against.
    pub fn probe_with_code_eager(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>) {
        let mut sc = take_probe_scratch(self.subs.len());
        // Per-range counting sort: one O(total buckets) pass (§3.3).
        for (sub, s) in self.subs.iter().zip(sc.per_sub.iter_mut()) {
            sub.table.counting_sort_by_matches(qcode, s);
        }
        // Walk the pre-sorted (U_j, l) schedule.
        let mut remaining = budget;
        'walk: for &(j, l) in self.order.entries() {
            let sub = &self.subs[j as usize];
            let s = &sc.per_sub[j as usize];
            let (lo, hi) = (s.levels[l as usize] as usize, s.levels[l as usize + 1] as usize);
            for &b in &s.order[lo..hi] {
                let bucket = sub.table.bucket_items(b as usize);
                if remaining == 0 {
                    break 'walk;
                }
                let take = bucket.len().min(remaining);
                out.extend_from_slice(&bucket[..take]);
                remaining -= take;
            }
        }
        return_probe_scratch(sc);
    }
}

impl<C: CodeWord> CodeProbe<C> for RangeLshIndex<C> {
    fn probe_with_code(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>) {
        self.session(qcode).extend(budget, out);
    }

    fn prober_with_code(&self, qcode: C) -> Box<dyn Prober + '_> {
        Box::new(self.session(qcode))
    }
}

impl<C: CodeWord> SingleProbe for RangeLshIndex<C> {
    /// Single-probe protocol: visit the query-code bucket in every range
    /// (the multi-table supplementary experiment).
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>) {
        let qcode = self.hash_query(query);
        for sub in &self.subs {
            if let Some(items) = sub.table.exact(qcode) {
                out.extend_from_slice(items);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::{Code128, Code256};
    use crate::index::simple::{SimpleLshIndex, SimpleLshParams};

    fn build(d: &Dataset, bits: usize, m: usize) -> RangeLshIndex {
        let h: NativeHasher = NativeHasher::new(d.dim(), 64, 99);
        RangeLshIndex::build(d, &h, RangeLshParams::new(bits, m)).unwrap()
    }

    #[test]
    fn hash_bit_accounting_matches_paper_examples() {
        // §4: 16-bit code + 32 ranges ⇒ 5 id bits + 11 hash bits.
        assert_eq!(RangeLshParams::new(16, 32).hash_bits(), 11);
        assert_eq!(RangeLshParams::new(32, 64).hash_bits(), 26);
        assert_eq!(RangeLshParams::new(64, 128).hash_bits(), 57);
        assert_eq!(RangeLshParams::new(16, 1).hash_bits(), 16);
        // The wide regimes this refactor opens up: the per-range budget
        // stays large instead of being squeezed toward zero.
        assert_eq!(RangeLshParams::new(128, 32).hash_bits(), 123);
        assert_eq!(RangeLshParams::new(128, 64).hash_bits(), 122);
        assert_eq!(RangeLshParams::new(256, 128).hash_bits(), 249);
    }

    #[test]
    fn probe_covers_everything_and_is_unique() {
        let d = synthetic::longtail_sift(500, 8, 0);
        let idx = build(&d, 16, 8);
        let q = synthetic::gaussian_queries(1, 8, 3);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), d.len());
    }

    #[test]
    fn budget_is_respected() {
        let d = synthetic::longtail_sift(500, 8, 1);
        let idx = build(&d, 16, 8);
        let q = synthetic::gaussian_queries(1, 8, 4);
        let mut out = Vec::new();
        idx.probe(q.row(0), 37, &mut out);
        assert_eq!(out.len(), 37);
    }

    #[test]
    fn probe_order_follows_metric_schedule() {
        let d = synthetic::longtail_sift(400, 8, 2);
        let idx = build(&d, 16, 4);
        let q = synthetic::gaussian_queries(1, 8, 5);
        let qcode = idx.hash_query(q.row(0));
        let mut out = Vec::new();
        idx.probe_with_code(qcode, usize::MAX, &mut out);
        // Reconstruct each emitted item's (j, l) and check the schedule
        // positions are non-decreasing.
        let hash_bits = idx.params().hash_bits();
        let mask = crate::hash::mask_bits(hash_bits);
        let h: NativeHasher = NativeHasher::with_projection(idx.projection().clone());
        let mut schedule_pos = std::collections::HashMap::new();
        for (pos, &(j, l)) in idx.metric_order().entries().iter().enumerate() {
            schedule_pos.insert((j, l), pos);
        }
        // item -> (j, l)
        let mut item_jl = std::collections::HashMap::new();
        for (j, u_j) in idx.u_maxes().iter().enumerate() {
            // recompute codes for the items of range j
            for (code, ids) in idx.sub_table(j).buckets() {
                let _ = code;
                for &id in ids {
                    let codes = h.hash_items(d.row(id as usize), *u_j).unwrap();
                    let l = crate::hash::matches(codes[0] & mask, qcode & mask, hash_bits);
                    item_jl.insert(id, (j as u32, l));
                }
            }
        }
        let mut prev = 0usize;
        for id in out {
            let pos = schedule_pos[&item_jl[&id]];
            assert!(pos >= prev, "probe order violates metric schedule");
            prev = pos;
        }
    }

    #[test]
    fn m1_percentile_equals_simple_lsh_order_grouping() {
        // With one range, RANGE-LSH degenerates to SIMPLE-LSH: same U, same
        // panel ⇒ identical buckets and Hamming probing order grouping.
        let d = synthetic::longtail_sift(300, 8, 3);
        let h: NativeHasher = NativeHasher::new(8, 64, 42);
        let r = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 1)).unwrap();
        let s = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 6);
        let (mut ro, mut so) = (Vec::new(), Vec::new());
        r.probe(q.row(0), usize::MAX, &mut ro);
        s.probe(q.row(0), usize::MAX, &mut so);
        assert_eq!(ro.len(), so.len());
        // Same multiset; order may differ within equal-l groups only.
        let (mut rs, mut ss) = (ro.clone(), so.clone());
        rs.sort_unstable();
        ss.sort_unstable();
        assert_eq!(rs, ss);
        let rstats = r.stats();
        let sstats = s.stats();
        assert_eq!(rstats.n_buckets, sstats.n_buckets);
        assert_eq!(rstats.largest_bucket, sstats.largest_bucket);
    }

    #[test]
    fn bucket_balance_beats_simple_on_longtail_data() {
        // The §3.2 claim: RANGE-LSH spreads items over far more buckets.
        let d = synthetic::longtail_sift(5000, 16, 4);
        let h: NativeHasher = NativeHasher::new(16, 64, 7);
        let r = RangeLshIndex::build(&d, &h, RangeLshParams::new(16, 32)).unwrap();
        let s = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).unwrap();
        let (rs, ss) = (r.stats(), s.stats());
        assert!(
            rs.largest_bucket * 2 < ss.largest_bucket,
            "RANGE largest {} should be well under SIMPLE largest {}",
            rs.largest_bucket,
            ss.largest_bucket
        );
        assert!(rs.n_buckets > ss.n_buckets);
    }

    #[test]
    fn rejects_budget_smaller_than_id_bits() {
        let d = synthetic::longtail_sift(100, 8, 0);
        let h: NativeHasher = NativeHasher::new(8, 64, 0);
        // 128 partitions need 7 id bits; a 7-bit budget leaves 0 hash bits.
        assert!(RangeLshIndex::build(&d, &h, RangeLshParams::new(7, 128)).is_err());
    }

    #[test]
    fn stats_count_partitions_and_buckets() {
        let d = synthetic::longtail_sift(1000, 8, 5);
        let idx = build(&d, 16, 16);
        let s = idx.stats();
        assert_eq!(s.n_partitions, 16);
        assert_eq!(s.n_items, 1000);
        assert_eq!(s.hash_bits, 12);
        assert!(s.n_buckets >= 16);
    }

    #[test]
    fn uniform_scheme_builds_and_probes() {
        let d = synthetic::longtail_sift(800, 8, 6);
        let h: NativeHasher = NativeHasher::new(8, 64, 1);
        let idx = RangeLshIndex::build(
            &d,
            &h,
            RangeLshParams::new(16, 16).with_scheme(PartitionScheme::UniformRange),
        )
        .unwrap();
        let q = synthetic::gaussian_queries(1, 8, 7);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
    }

    #[test]
    fn probe_exact_hits_every_range_at_most_once() {
        let d = synthetic::longtail_sift(500, 8, 8);
        let idx = build(&d, 16, 8);
        let q = synthetic::gaussian_queries(1, 8, 9);
        let mut out = Vec::new();
        idx.probe_exact(q.row(0), &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), out.len(), "duplicates from single-probe");
    }

    #[test]
    fn wide_range_index_builds_and_probes_at_l128() {
        // The regime the refactor exists for: L = 128 total bits, 16
        // ranges ⇒ 124 hash bits per range — impossible with u64 codes.
        let d = synthetic::longtail_sift(600, 8, 10);
        let params = RangeLshParams::new(128, 16);
        let h: NativeHasher<Code128> = NativeHasher::new(8, params.hash_bits(), 17);
        let idx = RangeLshIndex::build(&d, &h, params).unwrap();
        assert_eq!(idx.stats().hash_bits, 124);
        assert_eq!(idx.stats().n_partitions, 16);
        let q = synthetic::gaussian_queries(2, 8, 11);
        for qi in 0..q.len() {
            let mut out = Vec::new();
            idx.probe(q.row(qi), usize::MAX, &mut out);
            assert_eq!(out.len(), d.len());
            let mut s = out.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), d.len());
            let mut capped = Vec::new();
            idx.probe(q.row(qi), 33, &mut capped);
            assert_eq!(capped.len(), 33);
        }
    }

    #[test]
    fn budget_one_query_sorts_exactly_one_range() {
        // The lazy-probing contract: a budget-1 query whose code lands in
        // a bucket of the top-norm range (the schedule's first entries)
        // counting-sorts that one range and leaves the other 31 untouched.
        let d = synthetic::longtail_sift(3000, 8, 21);
        let idx = build(&d, 16, 32);
        assert_eq!(idx.n_ranges(), 32);
        let top = idx.n_ranges() - 1; // partitions ascend in norm
        let (qcode, first_item) = {
            let (code, items) = idx.sub_table(top).buckets().next().expect("non-empty range");
            (code, items[0])
        };
        let mut out = Vec::new();
        let stats = idx.probe_with_code_stats(qcode, 1, &mut out);
        assert_eq!(out, vec![first_item]);
        assert_eq!(stats.ranges_sorted, 1, "lazy probe must sort only the touched range");
        assert_eq!(stats.buckets_scanned, idx.sub_table(top).n_buckets());
        assert_eq!(stats.items_emitted, 1);
        // An exhaustive probe sorts every range exactly once.
        let mut all = Vec::new();
        let stats = idx.probe_with_code_stats(qcode, usize::MAX, &mut all);
        assert_eq!(stats.ranges_sorted, 32);
        assert_eq!(stats.items_emitted, d.len());
    }

    #[test]
    fn session_resume_sorts_no_new_range_within_sorted_schedule() {
        // The resumable-session contract from the API redesign: when the
        // remaining schedule stays within ranges already sorted by an
        // earlier extend, resuming sorts nothing new. L=8 with 32 ranges
        // leaves 3 hash bits, so the ~94-item top range packs multi-item
        // buckets; probing that bucket's own code keeps the schedule head
        // inside the top range.
        let d = synthetic::longtail_sift(3000, 8, 31);
        let idx = build(&d, 8, 32);
        let top = idx.n_ranges() - 1; // partitions ascend in norm
        let (qcode, bucket_len) = idx
            .sub_table(top)
            .buckets()
            .map(|(code, items)| (code, items.len()))
            .max_by_key(|&(_, len)| len)
            .expect("non-empty range");
        assert!(bucket_len >= 2, "need a multi-item bucket for the resume check");
        let mut session = idx.session(qcode);
        let mut out = Vec::new();
        session.extend(1, &mut out);
        let first = session.stats();
        assert_eq!(first.ranges_sorted, 1, "first extend sorts only the touched range");
        assert_eq!(first.items_emitted, 1);
        // Resume within the same exact-match bucket: no new range sort,
        // no re-materialization, not even a new bucket scan.
        session.extend(1, &mut out);
        let second = session.stats();
        assert_eq!(second.ranges_sorted, 1, "resume must not sort a new range");
        assert_eq!(second.ranges_resorted, 0, "resume stayed above the floor");
        assert_eq!(second.buckets_scanned, first.buckets_scanned);
        assert_eq!(second.items_emitted, 2);
        assert_eq!(out.len(), 2);
        // Both candidates came from the one exact-match bucket, in bucket
        // order — the same prefix the one-shot probe emits.
        let mut oneshot = Vec::new();
        idx.probe_with_code(qcode, 2, &mut oneshot);
        assert_eq!(out, oneshot);
        // Draining the session eventually touches every range exactly once.
        session.extend(usize::MAX, &mut out);
        let drained = session.stats();
        assert_eq!(drained.ranges_sorted, 32);
        assert_eq!(drained.items_emitted, d.len());
    }

    #[test]
    fn session_norm_bound_is_sound_and_non_increasing() {
        let d = synthetic::longtail_sift(1000, 8, 40);
        let idx = build(&d, 16, 16);
        let q = synthetic::gaussian_queries(1, 8, 41);
        let qcode = idx.hash_query(q.row(0));
        let mut session = idx.session(qcode);
        let global_u = idx.u_maxes().iter().copied().fold(0.0f32, f32::max);
        assert_eq!(session.norm_bound(), Some(global_u), "fresh session bounds everything");
        let mut out = Vec::new();
        let mut prev = global_u;
        loop {
            let got = session.extend(100, &mut out);
            let bound = session.norm_bound().expect("range sessions always have a bound");
            assert!(bound <= prev, "bound must be non-increasing across extends");
            // Soundness: every item not yet emitted has norm <= bound.
            let mut emitted = vec![false; d.len()];
            for &id in &out {
                emitted[id as usize] = true;
            }
            for id in 0..d.len() {
                if !emitted[id] {
                    assert!(
                        d.norm(id) <= bound,
                        "unemitted item {id} (norm {}) above the bound {bound}",
                        d.norm(id)
                    );
                }
            }
            prev = bound;
            if got < 100 {
                break;
            }
        }
        assert!(session.is_exhausted());
        assert_eq!(session.norm_bound(), Some(0.0), "drained session bounds nothing");
    }

    #[test]
    fn lazy_probe_matches_eager_oracle() {
        let d = synthetic::longtail_sift(1200, 8, 22);
        for m in [1usize, 8, 32] {
            let idx = build(&d, 16, m);
            let q = synthetic::gaussian_queries(3, 8, 23);
            for qi in 0..q.len() {
                let qcode = idx.hash_query(q.row(qi));
                for budget in [0usize, 1, 7, 600, usize::MAX] {
                    let (mut lazy, mut eager) = (Vec::new(), Vec::new());
                    idx.probe_with_code(qcode, budget, &mut lazy);
                    idx.probe_with_code_eager(qcode, budget, &mut eager);
                    assert_eq!(lazy, eager, "m={m} q={qi} budget={budget}");
                }
            }
        }
    }

    #[test]
    fn probe_stats_report_fewer_sorts_at_small_budgets() {
        let d = synthetic::longtail_sift(4000, 8, 24);
        let idx = build(&d, 16, 32);
        let q = synthetic::gaussian_queries(1, 8, 25);
        let qcode = idx.hash_query(q.row(0));
        let mut prev = 0usize;
        for budget in [1usize, 100, 1000, usize::MAX] {
            let mut out = Vec::new();
            let stats = idx.probe_with_code_stats(qcode, budget, &mut out);
            assert!(
                stats.ranges_sorted >= prev,
                "sorted ranges must grow with budget ({} < {prev})",
                stats.ranges_sorted
            );
            prev = stats.ranges_sorted;
            assert_eq!(stats.items_emitted, out.len());
        }
        assert_eq!(prev, 32, "exhaustive probe sorts all ranges");
    }

    #[test]
    fn mih_backend_emits_identical_stream() {
        // The tie-order contract: with MIH tables attached, the candidate
        // stream is element-for-element the counting sort's, at any budget.
        let d = synthetic::longtail_sift(1500, 8, 50);
        for m in [1usize, 8] {
            let mut idx = build(&d, 16, m);
            let q = synthetic::gaussian_queries(2, 8, 51);
            for qi in 0..q.len() {
                let qcode = idx.hash_query(q.row(qi));
                idx.clear_mih();
                assert!(!idx.has_mih());
                let mut want = Vec::new();
                idx.probe_with_code(qcode, usize::MAX, &mut want);
                idx.enable_mih();
                assert!(idx.has_mih());
                for budget in [0usize, 1, 7, 750, usize::MAX] {
                    let mut got = Vec::new();
                    idx.probe_with_code(qcode, budget, &mut got);
                    assert_eq!(
                        got[..],
                        want[..budget.min(want.len())],
                        "m={m} q={qi} budget={budget}"
                    );
                }
            }
        }
    }

    #[test]
    fn mih_session_resume_matches_counting_sort_session() {
        // Resumable sessions over the MIH backend, including a resume
        // below the first sort's materialization floor (which re-sorts to
        // full depth through the counting sort).
        let d = synthetic::longtail_sift(1000, 8, 52);
        let mut idx = build(&d, 16, 8);
        let q = synthetic::gaussian_queries(1, 8, 53);
        let qcode = idx.hash_query(q.row(0));
        let mut want = Vec::new();
        idx.probe_with_code(qcode, usize::MAX, &mut want);
        idx.enable_mih();
        let mut got = Vec::new();
        let mut session = idx.session(qcode);
        session.extend(3, &mut got); // small first rank → high floor
        session.extend(500, &mut got); // resumes below the floor
        session.extend(usize::MAX, &mut got);
        assert!(session.is_exhausted());
        assert_eq!(got, want);
    }

    #[test]
    fn wide_256_bit_range_index_round_trips_probing() {
        let d = synthetic::longtail_sift(300, 8, 12);
        let params = RangeLshParams::new(256, 8);
        let h: NativeHasher<Code256> = NativeHasher::new(8, params.hash_bits(), 19);
        let idx = RangeLshIndex::build(&d, &h, params).unwrap();
        assert_eq!(idx.stats().hash_bits, 253);
        let q = synthetic::gaussian_queries(1, 8, 13);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
    }
}
