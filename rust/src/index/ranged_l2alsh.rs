//! Ranged L2-ALSH (paper §5): norm-range partitioning applied to L2-ALSH.
//!
//! Each percentile range gets its own L2-ALSH table built with the *local*
//! max norm, which tightens both terms of Eq. 13 versus Eq. 7 (`ρ_j < ρ`).
//!
//! Cross-range probing mirrors §3.3's similarity metric, adapted to the
//! floor hash: a bucket in range `j` sharing `l` of `K` hash values with
//! the query collides with estimated probability `l/K`; inverting Eq. 3
//! gives an estimated L2 distance `d̂(l)`, and inverting Eq. 6 turns that
//! into an estimated raw inner product
//!
//! `ŝ(j, l) = (1 + m/4 + t_j − d̂(l)²) · U_j / (2·U_param)`
//!
//! where `t_j` is the range's mean lifted-tail magnitude and `U_j/U_param`
//! undoes the per-range scaling. The `(j, l)` schedule is pre-sorted at
//! build, exactly like [`crate::index::MetricOrder`]. (Plain match-count
//! ranking is *biased against* large-norm ranges: their items sit farther
//! from `Q(q)` in the lifted space even when their inner products are
//! larger — measured in EXPERIMENTS.md §5.)

use crate::data::Dataset;
use crate::index::l2alsh::{L2AlshIndex, L2AlshParams};
use crate::index::partition::{partition, PartitionScheme};
use crate::index::traits::drain_bucket;
use crate::index::{IndexStats, MipsIndex, ProbeStats, Prober};
use crate::theory::rho::f_r_inverse;
use crate::{ItemId, Result};

/// Parameters: the inner L2-ALSH config plus the range count.
#[derive(Debug, Clone, Copy)]
pub struct RangedL2AlshParams {
    pub inner: L2AlshParams,
    pub n_partitions: usize,
    pub scheme: PartitionScheme,
}

impl RangedL2AlshParams {
    pub fn recommended(k: usize, n_partitions: usize) -> Self {
        Self {
            inner: L2AlshParams::recommended(k),
            n_partitions,
            scheme: PartitionScheme::Percentile,
        }
    }
}

/// A built ranged L2-ALSH index: one [`L2AlshIndex`] per norm range plus
/// the pre-sorted `(j, l)` probing schedule (see module docs).
pub struct RangedL2AlshIndex {
    subs: Vec<(f32, L2AlshIndex)>, // (U_j, sub-index), ascending norm
    /// `(range j, match count l)` schedule, best estimated IP first.
    schedule: Vec<(u32, u32)>,
    params: RangedL2AlshParams,
    n_items: usize,
}

impl RangedL2AlshIndex {
    pub fn build(dataset: &Dataset, params: RangedL2AlshParams) -> Result<Self> {
        anyhow::ensure!(params.n_partitions >= 1, "need at least one partition");
        let parts = partition(dataset, params.n_partitions, params.scheme)?;
        let mut subs = Vec::with_capacity(parts.len());
        for part in parts {
            let idx = L2AlshIndex::build_with_max_norm(
                dataset,
                Some(&part.ids),
                params.inner,
                part.u_max,
            )?;
            subs.push((part.u_max, idx));
        }
        let schedule = Self::build_schedule(&subs, &params);
        Ok(Self {
            subs,
            schedule,
            params,
            n_items: dataset.len(),
        })
    }

    /// Pre-sort `(j, l)` by estimated raw inner product (module docs).
    fn build_schedule(subs: &[(f32, L2AlshIndex)], params: &RangedL2AlshParams) -> Vec<(u32, u32)> {
        let k = params.inner.k;
        let (m, u_param, r) = (params.inner.m, params.inner.u as f64, params.inner.r as f64);
        // d̂(l): estimated L2 distance when l of K hashes collide. Use the
        // ε-style softening from §3.3: shrink the implied miss rate a bit
        // so unlucky draws in high-norm ranges aren't buried.
        let d_hat: Vec<f64> = (0..=k)
            .map(|l| f_r_inverse(r, (l as f64 / k as f64).clamp(1e-6, 1.0 - 1e-9)))
            .collect();
        // t_j: the lifted tail ||Ux||^2 + ... with ||Ux|| ≈ U (items in a
        // range sit near their local max after scaling): Σ_{i=1..m} U^{2^i}.
        let mut t = 0.0f64;
        let mut p = u_param * u_param;
        for _ in 0..m {
            t += p;
            p = p * p;
        }
        let s_hat = |j: u32, l: u32| -> f64 {
            let u_j = subs[j as usize].0 as f64;
            let d2 = d_hat[l as usize] * d_hat[l as usize];
            // Eq. 6 inverted: 2·U_param·(x·q)/(U_j·|q|) = 1 + m/4 + t − d̂².
            (1.0 + m as f64 / 4.0 + t - d2) * u_j / (2.0 * u_param)
        };
        // Keys once per entry, not per comparison (same precompute-then-
        // sort shape as [`crate::index::MetricOrder::build`]).
        let mut keyed: Vec<(f64, u32, u32)> = (0..subs.len() as u32)
            .flat_map(|j| (0..=k as u32).map(move |l| (j, l)))
            .map(|(j, l)| (s_hat(j, l), j, l))
            .collect();
        keyed.sort_by(|&(sa, ja, la), &(sb, jb, lb)| {
            sb.total_cmp(&sa).then(ja.cmp(&jb)).then(lb.cmp(&la))
        });
        keyed.into_iter().map(|(_, j, l)| (j, l)).collect()
    }

    pub fn n_ranges(&self) -> usize {
        self.subs.len()
    }

    /// The probing schedule (diagnostics/tests).
    pub fn schedule(&self) -> &[(u32, u32)] {
        &self.schedule
    }

    /// Group each range's buckets by match count against `query` — the
    /// per-query half of probing, computed once per session.
    fn group_query(&self, query: &[f32]) -> Vec<Vec<Vec<ItemId>>> {
        let k = self.params.inner.k;
        let mut per_range: Vec<Vec<Vec<ItemId>>> = Vec::with_capacity(self.subs.len());
        for (_, idx) in &self.subs {
            let mut qhash = Vec::new();
            idx.hash_query(query, &mut qhash);
            let mut groups: Vec<Vec<ItemId>> = vec![Vec::new(); k + 1];
            idx.for_each_bucket(|key, items| {
                let l = crate::hash::L2Hash::matches(key, &qhash);
                groups[l].extend_from_slice(items);
            });
            per_range.push(groups);
        }
        per_range
    }
}

/// Resumable ranged L2-ALSH probe session: per-range match-count groups
/// are computed once at open; `extend` walks the pre-sorted estimated-IP
/// `(j, l)` schedule from a cursor.
struct RangedL2Prober<'a> {
    index: &'a RangedL2AlshIndex,
    per_range: Vec<Vec<Vec<ItemId>>>,
    sched_pos: usize,
    /// Offset into the current schedule entry's item list.
    item: usize,
    stats: ProbeStats,
    done: bool,
}

impl Prober for RangedL2Prober<'_> {
    // staticcheck: allow(panic-reach, "(j, l) come from the prebuilt schedule over this index's ranges and levels; per-bucket cursors are drained with clamped takes")
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        if additional_budget == 0 || self.done {
            return 0;
        }
        let schedule = &self.index.schedule;
        let mut remaining = additional_budget;
        while self.sched_pos < schedule.len() {
            let (j, l) = schedule[self.sched_pos];
            let finished = drain_bucket(
                &self.per_range[j as usize][l as usize],
                &mut self.item,
                &mut remaining,
                out,
                &mut self.stats,
            );
            if finished {
                self.sched_pos += 1;
            }
            if remaining == 0 {
                self.stats.items_emitted += additional_budget;
                return additional_budget;
            }
        }
        self.done = true;
        let emitted = additional_budget - remaining;
        self.stats.items_emitted += emitted;
        emitted
    }

    fn is_exhausted(&self) -> bool {
        self.done
    }

    fn stats(&self) -> ProbeStats {
        self.stats
    }
}

impl MipsIndex for RangedL2AlshIndex {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        // Thin wrapper: a fresh session extended once (the grouping was
        // per-probe work before the session refactor too).
        self.prober(query).extend(budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        Box::new(RangedL2Prober {
            index: self,
            per_range: self.group_query(query),
            sched_pos: 0,
            item: 0,
            stats: ProbeStats::default(),
            done: false,
        })
    }

    fn len(&self) -> usize {
        self.n_items
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.n_items,
            n_buckets: self.subs.iter().map(|(_, s)| s.stats().n_buckets).sum(),
            largest_bucket: self
                .subs
                .iter()
                .map(|(_, s)| s.stats().largest_bucket)
                .max()
                .unwrap_or(0),
            hash_bits: self.params.inner.k,
            n_partitions: self.subs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn probe_is_exhaustive_and_unique() {
        let d = synthetic::longtail_sift(400, 8, 0);
        let idx = RangedL2AlshIndex::build(&d, RangedL2AlshParams::recommended(8, 8)).unwrap();
        assert_eq!(idx.n_ranges(), 8);
        let q = synthetic::gaussian_queries(1, 8, 1);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), d.len());
    }

    #[test]
    fn budget_respected() {
        let d = synthetic::longtail_sift(200, 8, 1);
        let idx = RangedL2AlshIndex::build(&d, RangedL2AlshParams::recommended(8, 4)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 2);
        let mut out = Vec::new();
        idx.probe(q.row(0), 29, &mut out);
        assert_eq!(out.len(), 29);
    }

    #[test]
    fn stats_aggregate_ranges() {
        let d = synthetic::longtail_sift(300, 8, 2);
        let idx = RangedL2AlshIndex::build(&d, RangedL2AlshParams::recommended(8, 8)).unwrap();
        let s = idx.stats();
        assert_eq!(s.n_items, 300);
        assert_eq!(s.n_partitions, 8);
        assert!(s.n_buckets >= 8);
    }
}
