//! SIGN-ALSH index (Shrivastava & Li 2015) — the second asymmetric
//! baseline in the paper's lineage (§1/§2.3): Eq.-4 sign random projection
//! over the SIGN-ALSH transform, Hamming-ranked multi-probing, same total
//! code budget as the other algorithms. Generic over the code word `C`
//! ([`CodeWord`]) like the SIMPLE/RANGE indexes, so the baseline stays
//! comparable in the wide-code regimes.

use crate::data::Dataset;
use crate::hash::codes::MAX_CODE_BITS;
use crate::hash::{CodeWord, Projection};
use crate::index::{BucketTable, IndexStats, MipsIndex, Prober, SingleProbe};
use crate::transform::sign_alsh::SignAlshTransform;
use crate::util::par;
use crate::{ItemId, Result};

/// Parameters for [`SignAlshIndex`]. Authors' recommendation: `m=2, U=0.75`.
#[derive(Debug, Clone, Copy)]
pub struct SignAlshParams {
    pub code_bits: usize,
    pub m: usize,
    pub u: f32,
    pub seed: u64,
}

impl SignAlshParams {
    pub fn recommended(code_bits: usize) -> Self {
        Self { code_bits, m: 2, u: 0.75, seed: 0x516A }
    }
}

/// A built SIGN-ALSH index (single table, Hamming-ranked probing).
pub struct SignAlshIndex<C: CodeWord = u64> {
    table: BucketTable<C>,
    proj: Projection,
    transform: SignAlshTransform,
    params: SignAlshParams,
    n_items: usize,
}

impl<C: CodeWord> SignAlshIndex<C> {
    pub fn build(dataset: &Dataset, params: SignAlshParams) -> Result<Self> {
        anyhow::ensure!(
            params.code_bits >= 1 && params.code_bits <= C::MAX_BITS,
            "code_bits must be in 1..={}",
            C::MAX_BITS
        );
        let transform = SignAlshTransform::new(params.m, params.u);
        let dim_in = transform.dim_out(dataset.dim());
        let proj = Projection::gaussian(dim_in, params.code_bits, params.seed);
        let max_norm = dataset.max_norm();
        anyhow::ensure!(max_norm > 0.0, "dataset max norm must be positive");

        let codes: Vec<C> = par::par_map(dataset.len(), |i| {
            let mut buf = Vec::with_capacity(dim_in);
            transform.transform_item(dataset.row(i), max_norm, &mut buf);
            sign_project(&proj, &buf)
        });
        let table = BucketTable::build(&codes, None, params.code_bits);
        Ok(Self {
            table,
            proj,
            transform,
            params,
            n_items: dataset.len(),
        })
    }

    pub fn hash_query(&self, query: &[f32]) -> C {
        let mut buf = Vec::with_capacity(self.proj.dim_in());
        self.transform.transform_query(query, &mut buf);
        sign_project(&self.proj, &buf)
    }

    pub fn params(&self) -> &SignAlshParams {
        &self.params
    }
}

/// Sign-project a transformed row against the panel (strictly-positive
/// convention, same as the SIMPLE-LSH paths).
fn sign_project<C: CodeWord>(proj: &Projection, xt: &[f32]) -> C {
    debug_assert_eq!(xt.len(), proj.dim_in());
    let width = proj.width();
    let mut acc = [0.0f32; MAX_CODE_BITS];
    let acc = &mut acc[..width];
    for (k, &v) in xt.iter().enumerate() {
        for (a, &w) in acc.iter_mut().zip(proj.row(k)) {
            *a += v * w;
        }
    }
    C::pack_from_signs(acc)
}

impl<C: CodeWord> MipsIndex for SignAlshIndex<C> {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        // Thin wrapper over a fresh session — budget-adaptive counting
        // sort + Hamming-ranked emission, same machinery as SIMPLE-LSH,
        // alloc-free once a thread is warm (pooled scratch).
        self.table.prober(self.hash_query(query)).extend(budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        Box::new(self.table.prober(self.hash_query(query)))
    }

    fn len(&self) -> usize {
        self.n_items
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.n_items,
            n_buckets: self.table.n_buckets(),
            largest_bucket: self.table.largest_bucket(),
            hash_bits: self.params.code_bits,
            n_partitions: 1,
        }
    }
}

impl<C: CodeWord> SingleProbe for SignAlshIndex<C> {
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>) {
        if let Some(items) = self.table.exact(self.hash_query(query)) {
            out.extend_from_slice(items);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::Code128;

    #[test]
    fn probe_is_exhaustive_and_unique() {
        let d = synthetic::longtail_sift(400, 8, 0);
        let idx: SignAlshIndex = SignAlshIndex::build(&d, SignAlshParams::recommended(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 1);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), d.len());
    }

    #[test]
    fn budget_respected() {
        let d = synthetic::longtail_sift(200, 8, 1);
        let idx: SignAlshIndex = SignAlshIndex::build(&d, SignAlshParams::recommended(16)).unwrap();
        let q = synthetic::gaussian_queries(1, 8, 2);
        let mut out = Vec::new();
        idx.probe(q.row(0), 17, &mut out);
        assert_eq!(out.len(), 17);
    }

    #[test]
    fn better_than_random_at_finding_top_items() {
        // Probing 10% should capture the top-1 far more often than 10%.
        let d = synthetic::mf_embeddings(2000, 16, 8, 2);
        let q = synthetic::mf_user_queries(50, 16, 8, 2);
        let gt = crate::eval::exact_topk(&d, &q, 1);
        let idx: SignAlshIndex = SignAlshIndex::build(&d, SignAlshParams::recommended(32)).unwrap();
        let mut hits = 0;
        for qi in 0..q.len() {
            let mut out = Vec::new();
            idx.probe(q.row(qi), 200, &mut out);
            if out.contains(&gt[qi][0]) {
                hits += 1;
            }
        }
        assert!(hits > 20, "top-1 found in only {hits}/50 probes of 10%");
    }

    #[test]
    fn stats_are_consistent() {
        let d = synthetic::longtail_sift(300, 8, 3);
        let idx: SignAlshIndex = SignAlshIndex::build(&d, SignAlshParams::recommended(16)).unwrap();
        let s = idx.stats();
        assert_eq!(s.n_items, 300);
        assert!(s.n_buckets >= 1 && s.n_buckets <= 300);
        assert_eq!(s.n_partitions, 1);
    }

    #[test]
    fn wide_sign_alsh_probes_128_bit_codes() {
        let d = synthetic::longtail_sift(200, 8, 4);
        let idx: SignAlshIndex<Code128> =
            SignAlshIndex::build(&d, SignAlshParams::recommended(128)).unwrap();
        assert_eq!(idx.stats().hash_bits, 128);
        let q = synthetic::gaussian_queries(1, 8, 5);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        // Scalar words reject the same budget.
        assert!(SignAlshIndex::<u64>::build(&d, SignAlshParams::recommended(128)).is_err());
    }
}
