//! SIMPLE-LSH index (paper §2.3) — the state-of-the-art baseline whose
//! long-tail pathology motivates the paper. Generic over the code word
//! `C` ([`CodeWord`]): `SimpleLshIndex` is the original `u64` (L ≤ 64)
//! index; `SimpleLshIndex<Code128>` / `<Code256>` lift the code ceiling.
//!
//! Single table: items normalised by the *global* max norm `U`, transformed
//! (Eq. 8), sign-projected, bucketed by code. Multi-probing ranks buckets
//! by Hamming distance to the query code (§3.1: "they use Hamming distance
//! to determine the probing order of the buckets").

use std::sync::Arc;

use crate::data::Dataset;
use crate::hash::{CodeWord, ItemHasher, NativeHasher, Projection};
use crate::index::mih::MihTable;
use crate::index::{BucketTable, CodeProbe, IndexStats, MipsIndex, Prober, SingleProbe};
use crate::{ItemId, Result};

#[cfg(doc)]
use crate::hash::{Code128, Code256};

/// Parameters for [`SimpleLshIndex`].
#[derive(Debug, Clone, Copy)]
pub struct SimpleLshParams {
    /// Total code length L in bits (1..=C::MAX_BITS).
    pub code_bits: usize,
}

impl SimpleLshParams {
    pub fn new(code_bits: usize) -> Self {
        Self { code_bits }
    }
}

/// A built SIMPLE-LSH index over `C`-wide codes.
pub struct SimpleLshIndex<C: CodeWord = u64> {
    table: BucketTable<C>,
    proj: Arc<Projection>,
    /// Query hasher over the shared panel, built once at index build.
    qhasher: NativeHasher<C>,
    code_bits: usize,
    n_items: usize,
    /// MIH chunk tables (the sub-linear candidate-generation backend),
    /// present iff [`Self::enable_mih`] ran — probers use them
    /// automatically when attached.
    mih: Option<MihTable<C>>,
    /// Global normalisation constant `U` (kept for diagnostics/Fig 1(c)).
    pub u: f32,
}

impl<C: CodeWord> SimpleLshIndex<C> {
    /// Build over `dataset` using `hasher` for the bulk hashing work.
    /// The hasher's projection must have been created for `dataset.dim()`;
    /// codes are masked to `params.code_bits`.
    pub fn build(
        dataset: &Dataset,
        hasher: &dyn ItemHasher<C>,
        params: SimpleLshParams,
    ) -> Result<Self> {
        anyhow::ensure!(
            params.code_bits >= 1 && params.code_bits <= hasher.width(),
            "code_bits {} out of range 1..={}",
            params.code_bits,
            hasher.width()
        );
        anyhow::ensure!(
            params.code_bits <= C::MAX_BITS,
            "code_bits {} exceed the {}-bit code word",
            params.code_bits,
            C::MAX_BITS
        );
        anyhow::ensure!(
            hasher.dim() == dataset.dim(),
            "hasher dim {} != dataset dim {}",
            hasher.dim(),
            dataset.dim()
        );
        let u = dataset.max_norm();
        anyhow::ensure!(u > 0.0, "dataset max norm must be positive");
        let codes = hasher.hash_items(dataset.flat(), u)?;
        let table = BucketTable::build(&codes, None, params.code_bits);
        // Query hashing at probe time uses the same panel the item
        // codes were built with.
        let proj = hasher.projection().clone();
        Ok(Self {
            table,
            qhasher: NativeHasher::with_projection(proj.clone()),
            proj,
            code_bits: params.code_bits,
            n_items: dataset.len(),
            mih: None,
            u,
        })
    }

    /// Enable the MIH candidate-generation backend
    /// ([`crate::index::mih`]): build the chunk tables if absent.
    /// Idempotent; the emitted candidate stream is element-for-element
    /// identical to the counting sort's (property-tested).
    pub fn enable_mih(&mut self) {
        if self.mih.is_none() {
            self.mih = Some(MihTable::build(&self.table));
        }
    }

    /// Drop the MIH tables: probing falls back to the counting sort.
    pub fn clear_mih(&mut self) {
        self.mih = None;
    }

    /// Whether MIH tables are attached.
    pub fn has_mih(&self) -> bool {
        self.mih.is_some()
    }

    /// Hash one query natively through the cached hasher, alloc-free (the
    /// engine batches via PJRT instead and calls
    /// [`CodeProbe::probe_with_code`]).
    pub fn hash_query(&self, query: &[f32]) -> C {
        self.qhasher.hash_query_one(query).expect("query row length matches index dim")
    }

    pub fn code_bits(&self) -> usize {
        self.code_bits
    }

    pub fn table(&self) -> &BucketTable<C> {
        &self.table
    }

    pub fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }
}

impl<C: CodeWord> MipsIndex for SimpleLshIndex<C> {
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>) {
        self.probe_with_code(self.hash_query(query), budget, out);
    }

    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        Box::new(self.table.prober_mih(self.hash_query(query), self.mih.as_ref()))
    }

    fn len(&self) -> usize {
        self.n_items
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            n_items: self.n_items,
            n_buckets: self.table.n_buckets(),
            largest_bucket: self.table.largest_bucket(),
            hash_bits: self.code_bits,
            n_partitions: 1,
        }
    }
}

thread_local! {
    /// Per-thread sort scratch pool for the batched path: one slot per
    /// in-flight query of the worker's current chunk. (The single-query
    /// path runs through a [`crate::index::bucket::TableProber`] session,
    /// whose scratch comes from the bucket module's shared pool.)
    static SCRATCH: std::cell::RefCell<Vec<crate::index::bucket::SortScratch>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl<C: CodeWord> CodeProbe<C> for SimpleLshIndex<C> {
    fn probe_with_code(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>) {
        // Thin wrapper over a fresh session: budget-adaptive ranking
        // (counting sort, or MIH when enabled) + Hamming-ranked (most
        // matching bits first) emission, alloc-free once a thread is
        // warm (pooled scratch).
        self.table.prober_mih(qcode, self.mih.as_ref()).extend(budget, out);
    }

    fn prober_with_code(&self, qcode: C) -> Box<dyn Prober + '_> {
        Box::new(self.table.prober_mih(qcode, self.mih.as_ref()))
    }

    // staticcheck: allow(panic-reach, "the scratch pool is resized to qcodes.len() immediately before the slice")
    fn probe_batch_with_codes(&self, qcodes: &[C], budget: usize, outs: &mut [Vec<ItemId>]) {
        assert_eq!(qcodes.len(), outs.len(), "one output buffer per query code");
        SCRATCH.with(|scratch| {
            let pool = &mut *scratch.borrow_mut();
            if pool.len() < qcodes.len() {
                pool.resize_with(qcodes.len(), Default::default);
            }
            if let Some(mih) = &self.mih {
                // MIH ranks per query (the chunk-table walk has no
                // cross-query pass to share), same emitted stream.
                for ((&qcode, s), out) in
                    qcodes.iter().zip(pool.iter_mut()).zip(outs.iter_mut())
                {
                    mih.rank_partial(&self.table, qcode, budget, s);
                    self.table.emit_ranked(s, budget, out);
                }
                return;
            }
            // One streaming pass over the dense codes vector for the
            // whole batch, then per-query Hamming-ranked emission.
            self.table.counting_sort_batch(qcodes, budget, &mut pool[..qcodes.len()]);
            for (s, out) in pool.iter().zip(outs.iter_mut()) {
                self.table.emit_ranked(s, budget, out);
            }
        })
    }
}

impl<C: CodeWord> SingleProbe for SimpleLshIndex<C> {
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>) {
        if let Some(items) = self.table.exact(self.hash_query(query)) {
            out.extend_from_slice(items);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::hash::Code128;

    fn small_index(bits: usize) -> (Dataset, SimpleLshIndex) {
        let d = synthetic::longtail_sift(300, 8, 0);
        let h: NativeHasher = NativeHasher::new(8, 64, 0x51_3E_CA_FE);
        let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(bits)).unwrap();
        (d, idx)
    }

    #[test]
    fn probe_emits_unique_ids_up_to_budget() {
        let (d, idx) = small_index(16);
        let q = synthetic::gaussian_queries(1, 8, 1);
        let mut out = Vec::new();
        idx.probe(q.row(0), 50, &mut out);
        assert_eq!(out.len(), 50);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 50, "duplicate candidates");
        assert!(out.iter().all(|&id| (id as usize) < d.len()));
    }

    #[test]
    fn exhausting_budget_returns_everything() {
        let (d, idx) = small_index(16);
        let q = synthetic::gaussian_queries(1, 8, 2);
        let mut out = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
    }

    #[test]
    fn probe_order_is_nonincreasing_in_matches() {
        let (_, idx) = small_index(16);
        let qcode = 0xABCDu64;
        let mut out = Vec::new();
        idx.probe_with_code(qcode, usize::MAX, &mut out);
        // Walk the emitted ids and check their bucket match-counts never increase.
        // Rebuild code→matches from the table.
        let mut groups = Vec::new();
        idx.table().group_by_matches(qcode, &mut groups);
        let mut rank = std::collections::HashMap::new();
        for (l, bucket_list) in groups.iter().enumerate() {
            for bucket in bucket_list {
                for &id in *bucket {
                    rank.insert(id, l);
                }
            }
        }
        let mut prev = usize::MAX;
        for id in out {
            let l = rank[&id];
            assert!(l <= prev, "match count increased along probe order");
            prev = l;
        }
    }

    #[test]
    fn stats_reflect_bucket_balance() {
        let (d, idx) = small_index(16);
        let s = idx.stats();
        assert_eq!(s.n_items, d.len());
        assert!(s.n_buckets > 0 && s.n_buckets <= d.len());
        assert!(s.largest_bucket >= 1);
        assert_eq!(s.n_partitions, 1);
    }

    #[test]
    fn rejects_code_bits_beyond_width() {
        let d = synthetic::longtail_sift(10, 4, 0);
        let h: NativeHasher = NativeHasher::new(4, 32, 0);
        assert!(SimpleLshIndex::build(&d, &h, SimpleLshParams::new(33)).is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let d = synthetic::longtail_sift(10, 4, 0);
        let h: NativeHasher = NativeHasher::new(5, 32, 0);
        assert!(SimpleLshIndex::build(&d, &h, SimpleLshParams::new(16)).is_err());
    }

    #[test]
    fn single_probe_returns_exact_bucket_only() {
        let (_, idx) = small_index(10);
        let q = synthetic::gaussian_queries(1, 8, 5);
        let mut exact = Vec::new();
        idx.probe_exact(q.row(0), &mut exact);
        let mut full = Vec::new();
        idx.probe(q.row(0), usize::MAX, &mut full);
        // Exact bucket must be a prefix-set of the full probe order
        // (all its items share the max match count).
        assert!(exact.len() <= full.len());
        for id in &exact {
            assert!(full.contains(id));
        }
    }

    #[test]
    fn batched_probe_matches_single_query_probes() {
        let (_, idx) = small_index(16);
        let q = synthetic::gaussian_queries(6, 8, 11);
        let qcodes: Vec<u64> = (0..q.len()).map(|i| idx.hash_query(q.row(i))).collect();
        for budget in [1usize, 23, 300, usize::MAX] {
            let mut batched: Vec<Vec<crate::ItemId>> = vec![Vec::new(); qcodes.len()];
            idx.probe_batch_with_codes(&qcodes, budget, &mut batched);
            for (qi, qcode) in qcodes.iter().enumerate() {
                let mut single = Vec::new();
                idx.probe_with_code(*qcode, budget, &mut single);
                assert_eq!(batched[qi], single, "query {qi} budget {budget}");
            }
        }
    }

    #[test]
    fn mih_backend_matches_counting_sort_streams() {
        // Single-query, session, and batched paths all emit the same
        // stream with MIH tables attached.
        let d = synthetic::longtail_sift(400, 8, 12);
        let h: NativeHasher = NativeHasher::new(8, 64, 0xFACE);
        let mut idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(24)).unwrap();
        let q = synthetic::gaussian_queries(4, 8, 13);
        let qcodes: Vec<u64> = (0..q.len()).map(|i| idx.hash_query(q.row(i))).collect();
        for budget in [1usize, 23, 200, usize::MAX] {
            idx.clear_mih();
            let mut want: Vec<Vec<crate::ItemId>> = vec![Vec::new(); qcodes.len()];
            idx.probe_batch_with_codes(&qcodes, budget, &mut want);
            idx.enable_mih();
            assert!(idx.has_mih());
            let mut got: Vec<Vec<crate::ItemId>> = vec![Vec::new(); qcodes.len()];
            idx.probe_batch_with_codes(&qcodes, budget, &mut got);
            assert_eq!(got, want, "batched, budget {budget}");
            for (qi, &qcode) in qcodes.iter().enumerate() {
                let mut single = Vec::new();
                idx.probe_with_code(qcode, budget, &mut single);
                assert_eq!(single, want[qi], "single, query {qi} budget {budget}");
            }
        }
        // Resumable session over MIH, with a below-floor resume.
        idx.enable_mih();
        let mut want = Vec::new();
        let mut cs = idx.table().prober(qcodes[0]);
        cs.extend(usize::MAX, &mut want);
        let mut got = Vec::new();
        let mut p = idx.prober_with_code(qcodes[0]);
        p.extend(2, &mut got);
        p.extend(usize::MAX, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn wide_index_probes_with_128_bit_codes() {
        // The wide instantiation must behave like any SIMPLE-LSH index:
        // unique exhaustive probing, budget respected, wide query codes.
        let d = synthetic::longtail_sift(300, 8, 7);
        let h: NativeHasher<Code128> = NativeHasher::new(8, 128, 9);
        let idx = SimpleLshIndex::build(&d, &h, SimpleLshParams::new(128)).unwrap();
        assert_eq!(idx.code_bits(), 128);
        let q = synthetic::gaussian_queries(1, 8, 10);
        let qcode: Code128 = idx.hash_query(q.row(0));
        let mut out = Vec::new();
        idx.probe_with_code(qcode, usize::MAX, &mut out);
        assert_eq!(out.len(), d.len());
        let mut s = out.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), d.len());
        let mut capped = Vec::new();
        idx.probe(q.row(0), 40, &mut capped);
        assert_eq!(capped.len(), 40);
    }

    #[test]
    fn wide_bits_fit_wide_words_but_not_scalar() {
        let d = synthetic::longtail_sift(10, 4, 0);
        // 100 code bits fit a Code128 word...
        let wide_h: NativeHasher<Code128> = NativeHasher::new(4, 128, 0);
        assert!(SimpleLshIndex::build(&d, &wide_h, SimpleLshParams::new(100)).is_ok());
        // ... but exceed any u64 hasher's width (the scalar ceiling).
        let scalar_h: NativeHasher = NativeHasher::new(4, 64, 0);
        assert!(SimpleLshIndex::build(&d, &scalar_h, SimpleLshParams::new(100)).is_err());
    }
}
