//! Shared index interfaces.

use crate::hash::CodeWord;
use crate::ItemId;

/// A resumable probing session over one query — the paper's query
/// procedure is inherently incremental (Alg. 2 walks the ranked schedule
/// and stops once enough candidates are gathered), and this is the API
/// shape of that walk: ask for some candidates, look at them, ask for
/// more without rescanning.
///
/// Obtained from [`MipsIndex::prober`] (raw query) or
/// [`CodeProbe::prober_with_code`] (precomputed code). The session
/// borrows the index; candidates across consecutive `extend` calls form
/// the exact stream a single one-shot [`MipsIndex::probe`] with the
/// summed budget would emit, element for element (property-tested in
/// `tests/properties.rs`).
pub trait Prober {
    /// Append up to `additional_budget` *next* candidates in probing
    /// order, continuing from where the previous call stopped. Returns
    /// the number appended: fewer than requested exactly when the index
    /// ran out of items during this call, `0` for every call thereafter
    /// (and for `additional_budget == 0`, which is a true no-op — a fresh
    /// session does no sorting work until the first nonzero request).
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize;

    /// True once every indexed item has been emitted.
    fn is_exhausted(&self) -> bool;

    /// Cumulative instrumentation over every `extend` call so far.
    fn stats(&self) -> ProbeStats;

    /// Upper bound on the 2-norm of every item this session has **not
    /// yet** emitted, when the index can prove one cheaply — `None` means
    /// unknown/unbounded and callers must not assume anything. RANGE-LSH
    /// returns the suffix maximum of `U_j` over its remaining `(U_j, l)`
    /// schedule ([`crate::index::MetricOrder::remaining_u_max`]); since
    /// `q·x ≤ ‖q‖·‖x‖`, the streaming re-rank stops the whole query once
    /// `‖q‖ · bound` can no longer beat its kth exact score.
    fn norm_bound(&self) -> Option<f32> {
        None
    }
}

/// Shared inner step of every session's walk: emit as much of `items` as
/// `*remaining` allows, continuing from and advancing the within-bucket
/// cursor, and keep the stats current (a bucket counts as probed when its
/// first item is taken). Returns true when the bucket is fully consumed —
/// the cursor is then reset to 0 and the caller advances to the next
/// bucket. Must be called with `*remaining > 0` between checks.
// staticcheck: allow(panic-reach, "take = min(len - cursor, remaining), so cursor + take <= items.len()")
pub(crate) fn drain_bucket(
    items: &[ItemId],
    cursor: &mut usize,
    remaining: &mut usize,
    out: &mut Vec<ItemId>,
    stats: &mut ProbeStats,
) -> bool {
    if *cursor == 0 && !items.is_empty() {
        stats.buckets_probed += 1;
    }
    let take = (items.len() - *cursor).min(*remaining);
    out.extend_from_slice(&items[*cursor..*cursor + take]);
    *cursor += take;
    *remaining -= take;
    if *cursor == items.len() {
        *cursor = 0;
        true
    } else {
        false
    }
}

/// [`Prober`] over a fully materialized candidate list — the fallback
/// behind the default [`MipsIndex::prober`] (one eager full probe, then
/// stream from the buffer) and the natural session for indexes whose
/// probe is not incremental (the multi-table union).
pub struct BufferedProber {
    items: Vec<ItemId>,
    pos: usize,
}

impl BufferedProber {
    /// Wrap an already-ordered candidate list.
    pub fn new(items: Vec<ItemId>) -> Self {
        Self { items, pos: 0 }
    }
}

impl Prober for BufferedProber {
    // staticcheck: allow(panic-reach, "take is clamped to items.len() - pos, so the slice end never passes the buffer")
    fn extend(&mut self, additional_budget: usize, out: &mut Vec<ItemId>) -> usize {
        let take = additional_budget.min(self.items.len() - self.pos);
        out.extend_from_slice(&self.items[self.pos..self.pos + take]);
        self.pos += take;
        take
    }

    fn is_exhausted(&self) -> bool {
        self.pos == self.items.len()
    }

    fn stats(&self) -> ProbeStats {
        ProbeStats { items_emitted: self.pos, ..ProbeStats::default() }
    }
}

/// A built MIPS index that can emit candidates in probing order.
pub trait MipsIndex: Send + Sync {
    /// Append up to `budget` candidate item ids to `out`, in this index's
    /// probing order (best bucket first). Fewer than `budget` ids are
    /// appended only when the index is exhausted. Ids are unique per call.
    ///
    /// Thin one-shot wrapper: equivalent to opening a fresh
    /// [`Self::prober`] session and extending it once by `budget`. Prefer
    /// a session when the caller may come back for more candidates.
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>);

    /// Open a resumable probing session for `query`.
    ///
    /// The default buffers one eager full probe (correct for any index);
    /// every in-tree index overrides it with a true lazy cursor that
    /// keeps its schedule position and sort scratch alive across
    /// [`Prober::extend`] calls.
    fn prober(&self, query: &[f32]) -> Box<dyn Prober + '_> {
        let mut all = Vec::new();
        self.probe(query, usize::MAX, &mut all);
        Box::new(BufferedProber::new(all))
    }

    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural statistics (bucket balance — paper §3.1/§3.2 tables).
    fn stats(&self) -> IndexStats;
}

/// Indexes whose query hashing is a packed sign-RP code of word type `C`
/// (SIMPLE / RANGE). Defaults to `u64`, so `dyn CodeProbe` keeps meaning
/// the original single-word interface.
///
/// This is the hook the serving engine uses to batch query hashing through
/// the AOT Pallas kernel: hash a whole query batch on PJRT (or natively
/// for multi-word codes), then call [`CodeProbe::probe_with_code`] per
/// query — Python-free, matmul-batched.
pub trait CodeProbe<C: CodeWord = u64>: MipsIndex {
    /// Probe with a pre-computed (unmasked, full-width) query code.
    ///
    /// Thin one-shot wrapper over [`Self::prober_with_code`]: a fresh
    /// session extended once by `budget`.
    fn probe_with_code(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>);

    /// Open a resumable probing session over a pre-computed query code —
    /// the engine-facing twin of [`MipsIndex::prober`]. The default
    /// buffers one eager full probe; SIMPLE/RANGE override it with lazy
    /// cursors.
    fn prober_with_code(&self, qcode: C) -> Box<dyn Prober + '_> {
        let mut all = Vec::new();
        self.probe_with_code(qcode, usize::MAX, &mut all);
        Box::new(BufferedProber::new(all))
    }

    /// Probe a batch of pre-computed query codes, appending candidates
    /// into the matching `outs` entry. Per query the candidate stream is
    /// identical to [`Self::probe_with_code`]; implementations override
    /// this when they can amortize memory traffic across the batch (the
    /// single-table indexes stream their dense codes vector once per
    /// batch via [`crate::index::BucketTable::counting_sort_batch`]).
    /// RANGE-LSH keeps this default: its budget-adaptive lazy probing
    /// skips whole ranges per query, which a shared eager scan would
    /// forfeit.
    fn probe_batch_with_codes(&self, qcodes: &[C], budget: usize, outs: &mut [Vec<ItemId>]) {
        assert_eq!(qcodes.len(), outs.len(), "one output buffer per query code");
        for (&qcode, out) in qcodes.iter().zip(outs.iter_mut()) {
            self.probe_with_code(qcode, budget, out);
        }
    }
}

/// Instrumentation from one probe call — the §Perf hook behind the
/// budget-adaptive lazy probing tests and the hotpath bench: a budget-1
/// query on an m-range index must counting-sort one range, not m.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ProbeStats {
    /// Ranges whose bucket table was counting-sorted (lazy probing sorts
    /// a range only when the schedule first touches it).
    pub ranges_sorted: usize,
    /// Ranges re-sorted on session resume because the walk reached a
    /// level below a previously materialized floor. Pure
    /// re-materialization — the sort is deterministic, so already-walked
    /// slices are reproduced identically — and never a *new* range:
    /// [`ProbeStats::ranges_sorted`] does not grow when the remaining
    /// schedule stays within already-sorted ranges.
    pub ranges_resorted: usize,
    /// Buckets popcounted across those sorts (the histogram pass).
    pub buckets_scanned: usize,
    /// Buckets whose items were emitted (schedule walk).
    pub buckets_probed: usize,
    /// Candidate ids appended to the output.
    pub items_emitted: usize,
}

/// Indexes supporting the supplementary multi-table single-probe protocol:
/// visit only the bucket(s) whose code equals the query's code exactly.
pub trait SingleProbe: Send + Sync {
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>);
}

/// Bucket-balance statistics. The paper quotes these for ImageNet at 32
/// bits: SIMPLE-LSH ≈ 60K buckets with a ≈ 200K-item largest bucket;
/// RANGE-LSH ≈ 2M buckets, mostly singletons.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IndexStats {
    pub n_items: usize,
    pub n_buckets: usize,
    pub largest_bucket: usize,
    /// Effective hash bits per code (excludes partition-id bits).
    pub hash_bits: usize,
    /// Number of norm ranges (1 for unpartitioned indexes).
    pub n_partitions: usize,
}

impl IndexStats {
    /// Mean bucket occupancy — 1.0 is ideal balance.
    pub fn mean_occupancy(&self) -> f64 {
        if self.n_buckets == 0 {
            0.0
        } else {
            self.n_items as f64 / self.n_buckets as f64
        }
    }
}
