//! Shared index interfaces.

use crate::hash::CodeWord;
use crate::ItemId;

/// A built MIPS index that can emit candidates in probing order.
pub trait MipsIndex: Send + Sync {
    /// Append up to `budget` candidate item ids to `out`, in this index's
    /// probing order (best bucket first). Fewer than `budget` ids are
    /// appended only when the index is exhausted. Ids are unique per call.
    fn probe(&self, query: &[f32], budget: usize, out: &mut Vec<ItemId>);

    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural statistics (bucket balance — paper §3.1/§3.2 tables).
    fn stats(&self) -> IndexStats;
}

/// Indexes whose query hashing is a packed sign-RP code of word type `C`
/// (SIMPLE / RANGE). Defaults to `u64`, so `dyn CodeProbe` keeps meaning
/// the original single-word interface.
///
/// This is the hook the serving engine uses to batch query hashing through
/// the AOT Pallas kernel: hash a whole query batch on PJRT (or natively
/// for multi-word codes), then call [`CodeProbe::probe_with_code`] per
/// query — Python-free, matmul-batched.
pub trait CodeProbe<C: CodeWord = u64>: MipsIndex {
    /// Probe with a pre-computed (unmasked, full-width) query code.
    fn probe_with_code(&self, qcode: C, budget: usize, out: &mut Vec<ItemId>);

    /// Probe a batch of pre-computed query codes, appending candidates
    /// into the matching `outs` entry. Per query the candidate stream is
    /// identical to [`Self::probe_with_code`]; implementations override
    /// this when they can amortize memory traffic across the batch (the
    /// single-table indexes stream their dense codes vector once per
    /// batch via [`crate::index::BucketTable::counting_sort_batch`]).
    /// RANGE-LSH keeps this default: its budget-adaptive lazy probing
    /// skips whole ranges per query, which a shared eager scan would
    /// forfeit.
    fn probe_batch_with_codes(&self, qcodes: &[C], budget: usize, outs: &mut [Vec<ItemId>]) {
        assert_eq!(qcodes.len(), outs.len(), "one output buffer per query code");
        for (&qcode, out) in qcodes.iter().zip(outs.iter_mut()) {
            self.probe_with_code(qcode, budget, out);
        }
    }
}

/// Instrumentation from one probe call — the §Perf hook behind the
/// budget-adaptive lazy probing tests and the hotpath bench: a budget-1
/// query on an m-range index must counting-sort one range, not m.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ProbeStats {
    /// Ranges whose bucket table was counting-sorted (lazy probing sorts
    /// a range only when the schedule first touches it).
    pub ranges_sorted: usize,
    /// Buckets popcounted across those sorts (the histogram pass).
    pub buckets_scanned: usize,
    /// Buckets whose items were emitted (schedule walk).
    pub buckets_probed: usize,
    /// Candidate ids appended to the output.
    pub items_emitted: usize,
}

/// Indexes supporting the supplementary multi-table single-probe protocol:
/// visit only the bucket(s) whose code equals the query's code exactly.
pub trait SingleProbe: Send + Sync {
    fn probe_exact(&self, query: &[f32], out: &mut Vec<ItemId>);
}

/// Bucket-balance statistics. The paper quotes these for ImageNet at 32
/// bits: SIMPLE-LSH ≈ 60K buckets with a ≈ 200K-item largest bucket;
/// RANGE-LSH ≈ 2M buckets, mostly singletons.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IndexStats {
    pub n_items: usize,
    pub n_buckets: usize,
    pub largest_bucket: usize,
    /// Effective hash bits per code (excludes partition-id bits).
    pub hash_bits: usize,
    /// Number of norm ranges (1 for unpartitioned indexes).
    pub n_partitions: usize,
}

impl IndexStats {
    /// Mean bucket occupancy — 1.0 is ideal balance.
    pub fn mean_occupancy(&self) -> f64 {
        if self.n_buckets == 0 {
            0.0
        } else {
            self.n_items as f64 / self.n_buckets as f64
        }
    }
}
