//! # rangelsh — Norm-Ranging LSH for Maximum Inner Product Search
//!
//! A full-system reproduction of *Norm-Ranging LSH for Maximum Inner Product
//! Search* (Yan, Li, Dai, Chen, Cheng — NeurIPS 2018), built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   norm-ranging partitioner ([`index::partition`]), per-range SIMPLE-LSH
//!   indexes ranked across ranges by the Eq. 12 similarity metric
//!   ([`index::range`]), baselines (SIMPLE-LSH, L2-ALSH, ranged L2-ALSH,
//!   multi-table), the evaluation harness that regenerates every figure and
//!   table in the paper, and an async serving engine ([`coordinator`]).
//!   Probing is a resumable session ([`index::Prober`]): every index keeps
//!   its schedule cursor alive across `extend` calls, and the serving
//!   layer threads per-request [`config::QueryParams`] (k, budget,
//!   early-stop) over the engine defaults — see README "Query sessions".
//!   The whole stack is generic over the code word ([`hash::CodeWord`]:
//!   `u64`, `[u64; 2]`, `[u64; 4]`), lifting the paper's 64-bit code
//!   ceiling to 256 bits — see README "Code-width architecture".
//! - **Layer 2/1 (python/, build-time only)** — the JAX hash/score graphs and
//!   the Pallas sign-hash kernel, AOT-lowered to HLO text and executed from
//!   Rust via the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use rangelsh::data::synthetic;
//! use rangelsh::hash::{Code128, NativeHasher};
//! use rangelsh::index::{range::RangeLshIndex, range::RangeLshParams, MipsIndex, Prober};
//!
//! let dataset = synthetic::longtail_sift(10_000, 64, 42);
//! let queries = synthetic::gaussian_queries(100, 64, 7);
//! // The original u64 path (L <= 64) ...
//! let hasher: NativeHasher = NativeHasher::new(64, 64, 1);
//! let index = RangeLshIndex::build(&dataset, &hasher, RangeLshParams::new(16, 16)).unwrap();
//! // Query through a resumable session: ask for candidates, look at
//! // them, ask for more — the schedule walk continues where it stopped.
//! let mut session = index.prober(queries.row(0));
//! let mut out = Vec::new();
//! session.extend(100, &mut out); // first 100 candidates in probing order
//! session.extend(400, &mut out); // the *next* 400 — no rescan
//! println!("first 500 candidates in probing order: {out:?}");
//! // (One-shot `index.probe(q, 500, &mut out)` is the same stream.)
//! // ... and the wide-code regime the CodeWord refactor opens up (L = 128):
//! let params = RangeLshParams::new(128, 16);
//! let wide_hasher: NativeHasher<Code128> = NativeHasher::new(64, params.hash_bits(), 1);
//! let wide = RangeLshIndex::build(&dataset, &wide_hasher, params).unwrap();
//! assert_eq!(wide.stats().hash_bits, 124);
//! ```
//!
//! See `examples/` for end-to-end drivers and `benches/` for the
//! paper-figure regeneration harnesses.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hash;
pub mod index;
pub mod persist;
pub mod runtime;
pub mod theory;
pub mod transform;
pub mod util;

/// Item identifier within a dataset (row index).
pub type ItemId = u32;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Crate-wide error type (vendored `anyhow`). Typed errors such as
/// [`coordinator::OverloadedError`] and [`coordinator::ShardLossError`]
/// travel through it and are recovered with [`Error::downcast_ref`].
pub use anyhow::Error;
