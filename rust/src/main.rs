//! `rangelsh` — the RANGE-LSH coordinator CLI.
//!
//! Subcommands:
//! - `gen-data`   generate a synthetic dataset to a `.rdat` file
//! - `eval`       run a probed-items/recall experiment from a TOML config
//! - `theory`     print ρ curves and the Theorem 1 report for a config
//! - `serve`      build an index and drive a batched serving workload
//! - `ingest`     append rows to a crash-consistent mutable store
//!                (creating it on first use)
//! - `delete`     tombstone ids in a mutable store
//! - `artifacts`  check the AOT artifact directory and runtime
//!
//! The argument parser is in-tree (offline build, no clap): flags are
//! `--key value` pairs (plus bare `--flag` booleans) after the subcommand.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context};

use rangelsh::config::{Config, DatasetKind, IndexAlgo, ProbeBackend};
use rangelsh::coordinator::server::drive_any_with;
use rangelsh::coordinator::{
    AnyEngine, AnyStore, BatchPolicy, DegradeReason, MutableConfig, QueryParams, RouterPolicy,
    SearchEngine, Shard, ShardedRouter,
};
use rangelsh::data::{load_dataset, save_dataset, synthetic, Dataset};
use rangelsh::eval::harness::{format_probe_table, ground_truth, run_curve, CurveSpec};
use rangelsh::eval::recall::geometric_checkpoints;
use rangelsh::hash::{Code128, Code256, CodeWord, ItemHasher, NativeHasher, Projection};
use rangelsh::index::range::{RangeLshIndex, RangeLshParams};
use rangelsh::index::simple::{SimpleLshIndex, SimpleLshParams};
use rangelsh::index::{
    load_any_range_index, partition, save_range_index, AnyRangeLshIndex, CodeProbe, IndexStats,
    MipsIndex,
};
use rangelsh::runtime::{PjrtHasher, RuntimeHandle, DEFAULT_ARTIFACT_DIR};
use rangelsh::theory::{g_rho, theorem1_check};
use rangelsh::util::json::Json;
use rangelsh::Result;

const USAGE: &str = "\
rangelsh — Norm-Ranging LSH for MIPS (NeurIPS 2018) full-system reproduction

USAGE: rangelsh <SUBCOMMAND> [--key value ...]

SUBCOMMANDS:
  gen-data   --kind <mf_embeddings|longtail_sift|uniform_norm> --n N --dim D
             [--seed S] --out FILE.rdat
  build      --config FILE.toml --out-dir DIR   (writes items.rdat + index.rlsh)
  eval       --config FILE.toml [--compare] [--json-out FILE.json]
  theory     --config FILE.toml [--c 0.7]
  serve      --config FILE.toml [--load DIR] [--n-queries 2000] [--native]
             [--artifacts DIR] [--clients 16] [--rerank streaming|exhaustive]
             [--probe-backend auto|counting_sort|mih]
             [--k K] [--budget B] [--min-candidates M] [--extend-step S]
             (per-request QueryParams overriding the [serve] defaults)
             [--deadline-ms MS]  per-query time budget: an expired query
             returns its best-so-far top-k tagged degraded, never an error
             [--shards N] [--min-shards M]  fan out over N row-sliced
             shards with fault isolation; a merge needs >= M live shards
             (default: all)
             [--wal-dir DIR]  serve a crash-consistent mutable store
             (from `rangelsh ingest`) instead of building/loading an
             immutable index
  ingest     --dir DIR --data FILE.rdat [--compact]
             [--code-bits L] [--partitions M] [--seed S]
             append rows to the store at DIR (WAL-acknowledged, replayed
             on reopen after any crash); first use creates the store
             from the data file with the given index shape
  delete     --dir DIR --ids 1,2,3 [--compact]
             tombstone ids in the store at DIR; deleted ids never
             surface in any answer, compaction reclaims them
  artifacts  [--dir DIR]
";

/// Tiny flag parser: `--key value` pairs and bare boolean `--flag`s.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], boolean_flags: &[&str]) -> Result<Self> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let key = arg
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {arg:?}"))?;
            if boolean_flags.contains(&key) {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(Self { flags })
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(String::as_str)
            .with_context(|| format!("missing required flag --{key}"))
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_some(key)?.unwrap_or(default))
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Optional flag parsed to `Some(T)` when present, `None` otherwise.
    fn opt_some<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "gen-data" => gen_data(&Args::parse(rest, &[])?),
        "build" => build(&Args::parse(rest, &[])?),
        "eval" => eval(&Args::parse(rest, &["compare"])?),
        "theory" => theory(&Args::parse(rest, &[])?),
        "serve" => serve(&Args::parse(rest, &["native"])?),
        "ingest" => ingest_cmd(&Args::parse(rest, &["compact"])?),
        "delete" => delete_cmd(&Args::parse(rest, &["compact"])?),
        "artifacts" => artifacts_check(&Args::parse(rest, &[])?),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let kind: DatasetKind = args.req("kind")?.parse()?;
    let n: usize = args.req("n")?.parse().context("--n")?;
    let dim: usize = args.req("dim")?.parse().context("--dim")?;
    let seed: u64 = args.opt_parse("seed", 42)?;
    let out = PathBuf::from(args.req("out")?);
    let d = match kind {
        DatasetKind::MfEmbeddings => synthetic::mf_embeddings(n, dim, 32.min(dim), seed),
        DatasetKind::LongtailSift => synthetic::longtail_sift(n, dim, seed),
        DatasetKind::UniformNorm => synthetic::uniform_norm(n, dim, seed),
    };
    let stats = d.norm_stats();
    save_dataset(&d, &out)?;
    println!(
        "wrote {} items (dim {}) to {} — norm median {:.3}, max {:.3}, tail ratio {:.2}",
        d.len(),
        dim,
        out.display(),
        stats.median,
        stats.max,
        stats.tail_ratio()
    );
    Ok(())
}

fn build(args: &Args) -> Result<()> {
    let cfg = Config::from_path(args.req("config")?)?;
    let out_dir = PathBuf::from(args.req("out-dir")?);
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let items = cfg.dataset.build_items();
    let params = RangeLshParams::new(cfg.index.code_bits, cfg.index.n_partitions)
        .with_scheme(cfg.index.scheme)
        .with_epsilon(cfg.index.epsilon);
    let t0 = std::time::Instant::now();
    // Monomorphized dispatch on the code budget: u64 keeps its historical
    // 64-wide panel; wider budgets hash with a hash_bits-wide panel.
    let out_path = out_dir.join("index.rlsh");
    let backend = cfg.serve.probe_backend;
    let stats = if cfg.index.code_bits <= 64 {
        build_and_save::<u64>(&items, params, cfg.index.seed, 64, &out_path, backend)?
    } else if cfg.index.code_bits <= 128 {
        build_and_save::<Code128>(
            &items,
            params,
            cfg.index.seed,
            params.hash_bits(),
            &out_path,
            backend,
        )?
    } else {
        build_and_save::<Code256>(
            &items,
            params,
            cfg.index.seed,
            params.hash_bits(),
            &out_path,
            backend,
        )?
    };
    println!("built index in {:.2}s: {stats:?}", t0.elapsed().as_secs_f64());
    save_dataset(&items, out_dir.join("items.rdat"))?;
    println!("wrote {}/items.rdat and {}/index.rlsh", out_dir.display(), out_dir.display());
    Ok(())
}

/// Build a RANGE-LSH index at one code width and persist it (v3 format:
/// checksummed sections, atomic temp-file + rename write). When the
/// `[serve]` probe backend resolves to
/// MIH at this width, the chunk tables are built now and saved in the
/// file's optional MIH section, so `serve --load` skips the rebuild.
fn build_and_save<C: CodeWord>(
    items: &Dataset,
    params: RangeLshParams,
    seed: u64,
    width: usize,
    out_path: &std::path::Path,
    backend: ProbeBackend,
) -> Result<IndexStats> {
    let hasher: NativeHasher<C> = NativeHasher::new(items.dim(), width, seed);
    let mut index = RangeLshIndex::build(items, &hasher, params)?;
    if backend.resolve(params.code_bits) == ProbeBackend::Mih {
        index.enable_mih();
    }
    save_range_index(&index, out_path)?;
    Ok(index.stats())
}

fn eval(args: &Args) -> Result<()> {
    let cfg = Config::from_path(args.req("config")?)?;
    let items = cfg.dataset.build_items();
    let queries = cfg.dataset.build_queries();
    println!(
        "dataset: {} items, {} queries, dim {} (tail ratio {:.2})",
        items.len(),
        queries.len(),
        items.dim(),
        items.norm_stats().tail_ratio()
    );
    let gt = ground_truth(&items, &queries, cfg.eval.top_k);
    let max_probe = cfg.eval.max_probe.unwrap_or(items.len()).min(items.len());
    let cps =
        geometric_checkpoints(cfg.eval.min_probe, max_probe, cfg.eval.checkpoints_per_decade);

    let algos: Vec<IndexAlgo> = if args.has("compare") {
        vec![
            IndexAlgo::RangeLsh,
            IndexAlgo::SimpleLsh,
            IndexAlgo::L2Alsh,
            IndexAlgo::RangedL2Alsh,
        ]
    } else {
        vec![cfg.index.algo]
    };
    let mut results = Vec::new();
    for algo in algos {
        let mut spec = CurveSpec::new(algo, cfg.index.code_bits, cfg.index.n_partitions);
        spec.scheme = cfg.index.scheme;
        spec.epsilon = cfg.index.epsilon;
        spec.top_k = cfg.eval.top_k;
        spec.seed = cfg.index.seed;
        let label = format!("{algo} L={}", cfg.index.code_bits);
        let res = run_curve(&items, &queries, &gt, &cps, &spec, label)?;
        println!(
            "{}: build {:.2}s, query {:.2}s, final recall {:.3}",
            res.label,
            res.build_secs,
            res.query_secs,
            res.curve.final_recall()
        );
        results.push(res);
    }
    println!("\n{}", format_probe_table(&results, &cfg.eval.recall_targets));
    if let Some(path) = args.opt("json-out") {
        let json = Json::Arr(results.iter().map(result_to_json).collect()).to_string();
        std::fs::write(path, json).with_context(|| format!("writing {path}"))?;
        println!("wrote JSON results to {path}");
    }
    Ok(())
}

fn result_to_json(r: &rangelsh::eval::harness::ExperimentResult) -> Json {
    Json::obj(vec![
        ("label", Json::Str(r.label.clone())),
        ("checkpoints", Json::arr_usize(r.curve.checkpoints.iter().copied())),
        ("recalls", Json::arr_f64(r.curve.recalls.iter().copied())),
        ("n_buckets", Json::Num(r.stats.n_buckets as f64)),
        ("largest_bucket", Json::Num(r.stats.largest_bucket as f64)),
        ("build_secs", Json::Num(r.build_secs)),
        ("query_secs", Json::Num(r.query_secs)),
    ])
}

fn theory(args: &Args) -> Result<()> {
    let cfg = Config::from_path(args.req("config")?)?;
    let c: f64 = args.opt_parse("c", 0.7)?;
    let items = cfg.dataset.build_items();
    println!("# Fig 1(a): rho = G(c, S0)");
    println!("{:>6}  {:>8}  {:>8}  {:>8}", "S0", "c=0.5", "c=0.7", "c=0.9");
    for i in 1..=19 {
        let s0 = 0.05 * i as f64;
        println!(
            "{:>6.2}  {:>8.4}  {:>8.4}  {:>8.4}",
            s0,
            g_rho(0.5, s0),
            g_rho(0.7, s0),
            g_rho(0.9, s0)
        );
    }
    let parts = partition(&items, cfg.index.n_partitions, cfg.index.scheme)?;
    let us: Vec<f32> = parts.iter().map(|p| p.u_max).collect();
    let queries = cfg.dataset.build_queries();
    let mips = rangelsh::eval::max_inner_products(&items, &queries);
    let mean_s0 = (mips.iter().map(|&v| v as f64).sum::<f64>() / mips.len() as f64)
        .min(items.max_norm() as f64);
    let rep = theorem1_check(items.len(), &us, items.max_norm(), mean_s0, c);
    println!("\n# Theorem 1 report (S0 = mean max-IP = {mean_s0:.4}, c = {c})");
    println!(
        "rho = {:.4}, rho* = {:.4}, alpha = {:.4} (limit {:.4}), beta = {:.4} (limit {:.4})",
        rep.rho, rep.rho_star, rep.alpha, rep.alpha_limit, rep.beta, rep.beta_limit
    );
    println!(
        "conditions hold: {} — predicted RANGE/SIMPLE cost ratio: {:.4}",
        rep.conditions_hold, rep.predicted_cost_ratio
    );
    Ok(())
}

/// Load the PJRT runtime when artifacts exist (unless `--native`); every
/// serve arm then selects PJRT-vs-native per width through `AnyEngine`.
fn load_runtime(native_only: bool, artifacts: &std::path::Path) -> Option<RuntimeHandle> {
    if native_only || !artifacts.join("manifest.json").exists() {
        return None;
    }
    match RuntimeHandle::load(artifacts) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("PJRT unavailable ({e:#}); falling back to native hashing");
            None
        }
    }
}

/// Prefer the AOT Pallas kernel via PJRT; fall back to native (u64 path).
fn pick_u64_hasher(
    runtime: Option<&RuntimeHandle>,
    proj: Arc<Projection>,
) -> Arc<dyn ItemHasher> {
    if let Some(rt) = runtime {
        match PjrtHasher::<u64>::new(rt.clone(), proj.clone()) {
            Ok(h) => return Arc::new(h),
            Err(e) => println!("PJRT hasher unavailable ({e:#}); native hashing"),
        }
    }
    Arc::new(NativeHasher::with_projection(proj))
}

fn serve(args: &Args) -> Result<()> {
    let mut cfg = Config::from_path(args.req("config")?)?;
    // --rerank streaming|exhaustive: override the [serve] re-rank mode
    // (streaming is the default; exhaustive keeps the probe-then-score
    // oracle path and SIMPLE-LSH's batched codes-vector scan).
    if let Some(mode) = args.opt("rerank") {
        cfg.serve.rerank = mode.parse()?;
    }
    // --probe-backend auto|counting_sort|mih: override the [serve]
    // candidate-generation backend (auto width-gates — MIH chunk tables
    // at code_bits >= 128, counting sort below).
    if let Some(backend) = args.opt("probe-backend") {
        cfg.serve.probe_backend = backend.parse()?;
    }
    // --shards N: the fault-isolated multi-shard serving story takes a
    // separate path (router fan-out instead of the batch server).
    if let Some(n_shards) = args.opt_some::<usize>("shards")? {
        anyhow::ensure!(n_shards >= 1, "--shards must be >= 1");
        return serve_sharded(args, &cfg, n_shards);
    }
    // --wal-dir DIR: serve a crash-consistent mutable store (built by
    // `rangelsh ingest`) through its current epoch handle.
    if let Some(dir) = args.opt("wal-dir") {
        anyhow::ensure!(
            args.opt("load").is_none(),
            "--wal-dir and --load are mutually exclusive"
        );
        return serve_store(args, &cfg, &PathBuf::from(dir));
    }
    let n_queries: usize = args.opt_parse("n-queries", 2000)?;
    let clients: usize = args.opt_parse("clients", 16)?;
    let artifacts = PathBuf::from(args.opt("artifacts").unwrap_or(DEFAULT_ARTIFACT_DIR));
    // --load DIR: serve a pre-built index (from `rangelsh build`); the
    // file's width header selects the monomorphized engine.
    let loaded: Option<(Arc<Dataset>, AnyRangeLshIndex)> = match args.opt("load") {
        Some(dir) => {
            let dir = PathBuf::from(dir);
            let items = Arc::new(load_dataset(dir.join("items.rdat"))?);
            let index = load_any_range_index(dir.join("index.rlsh"))?;
            println!(
                "loaded {} items + {}-bit-code index from {}",
                items.len(),
                index.code_words() * 64,
                dir.display()
            );
            Some((items, index))
        }
        None => None,
    };
    let items = match &loaded {
        Some((items, _)) => items.clone(),
        None => Arc::new(cfg.dataset.build_items()),
    };
    let dim = items.dim();

    let t0 = std::time::Instant::now();
    // One runtime serves every arm: `AnyEngine` picks PJRT per width when
    // the artifact geometry matches, blocked-native otherwise.
    let runtime = load_runtime(args.has("native"), &artifacts);
    let engine: AnyEngine = match loaded {
        // Loaded index of whatever width the file declared: batch
        // queries through the kernel when the stored panel matches the
        // artifact geometry, else native with the same panel.
        Some((_, index)) => {
            AnyEngine::from_loaded_with(index, items.clone(), cfg.serve.clone(), runtime.as_ref())?
        }
        // Fresh SIMPLE-LSH build: the historical u64-only arm. The
        // serve-time budget (`[serve] code_bits`, defaulting to the index
        // budget) drives both the width dispatch and the index build, so
        // an override is honoured instead of producing a mismatch.
        None if matches!(cfg.index.algo, IndexAlgo::SimpleLsh) => {
            anyhow::ensure!(
                cfg.serve.code_bits <= 64,
                "algo simple_lsh serves code_bits <= 64 (got {})",
                cfg.serve.code_bits
            );
            let proj = Arc::new(Projection::gaussian(dim + 1, 64, cfg.index.seed));
            let hasher = pick_u64_hasher(runtime.as_ref(), proj);
            let mut simple = SimpleLshIndex::build(
                &items,
                hasher.as_ref(),
                SimpleLshParams::new(cfg.serve.code_bits),
            )?;
            // Honour an explicit MIH request (auto resolves to counting
            // sort at <= 64 bits, simple_lsh's whole range).
            if cfg.serve.probe_backend.resolve(cfg.serve.code_bits) == ProbeBackend::Mih {
                simple.enable_mih();
            }
            let index: Arc<dyn CodeProbe> = Arc::new(simple);
            AnyEngine::W64(Arc::new(SearchEngine::new(
                index,
                items.clone(),
                hasher,
                cfg.serve.clone(),
            )?))
        }
        // Fresh RANGE-LSH build at any width: monomorphized dispatch with
        // per-arm backend selection (the multi-word kernel restores PJRT
        // batching at L > 64). Non-range algos keep the historical
        // behavior: range serving at L <= 64, an explicit error wider.
        None => {
            anyhow::ensure!(
                cfg.serve.code_bits <= 64 || matches!(cfg.index.algo, IndexAlgo::RangeLsh),
                "code_bits {} > 64 currently serves algo range_lsh only (got {})",
                cfg.serve.code_bits,
                cfg.index.algo
            );
            AnyEngine::build_range_auto(
                items.clone(),
                RangeLshParams::new(cfg.serve.code_bits, cfg.index.n_partitions)
                    .with_scheme(cfg.index.scheme)
                    .with_epsilon(cfg.index.epsilon),
                cfg.index.seed,
                cfg.serve.clone(),
                runtime.as_ref(),
            )?
        }
    };
    println!(
        "engine ready in {:.2}s ({} x u64 code words, {} hashing, {:?} re-rank)",
        t0.elapsed().as_secs_f64(),
        engine.code_words(),
        engine.hasher_backend(),
        cfg.serve.rerank
    );

    // Per-request overrides of the [serve] defaults — the knobs every
    // request could set individually through `ServerHandle::query_with`;
    // the CLI applies one override to the whole workload.
    let qp = query_params_from(args)?;
    if !qp.is_default() {
        println!("per-request params: {qp:?}");
    }
    let queries = synthetic::gaussian_queries(n_queries, dim, cfg.dataset.seed ^ 0xDEAD);
    let policy = BatchPolicy::new(
        cfg.serve.max_batch,
        Duration::from_micros(cfg.serve.deadline_us),
    );
    let (results, wall) = drive_any_with(&engine, policy, &queries, clients, qp)?;
    let snap = engine.metrics().snapshot();
    println!(
        "served {} queries in {:.2}s — {:.0} qps, p50 {}us, p95 {}us, p99 {}us, \
         mean probed {:.0}, mean batch {:.1}, degraded {}, shed {}",
        results.len(),
        wall.as_secs_f64(),
        results.len() as f64 / wall.as_secs_f64(),
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_probed,
        snap.mean_batch_rows,
        snap.queries_degraded,
        snap.shed,
    );
    Ok(())
}

/// `serve --wal-dir DIR`: reopen the mutable store (replaying its WAL —
/// recovery after a crash is exactly this path) and drive the workload
/// through the current epoch's engine.
fn serve_store(args: &Args, cfg: &Config, dir: &std::path::Path) -> Result<()> {
    let t0 = std::time::Instant::now();
    let store = AnyStore::open(dir, cfg.serve.clone(), MutableConfig::default())?;
    let engine = store.engine();
    let dim = store.dim();
    println!(
        "mutable store ready in {:.2}s ({} x u64 code words, epoch {}, {} live items, \
         {} tombstoned)",
        t0.elapsed().as_secs_f64(),
        store.code_words(),
        store.epoch(),
        store.live_len(),
        store.tombstoned_len(),
    );
    let qp = query_params_from(args)?;
    if !qp.is_default() {
        println!("per-request params: {qp:?}");
    }
    let n_queries: usize = args.opt_parse("n-queries", 2000)?;
    let clients: usize = args.opt_parse("clients", 16)?;
    let queries = synthetic::gaussian_queries(n_queries, dim, cfg.dataset.seed ^ 0xDEAD);
    let policy = BatchPolicy::new(
        cfg.serve.max_batch,
        Duration::from_micros(cfg.serve.deadline_us),
    );
    let (results, wall) = drive_any_with(&engine, policy, &queries, clients, qp)?;
    let snap = engine.metrics().snapshot();
    println!(
        "served {} queries in {:.2}s — {:.0} qps, p50 {}us, p95 {}us, p99 {}us, \
         mean probed {:.0}, mean batch {:.1}, degraded {}, shed {}",
        results.len(),
        wall.as_secs_f64(),
        results.len() as f64 / wall.as_secs_f64(),
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_probed,
        snap.mean_batch_rows,
        snap.queries_degraded,
        snap.shed,
    );
    Ok(())
}

/// `rangelsh ingest`: WAL-acknowledged row append. On a fresh directory
/// the data file seeds the store (index shape from `--code-bits` /
/// `--partitions` / `--seed`); on an existing store those flags are
/// ignored and the rows are ingested into the stored shape.
fn ingest_cmd(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.req("dir")?);
    let data = load_dataset(args.req("data")?)?;
    let mcfg = MutableConfig::default();
    let t0 = std::time::Instant::now();
    let store = if dir.join("MANIFEST").exists() {
        let store = AnyStore::open(&dir, rangelsh::config::ServeConfig::default(), mcfg)?;
        anyhow::ensure!(
            data.dim() == store.dim(),
            "data dim {} != store dim {}",
            data.dim(),
            store.dim()
        );
        let ids = store.ingest(data.flat())?;
        println!(
            "ingested {} rows into {} in {:.2}s (ids {}..={}, epoch {}, {} live)",
            ids.len(),
            dir.display(),
            t0.elapsed().as_secs_f64(),
            ids.first().copied().unwrap_or(0),
            ids.last().copied().unwrap_or(0),
            store.epoch(),
            store.live_len(),
        );
        store
    } else {
        let code_bits: usize = args.opt_parse("code-bits", 64)?;
        let n_partitions: usize = args.opt_parse("partitions", 8)?;
        let seed: u64 = args.opt_parse("seed", 42)?;
        let cfg = rangelsh::config::ServeConfig { code_bits, ..Default::default() };
        let params = RangeLshParams::new(code_bits, n_partitions);
        let n = data.len();
        let store = AnyStore::create(&dir, Arc::new(data), params, seed, cfg, mcfg)?;
        println!(
            "created store at {} with {n} rows in {:.2}s ({code_bits}-bit codes, \
             {n_partitions} ranges)",
            dir.display(),
            t0.elapsed().as_secs_f64(),
        );
        store
    };
    if args.has("compact") {
        store.compact()?;
        println!("compacted: epoch {}, {} live", store.epoch(), store.live_len());
    }
    Ok(())
}

/// `rangelsh delete`: tombstone ids; re-deletes are idempotent no-ops,
/// unknown ids are an error before anything is logged.
fn delete_cmd(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.req("dir")?);
    let ids = args
        .req("ids")?
        .split(',')
        .map(|s| s.trim().parse::<u32>().map_err(|e| anyhow::anyhow!("--ids {s:?}: {e}")))
        .collect::<Result<Vec<_>>>()?;
    let store =
        AnyStore::open(&dir, rangelsh::config::ServeConfig::default(), MutableConfig::default())?;
    let n = store.delete(&ids)?;
    println!(
        "tombstoned {n} of {} ids (epoch {}, {} live, {} tombstoned)",
        ids.len(),
        store.epoch(),
        store.live_len(),
        store.tombstoned_len(),
    );
    if args.has("compact") {
        store.compact()?;
        println!("compacted: epoch {}, {} live", store.epoch(), store.live_len());
    }
    Ok(())
}

/// The per-request override flags shared by the single-engine and sharded
/// serve paths (`--k` / `--budget` / `--min-candidates` / `--extend-step`
/// / `--deadline-ms`).
fn query_params_from(args: &Args) -> Result<QueryParams> {
    Ok(QueryParams {
        top_k: args.opt_some("k")?,
        probe_budget: args.opt_some("budget")?,
        min_candidates: args.opt_some("min-candidates")?,
        extend_step: args.opt_some("extend-step")?,
        time_budget: args.opt_some::<u64>("deadline-ms")?.map(Duration::from_millis),
    })
}

/// `serve --shards N`: fan the workload over `N` row-sliced shards, each
/// with its own RANGE-LSH index and engine (Alg. 1 per sub-dataset owner),
/// behind the fault-isolating [`ShardedRouter`]. Queries go straight to
/// the router (no batch server: fan-out parallelism replaces batching);
/// degraded merges are counted, not hidden.
fn serve_sharded(args: &Args, cfg: &Config, n_shards: usize) -> Result<()> {
    anyhow::ensure!(args.opt("load").is_none(), "--shards serves fresh builds only (no --load)");
    anyhow::ensure!(
        matches!(cfg.index.algo, IndexAlgo::RangeLsh),
        "--shards serves algo range_lsh (got {})",
        cfg.index.algo
    );
    let params = RangeLshParams::new(cfg.serve.code_bits, cfg.index.n_partitions)
        .with_scheme(cfg.index.scheme)
        .with_epsilon(cfg.index.epsilon);
    if cfg.serve.code_bits <= 64 {
        serve_sharded_width::<u64>(args, cfg, n_shards, params, 64)
    } else if cfg.serve.code_bits <= 128 {
        serve_sharded_width::<Code128>(args, cfg, n_shards, params, params.hash_bits())
    } else {
        serve_sharded_width::<Code256>(args, cfg, n_shards, params, params.hash_bits())
    }
}

fn serve_sharded_width<C: CodeWord>(
    args: &Args,
    cfg: &Config,
    n_shards: usize,
    params: RangeLshParams,
    width: usize,
) -> Result<()> {
    let items = cfg.dataset.build_items();
    let (dim, n) = (items.dim(), items.len());
    anyhow::ensure!(n >= n_shards, "{n} items cannot fill {n_shards} shards");
    let t0 = std::time::Instant::now();
    let per = n.div_ceil(n_shards);
    let mut shards = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let (lo, hi) = (s * per, ((s + 1) * per).min(n));
        if lo >= hi {
            break;
        }
        let d = Arc::new(Dataset::from_flat(dim, items.flat()[lo * dim..hi * dim].to_vec()));
        let hasher: Arc<NativeHasher<C>> =
            Arc::new(NativeHasher::new(dim, width, cfg.index.seed + s as u64));
        let index = Arc::new(RangeLshIndex::build(&d, hasher.as_ref(), params)?);
        let engine = Arc::new(SearchEngine::new(index, d, hasher, cfg.serve.clone())?);
        shards.push(Shard { engine, id_offset: lo as u32 });
    }
    let policy = RouterPolicy {
        min_shards: args.opt_parse("min-shards", usize::MAX)?,
        ..RouterPolicy::default()
    };
    let router =
        Arc::new(ShardedRouter::with_policy(shards, cfg.serve.top_k, policy)?);
    println!(
        "sharded engine ready in {:.2}s ({} shards x ~{per} items, min_shards {})",
        t0.elapsed().as_secs_f64(),
        router.n_shards(),
        router.policy().min_shards
    );

    let qp = query_params_from(args)?;
    if !qp.is_default() {
        println!("per-request params: {qp:?}");
    }
    let n_queries: usize = args.opt_parse("n-queries", 2000)?;
    let clients: usize = args.opt_parse("clients", 16)?.max(1);
    let queries = synthetic::gaussian_queries(n_queries, dim, cfg.dataset.seed ^ 0xDEAD);
    let t0 = std::time::Instant::now();
    let chunk = n_queries.div_ceil(clients);
    let mut served = 0usize;
    let mut degraded = [0usize; 3]; // indexed by DegradeReason severity
    let mut failed = 0usize;
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for t in 0..clients {
            let (lo, hi) = (t * chunk, ((t + 1) * chunk).min(n_queries));
            let (router, queries, qp) = (router.clone(), &queries, &qp);
            handles.push(scope.spawn(move || {
                let mut counts = (0usize, [0usize; 3], 0usize);
                for qi in lo..hi {
                    match router.query_full(queries.row(qi), qp) {
                        Ok(resp) => {
                            counts.0 += 1;
                            if let Some(tag) = resp.degraded {
                                counts.1[match tag.reason {
                                    DegradeReason::BudgetExhausted => 0,
                                    DegradeReason::Deadline => 1,
                                    DegradeReason::ShardLoss => 2,
                                }] += 1;
                            }
                        }
                        Err(_) => counts.2 += 1,
                    }
                }
                counts
            }));
        }
        for h in handles {
            let (s, d, f) = h.join().expect("client thread panicked");
            served += s;
            for (acc, v) in degraded.iter_mut().zip(d) {
                *acc += v;
            }
            failed += f;
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    let snap = router.metrics().snapshot();
    println!(
        "served {served} queries in {:.2}s — {:.0} qps; degraded: {} budget / {} deadline / \
         {} shard-loss; failed {failed}; shard failures {}, retries {}",
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64(),
        degraded[0],
        degraded[1],
        degraded[2],
        snap.shard_failures,
        snap.retries,
    );
    Ok(())
}

/// Smoke-execute one hash dim at the artifact's code width and
/// cross-check against the blocked native path.
fn smoke_hash<C: CodeWord>(rt: &RuntimeHandle, dim: usize) -> Result<()> {
    let proj = Arc::new(Projection::gaussian(dim + 1, rt.manifest().proj_width, 0));
    let hasher: PjrtHasher<C> = PjrtHasher::new(rt.clone(), proj.clone())?;
    let rows = vec![0.5f32; 4 * dim];
    let codes = hasher.hash_items(&rows, 2.0)?;
    let native_hasher: NativeHasher<C> = NativeHasher::with_projection(proj);
    let native = native_hasher.hash_items(&rows, 2.0)?;
    println!(
        "smoke hash (dim {dim}): pjrt {:016x?} vs native {:016x?} — {}",
        codes[0].as_words(),
        native[0].as_words(),
        if codes == native { "MATCH" } else { "MISMATCH" }
    );
    Ok(())
}

fn artifacts_check(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt("dir").unwrap_or(DEFAULT_ARTIFACT_DIR));
    let rt = RuntimeHandle::load(&dir)?;
    let m = rt.manifest();
    println!(
        "artifacts ok: format={}, item_block={}, query_block={}, proj_width={}, code_words={}",
        m.format, m.item_block, m.query_block, m.proj_width, m.code_words
    );
    for e in &m.entries {
        println!("  {} <- {}", e.name, e.file);
    }
    if let Some(&dim) = m.hash_dims().first() {
        match rt.code_words() {
            1 => smoke_hash::<u64>(&rt, dim)?,
            2 => smoke_hash::<Code128>(&rt, dim)?,
            _ => smoke_hash::<Code256>(&rt, dim)?,
        }
    }
    rt.shutdown();
    Ok(())
}
