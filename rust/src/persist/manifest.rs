//! Checkpoint manifest: the small, checksummed file that names which
//! epoch a store directory's `items.rdat` + `index.rlsh` pair represents
//! and which ids were tombstoned as of that checkpoint.
//!
//! The manifest is written *last* in the checkpoint sequence (items →
//! index → manifest → WAL truncate) and published by temp-file/rename,
//! so its presence certifies that the files it describes are complete.
//!
//! ## On-disk format (all little-endian)
//!
//! ```text
//! [magic "RLSHMAN\x01": 8 bytes]
//! [epoch: u64] [n_rows: u64] [dim: u32] [tombstones: u64 len, u32 × len]
//! [crc32 of everything after the magic: u32]   -- the "manifest" section
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::bytes::{
    read_u32, read_u32s, read_u64, write_u32, write_u32s, write_u64, HashingReader,
    HashingWriter,
};
use crate::{ItemId, Result};

/// Manifest file magic (`RLSHMAN`, version 1).
pub const MANIFEST_MAGIC: &[u8; 8] = b"RLSHMAN\x01";

/// The durable summary of one checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Monotonic epoch counter; bumped by every checkpoint.
    pub epoch: u64,
    /// Rows in `items.rdat` at checkpoint time (WAL inserts resume after
    /// this prefix — the file is append-only and prefix-stable).
    pub n_rows: u64,
    /// Row dimensionality, cross-checked against the dataset on open.
    pub dim: u32,
    /// Ids tombstoned as of this checkpoint, ascending.
    pub tombstones: Vec<ItemId>,
}

/// Atomically write `manifest` to `path`: staged as a `.tmp` sibling,
/// fsynced, then renamed into place (plus a best-effort directory sync),
/// so a crash leaves either the old manifest or the new one — never a
/// torn file.
pub fn save_manifest(path: impl AsRef<Path>, manifest: &Manifest) -> Result<()> {
    let path = path.as_ref();
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    {
        let file =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(MANIFEST_MAGIC)?;
        let mut hw = HashingWriter::new(&mut w);
        write_u64(&mut hw, manifest.epoch)?;
        write_u64(&mut hw, manifest.n_rows)?;
        write_u32(&mut hw, manifest.dim)?;
        write_u32s(&mut hw, &manifest.tombstones)?;
        hw.emit_section_crc()?;
        w.flush()?;
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(dir) = path.parent() {
        super::sync_dir(dir);
    }
    Ok(())
}

/// Load and verify a manifest. Fails on a bad magic, a checksum
/// mismatch, or trailing bytes (strict EOF, like the `.rlsh` loaders).
pub fn load_manifest(path: impl AsRef<Path>) -> Result<Manifest> {
    let path = path.as_ref();
    let file =
        File::open(path).with_context(|| format!("opening manifest {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("reading manifest magic from {}", path.display()))?;
    anyhow::ensure!(
        &magic == MANIFEST_MAGIC,
        "{}: not a rangelsh manifest",
        path.display()
    );
    let mut hr = HashingReader::new(&mut r);
    let epoch = read_u64(&mut hr)?;
    let n_rows = read_u64(&mut hr)?;
    let dim = read_u32(&mut hr)?;
    let tombstones = read_u32s(&mut hr)?;
    hr.verify_section_crc("manifest")?;
    let mut trailing = [0u8; 1];
    anyhow::ensure!(
        r.read(&mut trailing)? == 0,
        "{}: trailing bytes after manifest",
        path.display()
    );
    Ok(Manifest { epoch, n_rows, dim, tombstones })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempPath;

    fn sample() -> Manifest {
        Manifest { epoch: 3, n_rows: 1200, dim: 16, tombstones: vec![4, 17, 901] }
    }

    #[test]
    fn round_trips() {
        let tmp = TempPath::new("manifest");
        save_manifest(tmp.path(), &sample()).unwrap();
        assert_eq!(load_manifest(tmp.path()).unwrap(), sample());
    }

    #[test]
    fn empty_tombstones_round_trip() {
        let tmp = TempPath::new("manifest-empty");
        let m = Manifest { epoch: 0, n_rows: 0, dim: 1, tombstones: vec![] };
        save_manifest(tmp.path(), &m).unwrap();
        assert_eq!(load_manifest(tmp.path()).unwrap(), m);
    }

    #[test]
    fn save_replaces_existing_atomically() {
        let tmp = TempPath::new("manifest-replace");
        save_manifest(tmp.path(), &sample()).unwrap();
        let newer = Manifest { epoch: 4, ..sample() };
        save_manifest(tmp.path(), &newer).unwrap();
        assert_eq!(load_manifest(tmp.path()).unwrap(), newer);
    }

    #[test]
    fn detects_corruption() {
        let tmp = TempPath::new("manifest-corrupt");
        save_manifest(tmp.path(), &sample()).unwrap();
        let mut bytes = std::fs::read(tmp.path()).unwrap();
        bytes[10] ^= 0x01; // inside the epoch field
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_manifest(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("manifest section"));
    }

    #[test]
    fn rejects_bad_magic_and_trailing_bytes() {
        let tmp = TempPath::new("manifest-magic");
        std::fs::write(tmp.path(), b"NOTAMANIFEST").unwrap();
        let err = load_manifest(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("not a rangelsh manifest"));

        save_manifest(tmp.path(), &sample()).unwrap();
        let mut bytes = std::fs::read(tmp.path()).unwrap();
        bytes.push(0);
        std::fs::write(tmp.path(), &bytes).unwrap();
        let err = load_manifest(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("trailing bytes"));
    }

    #[test]
    fn truncated_file_fails_cleanly() {
        let tmp = TempPath::new("manifest-trunc");
        save_manifest(tmp.path(), &sample()).unwrap();
        let bytes = std::fs::read(tmp.path()).unwrap();
        for cut in 0..bytes.len() {
            std::fs::write(tmp.path(), &bytes[..cut]).unwrap();
            assert!(load_manifest(tmp.path()).is_err(), "cut at {cut}");
        }
    }
}
