//! Crash-consistent store persistence: the write-ahead log ([`wal`]) and
//! the checkpoint manifest ([`manifest`]) that together make the mutable
//! index durable (README §"Mutability & recovery model").
//!
//! The durability contract, shared by both submodules and the
//! [`crate::coordinator::store::MutableStore`] that drives them:
//!
//! - a mutation is **acknowledged** only after its WAL record is written
//!   and fsynced — an acked mutation survives any crash;
//! - checkpoint files (`items.rdat`, `index.rlsh`, `MANIFEST`) are only
//!   ever published by atomic temp-file/rename (like the `.rlsh` v3
//!   saves), so a reader never observes a torn file;
//! - the WAL is truncated (atomically, by renaming a fresh header-only
//!   file over it) strictly *after* the checkpoint that covers its
//!   records is on disk — a crash between the two merely replays
//!   idempotent records.

pub mod manifest;
pub mod wal;

pub use manifest::{load_manifest, save_manifest, Manifest};
pub use wal::{Wal, WalRecord};

use std::path::Path;

/// Best-effort directory fsync: after a rename publishes a file, the
/// directory entry itself must reach disk for the publish to survive a
/// power cut. Errors are ignored — not every platform/filesystem supports
/// opening a directory for sync, and the rename itself already happened.
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}
