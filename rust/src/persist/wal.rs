//! Write-ahead log for the mutable index: CRC32-framed insert/delete
//! records, fsynced on append, truncated atomically after a checkpoint.
//!
//! ## On-disk format
//!
//! The file starts with the 8-byte magic `RLSHWAL\x01`. Each record is a
//! self-delimiting frame, all little-endian:
//!
//! ```text
//! [payload_len: u32] [crc32(payload): u32] [payload]
//! payload = [kind: u8] [id: u32] [row: f32 × dim]   kind 1 = insert
//! payload = [kind: u8] [id: u32]                    kind 2 = delete
//! ```
//!
//! ## Torn-tail recovery
//!
//! A crash mid-append leaves a prefix of the last frame on disk. Replay
//! ([`Wal::open`]) reads frames until the first one that is short,
//! CRC-mismatched, or structurally invalid, truncates the file back to
//! the last good frame boundary, and returns the records before it.
//! Because [`Wal::append`] acknowledges only after `sync_data`, every
//! record lost this way was never acknowledged — the recovered state is
//! exactly "all acknowledged mutations" (chaos-tested at the named crash
//! points in `tests/chaos.rs`).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::crc32::crc32;
use crate::{ItemId, Result};

/// WAL file magic (`RLSHWAL`, version 1).
pub const WAL_MAGIC: &[u8; 8] = b"RLSHWAL\x01";

/// Frame headers are `payload_len` + `crc`, 4 bytes each.
const FRAME_HEADER: usize = 8;

/// Payload-length sanity bound: a single logged row cannot plausibly
/// exceed this (it would mean a ~2^28-dimensional item); anything larger
/// is torn-tail garbage and truncates the log there.
const MAX_PAYLOAD: u32 = 1 << 30;

const KIND_INSERT: u8 = 1;
const KIND_DELETE: u8 = 2;

/// One logged mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Append `row` as item `id` and index it.
    Insert { id: ItemId, row: Vec<f32> },
    /// Tombstone item `id`.
    Delete { id: ItemId },
}

impl WalRecord {
    /// Serialized payload (the CRC-covered bytes).
    fn payload(&self) -> Vec<u8> {
        match self {
            Self::Insert { id, row } => {
                let mut p = Vec::with_capacity(5 + row.len() * 4);
                p.push(KIND_INSERT);
                p.extend_from_slice(&id.to_le_bytes());
                for v in row {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                p
            }
            Self::Delete { id } => {
                let mut p = Vec::with_capacity(5);
                p.push(KIND_DELETE);
                p.extend_from_slice(&id.to_le_bytes());
                p
            }
        }
    }

    /// Decode a payload; `None` means structurally invalid (torn tail).
    // staticcheck: allow(panic-reach, "payload indices 0..5 sit behind the len<5 early return; chunk bytes come from chunks_exact(4)")
    fn decode(payload: &[u8]) -> Option<Self> {
        if payload.len() < 5 {
            return None;
        }
        let id = ItemId::from_le_bytes([payload[1], payload[2], payload[3], payload[4]]);
        match payload[0] {
            KIND_INSERT if (payload.len() - 5) % 4 == 0 => {
                let row = payload[5..]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Some(Self::Insert { id, row })
            }
            KIND_DELETE if payload.len() == 5 => Some(Self::Delete { id }),
            _ => None,
        }
    }
}

/// An open write-ahead log, positioned at its end for appends.
pub struct Wal {
    file: File,
    path: PathBuf,
}

impl Wal {
    /// Open (or create) the log at `path` and replay it: returns the
    /// acknowledged records in append order, with any torn tail truncated
    /// off the file first (see the module docs).
    // staticcheck: allow(panic-reach, "every index is a constant in-bound offset into a fixed [u8; 8] stack array")
    pub fn open(path: impl AsRef<Path>) -> Result<(Self, Vec<WalRecord>)> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        let file_len = file.metadata()?.len();
        if file_len < WAL_MAGIC.len() as u64 {
            // Fresh file, or a creation torn before the header landed
            // (nothing was ever acknowledged against it either way).
            file.set_len(0)?;
            file.write_all(WAL_MAGIC)?;
            file.sync_data()?;
            return Ok((Self { file, path }, Vec::new()));
        }
        let mut magic = [0u8; 8];
        file.seek(SeekFrom::Start(0))?;
        file.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == WAL_MAGIC, "{}: not a rangelsh WAL", path.display());
        let mut records = Vec::new();
        let mut good_end = WAL_MAGIC.len() as u64;
        loop {
            let mut header = [0u8; FRAME_HEADER];
            match read_exact_or_eof(&mut file, &mut header)? {
                false => break, // clean or torn mid-header: truncate here
                true => {}
            }
            let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
            let stored_crc = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
            if len > MAX_PAYLOAD {
                break;
            }
            let mut payload = vec![0u8; len as usize];
            if !read_exact_or_eof(&mut file, &mut payload)? {
                break;
            }
            if crc32(&payload) != stored_crc {
                break;
            }
            let Some(rec) = WalRecord::decode(&payload) else { break };
            records.push(rec);
            good_end += (FRAME_HEADER + len as usize) as u64;
        }
        if good_end < file_len {
            // Drop the torn tail so the next append starts at a frame
            // boundary; the dropped bytes were never acknowledged.
            file.set_len(good_end)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::Start(good_end))?;
        Ok((Self { file, path }, records))
    }

    /// Append one record and fsync it. Returning `Ok` *is* the durability
    /// acknowledgement: the record will survive any subsequent crash.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let payload = rec.payload();
        let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to WAL {}", self.path.display()))?;
        self.file
            .sync_data()
            .with_context(|| format!("syncing WAL {}", self.path.display()))?;
        Ok(())
    }

    /// Atomically truncate the log back to an empty (header-only) state —
    /// called after a checkpoint has made its records redundant. A fresh
    /// header-only file is staged as a `.tmp` sibling, fsynced, and
    /// renamed over the log, so a crash at any point leaves either the
    /// full old log (records replay idempotently) or the empty new one.
    pub fn reset(&mut self) -> Result<()> {
        let mut tmp = self.path.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let mut f =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(WAL_MAGIC)?;
        f.sync_all()?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        if let Some(dir) = self.path.parent() {
            super::sync_dir(dir);
        }
        // Appends must go to the *new* inode, not the renamed-away one.
        self.file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.path)
            .with_context(|| format!("reopening WAL {}", self.path.display()))?;
        self.file.seek(SeekFrom::End(0))?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// `read_exact` that distinguishes "hit EOF (possibly mid-buffer)" —
/// `Ok(false)`, the torn-tail signal — from real IO errors.
// staticcheck: allow(panic-reach, "filled < buf.len() is the loop guard, so the range start never passes the end")
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..])? {
            0 => return Ok(false),
            n => filled += n,
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempPath;

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert { id: 7, row: vec![1.0, -2.5, 0.0, 3.25] },
            WalRecord::Delete { id: 3 },
            WalRecord::Insert { id: 8, row: vec![0.5; 4] },
            WalRecord::Delete { id: 7 },
        ]
    }

    #[test]
    fn append_replay_round_trips() {
        let tmp = TempPath::new("wal");
        let recs = sample_records();
        {
            let (mut wal, replayed) = Wal::open(tmp.path()).unwrap();
            assert!(replayed.is_empty());
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert_eq!(replayed, recs);
        // Replay is idempotent: a second open sees the same records.
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert_eq!(replayed, recs);
    }

    #[test]
    fn torn_tail_truncates_to_last_acknowledged_record() {
        // Cut the file at *every* byte length and reopen: the replay must
        // recover exactly the records whose frames fit the prefix, and
        // appending afterwards must work (frame-boundary truncation).
        let tmp = TempPath::new("wal-torn");
        let recs = sample_records();
        {
            let (mut wal, _) = Wal::open(tmp.path()).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        let clean = std::fs::read(tmp.path()).unwrap();
        // Frame boundaries: magic, then each frame's cumulative end.
        let mut boundaries = vec![WAL_MAGIC.len()];
        for r in &recs {
            boundaries.push(boundaries.last().unwrap() + FRAME_HEADER + r.payload().len());
        }
        assert_eq!(*boundaries.last().unwrap(), clean.len());
        for cut in 0..clean.len() {
            std::fs::write(tmp.path(), &clean[..cut]).unwrap();
            let complete = boundaries.iter().filter(|&&b| b <= cut).count().saturating_sub(1);
            let (mut wal, replayed) = Wal::open(tmp.path()).unwrap();
            assert_eq!(replayed, recs[..complete], "cut at {cut}");
            // The torn tail is gone from disk and appends resume cleanly.
            wal.append(&WalRecord::Delete { id: 99 }).unwrap();
            drop(wal);
            let (_, again) = Wal::open(tmp.path()).unwrap();
            assert_eq!(again.len(), complete + 1, "cut at {cut}");
            assert_eq!(again[complete], WalRecord::Delete { id: 99 }, "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_payload_truncates_at_the_flip() {
        let tmp = TempPath::new("wal-flip");
        let recs = sample_records();
        {
            let (mut wal, _) = Wal::open(tmp.path()).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        let clean = std::fs::read(tmp.path()).unwrap();
        // Flip a byte inside record 2's payload: records 0..2 survive.
        let rec2_payload_start =
            WAL_MAGIC.len() + (0..2).map(|i| FRAME_HEADER + recs[i].payload().len()).sum::<usize>()
                + FRAME_HEADER;
        let mut bad = clean.clone();
        bad[rec2_payload_start + 2] ^= 0x40;
        std::fs::write(tmp.path(), &bad).unwrap();
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert_eq!(replayed, recs[..2]);
    }

    #[test]
    fn reset_empties_the_log_atomically() {
        let tmp = TempPath::new("wal-reset");
        let (mut wal, _) = Wal::open(tmp.path()).unwrap();
        for r in &sample_records() {
            wal.append(r).unwrap();
        }
        wal.reset().unwrap();
        // Post-reset appends land in the fresh log.
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        drop(wal);
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert_eq!(replayed, vec![WalRecord::Delete { id: 1 }]);
    }

    #[test]
    fn rejects_foreign_files() {
        let tmp = TempPath::new("wal-foreign");
        std::fs::write(tmp.path(), b"definitely not a WAL, but long enough").unwrap();
        let err = Wal::open(tmp.path()).unwrap_err();
        assert!(format!("{err:#}").contains("not a rangelsh WAL"));
    }

    #[test]
    fn sub_header_garbage_is_reinitialised() {
        // Fewer bytes than the magic: nothing was ever acked, start fresh.
        let tmp = TempPath::new("wal-stub");
        std::fs::write(tmp.path(), b"RLS").unwrap();
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert!(replayed.is_empty());
    }

    #[test]
    fn empty_row_and_zero_id_round_trip() {
        let tmp = TempPath::new("wal-edge");
        let recs = vec![
            WalRecord::Insert { id: 0, row: vec![] },
            WalRecord::Delete { id: 0 },
            WalRecord::Insert { id: u32::MAX, row: vec![f32::MIN_POSITIVE] },
        ];
        {
            let (mut wal, _) = Wal::open(tmp.path()).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        let (_, replayed) = Wal::open(tmp.path()).unwrap();
        assert_eq!(replayed, recs);
        // Bit-exactness of logged rows (the replay feeds hashing).
        let WalRecord::Insert { row, .. } = &replayed[2] else { panic!() };
        assert_eq!(row[0].to_bits(), f32::MIN_POSITIVE.to_bits());
    }
}
