//! [`PjrtHasher`]: the [`ItemHasher`] implementation backed by the AOT
//! Pallas sign-hash kernel. Chunks arbitrary row counts into the fixed
//! `item_block` geometry, pads the tail block with zeros, discards padded
//! outputs, and packs the kernel's `[B, 2] u32` words into `u64` codes.

use std::sync::Arc;

use crate::hash::{ItemHasher, Projection};
use crate::runtime::RuntimeHandle;
use crate::Result;

/// PJRT-backed bulk hasher sharing a [`Projection`] with the native path.
pub struct PjrtHasher {
    runtime: RuntimeHandle,
    proj: Arc<Projection>,
    /// Flat panel cached in the Arc<Vec> shape the worker wants.
    proj_flat: Arc<Vec<f32>>,
}

impl PjrtHasher {
    /// `proj.dim_in()` must equal `d + 1` for a compiled `hash_*_d{d}`
    /// artifact, and `proj.width()` must equal the manifest's proj width.
    pub fn new(runtime: RuntimeHandle, proj: Arc<Projection>) -> Result<Self> {
        let dim = proj.dim_in() - 1;
        anyhow::ensure!(
            runtime.supports_dim(dim),
            "no hash artifact for dim {dim}; compiled dims: {:?} — \
             re-run `make artifacts` with --dims including {dim}",
            runtime.manifest().hash_dims()
        );
        anyhow::ensure!(
            proj.width() == runtime.manifest().proj_width,
            "projection width {} != artifact width {}",
            proj.width(),
            runtime.manifest().proj_width
        );
        let proj_flat = Arc::new(proj.flat().to_vec());
        Ok(Self { runtime, proj, proj_flat })
    }

    /// Words per item emitted by the kernel (width / 32).
    fn words(&self) -> usize {
        self.proj.width().div_ceil(32)
    }

    fn hash_blocks(&self, rows: &[f32], u: Option<f32>) -> Result<Vec<u64>> {
        let dim = self.dim();
        anyhow::ensure!(
            rows.len() % dim == 0,
            "row buffer length {} not a multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        let item_block = self.runtime.manifest().item_block;
        let query_block = self.runtime.manifest().query_block;
        let words = self.words();
        let mut codes = Vec::with_capacity(n);
        for chunk in rows.chunks(item_block * dim) {
            let valid = chunk.len() / dim;
            // Query chunks small enough for the small-batch artifact pad
            // to query_block instead of item_block - 8x less kernel work
            // for typical serving batches (see EXPERIMENTS.md §Perf).
            let block_rows = if u.is_none() && valid <= query_block {
                query_block
            } else {
                item_block
            };
            let mut block = Vec::with_capacity(block_rows * dim);
            block.extend_from_slice(chunk);
            block.resize(block_rows * dim, 0.0); // zero-pad the tail block
            let packed = match u {
                Some(u) => self
                    .runtime
                    .hash_items_block(dim, block, u, self.proj_flat.clone())?,
                None => self
                    .runtime
                    .hash_queries_block(dim, block, self.proj_flat.clone())?,
            };
            anyhow::ensure!(packed.len() == block_rows * words, "kernel output size mismatch");
            for i in 0..valid {
                let mut code = 0u64;
                for w in 0..words {
                    code |= (packed[i * words + w] as u64) << (32 * w);
                }
                codes.push(code);
            }
        }
        Ok(codes)
    }
}

impl ItemHasher for PjrtHasher {
    fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }

    fn hash_items(&self, rows: &[f32], u: f32) -> Result<Vec<u64>> {
        anyhow::ensure!(u > 0.0, "normalisation constant must be positive");
        self.hash_blocks(rows, Some(u))
    }

    fn hash_queries(&self, rows: &[f32]) -> Result<Vec<u64>> {
        self.hash_blocks(rows, None)
    }
}
