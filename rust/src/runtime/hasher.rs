//! [`PjrtHasher`]: the [`ItemHasher`] implementation backed by the AOT
//! Pallas sign-hash kernel, generic over the code word width. Chunks
//! arbitrary row counts into the fixed `item_block` geometry, pads the
//! tail block with zeros, discards padded outputs, and packs the
//! kernel's `[B, width/32] u32` words into `C`-wide codes — 2 u32 words
//! per `u64` code, 4 per [`Code128`], 8 per [`Code256`], matching the
//! manifest's `code_words` key (`C::WORDS` must equal it, checked at
//! construction so a width-128 artifact directory can never feed a
//! `u64` engine and vice versa).

use std::marker::PhantomData;
use std::sync::Arc;

use crate::hash::{CodeWord, ItemHasher, Projection};
use crate::runtime::RuntimeHandle;
use crate::Result;

#[cfg(doc)]
use crate::hash::{Code128, Code256};

/// PJRT-backed bulk hasher sharing a [`Projection`] with the native path.
/// Defaults to the original `u64` single-word codes.
pub struct PjrtHasher<C: CodeWord = u64> {
    runtime: RuntimeHandle,
    proj: Arc<Projection>,
    /// Flat panel cached in the Arc<Vec> shape the worker wants.
    proj_flat: Arc<Vec<f32>>,
    _code: PhantomData<fn() -> C>,
}

impl<C: CodeWord> PjrtHasher<C> {
    /// `proj.dim_in()` must equal `d + 1` for a compiled `hash_*_d{d}`
    /// artifact, `proj.width()` must equal the manifest's proj width,
    /// and the manifest's `code_words` must equal `C::WORDS` (one
    /// artifact directory serves exactly one code width).
    pub fn new(runtime: RuntimeHandle, proj: Arc<Projection>) -> Result<Self> {
        let dim = proj.dim_in() - 1;
        anyhow::ensure!(
            runtime.supports_dim(dim),
            "no hash artifact for dim {dim}; compiled dims: {:?} — \
             re-run `make artifacts` with --dims including {dim}",
            runtime.manifest().hash_dims()
        );
        anyhow::ensure!(
            proj.width() == runtime.manifest().proj_width,
            "projection width {} != artifact width {}",
            proj.width(),
            runtime.manifest().proj_width
        );
        anyhow::ensure!(
            runtime.manifest().code_words == C::WORDS,
            "artifact packs {} code word(s) but the engine runs {}-word codes — \
             re-run `make artifacts` with --width {}",
            runtime.manifest().code_words,
            C::WORDS,
            C::MAX_BITS
        );
        let proj_flat = Arc::new(proj.flat().to_vec());
        Ok(Self { runtime, proj, proj_flat, _code: PhantomData })
    }

    /// u32 words per item emitted by the kernel (width / 32).
    fn kernel_words(&self) -> usize {
        self.proj.width().div_ceil(32)
    }

    // staticcheck: allow(panic-reach, "the kernel output length is ensure!d to block_rows * words before the unpack loop, and words <= 2 * C::WORDS keeps w / 2 inside w64")
    fn hash_blocks(&self, rows: &[f32], u: Option<f32>) -> Result<Vec<C>> {
        let dim = self.dim();
        anyhow::ensure!(
            rows.len() % dim == 0,
            "row buffer length {} not a multiple of dim {dim}",
            rows.len()
        );
        let n = rows.len() / dim;
        let item_block = self.runtime.manifest().item_block;
        let query_block = self.runtime.manifest().query_block;
        let words = self.kernel_words();
        let mut codes = Vec::with_capacity(n);
        for chunk in rows.chunks(item_block * dim) {
            let valid = chunk.len() / dim;
            // Query chunks small enough for the small-batch artifact pad
            // to query_block instead of item_block - 8x less kernel work
            // for typical serving batches (see EXPERIMENTS.md §Perf).
            let block_rows = if u.is_none() && valid <= query_block {
                query_block
            } else {
                item_block
            };
            let mut block = Vec::with_capacity(block_rows * dim);
            block.extend_from_slice(chunk);
            block.resize(block_rows * dim, 0.0); // zero-pad the tail block
            let packed = match u {
                Some(u) => self
                    .runtime
                    .hash_items_block(dim, block, u, self.proj_flat.clone())?,
                None => self
                    .runtime
                    .hash_queries_block(dim, block, self.proj_flat.clone())?,
            };
            anyhow::ensure!(packed.len() == block_rows * words, "kernel output size mismatch");
            for i in 0..valid {
                // Little-endian across u32 words: kernel word w holds
                // hash functions 32w..32w+31, i.e. bits 32(w%2).. of u64
                // word w/2 — the CodeWord bit convention exactly.
                let mut w64 = [0u64; 4];
                for w in 0..words {
                    w64[w / 2] |= (packed[i * words + w] as u64) << (32 * (w % 2));
                }
                codes.push(C::from_words(&w64[..C::WORDS]));
            }
        }
        Ok(codes)
    }
}

impl<C: CodeWord> ItemHasher<C> for PjrtHasher<C> {
    fn projection(&self) -> &Arc<Projection> {
        &self.proj
    }

    fn hash_items(&self, rows: &[f32], u: f32) -> Result<Vec<C>> {
        anyhow::ensure!(u > 0.0, "normalisation constant must be positive");
        self.hash_blocks(rows, Some(u))
    }

    fn hash_queries(&self, rows: &[f32]) -> Result<Vec<C>> {
        self.hash_blocks(rows, None)
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}
