//! Artifact manifest: the shape contract between `python/compile/aot.py`
//! and the Rust runtime (written once at build time, read at startup).
//! Parsed with the in-tree JSON parser ([`crate::util::json`]).

use std::path::Path;

use anyhow::Context;

use crate::util::json::Json;
use crate::Result;

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArtifactInput>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInput {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    /// Rows per hash/score item block (AOT-fixed; runtime pads).
    pub item_block: usize,
    /// Rows per score query block.
    pub query_block: usize,
    /// Hash functions per artifact (Rust masks down to the code length).
    pub proj_width: usize,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let m = Self::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Ok(m)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let str_field = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing string field {key:?}"))?
                .to_string())
        };
        let usize_field = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing integer field {key:?}"))
        };
        let format = str_field("format")?;
        anyhow::ensure!(
            format == "hlo-text",
            "unsupported artifact format {format:?} (want hlo-text)"
        );
        let proj_width = usize_field("proj_width")?;
        anyhow::ensure!((1..=64).contains(&proj_width), "bad proj_width {proj_width}");

        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries array")?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("entry missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("entry missing file")?
                .to_string();
            let mut inputs = Vec::new();
            for inp in e.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("input missing shape")?
                    .iter()
                    .map(|d| d.as_usize().context("non-integer dim"))
                    .collect::<Result<Vec<usize>>>()?;
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(ArtifactInput { shape, dtype });
            }
            entries.push(ArtifactEntry { name, file, inputs });
        }
        Ok(Self {
            format,
            item_block: usize_field("item_block")?,
            query_block: usize_field("query_block")?,
            proj_width,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Dimensionalities with a compiled `hash_items` variant.
    pub fn hash_dims(&self) -> Vec<usize> {
        self.entries
            .iter()
            .filter_map(|e| e.name.strip_prefix("hash_items_d").and_then(|d| d.parse().ok()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let json = r#"{
            "format": "hlo-text", "item_block": 2048, "query_block": 256,
            "proj_width": 64,
            "entries": [
                {"name": "hash_items_d16", "file": "hash_items_d16.hlo.txt",
                 "inputs": [{"shape": [2048, 16], "dtype": "float32"},
                            {"shape": [], "dtype": "float32"},
                            {"shape": [17, 64], "dtype": "float32"}]}
            ]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.item_block, 2048);
        assert_eq!(m.query_block, 256);
        let e = m.entry("hash_items_d16").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![2048, 16]);
        assert_eq!(e.inputs[1].shape, Vec::<usize>::new());
        assert!(m.entry("nope").is_none());
        assert_eq!(m.hash_dims(), vec![16]);
    }

    #[test]
    fn rejects_wrong_format() {
        let json = r#"{"format": "proto", "item_block": 1, "query_block": 1,
                       "proj_width": 64, "entries": []}"#;
        assert!(Manifest::parse(json).is_err());
    }

    #[test]
    fn load_rejects_missing_dir() {
        let err = Manifest::load("/no/such/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn parses_real_generated_manifest_if_present() {
        // Integration nicety: if `make artifacts` has run, the real file
        // must parse and contain the default geometry.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if path.join("manifest.json").exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(!m.entries.is_empty());
            assert_eq!(m.proj_width, 64);
        }
    }
}
