//! Artifact manifest: the shape contract between `python/compile/aot.py`
//! and the Rust runtime (written once at build time, read at startup).
//! Parsed with the in-tree JSON parser ([`crate::util::json`]).

use std::path::Path;

use anyhow::Context;

use crate::util::json::Json;
use crate::Result;

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArtifactInput>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInput {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    /// Rows per hash/score item block (AOT-fixed; runtime pads).
    pub item_block: usize,
    /// Rows per score query block.
    pub query_block: usize,
    /// Hash functions per artifact (Rust masks down to the code length).
    /// One directory is compiled at exactly one width (64/128/256 via
    /// `aot.py --width`).
    pub proj_width: usize,
    /// `u64` words per packed code (1/2/4) — the key the hashing layer
    /// uses to select the matching [`crate::hash::CodeWord`]
    /// monomorphization (`PjrtHasher<C>` requires `C::WORDS` equal to
    /// this). Always `ceil(proj_width / 64)`; older width-64 manifests
    /// omit the field and default to 1.
    pub code_words: usize,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let m = Self::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Ok(m)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let str_field = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing string field {key:?}"))?
                .to_string())
        };
        let usize_field = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing integer field {key:?}"))
        };
        let format = str_field("format")?;
        anyhow::ensure!(
            format == "hlo-text",
            "unsupported artifact format {format:?} (want hlo-text)"
        );
        let proj_width = usize_field("proj_width")?;
        anyhow::ensure!((1..=256).contains(&proj_width), "bad proj_width {proj_width}");
        // Wide manifests (aot.py --width) record the u64 word count the
        // packed codes fill; legacy width-64 manifests omit it. Absent
        // is fine (derive from the width); present-but-unparseable is a
        // corrupt manifest, not a default.
        let derived_words = proj_width.div_ceil(64);
        let code_words = match j.get("code_words") {
            None => derived_words,
            Some(v) => v
                .as_usize()
                .context("manifest code_words must be a non-negative integer")?,
        };
        anyhow::ensure!(
            code_words == derived_words,
            "manifest code_words {code_words} inconsistent with proj_width \
             {proj_width} (expect {derived_words})"
        );
        anyhow::ensure!(
            matches!(code_words, 1 | 2 | 4),
            "code_words {code_words} has no CodeWord impl (want 1, 2 or 4)"
        );

        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries array")?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("entry missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("entry missing file")?
                .to_string();
            let mut inputs = Vec::new();
            for inp in e.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("input missing shape")?
                    .iter()
                    .map(|d| d.as_usize().context("non-integer dim"))
                    .collect::<Result<Vec<usize>>>()?;
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(ArtifactInput { shape, dtype });
            }
            entries.push(ArtifactEntry { name, file, inputs });
        }
        Ok(Self {
            format,
            item_block: usize_field("item_block")?,
            query_block: usize_field("query_block")?,
            proj_width,
            code_words,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Dimensionalities with a compiled `hash_items` variant.
    pub fn hash_dims(&self) -> Vec<usize> {
        self.entries
            .iter()
            .filter_map(|e| e.name.strip_prefix("hash_items_d").and_then(|d| d.parse().ok()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let json = r#"{
            "format": "hlo-text", "item_block": 2048, "query_block": 256,
            "proj_width": 64,
            "entries": [
                {"name": "hash_items_d16", "file": "hash_items_d16.hlo.txt",
                 "inputs": [{"shape": [2048, 16], "dtype": "float32"},
                            {"shape": [], "dtype": "float32"},
                            {"shape": [17, 64], "dtype": "float32"}]}
            ]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.item_block, 2048);
        assert_eq!(m.query_block, 256);
        // Legacy manifest without code_words: defaults from proj_width.
        assert_eq!(m.code_words, 1);
        let e = m.entry("hash_items_d16").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![2048, 16]);
        assert_eq!(e.inputs[1].shape, Vec::<usize>::new());
        assert!(m.entry("nope").is_none());
        assert_eq!(m.hash_dims(), vec![16]);
    }

    #[test]
    fn parses_wide_manifest_code_words() {
        let json = r#"{"format": "hlo-text", "item_block": 2048,
                       "query_block": 256, "proj_width": 128,
                       "code_words": 2, "entries": []}"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.proj_width, 128);
        assert_eq!(m.code_words, 2);
        // Omitted code_words derives from the width at any width.
        let json = r#"{"format": "hlo-text", "item_block": 2048,
                       "query_block": 256, "proj_width": 256, "entries": []}"#;
        assert_eq!(Manifest::parse(json).unwrap().code_words, 4);
    }

    #[test]
    fn rejects_inconsistent_or_unsupported_code_words() {
        // code_words contradicting proj_width.
        let json = r#"{"format": "hlo-text", "item_block": 1, "query_block": 1,
                       "proj_width": 128, "code_words": 1, "entries": []}"#;
        assert!(Manifest::parse(json).is_err());
        // A width needing 3 words has no CodeWord impl.
        let json = r#"{"format": "hlo-text", "item_block": 1, "query_block": 1,
                       "proj_width": 192, "entries": []}"#;
        assert!(Manifest::parse(json).is_err());
        // Width past the 256-bit ceiling.
        let json = r#"{"format": "hlo-text", "item_block": 1, "query_block": 1,
                       "proj_width": 320, "entries": []}"#;
        assert!(Manifest::parse(json).is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let json = r#"{"format": "proto", "item_block": 1, "query_block": 1,
                       "proj_width": 64, "entries": []}"#;
        assert!(Manifest::parse(json).is_err());
    }

    #[test]
    fn load_rejects_missing_dir() {
        let err = Manifest::load("/no/such/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn parses_real_generated_manifest_if_present() {
        // Integration nicety: if `make artifacts` has run, the real file
        // must parse and contain the default geometry.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if path.join("manifest.json").exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(!m.entries.is_empty());
            // One width per directory, whichever `aot.py --width` built.
            assert_eq!(m.code_words, m.proj_width.div_ceil(64));
        }
    }
}
