//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `make artifacts`) and executes them from the
//! Rust hot path. Python is never involved at runtime.
//!
//! ## Threading model
//!
//! The `xla` crate's wrappers hold raw PJRT pointers and are not
//! `Send`/`Sync`, so the runtime runs as an **actor**: one worker thread
//! owns the client and the compiled executables; [`RuntimeHandle`] is a
//! cheap, cloneable, `Send + Sync` front that routes requests over a
//! channel. This matches the coordinator design anyway — the dynamic
//! batcher serialises hash batches through one compiled executable.
//!
//! ## Interchange gotcha
//!
//! Artifacts are HLO **text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md §6).

mod hasher;
mod manifest;
mod scorer;
mod worker;

pub use hasher::PjrtHasher;
pub use manifest::{ArtifactEntry, Manifest};
pub use scorer::{BoundedTopK, PjrtScorer, RerankStats};
pub use worker::RuntimeHandle;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
