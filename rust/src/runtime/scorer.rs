//! [`PjrtScorer`]: exact inner-product scoring through the AOT Pallas
//! blocked-matmul kernel — ground truth generation and candidate
//! re-ranking with MXU-shaped compute.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::Dataset;
use crate::runtime::RuntimeHandle;
use crate::{ItemId, Result};

/// PJRT-backed exact scorer.
pub struct PjrtScorer {
    runtime: RuntimeHandle,
}

impl PjrtScorer {
    pub fn new(runtime: RuntimeHandle) -> Self {
        Self { runtime }
    }

    /// Exact scores `[n_queries, n_items]` (row-major), computed block by
    /// block through the score artifact.
    pub fn score_all(&self, queries: &Dataset, items: &Dataset) -> Result<Vec<f32>> {
        anyhow::ensure!(queries.dim() == items.dim(), "dimension mismatch");
        let dim = items.dim();
        let qb = self.runtime.manifest().query_block;
        let ib = self.runtime.manifest().item_block;
        let (nq, ni) = (queries.len(), items.len());
        let mut out = vec![0.0f32; nq * ni];
        for (qci, qchunk) in queries.flat().chunks(qb * dim).enumerate() {
            let vq = qchunk.len() / dim;
            let mut q_block = Vec::with_capacity(qb * dim);
            q_block.extend_from_slice(qchunk);
            q_block.resize(qb * dim, 0.0);
            for (xci, xchunk) in items.flat().chunks(ib * dim).enumerate() {
                let vx = xchunk.len() / dim;
                let mut x_block = Vec::with_capacity(ib * dim);
                x_block.extend_from_slice(xchunk);
                x_block.resize(ib * dim, 0.0);
                let scores = self.runtime.score_block(dim, q_block.clone(), x_block)?;
                anyhow::ensure!(scores.len() == qb * ib, "score output size mismatch");
                for qi in 0..vq {
                    let dst_row = (qci * qb + qi) * ni + xci * ib;
                    out[dst_row..dst_row + vx]
                        .copy_from_slice(&scores[qi * ib..qi * ib + vx]);
                }
            }
        }
        Ok(out)
    }

    /// Exact top-`k` MIPS per query via the score artifact (same contract
    /// as [`crate::eval::exact_topk`]; the integration tests assert they
    /// agree).
    pub fn exact_topk(
        &self,
        items: &Dataset,
        queries: &Dataset,
        k: usize,
    ) -> Result<Vec<Vec<ItemId>>> {
        let scores = self.score_all(queries, items)?;
        let ni = items.len();
        Ok((0..queries.len())
            .map(|qi| topk_row(&scores[qi * ni..(qi + 1) * ni], k))
            .collect())
    }

    /// Re-rank `candidates` for `query` by exact inner product (descending)
    /// — the serving engine's final stage. Small candidate sets are scored
    /// natively; this avoids paying a padded PJRT block per query.
    ///
    /// §Perf: scoring walks candidates four rows at a time
    /// ([`Dataset::dot4`], bit-identical to per-row dots) into a reusable
    /// per-worker `(score, id)` scratch — no allocation per query once a
    /// thread is warm. Select-then-sort: `select_nth_unstable` partitions
    /// the top `k` in O(n), then only those `k` are sorted (vs sorting
    /// all `n = probe_budget` candidates).
    pub fn rerank(dataset: &Dataset, query: &[f32], candidates: &mut Vec<ItemId>, k: usize) {
        thread_local! {
            static DISCARD: std::cell::RefCell<Vec<f32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        DISCARD.with(|d| {
            Self::rerank_scored(dataset, query, candidates, k, &mut d.borrow_mut());
        })
    }

    /// [`Self::rerank`], but also hands back the winners' exact scores in
    /// `scores` (aligned with the surviving `candidates`): the engine
    /// builds its ranked answers from these instead of re-computing a
    /// full-dimension dot per returned result.
    // staticcheck: allow(panic-reach, "chunks_exact(4) guarantees quad.len() == 4; candidate ids are index-produced dataset row ids")
    pub fn rerank_scored(
        dataset: &Dataset,
        query: &[f32],
        candidates: &mut Vec<ItemId>,
        k: usize,
        scores: &mut Vec<f32>,
    ) {
        thread_local! {
            static SCORE_SCRATCH: std::cell::RefCell<Vec<(f32, ItemId)>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SCORE_SCRATCH.with(|cell| {
            let scored = &mut *cell.borrow_mut();
            scored.clear();
            scored.reserve(candidates.len());
            let mut quads = candidates.chunks_exact(4);
            for quad in quads.by_ref() {
                let s = dataset.dot4(
                    [quad[0] as usize, quad[1] as usize, quad[2] as usize, quad[3] as usize],
                    query,
                );
                for (k4, &id) in quad.iter().enumerate() {
                    scored.push((s[k4], id));
                }
            }
            for &id in quads.remainder() {
                scored.push((dataset.dot(id as usize, query), id));
            }
            let cmp = |a: &(f32, ItemId), b: &(f32, ItemId)| {
                b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
            };
            if k < scored.len() {
                scored.select_nth_unstable_by(k, cmp);
                scored.truncate(k);
            }
            scored.sort_by(cmp);
            candidates.clear();
            scores.clear();
            for &(s, id) in scored.iter() {
                candidates.push(id);
                scores.push(s);
            }
        })
    }
}

/// Streaming top-k accumulator with Cauchy–Schwarz admission pruning —
/// the scorer half of the fused probe/re-rank path (§Perf).
///
/// Feed candidates in any order: [`Self::offer`] the candidate's cached
/// 2-norm first, and only when it is admitted pay the full-dimension dot
/// and [`Self::insert`] the exact score. Once `k` results are held, a
/// candidate is rejected exactly when its guarded upper bound
/// `‖q‖·‖x‖·(1+guard)` is **strictly below** the current kth score —
/// the strict-inequality tie rule: a candidate whose bound merely *ties*
/// the threshold could still equal it exactly and win the ascending-id
/// tie-break, so it must be scored.
///
/// Equivalence to the exhaustive oracle ([`PjrtScorer::rerank_scored`]):
/// every comparison that decides membership uses the exact
/// `(score desc, id asc)` total order on exactly-computed dots, and a
/// rejected candidate has `fl(q·x) <= ‖q‖·‖x‖·(1+guard) < kth`, i.e. it
/// is strictly worse than `k` already-held candidates — so the final set
/// and its order are identical, ids and score bits both
/// (property-tested in `tests/properties.rs` across widths, `m`, `k`,
/// budgets, tie-heavy data and all-zero queries).
///
/// The guard covers floating-point slack in the bound chain: the f32 dot
/// accumulates relative error up to ~`dim · 2⁻²⁴` of `‖q‖‖x‖`
/// (each partial product is bounded by Cauchy–Schwarz on the absolute
/// values), and the cached norms carry their own rounding. Inflating the
/// bound can only *admit more* candidates — pruning power varies, results
/// cannot. An all-zero query (`‖q‖ = 0`) has bound `0`, which is never
/// strictly below a kth score of `±0.0`, so nothing is ever pruned and
/// the accumulator degenerates to the plain top-k heap.
pub struct BoundedTopK {
    k: usize,
    heap: BinaryHeap<Entry>,
    /// `‖q‖ · (1 + guard)` in f64 — multiplied by a candidate's norm to
    /// form the admission bound.
    q_norm_guarded: f64,
    stats: RerankStats,
}

/// Instrumentation from one streaming re-rank (the §Perf hook behind the
/// pruning tests and the hotpath bench's `rerank_axis` rows).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RerankStats {
    /// Candidates offered to the accumulator.
    pub seen: usize,
    /// Candidates whose exact dot was computed.
    pub scored: usize,
    /// Candidates skipped by the norm-bound admission test.
    pub pruned: usize,
}

impl BoundedTopK {
    /// `q_norm` is the query's 2-norm; `dim` sizes the rounding guard.
    pub fn new(k: usize, q_norm: f32, dim: usize) -> Self {
        let guard = 1.0 + 8.0 * (dim as f64 + 4.0) * f64::from(f32::EPSILON);
        Self {
            k,
            heap: BinaryHeap::with_capacity(k.saturating_add(1)),
            q_norm_guarded: f64::from(q_norm) * guard,
            stats: RerankStats::default(),
        }
    }

    /// The kth-best exact score, once `k` results are held — the pruning
    /// threshold. `None` while the heap is still filling (every candidate
    /// is admitted then).
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() == self.k {
            self.heap.peek().map(|e| e.0)
        } else {
            None
        }
    }

    /// Could an item of 2-norm `x_norm` still enter the top-k? Strict
    /// rule: reject only when the guarded bound is strictly below the
    /// threshold (`!(bound < kth)` rather than `bound >= kth`, so a NaN
    /// norm is conservatively admitted and scored exactly). Also the
    /// whole-query early-out test: pass the probe schedule's remaining
    /// norm bound to learn whether any not-yet-emitted candidate matters.
    pub fn would_admit(&self, x_norm: f32) -> bool {
        match self.threshold() {
            None => true,
            Some(kth) => !(self.q_norm_guarded * f64::from(x_norm) < f64::from(kth)),
        }
    }

    /// Counted admission test for one candidate: true means the caller
    /// must compute the exact dot and [`Self::insert`] it.
    pub fn offer(&mut self, x_norm: f32) -> bool {
        self.stats.seen += 1;
        let admit = self.would_admit(x_norm);
        if !admit {
            self.stats.pruned += 1;
        }
        admit
    }

    /// Insert an exactly-scored candidate. Membership is decided by the
    /// exact `(score desc, id asc)` order, never by the bound.
    pub fn insert(&mut self, score: f32, id: ItemId) {
        self.stats.scored += 1;
        let e = Entry(score, id);
        if self.heap.len() < self.k {
            self.heap.push(e);
        } else if let Some(top) = self.heap.peek() {
            if e < *top {
                self.heap.pop();
                self.heap.push(e);
            }
        }
    }

    pub fn stats(&self) -> RerankStats {
        self.stats
    }

    /// The accumulated top-k as `(score, id)`, best first — the same
    /// order [`PjrtScorer::rerank_scored`] returns.
    pub fn into_sorted(self) -> Vec<(f32, ItemId)> {
        let mut v: Vec<(f32, ItemId)> =
            self.heap.into_vec().into_iter().map(|e| (e.0, e.1)).collect();
        v.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        v
    }
}

/// Max-heap entry whose `Ord` ranks "worse = greater" under the result
/// order `(score desc, id asc)`: the peek is the entry the oracle would
/// drop first — lowest score, and among exact score ties the *largest*
/// id (ascending id wins ties, so the largest tied id is the worst).
/// The tie arm must be `self.1.cmp(&other.1)`, not the reverse: an
/// inverted tie-break would evict the smallest tied id and silently
/// diverge from the `rerank_scored` oracle on duplicated rows.
#[derive(PartialEq)]
struct Entry(f32, ItemId);
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.total_cmp(&self.0).then(self.1.cmp(&other.1))
    }
}

fn topk_row(scores: &[f32], k: usize) -> Vec<ItemId> {
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(s, i as ItemId));
        } else if let Some(top) = heap.peek() {
            if s > top.0 {
                heap.pop();
                heap.push(Entry(s, i as ItemId));
            }
        }
    }
    let mut v: Vec<Entry> = heap.into_vec();
    v.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    v.into_iter().map(|e| e.1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_row_orders_descending() {
        let ids = topk_row(&[0.1, 0.9, 0.5, 0.9, -1.0], 3);
        assert_eq!(ids, vec![1, 3, 2]);
    }

    #[test]
    fn topk_row_evicts_largest_tied_id_first() {
        // Regression for the inverted Entry tie-break: with ids 0 and 1
        // tied at the cut, the strictly better late arrival must evict
        // the *largest* tied id — a full sort keeps [2, 0], not [2, 1].
        assert_eq!(topk_row(&[1.0, 1.0, 3.0], 2), vec![2, 0]);
        // Ties that straddle the cut keep the smallest ids.
        assert_eq!(topk_row(&[5.0, 2.0, 2.0, 2.0], 2), vec![0, 1]);
    }

    #[test]
    fn rerank_keeps_best_k() {
        let d = crate::data::synthetic::longtail_sift(50, 8, 0);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 1);
        let mut cands: Vec<ItemId> = (0..50).collect();
        PjrtScorer::rerank(&d, q.row(0), &mut cands, 5);
        assert_eq!(cands.len(), 5);
        let gt = crate::eval::exact_topk(&d, &q, 5);
        assert_eq!(cands, gt[0]);
    }

    /// Drive a [`BoundedTopK`] over `candidates` exactly as the engine's
    /// streaming path does (offer norm, dot only when admitted) and
    /// assert the result matches `rerank_scored` bit for bit.
    fn check_bounded_matches_oracle(
        d: &Dataset,
        query: &[f32],
        candidates: &[ItemId],
        k: usize,
    ) -> RerankStats {
        let q_norm = crate::data::dot_slices(query, query).sqrt();
        let mut acc = BoundedTopK::new(k, q_norm, d.dim());
        for &id in candidates {
            if acc.offer(d.norm(id as usize)) {
                acc.insert(d.dot(id as usize, query), id);
            }
        }
        let stats = acc.stats();
        assert_eq!(stats.seen, candidates.len());
        assert_eq!(stats.scored + stats.pruned, stats.seen);
        let got = acc.into_sorted();
        let mut want_ids = candidates.to_vec();
        let mut want_scores = Vec::new();
        PjrtScorer::rerank_scored(d, query, &mut want_ids, k, &mut want_scores);
        assert_eq!(got.len(), want_ids.len(), "k={k}");
        for (i, &(s, id)) in got.iter().enumerate() {
            assert_eq!(id, want_ids[i], "k={k} position {i}");
            assert_eq!(s.to_bits(), want_scores[i].to_bits(), "k={k} position {i}");
        }
        stats
    }

    #[test]
    fn bounded_topk_matches_oracle_and_prunes_on_norm_sorted_stream() {
        let base = crate::data::synthetic::longtail_sift(400, 8, 7);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 8);
        // Plant a query-aligned huge-norm row: once it is scored, the kth
        // score towers over every other candidate's ‖q‖·‖x‖ bound, so
        // pruning is guaranteed to fire, not just likely.
        let mut rows: Vec<Vec<f32>> = (0..400).map(|i| base.row(i).to_vec()).collect();
        rows.push(q.row(0).iter().map(|v| v * 1000.0).collect());
        let d = Dataset::from_rows(&rows);
        // Norm-descending candidate order (what the range schedule roughly
        // emits) puts the planted row first.
        let mut cands: Vec<ItemId> = (0..401).collect();
        cands.sort_by(|&a, &b| d.norm(b as usize).total_cmp(&d.norm(a as usize)));
        for k in [1usize, 10, 401] {
            let stats = check_bounded_matches_oracle(&d, q.row(0), &cands, k);
            if k == 1 {
                assert!(stats.pruned > 0, "k=1 after the planted row must prune the tail");
            }
        }
        // Original (unsorted) order must agree too.
        let cands: Vec<ItemId> = (0..401).collect();
        check_bounded_matches_oracle(&d, q.row(0), &cands, 10);
    }

    #[test]
    fn bounded_topk_zero_query_prunes_nothing() {
        let d = crate::data::synthetic::longtail_sift(100, 8, 9);
        let zero = vec![0.0f32; 8];
        let cands: Vec<ItemId> = (0..100).collect();
        let stats = check_bounded_matches_oracle(&d, &zero, &cands, 5);
        assert_eq!(stats.pruned, 0, "‖q‖ = 0 must not prune anything");
        assert_eq!(stats.scored, 100);
    }

    #[test]
    fn bounded_topk_handles_tie_heavy_duplicates() {
        // Duplicated rows: identical scores, membership decided purely by
        // the ascending-id tie-break — the case the strict-inequality
        // admission rule exists for.
        let base = crate::data::synthetic::longtail_sift(30, 8, 10);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for i in 0..30 {
            rows.push(base.row(i).to_vec());
            rows.push(base.row(i).to_vec());
            rows.push(base.row(i).to_vec());
        }
        let d = Dataset::from_rows(&rows);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 11);
        let cands: Vec<ItemId> = (0..90).collect();
        for k in [1usize, 4, 10, 90] {
            check_bounded_matches_oracle(&d, q.row(0), &cands, k);
        }
    }

    #[test]
    fn bounded_topk_threshold_appears_only_when_full() {
        let mut acc = BoundedTopK::new(2, 1.0, 4);
        assert_eq!(acc.threshold(), None);
        assert!(acc.would_admit(0.0));
        acc.insert(1.0, 7);
        assert_eq!(acc.threshold(), None);
        acc.insert(3.0, 2);
        assert_eq!(acc.threshold(), Some(1.0));
        // Bound strictly below the kth score → rejected; ties admitted.
        assert!(!acc.would_admit(0.5));
        assert!(acc.would_admit(1.0));
        acc.insert(2.0, 9);
        assert_eq!(acc.threshold(), Some(2.0));
        assert_eq!(acc.into_sorted(), vec![(3.0, 2), (2.0, 9)]);
    }

    #[test]
    fn rerank_scored_returns_aligned_exact_scores() {
        let d = crate::data::synthetic::longtail_sift(60, 8, 2);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 3);
        let mut cands: Vec<ItemId> = (0..60).collect();
        let mut scores = Vec::new();
        PjrtScorer::rerank_scored(&d, q.row(0), &mut cands, 7, &mut scores);
        assert_eq!(cands.len(), 7);
        assert_eq!(scores.len(), 7);
        for (i, (&id, &s)) in cands.iter().zip(&scores).enumerate() {
            assert_eq!(
                s.to_bits(),
                d.dot(id as usize, q.row(0)).to_bits(),
                "position {i}: score must be the exact dot"
            );
        }
        for w in scores.windows(2) {
            assert!(w[0] >= w[1], "scores must descend");
        }
    }
}
