//! [`PjrtScorer`]: exact inner-product scoring through the AOT Pallas
//! blocked-matmul kernel — ground truth generation and candidate
//! re-ranking with MXU-shaped compute.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::Dataset;
use crate::runtime::RuntimeHandle;
use crate::{ItemId, Result};

/// PJRT-backed exact scorer.
pub struct PjrtScorer {
    runtime: RuntimeHandle,
}

impl PjrtScorer {
    pub fn new(runtime: RuntimeHandle) -> Self {
        Self { runtime }
    }

    /// Exact scores `[n_queries, n_items]` (row-major), computed block by
    /// block through the score artifact.
    pub fn score_all(&self, queries: &Dataset, items: &Dataset) -> Result<Vec<f32>> {
        anyhow::ensure!(queries.dim() == items.dim(), "dimension mismatch");
        let dim = items.dim();
        let qb = self.runtime.manifest().query_block;
        let ib = self.runtime.manifest().item_block;
        let (nq, ni) = (queries.len(), items.len());
        let mut out = vec![0.0f32; nq * ni];
        for (qci, qchunk) in queries.flat().chunks(qb * dim).enumerate() {
            let vq = qchunk.len() / dim;
            let mut q_block = Vec::with_capacity(qb * dim);
            q_block.extend_from_slice(qchunk);
            q_block.resize(qb * dim, 0.0);
            for (xci, xchunk) in items.flat().chunks(ib * dim).enumerate() {
                let vx = xchunk.len() / dim;
                let mut x_block = Vec::with_capacity(ib * dim);
                x_block.extend_from_slice(xchunk);
                x_block.resize(ib * dim, 0.0);
                let scores = self.runtime.score_block(dim, q_block.clone(), x_block)?;
                anyhow::ensure!(scores.len() == qb * ib, "score output size mismatch");
                for qi in 0..vq {
                    let dst_row = (qci * qb + qi) * ni + xci * ib;
                    out[dst_row..dst_row + vx]
                        .copy_from_slice(&scores[qi * ib..qi * ib + vx]);
                }
            }
        }
        Ok(out)
    }

    /// Exact top-`k` MIPS per query via the score artifact (same contract
    /// as [`crate::eval::exact_topk`]; the integration tests assert they
    /// agree).
    pub fn exact_topk(
        &self,
        items: &Dataset,
        queries: &Dataset,
        k: usize,
    ) -> Result<Vec<Vec<ItemId>>> {
        let scores = self.score_all(queries, items)?;
        let ni = items.len();
        Ok((0..queries.len())
            .map(|qi| topk_row(&scores[qi * ni..(qi + 1) * ni], k))
            .collect())
    }

    /// Re-rank `candidates` for `query` by exact inner product (descending)
    /// — the serving engine's final stage. Small candidate sets are scored
    /// natively; this avoids paying a padded PJRT block per query.
    ///
    /// §Perf: scoring walks candidates four rows at a time
    /// ([`Dataset::dot4`], bit-identical to per-row dots) into a reusable
    /// per-worker `(score, id)` scratch — no allocation per query once a
    /// thread is warm. Select-then-sort: `select_nth_unstable` partitions
    /// the top `k` in O(n), then only those `k` are sorted (vs sorting
    /// all `n = probe_budget` candidates).
    pub fn rerank(dataset: &Dataset, query: &[f32], candidates: &mut Vec<ItemId>, k: usize) {
        thread_local! {
            static DISCARD: std::cell::RefCell<Vec<f32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        DISCARD.with(|d| {
            Self::rerank_scored(dataset, query, candidates, k, &mut d.borrow_mut());
        })
    }

    /// [`Self::rerank`], but also hands back the winners' exact scores in
    /// `scores` (aligned with the surviving `candidates`): the engine
    /// builds its ranked answers from these instead of re-computing a
    /// full-dimension dot per returned result.
    pub fn rerank_scored(
        dataset: &Dataset,
        query: &[f32],
        candidates: &mut Vec<ItemId>,
        k: usize,
        scores: &mut Vec<f32>,
    ) {
        thread_local! {
            static SCORE_SCRATCH: std::cell::RefCell<Vec<(f32, ItemId)>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SCORE_SCRATCH.with(|cell| {
            let scored = &mut *cell.borrow_mut();
            scored.clear();
            scored.reserve(candidates.len());
            let mut quads = candidates.chunks_exact(4);
            for quad in quads.by_ref() {
                let s = dataset.dot4(
                    [quad[0] as usize, quad[1] as usize, quad[2] as usize, quad[3] as usize],
                    query,
                );
                for (k4, &id) in quad.iter().enumerate() {
                    scored.push((s[k4], id));
                }
            }
            for &id in quads.remainder() {
                scored.push((dataset.dot(id as usize, query), id));
            }
            let cmp = |a: &(f32, ItemId), b: &(f32, ItemId)| {
                b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
            };
            if k < scored.len() {
                scored.select_nth_unstable_by(k, cmp);
                scored.truncate(k);
            }
            scored.sort_by(cmp);
            candidates.clear();
            scores.clear();
            for &(s, id) in scored.iter() {
                candidates.push(id);
                scores.push(s);
            }
        })
    }
}

#[derive(PartialEq)]
struct Entry(f32, ItemId);
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.total_cmp(&self.0).then(other.1.cmp(&self.1))
    }
}

fn topk_row(scores: &[f32], k: usize) -> Vec<ItemId> {
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(s, i as ItemId));
        } else if let Some(top) = heap.peek() {
            if s > top.0 {
                heap.pop();
                heap.push(Entry(s, i as ItemId));
            }
        }
    }
    let mut v: Vec<Entry> = heap.into_vec();
    v.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    v.into_iter().map(|e| e.1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_row_orders_descending() {
        let ids = topk_row(&[0.1, 0.9, 0.5, 0.9, -1.0], 3);
        assert_eq!(ids, vec![1, 3, 2]);
    }

    #[test]
    fn rerank_keeps_best_k() {
        let d = crate::data::synthetic::longtail_sift(50, 8, 0);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 1);
        let mut cands: Vec<ItemId> = (0..50).collect();
        PjrtScorer::rerank(&d, q.row(0), &mut cands, 5);
        assert_eq!(cands.len(), 5);
        let gt = crate::eval::exact_topk(&d, &q, 5);
        assert_eq!(cands, gt[0]);
    }

    #[test]
    fn rerank_scored_returns_aligned_exact_scores() {
        let d = crate::data::synthetic::longtail_sift(60, 8, 2);
        let q = crate::data::synthetic::gaussian_queries(1, 8, 3);
        let mut cands: Vec<ItemId> = (0..60).collect();
        let mut scores = Vec::new();
        PjrtScorer::rerank_scored(&d, q.row(0), &mut cands, 7, &mut scores);
        assert_eq!(cands.len(), 7);
        assert_eq!(scores.len(), 7);
        for (i, (&id, &s)) in cands.iter().zip(&scores).enumerate() {
            assert_eq!(
                s.to_bits(),
                d.dot(id as usize, q.row(0)).to_bits(),
                "position {i}: score must be the exact dot"
            );
        }
        for w in scores.windows(2) {
            assert!(w[0] >= w[1], "scores must descend");
        }
    }
}
