//! The runtime actor: one thread owns the PJRT client and compiled
//! executables; [`RuntimeHandle`] routes requests to it over a channel.
//!
//! The PJRT backend (the `xla` bindings) is only compiled with the `pjrt`
//! cargo feature; the default offline build ships a stub whose
//! [`RuntimeHandle::load`] fails with a clear error, and every call site
//! falls back to the native hashing/scoring path.
//!
//! Code-width note: the actor protocol is width-agnostic — hash requests
//! carry padded f32 blocks and replies carry `proj_width / 32` packed
//! u32 words per row, whatever width the artifact directory was compiled
//! at (`aot.py --width`, recorded as the manifest's `proj_width` +
//! `code_words`). The `CodeWord`-typed packing lives entirely in
//! [`crate::runtime::PjrtHasher`], so wide codes add no new request
//! variants here.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Context};

use super::manifest::Manifest;
use crate::Result;

/// Request messages processed by the worker thread.
enum Request {
    /// Execute `hash_items_d{dim}` over one padded block.
    HashItems {
        dim: usize,
        /// Padded row-major `[item_block, dim]`.
        block: Vec<f32>,
        u: f32,
        /// Row-major `[dim+1, proj_width]`.
        proj: Arc<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<u32>>>,
    },
    /// Execute `hash_queries_d{dim}` over one padded block.
    HashQueries {
        dim: usize,
        block: Vec<f32>,
        proj: Arc<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<u32>>>,
    },
    /// Execute `score_d{dim}`: `[query_block, dim] x [item_block, dim]`.
    Score {
        dim: usize,
        q_block: Vec<f32>,
        x_block: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Cloneable, `Send + Sync` handle to the PJRT runtime actor.
///
/// All methods are synchronous (they block on the actor's reply); the
/// coordinator calls them from `spawn_blocking` contexts.
///
/// `std::sync::mpsc::Sender` is `Send` but not `Sync`, so the sender sits
/// behind a mutex (uncontended in practice: requests are coarse — one
/// 2048-row block per send).
pub struct RuntimeHandle {
    tx: std::sync::Mutex<mpsc::Sender<Request>>,
    manifest: Arc<Manifest>,
}

impl Clone for RuntimeHandle {
    // staticcheck: allow(panic-reach, "Mutex::lock only errs on poisoning, which requires a prior panic - re-panicking propagates the original failure")
    fn clone(&self) -> Self {
        Self {
            tx: std::sync::Mutex::new(self.tx.lock().unwrap().clone()),
            manifest: self.manifest.clone(),
        }
    }
}

impl RuntimeHandle {
    /// Load the manifest in `dir`, start the worker thread, and eagerly
    /// compile every artifact (fail fast on missing/corrupt HLO).
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir: PathBuf = dir.into();
        let manifest = Arc::new(Manifest::load(&dir)?);
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker_manifest = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || worker_main(dir, worker_manifest, rx, ready_tx))
            .context("spawning pjrt runtime thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt runtime thread died during startup"))??;
        Ok(Self { tx: std::sync::Mutex::new(tx), manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if a `hash_items` artifact exists for dimensionality `dim`.
    pub fn supports_dim(&self, dim: usize) -> bool {
        self.manifest.entry(&format!("hash_items_d{dim}")).is_some()
    }

    /// `u64` words per packed code for this artifact directory (1/2/4).
    /// The worker itself is width-agnostic — padded f32 blocks in, packed
    /// u32 words out — so the `CodeWord` dispatch happens one level up in
    /// [`crate::runtime::PjrtHasher`], keyed off this value.
    pub fn code_words(&self) -> usize {
        self.manifest.code_words
    }

    // staticcheck: allow(panic-reach, "Mutex::lock only errs on poisoning, which requires a prior panic - re-panicking propagates the original failure")
    fn roundtrip<T>(&self, make: impl FnOnce(mpsc::Sender<Result<T>>) -> Request) -> Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(make(reply_tx))
            .map_err(|_| anyhow!("pjrt runtime thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("pjrt runtime dropped the reply"))?
    }

    /// Hash one padded item block (`block.len() == item_block * dim`).
    /// Returns `item_block * words` packed u32s.
    pub fn hash_items_block(
        &self,
        dim: usize,
        block: Vec<f32>,
        u: f32,
        proj: Arc<Vec<f32>>,
    ) -> Result<Vec<u32>> {
        self.roundtrip(|reply| Request::HashItems { dim, block, u, proj, reply })
    }

    /// Hash one padded query block.
    pub fn hash_queries_block(
        &self,
        dim: usize,
        block: Vec<f32>,
        proj: Arc<Vec<f32>>,
    ) -> Result<Vec<u32>> {
        self.roundtrip(|reply| Request::HashQueries { dim, block, proj, reply })
    }

    /// Score one `[query_block, dim] x [item_block, dim]` pair; returns
    /// row-major `[query_block, item_block]`.
    pub fn score_block(&self, dim: usize, q_block: Vec<f32>, x_block: Vec<f32>) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Score { dim, q_block, x_block, reply })
    }

    /// Stop the worker (also happens when the last handle drops the sender).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
    }
}

/// The worker: owns client + executables, loops on requests.
fn worker_main(
    dir: PathBuf,
    manifest: Arc<Manifest>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let state = match backend::WorkerState::new(&dir, &manifest) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::HashItems { dim, block, u, proj, reply } => {
                let _ = reply.send(state.run_hash(
                    &format!("hash_items_d{dim}"),
                    dim,
                    state.item_block(),
                    &block,
                    Some(u),
                    &proj,
                ));
            }
            Request::HashQueries { dim, block, proj, reply } => {
                // Dispatch to the small-batch variant when the block is
                // query_block-sized (8x less padded kernel work, §Perf).
                let rows = if dim > 0 { block.len() / dim } else { 0 };
                let (entry, expect) = if rows == state.query_block()
                    && state.has_entry(&format!("hash_queries_small_d{dim}"))
                {
                    (format!("hash_queries_small_d{dim}"), state.query_block())
                } else {
                    (format!("hash_queries_d{dim}"), state.item_block())
                };
                let _ = reply.send(state.run_hash(&entry, dim, expect, &block, None, &proj));
            }
            Request::Score { dim, q_block, x_block, reply } => {
                let _ = reply.send(state.run_score(dim, &q_block, &x_block));
            }
            Request::Shutdown => break,
        }
    }
}

/// Real PJRT backend: compiled only with the `pjrt` feature (needs the
/// `xla` bindings, which the offline build does not ship).
#[cfg(feature = "pjrt")]
mod backend {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::anyhow;

    use super::Manifest;
    use crate::Result;

    pub struct WorkerState {
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        item_block: usize,
        query_block: usize,
        proj_width: usize,
    }

    impl WorkerState {
        pub fn new(dir: &Path, manifest: &Manifest) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
            eprintln!(
                "[rangelsh] pjrt runtime up: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            let mut exes = HashMap::new();
            for entry in &manifest.entries {
                let path = dir.join(&entry.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e}", entry.name))?;
                exes.insert(entry.name.clone(), exe);
            }
            Ok(Self {
                exes,
                item_block: manifest.item_block,
                query_block: manifest.query_block,
                proj_width: manifest.proj_width,
            })
        }

        pub fn item_block(&self) -> usize {
            self.item_block
        }

        pub fn query_block(&self) -> usize {
            self.query_block
        }

        pub fn has_entry(&self, name: &str) -> bool {
            self.exes.contains_key(name)
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.exes
                .get(name)
                .ok_or_else(|| anyhow!("no artifact named {name}; rebuild with `make artifacts`"))
        }

        // staticcheck: allow(panic-reach, "the XLA executable returns exactly one tuple result, so result[0][0] is its documented shape; input lengths are ensure!d above")
        pub fn run_hash(
            &self,
            entry: &str,
            dim: usize,
            rows: usize,
            block: &[f32],
            u: Option<f32>,
            proj: &[f32],
        ) -> Result<Vec<u32>> {
            anyhow::ensure!(
                block.len() == rows * dim,
                "hash block must be padded to {rows} x {dim}, got {}",
                block.len()
            );
            anyhow::ensure!(
                proj.len() == (dim + 1) * self.proj_width,
                "projection must be ({} + 1) x {}, got {}",
                dim,
                self.proj_width,
                proj.len()
            );
            let exe = self.exe(entry)?;
            let x = xla::Literal::vec1(block)
                .reshape(&[rows as i64, dim as i64])
                .map_err(|e| anyhow!("reshape x: {e}"))?;
            let p = xla::Literal::vec1(proj)
                .reshape(&[(dim + 1) as i64, self.proj_width as i64])
                .map_err(|e| anyhow!("reshape proj: {e}"))?;
            let result = match u {
                Some(u) => exe.execute::<xla::Literal>(&[x, xla::Literal::scalar(u), p]),
                None => exe.execute::<xla::Literal>(&[x, p]),
            }
            .map_err(|e| anyhow!("execute {entry}: {e}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
            out.to_vec::<u32>().map_err(|e| anyhow!("to_vec<u32>: {e}"))
        }

        // staticcheck: allow(panic-reach, "the XLA executable returns exactly one tuple result, so result[0][0] is its documented shape; input lengths are ensure!d above")
        pub fn run_score(&self, dim: usize, q_block: &[f32], x_block: &[f32]) -> Result<Vec<f32>> {
            anyhow::ensure!(q_block.len() == self.query_block * dim, "bad query block");
            anyhow::ensure!(x_block.len() == self.item_block * dim, "bad item block");
            let exe = self.exe(&format!("score_d{dim}"))?;
            let q = xla::Literal::vec1(q_block)
                .reshape(&[self.query_block as i64, dim as i64])
                .map_err(|e| anyhow!("reshape q: {e}"))?;
            let x = xla::Literal::vec1(x_block)
                .reshape(&[self.item_block as i64, dim as i64])
                .map_err(|e| anyhow!("reshape x: {e}"))?;
            let result = exe
                .execute::<xla::Literal>(&[q, x])
                .map_err(|e| anyhow!("execute score: {e}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e}"))
        }
    }
}

/// Stub backend for the offline build: startup fails with a clear error,
/// so `RuntimeHandle::load` returns `Err` and callers fall back to native.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use super::Manifest;
    use crate::Result;

    pub struct WorkerState;

    impl WorkerState {
        pub fn new(_dir: &Path, _manifest: &Manifest) -> Result<Self> {
            anyhow::bail!(
                "PJRT runtime unavailable: built without the `pjrt` cargo feature \
                 (the xla bindings are not part of the offline build); \
                 query hashing falls back to the native path"
            )
        }

        pub fn item_block(&self) -> usize {
            unreachable!("stub backend never constructs")
        }

        pub fn query_block(&self) -> usize {
            unreachable!("stub backend never constructs")
        }

        pub fn has_entry(&self, _name: &str) -> bool {
            unreachable!("stub backend never constructs")
        }

        pub fn run_hash(
            &self,
            _entry: &str,
            _dim: usize,
            _rows: usize,
            _block: &[f32],
            _u: Option<f32>,
            _proj: &[f32],
        ) -> Result<Vec<u32>> {
            unreachable!("stub backend never constructs")
        }

        pub fn run_score(&self, _dim: usize, _q: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
            unreachable!("stub backend never constructs")
        }
    }
}
