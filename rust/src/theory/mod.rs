//! Theory: the ρ query-exponent formulas the paper's Figures 1(a) and
//! Theorem 1 are built on, plus the Theorem 1 condition checker.

pub mod rho;
pub mod theorem1;

pub use rho::{erf, f_r, g_rho, l2alsh_grid_search, rho_l2alsh, rho_l2alsh_ranged};
pub use theorem1::{theorem1_check, Theorem1Report};
