//! The ρ (query-time exponent) formulas.
//!
//! - [`g_rho`]: SIMPLE-LSH's `ρ = G(c, S0)` (paper Eq. 9) — the function
//!   plotted in Fig. 1(a); query time is `O(n^ρ log n)`.
//! - [`f_r`]: the Eq. 2 floor-hash collision probability (Eq. 3).
//! - [`rho_l2alsh`]: L2-ALSH's ρ (Eq. 7).
//! - [`rho_l2alsh_ranged`]: the §5 per-range ρ_j (Eq. 13).
//! - [`l2alsh_grid_search`]: the (m, U, r) tuning the L2-ALSH authors call for.

use std::f64::consts::PI;

/// Error function, Abramowitz & Stegun 7.1.26 (|err| <= 1.5e-7) — enough
/// for ρ values quoted to three decimals.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ.
fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Eq. 3: collision probability of the Eq. 2 floor-hash at L2 distance `d`
/// with bucket width `r`.
pub fn f_r(r: f64, d: f64) -> f64 {
    assert!(r > 0.0, "bucket width must be positive");
    if d <= 0.0 {
        return 1.0;
    }
    1.0 - 2.0 * phi(-r / d) - 2.0 * d / ((2.0 * PI).sqrt() * r) * (1.0 - (-(r / d).powi(2) / 2.0).exp())
}

/// Sign-random-projection collision probability (Eq. 4) at normalised
/// inner product `s` (i.e. cosine similarity): `1 - acos(s)/π`.
pub fn p_collision_srp(s: f64) -> f64 {
    1.0 - s.clamp(-1.0, 1.0).acos() / PI
}

/// Eq. 9: SIMPLE-LSH's `ρ = G(c, S0)` — decreasing in `S0`, which is the
/// Fig. 1(a) observation the whole paper builds on: excessive
/// normalisation shrinks `S0`, inflating ρ.
pub fn g_rho(c: f64, s0: f64) -> f64 {
    assert!((0.0..1.0).contains(&c), "approximation ratio c must be in (0,1)");
    assert!(s0 > 0.0 && s0 <= 1.0, "S0 must be in (0,1], got {s0}");
    let p1 = p_collision_srp(s0);
    let p2 = p_collision_srp(c * s0);
    p1.ln() / p2.ln()
}

/// Eq. 7: L2-ALSH's ρ for parameters `(m, u, r)` at `(S0, c)`.
pub fn rho_l2alsh(s0: f64, c: f64, m: u32, u: f64, r: f64) -> f64 {
    let pow = 2f64.powi(m as i32 + 1);
    let num_d = (1.0 + m as f64 / 4.0 - 2.0 * u * s0 + (u * s0).powf(pow)).sqrt();
    let den_d = (1.0 + m as f64 / 4.0 - 2.0 * c * u * s0).sqrt();
    f_r(r, num_d).ln() / f_r(r, den_d).ln()
}

/// Eq. 13: the §5 per-range ρ_j with norms confined to `(u_lo, u_hi]`
/// (raw, before the per-range scaling `u_j`).
pub fn rho_l2alsh_ranged(
    s0: f64,
    c: f64,
    m: u32,
    u_j: f64,
    r: f64,
    u_lo: f64,
    u_hi: f64,
) -> f64 {
    assert!(u_lo >= 0.0 && u_hi >= u_lo);
    let pow = 2f64.powi(m as i32 + 1);
    let num_d = (1.0 + m as f64 / 4.0 - 2.0 * u_j * s0 + (u_j * u_hi).powf(pow)).sqrt();
    let den_sq = 1.0 + m as f64 / 4.0 - 2.0 * c * u_j * s0 + (u_j * u_lo).powf(pow);
    let den_d = den_sq.max(0.0).sqrt();
    f_r(r, num_d).ln() / f_r(r, den_d).ln()
}

/// Grid search for L2-ALSH's `(m, U, r)` minimising ρ at `(S0, c)` —
/// the tuning procedure §2.2 prescribes. Returns `(m, u, r, rho)`.
pub fn l2alsh_grid_search(s0: f64, c: f64) -> (u32, f64, f64, f64) {
    let mut best = (3u32, 0.83, 2.5, f64::INFINITY);
    for m in 2..=4u32 {
        for ui in 1..20 {
            let u = 0.05 * ui as f64;
            for ri in 1..=20 {
                let r = 0.25 * ri as f64;
                let rho = rho_l2alsh(s0, c, m, u, r);
                if rho.is_finite() && rho > 0.0 && rho < best.3 {
                    best = (m, u, r, rho);
                }
            }
        }
    }
    best
}

/// §5's flexibility argument, made concrete: per-range grid search with the
/// Eq. 13 formula under the *relaxed* constraint `U_j < 1/u_hi` (only the
/// range's own max matters, not the dataset max). Returns `(u_j, rho_j)`.
pub fn ranged_l2alsh_grid_search(
    s0: f64,
    c: f64,
    m: u32,
    r: f64,
    u_lo: f64,
    u_hi: f64,
) -> (f64, f64) {
    let mut best = (0.83, f64::INFINITY);
    let cap = 1.0 / u_hi.max(1e-9);
    for ui in 1..200 {
        let u = 0.005 * ui as f64 * cap.min(20.0);
        if u * u_hi >= 1.0 {
            break;
        }
        let rho = rho_l2alsh_ranged(s0, c, m, u, r, u_lo, u_hi);
        if rho.is_finite() && rho > 0.0 && rho < best.1 {
            best = (u, rho);
        }
    }
    best
}

/// Numerically invert Eq. 3: the L2 distance whose collision probability
/// is `p` at bucket width `r` (bisection; `p` clamped to (0,1)).
pub fn f_r_inverse(r: f64, p: f64) -> f64 {
    let p = p.clamp(1e-9, 1.0 - 1e-9);
    let (mut lo, mut hi) = (1e-9, 1e3 * r);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if f_r(r, mid) > p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // vs. tabulated erf.
        assert!((erf(0.0) - 0.0).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953222650).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
    }

    #[test]
    fn f_r_is_a_probability_decreasing_in_distance() {
        let r = 2.5;
        let mut prev = 1.0;
        for i in 1..40 {
            let d = 0.1 * i as f64;
            let p = f_r(r, d);
            assert!((0.0..=1.0).contains(&p), "F_r({d}) = {p}");
            assert!(p < prev, "F_r not decreasing at d={d}");
            prev = p;
        }
        assert_eq!(f_r(r, 0.0), 1.0);
    }

    #[test]
    fn srp_collision_probability_endpoints() {
        assert!((p_collision_srp(1.0) - 1.0).abs() < 1e-12);
        assert!((p_collision_srp(0.0) - 0.5).abs() < 1e-12);
        assert!(p_collision_srp(-1.0).abs() < 1e-12);
    }

    #[test]
    fn g_rho_is_decreasing_in_s0() {
        // Fig. 1(a): larger max inner product ⇒ smaller ρ ⇒ faster queries.
        for &c in &[0.5, 0.7, 0.9] {
            let mut prev = 1.0;
            for i in 1..=9 {
                let s0 = 0.1 * i as f64;
                let rho = g_rho(c, s0);
                assert!(rho > 0.0 && rho < 1.0, "rho({c}, {s0}) = {rho}");
                assert!(rho < prev, "not decreasing at s0={s0}");
                prev = rho;
            }
        }
    }

    #[test]
    fn g_rho_decreasing_in_c() {
        // Looser approximation (smaller c) must be easier (smaller ρ).
        assert!(g_rho(0.5, 0.5) < g_rho(0.9, 0.5));
    }

    #[test]
    fn range_lsh_improves_rho_when_uj_smaller() {
        // The Theorem 1 mechanism: ρ_j = G(c, S0/U_j) < G(c, S0/U) for
        // U_j < U (and S0/U_j <= 1).
        let (c, s0) = (0.7, 0.4);
        let rho_global = g_rho(c, s0 / 1.0); // U = 1
        let rho_local = g_rho(c, (s0 / 0.5f64).min(1.0)); // U_j = 0.5
        assert!(rho_local < rho_global);
    }

    #[test]
    fn l2alsh_rho_worse_than_simple_lsh() {
        // The SIMPLE-LSH paper's headline: lower ρ than L2-ALSH at the
        // recommended parameters across moderate S0.
        for &s0 in &[0.3, 0.5, 0.7] {
            let c = 0.7;
            let simple = g_rho(c, s0);
            let l2 = rho_l2alsh(s0, c, 3, 0.83, 2.5);
            assert!(
                simple < l2,
                "S0={s0}: SIMPLE rho {simple} should beat L2-ALSH rho {l2}"
            );
        }
    }

    #[test]
    fn eq13_improves_on_eq7() {
        // §5: confining norms to a range strictly reduces ρ.
        let (s0, c, m, r) = (0.5, 0.7, 3u32, 2.5);
        let u = 0.83;
        let full = rho_l2alsh(s0, c, m, u, r);
        // A mid range: norms in (0.2, 0.5] (raw scale where S0 = 0.5 max).
        let ranged = rho_l2alsh_ranged(s0, c, m, u, r, 0.2, 0.5);
        assert!(
            ranged < full,
            "ranged rho {ranged} should be below full rho {full}"
        );
    }

    #[test]
    fn f_r_inverse_round_trips() {
        let r = 2.5;
        for &d in &[0.1, 0.5, 1.0, 2.0, 5.0] {
            let p = f_r(r, d);
            let back = f_r_inverse(r, p);
            assert!((back - d).abs() < 1e-4, "d={d} -> p={p} -> {back}");
        }
    }

    #[test]
    fn ranged_grid_search_beats_global_params() {
        // §5: the relaxed constraint U_j < 1/u_hi admits strictly better
        // per-range parameters than the global-U optimum.
        let (s0, c, m, r) = (0.5, 0.7, 3u32, 2.5);
        let global = rho_l2alsh(s0, c, m, 0.83, r);
        let (u_j, rho_j) = ranged_l2alsh_grid_search(s0, c, m, r, 0.1, 0.4);
        assert!(rho_j < global, "rho_j {rho_j} !< global {global} (u_j={u_j})");
    }

    #[test]
    fn grid_search_beats_recommended_or_ties() {
        let (s0, c) = (0.5, 0.7);
        let (_, _, _, best) = l2alsh_grid_search(s0, c);
        let recommended = rho_l2alsh(s0, c, 3, 0.83, 2.5);
        assert!(best <= recommended + 1e-12);
        assert!(best > 0.0);
    }

    #[test]
    #[should_panic(expected = "S0 must be in")]
    fn g_rho_rejects_s0_above_one() {
        g_rho(0.5, 1.5);
    }
}
