//! Theorem 1 condition checker: given a concrete partitioning, verify the
//! "mild conditions" under which RANGE-LSH's query-time bound beats
//! SIMPLE-LSH's, and quantify the predicted advantage (the Eq. 11 ratio).


use super::rho::g_rho;

/// Outcome of checking Theorem 1 on a concrete instance.
#[derive(Debug, Clone)]
pub struct Theorem1Report {
    /// SIMPLE-LSH exponent `ρ = G(c, S0/U)`.
    pub rho: f64,
    /// Per-range exponents `ρ_j = G(c, S0/U_j)` (clamped at S0/U_j <= 1).
    pub rho_j: Vec<f64>,
    /// `ρ* = max_{ρ_j < ρ} ρ_j`.
    pub rho_star: f64,
    /// `α = log_n m` for the instance's `m` and `n`.
    pub alpha: f64,
    /// `β = log_n (#ranges with U_j == U)`.
    pub beta: f64,
    /// Upper limit `min{ρ, (ρ-ρ*)/(1-ρ*)}` that α must stay below.
    pub alpha_limit: f64,
    /// Upper limit `αρ` that β must stay below.
    pub beta_limit: f64,
    /// Whether all Theorem 1 conditions hold.
    pub conditions_hold: bool,
    /// The Eq. 11 ratio `f(n) / (n^ρ log n)` — RANGE-LSH's predicted
    /// fraction of SIMPLE-LSH's cost (→ 0 as n grows when conditions hold).
    pub predicted_cost_ratio: f64,
}

/// Check Theorem 1 for a dataset of `n` items partitioned into ranges with
/// local max norms `u_maxes` (ascending), global max `u`, at operating
/// point `(s0, c)` where `s0` is the raw (unnormalised) inner-product
/// threshold.
pub fn theorem1_check(n: usize, u_maxes: &[f32], u: f32, s0: f64, c: f64) -> Theorem1Report {
    assert!(n >= 2, "need n >= 2");
    assert!(!u_maxes.is_empty());
    assert!(u > 0.0 && s0 > 0.0);
    let m = u_maxes.len() as f64;
    let nf = n as f64;
    let norm_s0 = |base: f64| (s0 / base).clamp(1e-9, 1.0);

    let rho = g_rho(c, norm_s0(u as f64));
    let rho_j: Vec<f64> = u_maxes
        .iter()
        .map(|&uj| g_rho(c, norm_s0(uj as f64)))
        .collect();
    let rho_star = rho_j
        .iter()
        .copied()
        .filter(|&r| r < rho)
        .fold(0.0f64, f64::max);
    let n_at_u = u_maxes.iter().filter(|&&uj| uj >= u).count().max(1);

    let alpha = m.ln() / nf.ln();
    let beta = (n_at_u as f64).ln() / nf.ln();
    let alpha_limit = rho.min((rho - rho_star) / (1.0 - rho_star));
    let beta_limit = alpha * rho;
    let conditions_hold = alpha < alpha_limit && beta < beta_limit;

    // Eq. 10/11: f(n) = n^α + Σ_j n^{(1-α)ρ_j} log n^{1-α}, vs n^ρ log n.
    let log_n = nf.ln();
    let f_n: f64 = nf.powf(alpha)
        + rho_j
            .iter()
            .map(|&rj| nf.powf((1.0 - alpha) * rj) * (1.0 - alpha) * log_n)
            .sum::<f64>();
    let simple_cost = nf.powf(rho) * log_n;
    Theorem1Report {
        rho,
        rho_j,
        rho_star,
        alpha,
        beta,
        alpha_limit,
        beta_limit,
        conditions_hold,
        predicted_cost_ratio: f_n / simple_cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::index::{partition, PartitionScheme};

    #[test]
    fn longtail_instance_satisfies_conditions() {
        // A realistic long-tail instance: the paper's "mild conditions"
        // should hold with a modest number of ranges.
        let d = synthetic::longtail_sift(50_000, 16, 0);
        let parts = partition(&d, 32, PartitionScheme::Percentile).unwrap();
        let us: Vec<f32> = parts.iter().map(|p| p.u_max).collect();
        let s0 = 0.3 * d.max_norm() as f64;
        let rep = theorem1_check(d.len(), &us, d.max_norm(), s0, 0.7);
        assert!(rep.conditions_hold, "{rep:?}");
        assert!(rep.predicted_cost_ratio < 1.0, "{rep:?}");
        // Exactly one range attains U (percentile partitioning).
        assert!((rep.beta - 0.0).abs() < 1e-9);
    }

    #[test]
    fn rho_j_increase_with_u_j() {
        let us = [0.3f32, 0.5, 0.8, 1.0];
        let rep = theorem1_check(10_000, &us, 1.0, 0.25, 0.7);
        for w in rep.rho_j.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "rho_j not monotone: {:?}", rep.rho_j);
        }
        // The last range (U_j == U) matches the SIMPLE-LSH rho.
        assert!((rep.rho_j.last().unwrap() - rep.rho).abs() < 1e-12);
    }

    #[test]
    fn degenerate_all_ranges_at_u_fails_conditions() {
        // If every U_j == U (uniform norms), partitioning cannot help:
        // beta == alpha > alpha*rho.
        let us = [1.0f32; 16];
        let rep = theorem1_check(10_000, &us, 1.0, 0.5, 0.7);
        assert!(!rep.conditions_hold);
    }

    #[test]
    fn too_many_partitions_violate_alpha_bound() {
        // α = log_n m must stay under min{ρ, (ρ-ρ*)/(1-ρ*)}; for tiny n and
        // huge m it cannot.
        let us: Vec<f32> = (1..=64).map(|i| i as f32 / 64.0).collect();
        let rep = theorem1_check(128, &us, 1.0, 0.5, 0.7);
        assert!(rep.alpha > rep.alpha_limit);
        assert!(!rep.conditions_hold);
    }

    #[test]
    fn cost_ratio_shrinks_with_n() {
        // Eq. 11 → 0 with sufficiently large n: the ratio at n=10^6 must be
        // below the ratio at n=10^4 for the same norm profile.
        let us = [0.3f32, 0.45, 0.6, 1.0];
        let small = theorem1_check(10_000, &us, 1.0, 0.25, 0.7);
        let large = theorem1_check(1_000_000, &us, 1.0, 0.25, 0.7);
        assert!(large.predicted_cost_ratio < small.predicted_cost_ratio);
    }
}
