//! L2-ALSH transform pair (Shrivastava & Li 2014; paper Eq. 5).
//!
//! Items are first scaled by `scale = U / max_norm` so that `||Ux|| <= U < 1`,
//! then lifted with `m` norm powers:
//!
//! `P(x) = [Ux ; ||Ux||^2 ; ||Ux||^4 ; ... ; ||Ux||^{2^m}]`
//! `Q(q) = [q/||q|| ; 1/2 ; ... ; 1/2]`
//!
//! so `||P(x) - Q(q)||^2 = 1 + m/4 - 2 U x.q + ||Ux||^{2^{m+1}}` (Eq. 6) and
//! MIPS becomes L2 nearest-neighbour search, solved with the Eq. 2
//! floor-hash. Recommended parameters (paper §4): `m = 3, U = 0.83, r = 2.5`.

/// L2-ALSH transform with fixed `(m, U)`; `r` lives in the hash, not here.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct L2AlshTransform {
    /// Number of appended norm powers (paper's `m`).
    pub m: usize,
    /// Target max norm after scaling (paper's `U`), must be in (0, 1).
    pub u: f32,
}

impl L2AlshTransform {
    pub fn new(m: usize, u: f32) -> Self {
        assert!(m >= 1, "need at least one norm power");
        assert!(u > 0.0 && u < 1.0, "U must be in (0,1), got {u}");
        Self { m, u }
    }

    /// Paper-recommended configuration `m=3, U=0.83` (used with `r=2.5`).
    pub fn recommended() -> Self {
        Self::new(3, 0.83)
    }

    /// Transformed dimensionality for raw dimensionality `d`.
    pub fn dim_out(&self, d: usize) -> usize {
        d + self.m
    }

    /// Transform an item. `max_norm` is the normalisation base: the dataset
    /// max for vanilla L2-ALSH, the *range-local* max for the §5 ranged
    /// variant (that locality is exactly what sharpens Eq. 13's ρ_j).
    pub fn transform_item(&self, x: &[f32], max_norm: f32, out: &mut Vec<f32>) {
        assert!(max_norm > 0.0, "max_norm must be positive");
        out.clear();
        let scale = self.u / max_norm;
        let mut sq = 0.0f32;
        for &v in x {
            let y = v * scale;
            sq += y * y;
            out.push(y);
        }
        // Append ||Ux||^2, ||Ux||^4, ..., ||Ux||^{2^m} by repeated squaring.
        let mut p = sq;
        for _ in 0..self.m {
            out.push(p);
            p = p * p;
        }
    }

    /// Transform a query: unit-normalise, append `m` halves.
    pub fn transform_query(&self, q: &[f32], out: &mut Vec<f32>) {
        out.clear();
        let norm = q.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-30);
        let inv = 1.0 / norm;
        out.extend(q.iter().map(|&v| v * inv));
        out.extend(std::iter::repeat(0.5).take(self.m));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_tail_powers() {
        let t = L2AlshTransform::new(3, 0.8);
        let mut out = Vec::new();
        t.transform_item(&[1.0, 0.0], 1.0, &mut out);
        assert_eq!(out.len(), t.dim_out(2));
        // ||Ux||^2 = 0.64, then 0.64^2, 0.64^4.
        assert!((out[2] - 0.64).abs() < 1e-6);
        assert!((out[3] - 0.64f32.powi(2)).abs() < 1e-6);
        assert!((out[4] - 0.64f32.powi(4)).abs() < 1e-6);
    }

    #[test]
    fn query_tail_is_halves() {
        let t = L2AlshTransform::recommended();
        let mut out = Vec::new();
        t.transform_query(&[3.0, 4.0], &mut out);
        assert_eq!(&out[..2], &[0.6, 0.8]);
        assert_eq!(&out[2..], &[0.5, 0.5, 0.5]);
    }

    #[test]
    fn eq6_distance_identity() {
        // ||P(x)-Q(q)||^2 == 1 + m/4 - 2*Ux.q + ||Ux||^{2^{m+1}}
        let t = L2AlshTransform::new(2, 0.7);
        let x = [0.4f32, -0.2, 0.5];
        let q = [0.1f32, 0.9, -0.3];
        let max_norm = 1.3f32;
        let (mut px, mut pq) = (Vec::new(), Vec::new());
        t.transform_item(&x, max_norm, &mut px);
        t.transform_query(&q, &mut pq);
        let d2: f32 = px.iter().zip(&pq).map(|(a, b)| (a - b) * (a - b)).sum();

        let qn = q.iter().map(|v| v * v).sum::<f32>().sqrt();
        let scale = t.u / max_norm;
        let ux: Vec<f32> = x.iter().map(|v| v * scale).collect();
        let ux_norm2: f32 = ux.iter().map(|v| v * v).sum();
        let ip: f32 = ux.iter().zip(&q).map(|(a, b)| a * b / qn).sum();
        let want = 1.0 + t.m as f32 / 4.0 - 2.0 * ip + ux_norm2.powi(2i32.pow(t.m as u32));
        assert!((d2 - want).abs() < 1e-5, "{d2} vs {want}");
    }

    #[test]
    fn scaling_bounds_norm_by_u() {
        let t = L2AlshTransform::recommended();
        let mut out = Vec::new();
        let x = [5.0f32, 12.0]; // norm 13 == dataset max
        t.transform_item(&x, 13.0, &mut out);
        let scaled_norm = (out[0] * out[0] + out[1] * out[1]).sqrt();
        assert!((scaled_norm - t.u).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "U must be in")]
    fn rejects_u_of_one() {
        L2AlshTransform::new(3, 1.0);
    }
}
