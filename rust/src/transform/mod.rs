//! The MIPS→similarity-search reductions compared in the paper.
//!
//! - [`simple`]: SIMPLE-LSH's symmetric transform (Eq. 8) — used by both
//!   SIMPLE-LSH (global `U`) and RANGE-LSH (per-range `U_j`).
//! - [`l2alsh`]: L2-ALSH's asymmetric transform pair (Eq. 5).

pub mod l2alsh;
pub mod sign_alsh;
pub mod simple;

pub use l2alsh::L2AlshTransform;
pub use sign_alsh::SignAlshTransform;
pub use simple::{transform_item, transform_query};
