//! SIGN-ALSH transform pair (Shrivastava & Li, UAI 2015) — the asymmetric
//! MIPS→angular reduction the paper's §1 cites as L2-ALSH's successor and
//! SIMPLE-LSH's immediate predecessor:
//!
//! `P(x) = [Ux ; 1/2 − ||Ux||^2 ; 1/2 − ||Ux||^4 ; ... ; 1/2 − ||Ux||^{2^m}]`
//! `Q(q) = [q/||q|| ; 0 ; ... ; 0]`
//!
//! so `P(x)·Q(q) = U·(x·q)/||q||`: inner products map to (unnormalised)
//! cosines and sign random projection applies. Unlike SIMPLE-LSH the
//! transformed items do **not** have unit norm — `||P(x)||` varies with
//! `||x||`, which is exactly why Neyshabur & Srebro could prove SIMPLE-LSH
//! universal and SIGN-ALSH not. Recommended parameters m = 2, U = 0.75.

/// SIGN-ALSH transform with fixed `(m, U)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SignAlshTransform {
    /// Number of appended norm terms.
    pub m: usize,
    /// Scaling target, in (0, 1).
    pub u: f32,
}

impl SignAlshTransform {
    pub fn new(m: usize, u: f32) -> Self {
        assert!(m >= 1, "need at least one norm term");
        assert!(u > 0.0 && u < 1.0, "U must be in (0,1), got {u}");
        Self { m, u }
    }

    /// The authors' recommended configuration `m = 2, U = 0.75`.
    pub fn recommended() -> Self {
        Self::new(2, 0.75)
    }

    pub fn dim_out(&self, d: usize) -> usize {
        d + self.m
    }

    /// Transform an item scaled against `max_norm` (global for vanilla
    /// SIGN-ALSH; a range-local max would give the §5-style variant).
    pub fn transform_item(&self, x: &[f32], max_norm: f32, out: &mut Vec<f32>) {
        assert!(max_norm > 0.0, "max_norm must be positive");
        out.clear();
        let scale = self.u / max_norm;
        let mut sq = 0.0f32;
        for &v in x {
            let y = v * scale;
            sq += y * y;
            out.push(y);
        }
        let mut p = sq;
        for _ in 0..self.m {
            out.push(0.5 - p);
            p = p * p;
        }
    }

    /// Transform a query: unit-normalise, zero-pad the `m` tail slots.
    pub fn transform_query(&self, q: &[f32], out: &mut Vec<f32>) {
        out.clear();
        let norm = q.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-30);
        let inv = 1.0 / norm;
        out.extend(q.iter().map(|&v| v * inv));
        out.extend(std::iter::repeat(0.0).take(self.m));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inner_product_identity() {
        // P(x).Q(q) == U * (x.q) / (max_norm * ||q||).
        let t = SignAlshTransform::recommended();
        let x = [0.3f32, -0.8, 0.5];
        let q = [1.0f32, 0.2, -0.4];
        let max_norm = 1.5f32;
        let (mut px, mut pq) = (Vec::new(), Vec::new());
        t.transform_item(&x, max_norm, &mut px);
        t.transform_query(&q, &mut pq);
        let lhs: f32 = px.iter().zip(&pq).map(|(a, b)| a * b).sum();
        let qn = q.iter().map(|v| v * v).sum::<f32>().sqrt();
        let rhs = t.u * x.iter().zip(&q).map(|(a, b)| a * b).sum::<f32>() / (max_norm * qn);
        assert!((lhs - rhs).abs() < 1e-6, "{lhs} vs {rhs}");
    }

    #[test]
    fn tail_terms_are_half_minus_norm_powers() {
        let t = SignAlshTransform::new(3, 0.8);
        let mut out = Vec::new();
        t.transform_item(&[1.0, 0.0], 1.0, &mut out); // ||Ux||^2 = 0.64
        assert_eq!(out.len(), 5);
        assert!((out[2] - (0.5 - 0.64)).abs() < 1e-6);
        assert!((out[3] - (0.5 - 0.64f32.powi(2))).abs() < 1e-6);
        assert!((out[4] - (0.5 - 0.64f32.powi(4))).abs() < 1e-6);
    }

    #[test]
    fn query_tail_is_zero() {
        let t = SignAlshTransform::recommended();
        let mut out = Vec::new();
        t.transform_query(&[3.0, 4.0], &mut out);
        assert_eq!(&out[..2], &[0.6, 0.8]);
        assert_eq!(&out[2..], &[0.0, 0.0]);
    }

    #[test]
    fn transformed_norm_is_bounded() {
        // ||P(x)||^2 = ||Ux||^2 + sum (1/2 - ||Ux||^{2^i})^2 <= m/4 + something
        // finite; just check boundedness across norms in [0, max].
        let t = SignAlshTransform::recommended();
        let mut out = Vec::new();
        for i in 0..=10 {
            let v = i as f32 / 10.0;
            t.transform_item(&[v, 0.0], 1.0, &mut out);
            let n2: f32 = out.iter().map(|x| x * x).sum();
            assert!(n2 <= 1.0 + t.m as f32 / 4.0 + 1e-5, "||P||^2 = {n2} at v={v}");
        }
    }

    #[test]
    #[should_panic(expected = "U must be in")]
    fn rejects_bad_u() {
        SignAlshTransform::new(2, 1.5);
    }
}
