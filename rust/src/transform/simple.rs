//! SIMPLE-LSH transform (paper Eq. 8), the symmetric MIPS→angular reduction.
//!
//! Item: `P(x) = [x/u ; sqrt(1 - ||x/u||^2)]` — on the unit sphere whenever
//! `||x|| <= u`. Query: `P(q) = [q/||q|| ; 0]`. Then
//! `P(q).P(x) = q.x / (u ||q||)`: inner-product order is preserved and MIPS
//! reduces to angular search, solvable with sign random projection.
//!
//! The whole paper hangs on the scalar `u`: SIMPLE-LSH must use the global
//! max norm, so a long-tailed norm distribution drives `||x||/u → 0` and the
//! appended `sqrt(1-..)` coordinate dominates (paper §3.1). RANGE-LSH calls
//! this same function with the *local* `U_j`.

/// Transform one item row into `out` (length `x.len() + 1`).
///
/// Round-off guard: for `||x|| == u` exactly the radicand can go slightly
/// negative in f32; clamp to 0 (matches the L2 graph's `max(0, .)`).
pub fn transform_item(x: &[f32], u: f32, out: &mut Vec<f32>) {
    assert!(u > 0.0, "normalisation constant must be positive, got {u}");
    out.clear();
    let inv = 1.0 / u;
    let mut sq = 0.0f32;
    for &v in x {
        let y = v * inv;
        sq += y * y;
        out.push(y);
    }
    out.push((1.0 - sq).max(0.0).sqrt());
}

/// Transform one query row into `out` (length `q.len() + 1`).
///
/// Zero queries (norm 0) are mapped to the zero vector with zero tail —
/// they hash arbitrarily, matching the L2 graph's epsilon-floor behaviour.
pub fn transform_query(q: &[f32], out: &mut Vec<f32>) {
    out.clear();
    let norm = q.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-30);
    let inv = 1.0 / norm;
    out.extend(q.iter().map(|&v| v * inv));
    out.push(0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norm(v: &[f32]) -> f32 {
        v.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    #[test]
    fn item_lands_on_unit_sphere() {
        let mut out = Vec::new();
        transform_item(&[3.0, 4.0], 10.0, &mut out);
        assert_eq!(out.len(), 3);
        assert!((norm(&out) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn item_at_max_norm_has_zero_tail() {
        let mut out = Vec::new();
        transform_item(&[3.0, 4.0], 5.0, &mut out);
        assert!((out[2]).abs() < 1e-3);
        assert!((out[0] - 0.6).abs() < 1e-6);
        assert!((out[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn small_item_is_tail_dominated() {
        // The paper's §3.1 pathology: ||x|| << u makes the appended
        // coordinate carry almost all of the transformed vector's mass.
        let mut out = Vec::new();
        transform_item(&[0.1, 0.0], 10.0, &mut out);
        assert!(out[2] > 0.99, "tail {} should dominate", out[2]);
    }

    #[test]
    fn query_is_unit_with_zero_tail() {
        let mut out = Vec::new();
        transform_query(&[1.0, 2.0, 2.0], &mut out);
        assert_eq!(out.len(), 4);
        assert!((norm(&out) - 1.0).abs() < 1e-6);
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn zero_query_is_finite() {
        let mut out = Vec::new();
        transform_query(&[0.0, 0.0], &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn transform_pair_preserves_inner_product_up_to_scale() {
        // P(q).P(x) == q.x / (u ||q||) — the Eq. 8 identity.
        let (x, q, u) = ([0.5f32, -1.0, 2.0], [1.0f32, 0.3, -0.7], 4.0);
        let (mut px, mut pq) = (Vec::new(), Vec::new());
        transform_item(&x, u, &mut px);
        transform_query(&q, &mut pq);
        let lhs: f32 = px.iter().zip(&pq).map(|(a, b)| a * b).sum();
        let qn = norm(&q);
        let rhs: f32 = x.iter().zip(&q).map(|(a, b)| a * b).sum::<f32>() / (u * qn);
        assert!((lhs - rhs).abs() < 1e-6, "{lhs} vs {rhs}");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_u() {
        transform_item(&[1.0], 0.0, &mut Vec::new());
    }
}
