//! Little-endian binary IO helpers for the on-disk formats
//! (`.rdat` datasets, `.rlsh` indexes).

use std::io::{Read, Write};

use anyhow::Result;

pub fn write_u8(w: &mut impl Write, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u32s(w: &mut impl Write, vs: &[u32]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_u32(w, v)?;
    }
    Ok(())
}

pub fn write_u64s(w: &mut impl Write, vs: &[u64]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_u64(w, v)?;
    }
    Ok(())
}

pub fn write_f32s(w: &mut impl Write, vs: &[f32]) -> Result<()> {
    write_u64(w, vs.len() as u64)?;
    for &v in vs {
        write_f32(w, v)?;
    }
    Ok(())
}

pub fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Bounded length read: fails fast on corrupt headers instead of OOMing.
fn read_len(r: &mut impl Read) -> Result<usize> {
    let len = read_u64(r)?;
    anyhow::ensure!(len <= (1 << 34), "implausible length {len} (corrupt file?)");
    Ok(len as usize)
}

pub fn read_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_u32(r)).collect()
}

pub fn read_u64s(r: &mut impl Read) -> Result<Vec<u64>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_u64(r)).collect()
}

pub fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let len = read_len(r)?;
    (0..len).map(|_| read_f32(r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars_and_vectors() {
        let mut buf = Vec::new();
        write_u8(&mut buf, 7).unwrap();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 1).unwrap();
        write_f32(&mut buf, -0.5).unwrap();
        write_u32s(&mut buf, &[1, 2, 3]).unwrap();
        write_u64s(&mut buf, &[9, 8]).unwrap();
        write_f32s(&mut buf, &[0.25, -1.0]).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_u8(&mut r).unwrap(), 7);
        assert_eq!(read_u32(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(read_f32(&mut r).unwrap(), -0.5);
        assert_eq!(read_u32s(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(read_u64s(&mut r).unwrap(), vec![9, 8]);
        assert_eq!(read_f32s(&mut r).unwrap(), vec![0.25, -1.0]);
        assert!(r.is_empty());
    }

    #[test]
    fn rejects_implausible_lengths() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX).unwrap();
        assert!(read_u32s(&mut buf.as_slice()).is_err());
    }
}
